"""Speculative decoding + parallel-sampling benchmark.

The serving-side face of the paper's heterogeneous-compute argument: pair
a small proposer with a large scorer so the expensive datapath runs once
per *batch* of tokens instead of once per token. A 1-layer draft and a
2-layer verifier are trained on the same deterministic bigram task (next
token = a fixed permutation of the current one — same seed, same
permutation) so the draft's greedy chain agrees with the verifier's and
the acceptance rate is realistic for a well-matched draft.

Asserts the directional claims:

  * speculative decode tokens/s >= 1.5x the plain engine on the identical
    greedy trace — k draft steps fold into one jitted scan and the
    verifier scores k+1 positions in one batched pass, so the per-token
    dispatch count collapses;
  * outputs are token-for-token identical (temperature 0): the acceptance
    rule is exact greedy parity, never an approximation;
  * acceptance rate is reported (and is high for the matched draft);
  * Request(n=4) fan-out allocates < 2x the fresh KV bytes of a single
    request — shared prompt pages ride the refcounted COW tables;
  * both engines drain leak-free: free + cached blocks == capacity.

``--dry-run`` imports the spec subsystem and checks the acceptance rule's
greedy all-accept identity without touching a model (the CI smoke step).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks._util import emit, emit_metrics
from benchmarks.quant_accuracy import _train_bigram

PAGE = 8
SPEC_K = 6
N_REQS = 8
MAX_NEW = 64
TRAIN_STEPS = 200


def _cfgs():
    from repro.configs import get_arch, reduced
    # vocab small enough that even the low-rank (d=32) draft can realize
    # the permutation's argmax exactly — acceptance then measures the
    # subsystem, not the draft's representational ceiling
    cfg = reduced(get_arch("qwen3-0.6b")).replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=64, dtype="float32", paged_kv=True,
        page_size=PAGE)
    dcfg = cfg.replace(n_layers=1, d_model=32, n_heads=2, n_kv_heads=1,
                       d_ff=64)
    return cfg, dcfg


def _requests(cfg, perm, seed: int = 0):
    """Short prompts, long generations: the trace is decode-heavy by
    design — the quantity under test is committed tokens per verifier
    dispatch, not prefill."""
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    out = []
    for i in range(N_REQS):
        L = int(rng.integers(4, 10))
        prompt = np.empty(L, np.int32)
        prompt[0] = rng.integers(0, cfg.vocab_size)
        for t in range(1, L):
            prompt[t] = perm[prompt[t - 1]]
        out.append(Request(uid=i, prompt=prompt, max_new_tokens=MAX_NEW))
    return out


def main(dry_run: bool = False) -> None:
    if dry_run:
        import jax
        import jax.numpy as jnp

        from repro.spec import (DraftWorker,  # noqa: F401
                                filter_logits, speculative_accept)
        k, V = 2, 8
        logits = jnp.asarray(np.random.default_rng(0).normal(
            size=(1, k + 1, V)), jnp.float32)
        argmax = np.asarray(jnp.argmax(logits, -1))[0]
        draft = jnp.asarray(argmax[None, :k], jnp.int32)
        dprobs = jnp.asarray(jax.nn.one_hot(draft, V), jnp.float32)
        out, n_acc = speculative_accept(
            logits, draft, dprobs, jnp.zeros(1), jnp.zeros(1, jnp.int32),
            jnp.ones(1), jax.random.PRNGKey(0)[None])
        assert int(n_acc[0]) == k, "greedy argmax chain must fully accept"
        assert np.asarray(out)[0].tolist() == argmax.tolist()
        kept = np.where(np.asarray(filter_logits(
            logits[:, 0], jnp.asarray([3]), jnp.asarray([1.0])))[0]
            > -1e29)[0]
        assert len(kept) == 3
        print("spec-decode dry-run OK")
        return

    from repro.serve import Request, ServeEngine

    cfg, dcfg = _cfgs()
    params, perm, loss = _train_bigram(cfg, seed=0, steps=TRAIN_STEPS)
    dparams, dperm, dloss = _train_bigram(dcfg, seed=0, steps=TRAIN_STEPS)
    assert (perm == dperm).all(), "draft must train on the same chain"
    reqs = _requests(cfg, perm)

    def build(spec: bool) -> ServeEngine:
        return ServeEngine(
            cfg, params, max_slots=4, max_len=128, paged=True,
            page_size=PAGE, prefill_chunk=16,
            draft_model=dcfg if spec else None,
            draft_params=dparams if spec else None, spec_k=SPEC_K)

    rows, tokens = [], {}
    for mode in ("plain", "spec"):
        engine = build(mode == "spec")
        # warm every jitted graph before the timed runs so the ratio
        # measures serving work, not compilation; the post-warm registry
        # snapshot isolates the timed runs' counters via delta()
        engine.run([Request(uid=99, prompt=reqs[0].prompt.copy(),
                            max_new_tokens=4)])
        snap_warm = engine.metrics.snapshot()
        best_dt = float("inf")
        for attempt in range(3):
            trace = [Request(uid=r.uid, prompt=r.prompt,
                             max_new_tokens=r.max_new_tokens) for r in reqs]
            t0 = time.perf_counter()
            results = engine.run(trace)
            dt = time.perf_counter() - t0
            assert all(r.finish_reason == "length" for r in results)
            toks = [r.tokens for r in results]
            tokens.setdefault(mode, toks)
            assert toks == tokens[mode], "greedy outputs drifted across runs"
            best_dt = min(best_dt, dt)
        d = engine.metrics.snapshot().delta(snap_warm)
        new_tokens = sum(len(t) for t in tokens[mode])
        assert engine.allocator.n_live == 0
        assert (engine.allocator.n_free + engine.allocator.n_evictable
                == engine.allocator.capacity), "block leak"
        if mode == "spec":
            emit_metrics("spec_decode", engine, extra={"spec_k": SPEC_K})
        rows.append({
            "mode": mode,
            "requests": len(reqs),
            "new_tokens": new_tokens,
            "tok_per_s": round(new_tokens / best_dt, 1),
            "spec_k": SPEC_K if mode == "spec" else 0,
            "spec_turns": int(d["spec_turns"]),
            "accept_rate": (round(d["spec_accepted"]
                                  / max(d["spec_proposed"], 1), 3)
                            if mode == "spec" else None),
            "train_loss": round(loss if mode == "plain" else dloss, 4),
            "kv_bytes_alloc": int(d["kv_bytes_alloc"]),
            "kv_bytes_single": None,
            "fork_shared_blocks": None,
        })

    # COW-forked parallel sampling: fresh-KV accounting for a fan-out
    rng = np.random.default_rng(1)
    prompt = np.empty(48, np.int32)
    prompt[0] = rng.integers(0, cfg.vocab_size)
    for t in range(1, len(prompt)):
        prompt[t] = perm[prompt[t - 1]]
    fan = ServeEngine(cfg, params, max_slots=6, max_len=128, paged=True,
                      page_size=PAGE, prefill_chunk=16)
    [fres] = fan.run([Request(uid=0, prompt=prompt, max_new_tokens=8,
                              temperature=1.0, seed=7, n=4)])
    single = ServeEngine(cfg, params, max_slots=6, max_len=128, paged=True,
                         page_size=PAGE, prefill_chunk=16)
    single.run([Request(uid=0, prompt=prompt, max_new_tokens=8,
                        temperature=1.0, seed=7)])
    assert (fan.allocator.n_free + fan.allocator.n_evictable
            == fan.allocator.capacity), "fork leaked blocks"
    rows.append({
        "mode": "fork_n4", "requests": 1,
        "new_tokens": (len(fres.tokens)
                       + sum(len(c.tokens) for c in fres.children)),
        "tok_per_s": None, "spec_k": 0, "spec_turns": 0,
        "accept_rate": None,
        "train_loss": None,
        "kv_bytes_alloc": fan.stats["kv_bytes_alloc"],
        "kv_bytes_single": single.stats["kv_bytes_alloc"],
        "fork_shared_blocks": fan.stats["fork_shared_blocks"],
    })
    emit(rows, "spec_decode")

    plain, spec = rows[0], rows[1]
    assert tokens["spec"] == tokens["plain"], \
        "speculative decoding changed greedy outputs"
    assert spec["accept_rate"] > 0.5, (
        "the matched bigram draft should mostly agree with the verifier: "
        f"accept_rate={spec['accept_rate']}")
    speedup = spec["tok_per_s"] / plain["tok_per_s"]
    assert speedup >= 1.5, (
        f"speculative decode should be >= 1.5x plain decode tok/s: "
        f"{spec['tok_per_s']} vs {plain['tok_per_s']} ({speedup:.2f}x)")
    assert (rows[2]["kv_bytes_alloc"]
            < 2 * rows[2]["kv_bytes_single"]), (
        "n=4 fan-out should allocate < 2x a single request's fresh KV: "
        f"{rows[2]['kv_bytes_alloc']} vs {rows[2]['kv_bytes_single']}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="import + acceptance-rule identity check (CI smoke)")
    args = ap.parse_args()
    main(dry_run=args.dry_run)

"""Aggregate dry-run artifacts into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m benchmarks.roofline_table [--strategy ramora]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks._util import ROOT

FIX_HINTS = {
    ("collective", "train"): "stage/overlap FSDP+TP collectives; hierarchical"
                             " schedule over (pod,data); chunked vocab loss",
    ("collective", "prefill"): "shard activations tighter (SP); fuse TP"
                               " collectives; avoid replicated logits",
    ("collective", "decode"): "keep cache local (context parallel);"
                              " tree-reduce single-token logits",
    ("memory", "train"): "less remat recompute traffic; bigger fused blocks",
    ("memory", "prefill"): "flash tiles resident in VMEM; avoid cache"
                           " rewrite round-trips",
    ("memory", "decode"): "decode is intrinsically HBM-bound (weights+KV per"
                          " token); shrink KV (GQA/window/quant), batch more",
    ("compute", "train"): "at compute roofline — increase arithmetic"
                          " intensity or chips",
    ("compute", "prefill"): "at compute roofline",
    ("compute", "decode"): "at compute roofline",
}


def load(strategy: str) -> list[dict]:
    rows = []
    for sub in ("dryrun", "dryrun_opt"):
        d = ROOT / "experiments" / sub
        if not d.exists():
            continue
        for fp in sorted(d.glob(f"*__{strategy}.json")):
            rows.append(json.loads(fp.read_text()))
    return rows


def table(strategy: str = "ramora") -> str:
    from repro.configs import ARCHS, SHAPES
    rows = load(strategy)
    by = {(r["arch"], r["shape"], r["mesh"]): r for r in rows}
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck |"
        " roofline frac | MODEL_FLOPS/HLO | GiB/dev (16GiB) | multipod |"
        " what moves the dominant term down |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCHS:
        for shape in SHAPES:
            r = by.get((arch, shape, "16x16"))
            if r is None:
                continue
            if r.get("status") == "skipped":
                lines.append(f"| {arch} | {shape} | — | — | — | — | — | — |"
                             f" — | — | SKIP: {r['reason']} |")
                continue
            roof = r["roofline"]
            mem = r["memory"]
            mp = by.get((arch, shape, "2x16x16"), {})
            mp_ok = "ok" if mp.get("status") == "ok" else "?"
            kind = ("train" if shape == "train_4k" else
                    "prefill" if shape == "prefill_32k" else "decode")
            hint = FIX_HINTS[(roof["bottleneck"], kind)]
            peak = mem.get("peak_floor_tpu_gib_per_dev",
                           mem.get("peak_tpu_adjusted_gib_per_dev",
                                   mem["peak_gib_per_dev"]))
            fits = "✓" if peak < 16.0 else "✗"
            lines.append(
                f"| {arch} | {shape} | {roof['compute_s']:.2e} |"
                f" {roof['memory_s']:.2e} | {roof['collective_s']:.2e} |"
                f" {roof['bottleneck']} | {roof['roofline_fraction']:.2f} |"
                f" {roof['useful_flops_ratio']:.2f} |"
                f" {peak:.1f} {fits} | {mp_ok} | {hint} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="ramora")
    args = ap.parse_args()
    print(table(args.strategy))


if __name__ == "__main__":
    main()

"""Serving latency benchmark: scheduling policy vs tail TTFT under load.

Replays one fixed Poisson-arrival trace — a long-running low-priority
``batch`` tenant plus a burst of short high-priority ``chat`` requests with
TTFT SLOs — through three engine variants at identical pool size:

  * ``fcfs``      — legacy arrival-order admission, no overtaking,
  * ``priority``  — priority classes + EDF + fair queuing + skip-with-aging,
  * ``preempt``   — priority plus preemption: a blocked chat request evicts
                    a batch decode (pages retained in the prefix index, so
                    the victim resumes via a warm prefix hit).

Reports per-tenant p50/p99 time-to-first-token (wall clock, from
``Result.token_ts``) and SLO goodput, and asserts the directional claims:

  * per-request greedy tokens are identical across all three variants —
    scheduling (and preemption/resumption) may reorder service, never
    change what a request generates,
  * every variant drains leak-free (free + cached blocks == capacity),
  * the preempting variant actually preempts, and its high-priority p99
    TTFT beats no-preemption and beats FCFS by >= 2x.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from benchmarks._util import emit, emit_metrics

SLOTS, PAGE, BLOCKS, MAX_LEN = 2, 8, 9, 64
CHAT_SLO_MS = 1e9   # classification threshold only; wall-clock is machine-
                    # dependent, the assertions ride the p99 *ratios*


def _trace(seed: int = 0):
    """Fixed mixed-tenant trace: (submit_step, Request) pairs."""
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    reqs = []
    # background tenant: admitted first, holds BOTH slots and the whole
    # pool (4 blocks apiece of the 8 usable) for ~20 decode steps each —
    # without preemption nothing else runs until one of them drains
    for uid in range(2):
        reqs.append((0, Request(
            uid=uid, prompt=rng.integers(0, 256, 12).astype(np.int32),
            max_new_tokens=20, priority=0, user="batch")))
    # interactive tenant: Poisson burst starting once the batch work is
    # mid-decode; short prompts, tight budgets, TTFT SLOs
    step = 4.0
    for uid in range(2, 8):
        step += rng.exponential(1.5)
        reqs.append((int(step), Request(
            uid=uid, prompt=rng.integers(0, 256, 6).astype(np.int32),
            max_new_tokens=3, priority=2, user="chat",
            slo_ttft_ms=CHAT_SLO_MS)))
    return reqs


def _replay(engine, trace):
    """Drive the engine with requests arriving at their trace steps."""
    from repro.serve import Request
    pending = sorted(trace, key=lambda p: p[0])
    i = step = 0
    while i < len(pending) or engine._busy():
        while i < len(pending) and pending[i][0] <= step:
            r = pending[i][1]
            engine.submit(Request(
                uid=r.uid, prompt=r.prompt, max_new_tokens=r.max_new_tokens,
                priority=r.priority, user=r.user, slo_ttft_ms=r.slo_ttft_ms))
            i += 1
        engine.step()
        step += 1
        assert step < 5000, "trace failed to drain"
    return step


def _pct(vals, q):
    return float(np.percentile(vals, q)) if vals else float("nan")


def _build_engine(cfg, params, variant):
    from repro.serve import ServeEngine
    sched = "fcfs" if variant == "fcfs" else "priority"
    return ServeEngine(cfg, params, max_slots=SLOTS, max_len=MAX_LEN,
                       paged=True, page_size=PAGE, max_blocks=BLOCKS,
                       prefill_chunk=8, prefix_cache=True, sched=sched,
                       preemption=(variant == "preempt"))


def _tiny_cfg():
    from repro.configs import get_arch, reduced
    return reduced(get_arch("qwen3-0.6b")).replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, dtype="float32")


def _dry_run() -> None:
    """Build the engine, submit the trace, run one admission pass — pure
    host-side bookkeeping, no device step — to smoke-test the scheduler/
    engine wiring in CI without paying a model compile."""
    import jax

    from repro.models import init

    cfg = _tiny_cfg()
    params = init(jax.random.PRNGKey(0), cfg)
    trace = _trace()
    for variant in ("fcfs", "priority", "preempt"):
        engine = _build_engine(cfg, params, variant)
        for _, r in trace:
            engine.submit(r)
        engine._admit()
        assert engine.active.any(), f"{variant}: nothing admitted"
        assert len(engine.queue) < len(trace), variant
    print(f"dry-run OK: {len(trace)} requests, 3 variants, "
          f"pool {BLOCKS - 1} blocks x {PAGE} rows")


def main(dry_run: bool = False) -> None:
    if dry_run:
        _dry_run()
        return

    import jax

    from repro.models import init

    cfg = _tiny_cfg()
    params = init(jax.random.PRNGKey(0), cfg)
    trace = _trace()

    rows, tokens, p99 = [], {}, {}
    for variant in ("fcfs", "priority", "preempt"):
        engine = _build_engine(cfg, params, variant)
        # registry snapshots over the replay window, not raw stats reads
        snap0 = engine.metrics.snapshot()
        t0 = time.perf_counter()
        steps = _replay(engine, trace)
        wall = time.perf_counter() - t0
        d = engine.metrics.snapshot().delta(snap0)
        results = [engine.results[r.uid] for _, r in trace]
        assert all(r.finish_reason == "length" for r in results), variant
        tokens[variant] = {r.uid: r.tokens for r in results}
        # leak-free drain: every block is free or prefix-cached
        alloc = engine.allocator
        cached = engine.prefix_index.n_evictable(alloc)
        assert alloc.n_live == 0 and alloc.n_free + cached == alloc.capacity
        by_user = {"batch": [], "chat": []}
        for (_, req), res in zip(trace, results):
            by_user[req.user].append(res.ttft_s)
        p99[variant] = _pct(by_user["chat"], 99)
        met = d["slo_met"]
        if variant == "preempt":
            emit_metrics("serve_latency", engine,
                         extra={"variant": variant, "steps": steps})
        rows.append({
            "variant": variant,
            "requests": len(results),
            "steps": steps,
            "wall_s": round(wall, 2),
            "chat_ttft_p50_ms": round(_pct(by_user["chat"], 50) * 1e3, 1),
            "chat_ttft_p99_ms": round(p99[variant] * 1e3, 1),
            "batch_ttft_p50_ms": round(_pct(by_user["batch"], 50) * 1e3, 1),
            "goodput": round(met / max(met + d["slo_missed"], 1), 3),
            "sched_skips": int(d["sched_skips"]),
            "preemptions": int(d["preemptions"]),
            "prefix_hits": int(d["prefix_hits"]),
        })
    emit(rows, "serve_latency")

    assert tokens["priority"] == tokens["fcfs"] == tokens["preempt"], \
        "scheduling policy changed greedy outputs"
    by = {r["variant"]: r for r in rows}
    assert by["preempt"]["preemptions"] > 0, \
        "pressure trace must trigger preemption"
    assert p99["preempt"] < p99["priority"], (
        "preemption-on must beat preemption-off on chat p99 TTFT: "
        f"{p99['preempt']:.3f}s vs {p99['priority']:.3f}s")
    assert p99["preempt"] * 2 <= p99["fcfs"], (
        "priorities+preemption must improve chat p99 TTFT >= 2x over FCFS: "
        f"{p99['preempt']:.3f}s vs {p99['fcfs']:.3f}s")


if __name__ == "__main__":
    main(dry_run="--dry-run" in sys.argv[1:])

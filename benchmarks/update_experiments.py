"""Inject the regenerated roofline tables into EXPERIMENTS.md.

  PYTHONPATH=src python -m benchmarks.update_experiments
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks._util import ROOT
from benchmarks.roofline_table import load, table

MARK = "<!-- ROOFLINE_TABLE -->"


def opt_table() -> str:
    rows = load("fsdp2d")
    rows = [r for r in rows if r.get("status") == "ok" and r["mesh"] == "16x16"
            and "roofline" in r]
    if not rows:
        return "(no fsdp2d artifacts yet)"
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck |"
        " roofline frac | multipod |",
        "|---|---|---|---|---|---|---|---|",
    ]
    mp = {(r["arch"], r["shape"]) for r in load("fsdp2d")
          if r.get("status") == "ok" and r["mesh"] == "2x16x16"}
    for r in sorted(rows, key=lambda r: r["arch"]):
        roof = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {roof['compute_s']:.2e} |"
            f" {roof['memory_s']:.2e} | {roof['collective_s']:.2e} |"
            f" {roof['bottleneck']} | {roof['roofline_fraction']:.2f} |"
            f" {'ok' if (r['arch'], r['shape']) in mp else '—'} |")
    return "\n".join(lines)


def main():
    fp = ROOT / "EXPERIMENTS.md"
    text = fp.read_text()
    head = text.split(MARK)[0]
    body = (MARK + "\n\n### ramora (paper-faithful baseline), 16×16\n\n"
            + table("ramora")
            + "\n\n### fsdp2d (beyond-paper optimized), train_4k cells, 16×16\n\n"
            + opt_table() + "\n")
    fp.write_text(head + body)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()

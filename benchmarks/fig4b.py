"""Paper Fig. 4b — GEMM throughput/efficiency vs precision with expanding
(widening) accumulation.

Paper: FP64→FP8 GEMM on Occamy scales ~2x per halving; expanding (widening)
accumulation costs ~nothing (even 6.5% *better* energy on FP16-EXP) thanks to
dedicated widening dot-product units.

TPU analogue: fp32 → bf16 → fp8 feeding the MXU with fp32 accumulation
(``preferred_element_type``), the MXU's native widening mode. We report:
  * roofline throughput per precision (the MXU 2x-per-halving ladder),
  * numerical error of widening vs same-precision accumulation (why
    expanding accumulation is the right default — the paper's C2 insight),
  * measured CPU wall-time ratios as a sanity signal only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit, timeit
from repro.core.topology import dtype_peak_flops
from repro.kernels import ref

N = 512


def _err(a, b):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    return float(np.abs(a - b).max() / (np.abs(b).max() + 1e-12))


def main() -> list[dict]:
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x64 = jax.random.normal(k1, (N, N), jnp.float32)
    w64 = jax.random.normal(k2, (N, N), jnp.float32)
    oracle = np.asarray(x64, np.float64) @ np.asarray(w64, np.float64)

    rows = []
    for dtype, name in [(jnp.float32, "fp32"), (jnp.bfloat16, "bf16"),
                        (jnp.float8_e4m3fn, "fp8_e4m3")]:
        x = x64.astype(dtype)
        w = w64.astype(dtype)
        # widening (expanding) accumulation — MXU-native
        wide = jnp.dot(x, w, preferred_element_type=jnp.float32)
        # non-expanding accumulation: same inputs, but the running
        # accumulator is held at narrow precision (bf16; fp8 accumulates in
        # bf16 — the paper's FP8 GEMM also expands only to FP16). Simulated
        # by chunked K with a downcast after every partial sum.
        acc_dtype = jnp.float32 if dtype == jnp.float32 else jnp.bfloat16
        chunk = 32
        narrow = jnp.zeros((N, N), acc_dtype)
        for i in range(0, N, chunk):
            part = jnp.dot(x[:, i:i + chunk], w[i:i + chunk, :],
                           preferred_element_type=jnp.float32)
            narrow = (narrow.astype(jnp.float32) + part).astype(acc_dtype)
        _, t = timeit(lambda: jnp.dot(x, w,
                                      preferred_element_type=jnp.float32),
                      n=3)
        peak = dtype_peak_flops({"fp32": "float32", "bf16": "bfloat16",
                                 "fp8_e4m3": "float8_e4m3fn"}[name])
        rows.append({
            "precision": name,
            "peak_tflops_per_chip": round(peak / 1e12, 1),
            "roofline_vs_bf16": round(peak / dtype_peak_flops("bfloat16"), 2),
            "err_widening_accum": round(_err(wide, oracle), 5),
            "err_narrow_accum": round(_err(narrow, oracle), 5),
            "cpu_ms": round(t * 1e3, 2),
        })

    # the ladder must double per halving, and widening accumulation must be
    # strictly more accurate than narrow accumulation at every precision
    assert rows[1]["roofline_vs_bf16"] == 1.0
    assert rows[2]["roofline_vs_bf16"] == 2.0
    for r in rows[1:]:
        assert r["err_widening_accum"] < r["err_narrow_accum"]
    emit(rows, "fig4b")
    return rows


if __name__ == "__main__":
    main()

"""Paper Fig. 4a — FPU utilization across regular→irregular workloads.

The paper's silicon result: dense GEMM 89%, stencil 83%, GCN 54%, SpMM 42% —
utilization declines monotonically with access irregularity, and the
streaming units (SUs) recover large factors over the non-streamed baseline.

This framework's analogue (CPU container; TPU is the target):
1. *achievable-utilization bound* per workload from the roofline model —
   util = compute_s / max(compute_s, memory_s) with each workload's FLOPs and
   HBM bytes at TPU v5e constants. The paper's monotone ordering must emerge.
2 *streaming speedup*: packed (index-sorted, 8-wide) gather vs naive
   per-row gather — the C5c mechanism's byte efficiency (paper: 4.8x,
   ideal 8x for the random pattern).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit, timeit
from repro.core.topology import CHIP
from repro.kernels import ops, ref, use_backend


def _util(flops: float, bytes_hbm: float, dtype="bfloat16") -> float:
    peak = CHIP.peak_bf16_flops if dtype == "bfloat16" else CHIP.peak_fp32_flops
    t_c = flops / peak
    t_m = bytes_hbm / CHIP.hbm_bw
    return t_c / max(t_c, t_m)


def workloads(n: int = 4096, nnz_frac: float = 0.01) -> list[dict]:
    """FLOPs & minimum HBM bytes for the paper's four workloads (bf16),
    *with the paper's own data-movement optimizations applied*: temporal
    blocking keeps stencil tiles VMEM-resident across sweeps (paper cites
    [15]/[16]); the C5c temporal coalescer gives gathered rows cache reuse.

    Machine-balance caveat (DESIGN.md §2): Occamy's balance is ~1 FLOP/B
    (0.77 TF vs 0.82 TB/s) while v5e's is ~240 FLOP/B, so the *absolute*
    utilizations of irregular workloads compress on TPU; the paper anchor
    is the monotone regular->irregular ordering, which must survive.
    """
    rows = []
    # dense GEMM n^3: 2n^3 flops, 3n^2 tiles streamed once (C1 pipeline)
    rows.append({"workload": "GEMM", "flops": 2 * n**3,
                 "bytes": 3 * n * n * 2})
    # star-7 stencil, T=64 sweeps temporally blocked in VMEM: grid crosses
    # HBM once per block of sweeps instead of once per sweep
    T = 64
    rows.append({"workload": "STC", "flops": 13 * n * n * T,
                 "bytes": 2 * n * n * 2})
    # GCN layer (A X) W: deg-16 gather with coalescer reuse ~deg, then GEMM
    deg, f = 16, 256
    nnz = n * deg
    gcn_bytes = (nnz * 4                 # indices
                 + nnz * 2 * f // deg    # gathered rows, coalesced reuse
                 + n * f * 2 * 2         # X in, out
                 + f * f * 2)            # W
    rows.append({"workload": "GCN",
                 "flops": 2 * nnz * f + 2 * n * f * f,
                 "bytes": gcn_bytes})
    # SpMM: sparse A (1%) x dense B: gather-dominated, VMEM-limited reuse 8
    nnz2 = int(n * n * nnz_frac)
    rows.append({"workload": "SpMM", "flops": 2 * nnz2 * f,
                 "bytes": nnz2 * (4 + 4) + nnz2 * 2 * f // 8
                 + n * f * 2 * 2})
    for r in rows:
        r["ai_flop_per_byte"] = round(r["flops"] / r["bytes"], 2)
        r["util_bound"] = round(_util(r["flops"], r["bytes"]), 3)
    return rows


def streaming_speedup() -> list[dict]:
    """Packed irregular streams (C5c) vs naive narrow gathers.

    Byte-efficiency model (what the D2D/HBM links see): a naive narrow
    access moves a full 256-bit minimum HBM transaction per <=64-bit row
    element; packing 8 requests per wide flit + coalescing duplicate rows
    approaches the ideal 8x. We report the modeled efficiency for the random
    pattern (paper: 4.8x) AND the measured CPU wall-time of both kernel paths.
    """
    k = jax.random.PRNGKey(0)
    table = jax.random.normal(k, (65536, 32), jnp.float32)
    idx = jax.random.randint(k, (8192,), 0, 65536)

    with use_backend("interpret"):
        _, t_naive = timeit(ops.gather_rows, table, idx, n=2)
        _, t_packed = timeit(ops.packed_gather_rows, table, idx, pack=8, n=2)
        got = ops.packed_gather_rows(table, idx, pack=8)
    exact = bool((np.asarray(got) == np.asarray(table)[np.asarray(idx)]).all())

    # byte model: naive moves 32B (256-bit) per 8B useful row-chunk element;
    # packed coalesces sorted duplicates and fills 32B lines 8/8.
    elem_bytes = 8
    line = 32
    naive_wire = len(idx) * line
    uniq = len(np.unique(np.asarray(idx)))
    packed_wire = uniq * line / (line // elem_bytes) * (line // elem_bytes) / 8 + len(idx) * elem_bytes
    model_gain = naive_wire / packed_wire
    return [{
        "mechanism": "packed_gather(C5c)",
        "paper_speedup": 4.8, "ideal": 8.0,
        "modeled_byte_efficiency_gain": round(model_gain, 2),
        "cpu_interpret_speedup": round(t_naive / t_packed, 2),
        "exact": exact,
    }]


def main() -> list[dict]:
    rows = workloads()
    utils = [r["util_bound"] for r in rows]
    assert all(a >= b for a, b in zip(utils, utils[1:])), \
        f"utilization must decline with irregularity: {utils}"
    paper = {"GEMM": 0.89, "STC": 0.83, "GCN": 0.54, "SpMM": 0.42}
    for r in rows:
        r["paper_fpu_util"] = paper[r["workload"]]
    rows += streaming_speedup()
    emit(rows, "fig4a")
    return rows


if __name__ == "__main__":
    main()

"""Paper Table I — the generation evolution (Occamy → Ramora → Ogopogo) as a
measurable ablation: the three distribution strategies on the same
(arch × shape) cell, dry-run lowered on the production mesh, roofline terms
compared.

Occamy (flat DP, replicated params, one big all-reduce) must lose to Ramora
(factored 2D mesh, TP+FSDP) on memory-per-device and collective seconds;
Ogopogo (pod axis + chunked loss + hierarchical collectives) extends the mesh
across pods. This is the paper's Table I reading of our system.

Uses cached dry-run artifacts under experiments/dryrun when present; computes
missing cells in a 512-device subprocess (slow: ~1-2 min each).
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks._util import ROOT, emit, run_subprocess

CELL = ("deepseek-7b", "train_4k")
OUT = ROOT / "experiments" / "dryrun"


def _get(strategy: str, multi_pod: bool) -> dict:
    tag = (f"{CELL[0]}__{CELL[1]}__{'2x16x16' if multi_pod else '16x16'}"
           f"__{strategy}")
    fp = OUT / f"{tag}.json"
    if fp.exists():
        r = json.loads(fp.read_text())
        if r.get("status") == "ok" and ("roofline" in r or multi_pod):
            return r
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import run_cell
r = run_cell({CELL[0]!r}, {CELL[1]!r}, multi_pod={multi_pod},
             strategy_name={strategy!r}, verbose=False)
print("JSON:" + json.dumps(r))
"""
    out = run_subprocess(code, n_devices=512, timeout=2400)
    line = [l for l in out.splitlines() if l.startswith("JSON:")][-1]
    r = json.loads(line[5:])
    OUT.mkdir(parents=True, exist_ok=True)
    fp.write_text(json.dumps(r, indent=1))
    return r


def main() -> list[dict]:
    rows = []
    for strat, mp, gen in [("occamy", False, "gen1-crossbar"),
                           ("ramora", False, "gen2-mesh"),
                           ("ogopogo", True, "gen3-multipod")]:
        r = _get(strat, mp)
        roof = r.get("roofline", {})
        rows.append({
            "generation": gen, "strategy": strat, "mesh": r["mesh"],
            "chips": r["n_chips"],
            "peak_gib_per_dev": round(r["memory"]["peak_gib_per_dev"], 2),
            "fits_16gib": r["memory"]["fits_16gib"],
            "compute_s": round(roof.get("compute_s", float("nan")), 3),
            "memory_s": round(roof.get("memory_s", float("nan")), 3),
            "collective_s": round(roof.get("collective_s", float("nan")), 3),
            "bottleneck": roof.get("bottleneck", "-"),
            "roofline_frac": round(roof.get("roofline_fraction", float("nan")), 3),
        })
    # paper Table I directionals: the mesh generation must fit where the
    # crossbar generation cannot, with less collective pressure
    occ, ram = rows[0], rows[1]
    assert ram["peak_gib_per_dev"] < occ["peak_gib_per_dev"]
    emit(rows, "table1")
    return rows


if __name__ == "__main__":
    main()

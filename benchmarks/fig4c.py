"""Paper Fig. 4c — transformer inference throughput vs sequence length.

Paper: GPT-J FP16 inference with FlashAttention-2 on Occamy — throughput
decays with sequence length as quadratic attention grows relative to GEMM.

Here: (1) measured decode throughput of a reduced model on CPU across KV
lengths (the engine path), and (2) the analytic roofline decode time for the
full gemma2-27b across KV lengths — both must show the same monotone decay,
and the roofline version quantifies the attention share the paper plots.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit
from repro.configs import get_arch, reduced
from repro.core.topology import CHIP
from repro.models import decode_step, forward, init
from repro.models.cache import init_cache


def measured_decode_tps(lengths=(64, 256, 1024)) -> list[dict]:
    cfg = reduced(get_arch("deepseek-7b")).replace(dtype="float32")
    params = init(jax.random.PRNGKey(0), cfg)
    rows = []
    B = 4
    step = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))
    for L in lengths:
        cache = init_cache(cfg, B, int(L) + 8)
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, L)), jnp.int32)
        _, cache, _ = forward(params, cfg, toks, cache=cache)
        t1 = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
        out = step(params, cache, t1, jnp.asarray(L))  # compile
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        n = 8
        for i in range(n):
            logits, cache = step(params, cache, t1, jnp.asarray(L + 1 + i))
        jax.block_until_ready(logits)
        dt = (time.perf_counter() - t0) / n
        rows.append({"kind": "measured_cpu", "model": cfg.name,
                     "kv_len": int(L), "tok_per_s": round(B / dt, 1),
                     "ms_per_step": round(dt * 1e3, 2)})
    return rows


def roofline_decode(lengths=(1024, 8192, 32768, 131072)) -> list[dict]:
    """Analytic per-token decode time for gemma2-27b on one v5e pod:
    weights-read time (constant) + KV-read time (linear in L for global
    layers, capped at window for local layers)."""
    cfg = get_arch("gemma2-27b")
    n_chips = 256
    pc = cfg.param_count()
    w_bytes = pc["total"] * 2  # bf16 serving weights
    hd, K = cfg.resolved_head_dim, cfg.n_kv_heads
    n_local = sum(1 for s in cfg.all_layers() if s.mixer == "local")
    n_global = cfg.n_layers - n_local
    B = 128
    rows = []
    for L in lengths:
        kv_global = n_global * L * K * hd * 2 * 2
        kv_local = n_local * min(L, cfg.window) * K * hd * 2 * 2
        kv_bytes = (kv_global + kv_local) * B
        t_w = w_bytes / (n_chips * CHIP.hbm_bw)
        t_kv = kv_bytes / (n_chips * CHIP.hbm_bw)
        t = t_w + t_kv
        rows.append({"kind": "roofline_v5e_pod", "model": cfg.name,
                     "kv_len": int(L),
                     "tok_per_s": round(B / t, 0),
                     "ms_per_step": round(t * 1e3, 3),
                     "attn_share": round(t_kv / t, 3)})
    return rows


def main() -> list[dict]:
    rows = measured_decode_tps() + roofline_decode()
    # paper anchor: throughput decays monotonically with sequence length
    for kind in ("measured_cpu", "roofline_v5e_pod"):
        tps = [r["tok_per_s"] for r in rows if r["kind"] == kind]
        assert all(a >= b for a, b in zip(tps, tps[1:])), (kind, tps)
    emit(rows, "fig4c")
    return rows


if __name__ == "__main__":
    main()

"""Shared benchmark utilities: timing, CSV emit, multi-device subprocess."""
from __future__ import annotations

import os
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = str(ROOT / "src")
OUTDIR = ROOT / "experiments" / "bench"


def timeit(fn, *args, n: int = 3, warmup: int = 1, **kw) -> tuple:
    import jax
    out = None
    for _ in range(warmup):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / n


def emit(rows: list[dict], name: str) -> None:
    """Print rows as CSV and persist under experiments/bench/<name>.csv."""
    if not rows:
        print(f"[{name}] no rows")
        return
    cols = list(rows[0])
    lines = [",".join(cols)]
    for r in rows:
        lines.append(",".join(str(r.get(c, "")) for c in cols))
    text = "\n".join(lines)
    print(f"\n=== {name} ===")
    print(text)
    OUTDIR.mkdir(parents=True, exist_ok=True)
    (OUTDIR / f"{name}.csv").write_text(text + "\n")


def run_subprocess(code: str, n_devices: int = 8, timeout: int = 1200) -> str:
    """Run a snippet with N fake devices; return stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{proc.stderr[-3000:]}")
    return proc.stdout

"""Shared benchmark utilities: timing, CSV emit, multi-device subprocess."""
from __future__ import annotations

import os
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = str(ROOT / "src")
OUTDIR = ROOT / "experiments" / "bench"

#: flattened per-run summary columns (benchmarks.run --metrics-dir)
METRICS_SUMMARY_COLS = (
    "suite", "tokens", "steps", "wall_s", "tok_per_s", "mfu", "hbm_util",
    "d2d_util", "decode_steps", "prefills", "prefix_hits", "preemptions",
    "spec_accepted", "blocks_granted", "blocks_released")


def metrics_path(suite: str) -> Path:
    """Where a suite's metrics-report JSON lands: ``REPRO_METRICS_DIR``
    (set by ``benchmarks.run --metrics-dir``) or experiments/bench/."""
    out = Path(os.environ.get("REPRO_METRICS_DIR") or OUTDIR)
    out.mkdir(parents=True, exist_ok=True)
    return out / f"{suite}.metrics.json"


def emit_metrics(suite: str, engine, extra: dict | None = None) -> dict:
    """Write a suite's engine metrics + utilization in the one shared
    schema (repro-metrics-report-v1) every serve benchmark and the
    launcher emit."""
    from repro.obs import utilization_report, write_metrics_json
    return write_metrics_json(str(metrics_path(suite)), suite=suite,
                              snapshot=engine.metrics.snapshot(),
                              utilization=utilization_report(engine),
                              extra=extra)


def summarize_metrics(payload: dict) -> dict:
    """One flat CSV row from a repro-metrics-report-v1 payload."""
    snap = payload.get("snapshot", {})
    c = snap.get("counters", {})
    u = payload.get("utilization", {})
    return {
        "suite": payload.get("suite", ""),
        "tokens": u.get("tokens", ""),
        "steps": u.get("steps", ""),
        "wall_s": u.get("wall_s", ""),
        "tok_per_s": u.get("tok_per_s", ""),
        "mfu": u.get("mfu", ""),
        "hbm_util": u.get("hbm_util", ""),
        "d2d_util": u.get("d2d_util", ""),
        "decode_steps": c.get("decode_steps", ""),
        "prefills": c.get("prefills", ""),
        "prefix_hits": c.get("prefix_hits", ""),
        "preemptions": c.get("preemptions", ""),
        "spec_accepted": c.get("spec_accepted", ""),
        "blocks_granted": c.get("blocks_granted", ""),
        "blocks_released": c.get("blocks_released", ""),
    }


def timeit(fn, *args, n: int = 3, warmup: int = 1, **kw) -> tuple:
    import jax
    out = None
    for _ in range(warmup):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / n


def emit(rows: list[dict], name: str) -> None:
    """Print rows as CSV and persist under experiments/bench/<name>.csv.

    The header is the ordered union of every row's keys (not just the
    first row's) — suites that append summary rows with disjoint keys
    used to render them as all-empty ",,,," lines. Rows whose rendered
    cells are all empty are dropped rather than written."""
    if not rows:
        print(f"[{name}] no rows")
        return
    cols: list[str] = []
    for r in rows:
        for c in r:
            if c not in cols:
                cols.append(c)
    lines = [",".join(cols)]
    for r in rows:
        cells = [str(r.get(c, "")) for c in cols]
        if not any(cells):
            continue
        lines.append(",".join(cells))
    text = "\n".join(lines)
    print(f"\n=== {name} ===")
    print(text)
    OUTDIR.mkdir(parents=True, exist_ok=True)
    (OUTDIR / f"{name}.csv").write_text(text + "\n")


def read_rows(name: str) -> list[dict]:
    """Read back an ``emit()``-style CSV as dicts, skipping blank/all-empty
    lines (tolerates trailing ",,,," rows from older emit versions)."""
    import csv
    path = OUTDIR / f"{name}.csv"
    if not path.exists():
        return []
    with path.open(newline="") as fh:
        return [r for r in csv.DictReader(fh)
                if any(v.strip() for v in r.values() if v is not None)]


def run_subprocess(code: str, n_devices: int = 8, timeout: int = 1200) -> str:
    """Run a snippet with N fake devices; return stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{proc.stderr[-3000:]}")
    return proc.stdout

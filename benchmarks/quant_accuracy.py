"""Quantized serving benchmark: int8 weights + int8 paged KV vs bf16.

The paper's precision ladder made measurable (Occamy's 8-to-64-bit FPU:
halving precision doubles density — Fig. 4b): one serving trace run twice
through the paged engine, once at the bf16 baseline and once with
``weight_dtype=int8, kv_dtype=int8`` (per-channel + per-block absmax
scales, ``quant_block=32``). Reports tokens/s, weight bytes, KV bytes per
request, and greedy token agreement, and asserts the directional claims:

  * weight bytes <= 0.55x the bf16 baseline (int8 storage + fp16 scales),
  * KV bytes/request <= 0.55x (int8 pools + per-row fp16 scales),
  * greedy decode matches the baseline on >= 95% of tokens, measured
    teacher-forced: per-position argmax agreement along the baseline's
    generated sequences (free-running agreement is also reported).

The model is first trained for a few seconds on a deterministic bigram
task (next token = a fixed random permutation of the current one) so its
logits are *peaked*, as a deployed model's are. A random-init model has
near-tied logits whose argmax flips under any perturbation — including the
bf16 rounding of the baseline itself — which measures tie-breaking noise,
not quantization fidelity.

``--dry-run`` imports the quant subsystem, resolves the registry entries
(``gemm_wq``, ``paged_attention``), and exits — the CI smoke step.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks._util import emit

TRAIN_STEPS = 60
TRAIN_LR = 0.5


def _requests(cfg, perm, n: int, seed: int = 0):
    """Mixed-length prompts walking the bigram chain (in-distribution)."""
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        L = int(rng.integers(4, 18))
        prompt = np.empty(L, np.int32)
        prompt[0] = rng.integers(0, cfg.vocab_size)
        for t in range(1, L):
            prompt[t] = perm[prompt[t - 1]]
        out.append(Request(uid=i, prompt=prompt,
                           max_new_tokens=int(rng.integers(4, 10))))
    return out


def _train_bigram(cfg_train, seed: int = 0, steps: int = TRAIN_STEPS):
    """A few SGD steps on next = perm[current] -> confident logits."""
    import jax
    import jax.numpy as jnp

    from repro.models import init, lm_loss

    params = init(jax.random.PRNGKey(seed), cfg_train)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(cfg_train.vocab_size)

    def batch(n=16, L=32):
        seqs = np.empty((n, L), np.int32)
        seqs[:, 0] = rng.integers(0, cfg_train.vocab_size, n)
        for t in range(1, L):
            seqs[:, t] = perm[seqs[:, t - 1]]
        return jnp.asarray(seqs)

    @jax.jit
    def step(p, toks):
        loss, g = jax.value_and_grad(
            lambda p: lm_loss(p, cfg_train, toks[:, :-1], toks[:, 1:]))(p)
        return jax.tree.map(
            lambda w, gw: w - TRAIN_LR * gw.astype(w.dtype), p, g), loss

    for _ in range(steps):
        params, loss = step(params, batch())
    return params, perm, float(loss)


def _teacher_forced_match(cfg, params, qcfg, qparams, reqs, results) -> tuple:
    """Per-position greedy agreement along the baseline sequences."""
    import jax.numpy as jnp

    from repro.models import forward, logits_fn

    match = total = 0
    for req, res in zip(reqs, results):
        seq = np.concatenate([np.asarray(req.prompt, np.int32),
                              np.asarray(res.tokens, np.int32)])
        toks = jnp.asarray(seq)[None]
        hb, _, _ = forward(params, cfg, toks)
        hq, _, _ = forward(qparams, qcfg, toks)
        lb = logits_fn(params, cfg, hb)[0, :, :cfg.vocab_size]
        lq = logits_fn(qparams, qcfg, hq)[0, :, :cfg.vocab_size]
        gb = np.asarray(jnp.argmax(lb.astype(jnp.float32), -1))
        gq = np.asarray(jnp.argmax(lq.astype(jnp.float32), -1))
        s = len(req.prompt) - 1          # positions that predict new tokens
        match += int((gb[s:-1] == gq[s:-1]).sum())
        total += len(gb[s:-1])
    return match, total


def main(dry_run: bool = False) -> None:
    if dry_run:
        from repro import quant  # noqa: F401 — import-time breakage check
        from repro.kernels.dispatch import registry, resolve_backend
        from repro.kernels import ops  # noqa: F401 — populates the registry
        for op in ("gemm_wq", "paged_attention"):
            impls = registry.implementations(op)
            assert impls, f"op {op!r} not registered"
            assert any("ref" in e.backends for e in impls), op
        print(f"kernel backend: {resolve_backend().name}")
        print(f"gemm_wq impls: "
              f"{', '.join(e.name for e in registry.implementations('gemm_wq'))}")
        print("quant dry-run OK")
        return

    import jax
    import jax.numpy as jnp

    from repro import quant
    from repro.configs import get_arch, reduced
    from repro.serve import Request, ServeEngine

    cfg = reduced(get_arch("qwen3-0.6b")).replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=256, dtype="bfloat16", param_dtype="bfloat16")
    qcfg = cfg.replace(weight_dtype="int8", kv_dtype="int8", quant_block=32)
    trained, perm, loss = _train_bigram(
        cfg.replace(dtype="float32", param_dtype="float32"))
    print(f"bigram pre-train: {TRAIN_STEPS} steps, final loss {loss:.3f}")
    # the bf16 *serving* baseline the quantized run is judged against
    params = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 else x,
        trained)
    reqs = _requests(cfg, perm, n=8)

    rows, tokens, engines = [], {}, {}
    for tag, c in (("bf16", cfg), ("int8", qcfg)):
        engine = ServeEngine(c, params, max_slots=3, max_len=64, paged=True,
                             page_size=8, prefill_chunk=8)
        trace = [Request(uid=r.uid, prompt=r.prompt,
                         max_new_tokens=r.max_new_tokens) for r in reqs]
        t0 = time.perf_counter()
        results = engine.run(trace)
        dt = time.perf_counter() - t0
        new_tokens = sum(len(r.tokens) for r in results)
        tokens[tag] = results
        engines[tag] = engine
        rows.append({
            "precision": tag,
            "requests": len(results),
            "new_tokens": new_tokens,
            "tok_per_s": round(new_tokens / dt, 1),
            "weight_bytes": quant.param_bytes(engine.params),
            "kv_bytes_per_request":
                engine.stats["kv_bytes_alloc"] // len(results),
        })

    base, q = rows
    w_ratio = q["weight_bytes"] / base["weight_bytes"]
    kv_ratio = q["kv_bytes_per_request"] / base["kv_bytes_per_request"]
    tf_match, tf_total = _teacher_forced_match(
        cfg, engines["bf16"].params, qcfg, engines["int8"].params,
        reqs, tokens["bf16"])
    free = sum(int(x == y) for a, b in zip(tokens["bf16"], tokens["int8"])
               for x, y in zip(a.tokens, b.tokens))
    free_total = sum(len(a.tokens) for a in tokens["bf16"])
    for r in rows:
        r["weight_ratio"] = round(w_ratio, 3)
        r["kv_ratio"] = round(kv_ratio, 3)
        r["token_match"] = round(tf_match / tf_total, 3)
        r["token_match_free_running"] = round(free / free_total, 3)
    emit(rows, "quant_accuracy")

    assert w_ratio <= 0.55, (
        f"int8 weight bytes should be <= 0.55x bf16: got {w_ratio:.3f}")
    assert kv_ratio <= 0.55, (
        f"int8 KV bytes/request should be <= 0.55x bf16: got {kv_ratio:.3f}")
    assert tf_match / tf_total >= 0.95, (
        f"greedy decode should match bf16 on >= 95% of tokens: got "
        f"{tf_match}/{tf_total} = {tf_match / tf_total:.3f}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="import + registry resolution only (CI smoke)")
    args = ap.parse_args()
    main(dry_run=args.dry_run)

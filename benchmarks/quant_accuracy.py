"""Quantized serving benchmark: int8/int4 weights + quantized paged KV vs bf16.

The paper's precision ladder made measurable (Occamy's 8-to-64-bit FPU:
halving precision doubles density — Fig. 4b): one serving trace run three
times through the paged engine — the bf16 baseline, ``weight_dtype=int8,
kv_dtype=int8``, and the bottom rung ``weight_dtype=int4, kv_dtype=fp8``
(two nibbles packed per stored byte; fp8 KV contracted natively in the
paged-attention kernel with no bf16 page bounce). Per-channel + per-block
absmax scales, ``quant_block=32`` throughout. Reports tokens/s, weight
bytes, KV bytes per request, and greedy token agreement, and asserts the
directional claims:

  * int8 weight bytes <= 0.55x the bf16 baseline (int8 storage + fp16
    scales); int4 <= 0.30x (nibble-packed storage),
  * int8 KV bytes/request <= 0.55x (int8 pools + per-row fp16 scales),
  * greedy decode matches the baseline on >= 95% of tokens at every rung,
    measured teacher-forced: per-position argmax agreement along the
    baseline's generated sequences (free-running agreement also reported).

Each run's engine telemetry lands in ``quant_accuracy.metrics.json``
(repro-metrics-report-v1 via ``_util.emit_metrics``) so ``benchmarks.run
--metrics-dir`` folds this suite into experiments/bench/metrics_runs.csv.

The model is first trained for a few seconds on a deterministic bigram
task (next token = a fixed random permutation of the current one) so its
logits are *peaked*, as a deployed model's are. A random-init model has
near-tied logits whose argmax flips under any perturbation — including the
bf16 rounding of the baseline itself — which measures tie-breaking noise,
not quantization fidelity.

``--dry-run`` imports the quant subsystem, resolves the registry entries
(``gemm_wq``, ``paged_attention``), and exits — the CI smoke step.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks._util import emit, emit_metrics

TRAIN_STEPS = 60
TRAIN_LR = 0.5


def _requests(cfg, perm, n: int, seed: int = 0):
    """Mixed-length prompts walking the bigram chain (in-distribution)."""
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        L = int(rng.integers(4, 18))
        prompt = np.empty(L, np.int32)
        prompt[0] = rng.integers(0, cfg.vocab_size)
        for t in range(1, L):
            prompt[t] = perm[prompt[t - 1]]
        out.append(Request(uid=i, prompt=prompt,
                           max_new_tokens=int(rng.integers(4, 10))))
    return out


def _train_bigram(cfg_train, seed: int = 0, steps: int = TRAIN_STEPS):
    """A few SGD steps on next = perm[current] -> confident logits."""
    import jax
    import jax.numpy as jnp

    from repro.models import init, lm_loss

    params = init(jax.random.PRNGKey(seed), cfg_train)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(cfg_train.vocab_size)

    def batch(n=16, L=32):
        seqs = np.empty((n, L), np.int32)
        seqs[:, 0] = rng.integers(0, cfg_train.vocab_size, n)
        for t in range(1, L):
            seqs[:, t] = perm[seqs[:, t - 1]]
        return jnp.asarray(seqs)

    @jax.jit
    def step(p, toks):
        loss, g = jax.value_and_grad(
            lambda p: lm_loss(p, cfg_train, toks[:, :-1], toks[:, 1:]))(p)
        return jax.tree.map(
            lambda w, gw: w - TRAIN_LR * gw.astype(w.dtype), p, g), loss

    for _ in range(steps):
        params, loss = step(params, batch())
    return params, perm, float(loss)


def _teacher_forced_match(cfg, params, qcfg, qparams, reqs, results) -> tuple:
    """Per-position greedy agreement along the baseline sequences."""
    import jax.numpy as jnp

    from repro.models import forward, logits_fn

    match = total = 0
    for req, res in zip(reqs, results):
        seq = np.concatenate([np.asarray(req.prompt, np.int32),
                              np.asarray(res.tokens, np.int32)])
        toks = jnp.asarray(seq)[None]
        hb, _, _ = forward(params, cfg, toks)
        hq, _, _ = forward(qparams, qcfg, toks)
        lb = logits_fn(params, cfg, hb)[0, :, :cfg.vocab_size]
        lq = logits_fn(qparams, qcfg, hq)[0, :, :cfg.vocab_size]
        gb = np.asarray(jnp.argmax(lb.astype(jnp.float32), -1))
        gq = np.asarray(jnp.argmax(lq.astype(jnp.float32), -1))
        s = len(req.prompt) - 1          # positions that predict new tokens
        match += int((gb[s:-1] == gq[s:-1]).sum())
        total += len(gb[s:-1])
    return match, total


def main(dry_run: bool = False) -> None:
    if dry_run:
        from repro import quant  # noqa: F401 — import-time breakage check
        from repro.kernels.dispatch import registry, resolve_backend
        from repro.kernels import ops  # noqa: F401 — populates the registry
        for op in ("gemm_wq", "paged_attention"):
            impls = registry.implementations(op)
            assert impls, f"op {op!r} not registered"
            assert any("ref" in e.backends for e in impls), op
        print(f"kernel backend: {resolve_backend().name}")
        print(f"gemm_wq impls: "
              f"{', '.join(e.name for e in registry.implementations('gemm_wq'))}")
        print("quant dry-run OK")
        return

    import jax
    import jax.numpy as jnp

    from repro import quant
    from repro.configs import get_arch, reduced
    from repro.serve import Request, ServeEngine

    cfg = reduced(get_arch("qwen3-0.6b")).replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=256, dtype="bfloat16", param_dtype="bfloat16")
    ladder = (
        ("bf16", cfg),
        ("int8", cfg.replace(weight_dtype="int8", kv_dtype="int8",
                             quant_block=32)),
        ("int4", cfg.replace(weight_dtype="int4", kv_dtype="fp8",
                             quant_block=32)),
    )
    trained, perm, loss = _train_bigram(
        cfg.replace(dtype="float32", param_dtype="float32"))
    print(f"bigram pre-train: {TRAIN_STEPS} steps, final loss {loss:.3f}")
    # the bf16 *serving* baseline the quantized runs are judged against
    params = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 else x,
        trained)
    reqs = _requests(cfg, perm, n=8)

    rows, tokens, engines, cfgs = [], {}, {}, {}
    for tag, c in ladder:
        engine = ServeEngine(c, params, max_slots=3, max_len=64, paged=True,
                             page_size=8, prefill_chunk=8)
        trace = [Request(uid=r.uid, prompt=r.prompt,
                         max_new_tokens=r.max_new_tokens) for r in reqs]
        t0 = time.perf_counter()
        results = engine.run(trace)
        dt = time.perf_counter() - t0
        new_tokens = sum(len(r.tokens) for r in results)
        tokens[tag] = results
        engines[tag] = engine
        cfgs[tag] = c
        rows.append({
            "precision": tag,
            "requests": len(results),
            "new_tokens": new_tokens,
            "tok_per_s": round(new_tokens / dt, 1),
            "weight_bytes": quant.param_bytes(engine.params),
            "kv_bytes_per_request":
                engine.stats["kv_bytes_alloc"] // len(results),
        })

    base = rows[0]
    free_total = sum(len(a.tokens) for a in tokens["bf16"])
    ratios: dict[str, dict] = {}
    for r in rows[1:]:
        tag = r["precision"]
        w_ratio = r["weight_bytes"] / base["weight_bytes"]
        kv_ratio = r["kv_bytes_per_request"] / base["kv_bytes_per_request"]
        tf_match, tf_total = _teacher_forced_match(
            cfg, engines["bf16"].params, cfgs[tag], engines[tag].params,
            reqs, tokens["bf16"])
        free = sum(int(x == y)
                   for a, b in zip(tokens["bf16"], tokens[tag])
                   for x, y in zip(a.tokens, b.tokens))
        r["weight_ratio"] = round(w_ratio, 3)
        r["kv_ratio"] = round(kv_ratio, 3)
        r["token_match"] = round(tf_match / tf_total, 3)
        r["token_match_free_running"] = round(free / free_total, 3)
        ratios[tag] = {"weight_ratio": r["weight_ratio"],
                       "kv_ratio": r["kv_ratio"],
                       "token_match": r["token_match"]}
    emit(rows, "quant_accuracy")
    # fold this suite into the shared telemetry stream (metrics_runs.csv)
    emit_metrics("quant_accuracy", engines["int4"],
                 extra={"precision_ladder": ratios})

    i8, i4 = ratios["int8"], ratios["int4"]
    assert i8["weight_ratio"] <= 0.55, (
        f"int8 weight bytes should be <= 0.55x bf16: got "
        f"{i8['weight_ratio']:.3f}")
    assert i8["kv_ratio"] <= 0.55, (
        f"int8 KV bytes/request should be <= 0.55x bf16: got "
        f"{i8['kv_ratio']:.3f}")
    assert i4["weight_ratio"] <= 0.30, (
        f"packed int4 weight bytes should be <= 0.30x bf16: got "
        f"{i4['weight_ratio']:.3f}")
    for tag in ("int8", "int4"):
        tm = ratios[tag]["token_match"]
        assert tm >= 0.95, (
            f"{tag} greedy decode should match bf16 on >= 95% of tokens: "
            f"got {tm:.3f}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="import + registry resolution only (CI smoke)")
    args = ap.parse_args()
    main(dry_run=args.dry_run)

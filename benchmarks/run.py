"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig4a,fig7] [--skip-slow]
                                          [--dry-run]

Each module prints a CSV (also persisted to experiments/bench/<name>.csv)
and asserts its paper-anchor directional claims (DESIGN.md §8).

``--dry-run`` imports every suite, resolves the kernel-backend registry, and
exits without running — the CI smoke step that catches import/registration
breakage in seconds.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback
from pathlib import Path


def _collect_metrics(metrics_dir: str) -> None:
    """Append one flat CSV row per suite metrics-report found in
    ``metrics_dir`` to experiments/bench/metrics_runs.csv (header written
    once; rows accumulate across harness runs)."""
    from benchmarks._util import METRICS_SUMMARY_COLS, OUTDIR, \
        summarize_metrics
    rows = []
    for p in sorted(Path(metrics_dir).glob("*.metrics.json")):
        try:
            payload = json.loads(p.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"[metrics] skipping {p.name}: {e}")
            continue
        if payload.get("schema") != "repro-metrics-report-v1":
            print(f"[metrics] skipping {p.name}: wrong schema")
            continue
        rows.append(summarize_metrics(payload))
    if not rows:
        print(f"[metrics] no suite reports under {metrics_dir}")
        return
    out = OUTDIR / "metrics_runs.csv"
    OUTDIR.mkdir(parents=True, exist_ok=True)
    lines = [] if out.exists() else [",".join(METRICS_SUMMARY_COLS)]
    lines += [",".join(str(r.get(c, "")) for c in METRICS_SUMMARY_COLS)
              for r in rows]
    with open(out, "a") as f:
        f.write("\n".join(lines) + "\n")
    print(f"[metrics] appended {len(rows)} suite rows to {out}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--skip-slow", action="store_true",
                    help="skip table1 (512-device compiles) unless cached")
    ap.add_argument("--dry-run", action="store_true",
                    help="import suites + registry and exit without running")
    ap.add_argument("--metrics-dir", default=None,
                    help="collect each suite's metrics-report JSON here and "
                         "append one summary CSV row per suite to "
                         "experiments/bench/metrics_runs.csv")
    args = ap.parse_args()
    if args.metrics_dir:
        # suites resolve their report path through _util.metrics_path
        os.makedirs(args.metrics_dir, exist_ok=True)
        os.environ["REPRO_METRICS_DIR"] = args.metrics_dir

    from benchmarks import (fig4a, fig4b, fig4c, fig7, prefix_cache,
                            quant_accuracy, serve_latency, serve_throughput,
                            sparse_gemm, spec_decode, table1)
    suites = {"fig4a": fig4a.main, "fig4b": fig4b.main, "fig4c": fig4c.main,
              "fig7": fig7.main, "prefix": prefix_cache.main,
              "quant": quant_accuracy.main, "serve": serve_throughput.main,
              "latency": serve_latency.main, "sparse": sparse_gemm.main,
              "spec": spec_decode.main, "table1": table1.main}
    if args.only:
        keep = args.only.split(",")
        suites = {k: v for k, v in suites.items() if k in keep}

    if args.dry_run:
        from repro.kernels.dispatch import registry, resolve_backend
        print(f"suites: {', '.join(suites)}")
        print(f"kernel backend: {resolve_backend().name} "
              f"(platform {resolve_backend().platform})")
        print("registered ops:")
        for line in registry.describe().splitlines():
            print(f"  {line}")
        print("dry-run OK")
        return

    failures = []
    for name, fn in suites.items():
        t0 = time.time()
        try:
            fn()
            print(f"[{name}] OK ({time.time() - t0:.1f}s)\n")
        except Exception as e:
            failures.append(name)
            print(f"[{name}] FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
    if args.metrics_dir:
        _collect_metrics(args.metrics_dir)
    if failures:
        sys.exit(f"benchmark failures: {failures}")
    print("all benchmarks passed")


if __name__ == "__main__":
    main()

"""Structured-sparse GEMM benchmark: block-pruned + 2:4 weights vs dense.

The paper's irregular-workload story (Fig. 4a: SpMM at 42% FPU util, the
streaming units recovering byte efficiency) made actionable for serving:
``gemm_sparse`` skips pruned weight blocks entirely — no MXU issue, no HBM
fetch — so both FLOPs and the weight stream scale linearly with the kept
density. This suite sweeps density 1.0 -> 0.125 over a block-pruned weight
and one 2:4 row, and gates:

  * **exact parity**: the sparse kernel equals the dense kernel applied to
    the hard-zeroed (masked) weight, bit-for-bit — on the ref backend vs a
    dense-mask jnp oracle AND on the interpret Pallas path vs ``ops.gemm``
    at identical tile sizes (a skipped block contributes exactly +0.0);
  * **cost scaling**: the analytic roofline terms
    (``repro.core.roofline.sparse_gemm_terms``) shrink linearly with
    density — flops(d)/flops(1.0) == d, weight bytes likewise.

``--dry-run`` imports the kernels, resolves the ``gemm_sparse`` registry
entries (pallas_block, pallas_24, ref), and exits — the CI smoke step.
"""
from __future__ import annotations

import numpy as np

from benchmarks._util import emit, timeit

M, K, N = 64, 128, 128
BS = 32                       # mask block (bs_k, bs_n)
BLOCKS = dict(block_m=32, block_n=32, block_k=32)
DENSITIES = (1.0, 0.5, 0.25, 0.125)


def main(dry_run: bool = False) -> list[dict]:
    if dry_run:
        from repro.kernels.dispatch import registry, resolve_backend
        from repro.kernels import ops  # noqa: F401 — populates the registry
        impls = {e.name for e in registry.implementations("gemm_sparse")}
        for need in ("pallas_block", "pallas_24", "ref"):
            assert need in impls, f"gemm_sparse missing impl {need!r}: {impls}"
        print(f"kernel backend: {resolve_backend().name}")
        print(f"gemm_sparse impls: {', '.join(sorted(impls))}")
        print("sparse_gemm dry-run OK")
        return []

    import jax
    import jax.numpy as jnp

    from repro.core.roofline import sparse_gemm_terms
    from repro.kernels import ops, ref, use_backend
    from repro.kernels.gemm_sparse import (apply_block_mask,
                                           block_mask_from_weight,
                                           densify_24, sparsify_24)

    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (M, K), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32)

    rows = []
    base_terms = None
    for density in DENSITIES:
        mask = block_mask_from_weight(w, BS, BS, density)
        kept = float(jnp.mean(mask.astype(jnp.float32)))
        wd = apply_block_mask(w, mask)

        with use_backend("ref"):
            y_ref = ops.gemm_sparse(x, w, mask)
        oracle = ref.gemm_ref(x, wd)
        ref_exact = bool((np.asarray(y_ref) == np.asarray(oracle)).all())

        with use_backend("interpret"):
            (y_sp, t_sparse) = timeit(ops.gemm_sparse, x, w, mask,
                                      n=2, **BLOCKS)
            (y_dn, t_dense) = timeit(ops.gemm, x, wd, n=2, **BLOCKS)
        kernel_exact = bool((np.asarray(y_sp) == np.asarray(y_dn)).all())

        terms = sparse_gemm_terms(M, K, N, density=kept,
                                  weight_bytes_elem=4.0, act_bytes_elem=4.0,
                                  mask_block=(BS, BS))
        if density == 1.0:
            base_terms = terms
        rows.append({
            "layout": f"block{BS}x{BS}",
            "density": density,
            "kept_frac": round(kept, 4),
            "flops": int(terms["flops"]),
            "weight_bytes": int(terms["weight_bytes"]),
            "total_bytes": int(terms["total_bytes"]),
            "ref_exact": ref_exact,
            "kernel_exact": kernel_exact,
            "cpu_interpret_ms": round(t_sparse * 1e3, 2),
            "dense_ms": round(t_dense * 1e3, 2),
        })
        assert ref_exact, f"ref gemm_sparse != masked-dense oracle (d={density})"
        assert kernel_exact, (
            f"interpret gemm_sparse != ops.gemm on masked weight (d={density})")

    # 2:4 fine-grained row: kernel densifies in-tile, parity vs dense gemm
    # on the scattered-back weight (density fixed at 0.5 by construction)
    vals, idx = sparsify_24(w)
    w24 = densify_24(vals, idx)
    with use_backend("ref"):
        y24_ref = ops.gemm_sparse_24(x, vals, idx)
    oracle24 = ref.gemm_ref(x, w24)
    ref24_exact = bool((np.asarray(y24_ref) == np.asarray(oracle24)).all())
    with use_backend("interpret"):
        (y24, t24) = timeit(ops.gemm_sparse_24, x, vals, idx, n=2, **BLOCKS)
        (y24d, t24d) = timeit(ops.gemm, x, w24, n=2, **BLOCKS)
    k24_exact = bool((np.asarray(y24) == np.asarray(y24d)).all())
    terms24 = sparse_gemm_terms(M, K, N, density=0.5,
                                weight_bytes_elem=4.0, act_bytes_elem=4.0)
    terms24["weight_bytes"] += K // 2 * N  # int8 index plane rides along
    rows.append({
        "layout": "2:4",
        "density": 0.5,
        "kept_frac": 0.5,
        "flops": int(terms24["flops"]),
        "weight_bytes": int(terms24["weight_bytes"]),
        "total_bytes": int(terms24["total_bytes"] + K // 2 * N),
        "ref_exact": ref24_exact,
        "kernel_exact": k24_exact,
        "cpu_interpret_ms": round(t24 * 1e3, 2),
        "dense_ms": round(t24d * 1e3, 2),
    })
    assert ref24_exact, "ref gemm_sparse_24 != densified oracle"
    assert k24_exact, "interpret gemm_sparse_24 != ops.gemm on densified w"

    # cost terms must track density linearly: a skipped block is neither
    # multiplied nor fetched
    for r in rows[:len(DENSITIES)]:
        want = r["kept_frac"]
        got_f = r["flops"] / base_terms["flops"]
        got_b = r["weight_bytes"] / base_terms["weight_bytes"]
        assert abs(got_f - want) < 1e-6, (r["density"], got_f, want)
        assert abs(got_b - want) < 1e-6, (r["density"], got_b, want)
    fl = [r["flops"] for r in rows[:len(DENSITIES)]]
    assert all(a > b for a, b in zip(fl, fl[1:])), \
        f"FLOPs must fall monotonically with density: {fl}"

    emit(rows, "sparse_gemm")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="import + registry resolution only (CI smoke)")
    args = ap.parse_args()
    main(dry_run=args.dry_run)

"""Prefix-cache benchmark: shared-system-prompt trace, warm vs cold.

The headline serving scenario the block-sharing stack exists for: every
request opens with the same long system prompt (multi-turn chat, agentic
tool preambles), so with the prefix cache warm only the short unique tail
ever runs prefill — matched pages map read-only out of the radix index.

Asserts the paper-anchored directional claims (bytes and FLOPs both scale
with *unique* tokens, the serving analogue of the Occamy line's
amortize-the-shared-structure argument):

  * warm prefix-hit throughput >= 1.5x the cold (prefix-cache-off) run on
    the identical trace — prefill chunks collapse to tail-only,
  * fresh KV bytes/request drop (shared pages are never re-stored),
  * greedy outputs are token-for-token identical with the cache on or off
    (sharing is a memory/scheduling optimization, never a semantics one),
  * the pool drains leak-free: free + cached blocks == capacity.

``--dry-run`` imports the serving stack and checks the prefix index
wiring without running the trace (the CI smoke step).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks._util import emit, emit_metrics

SYS_LEN = 112      # shared system prompt: 14 pages at page_size 8
PAGE = 8
N_REQS = 8


def _trace(cfg, seed: int = 0):
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, cfg.vocab_size, SYS_LEN).astype(np.int32)
    reqs = []
    for i in range(N_REQS):
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(4, 10))).astype(np.int32)
        # short generations: the trace is prefill-heavy by design — the
        # quantity under test is the skipped prefix work, not decode
        reqs.append(Request(uid=i, prompt=np.concatenate([sys_prompt, tail]),
                            max_new_tokens=int(rng.integers(2, 4))))
    return sys_prompt, reqs


def main(dry_run: bool = False) -> None:
    if dry_run:
        from repro.serve import (BlockAllocator, PrefixIndex,  # noqa: F401
                                 ServeEngine, page_hashes)
        alloc = BlockAllocator(8, PAGE)
        index = PrefixIndex(PAGE)
        alloc.evictor = index
        [blk] = alloc.alloc(1)
        toks = np.arange(PAGE, dtype=np.int32)
        index.publish(toks, [blk])
        assert index.lookup(toks, alloc) == [blk]
        assert len(page_hashes(np.arange(3 * PAGE), PAGE)) == 3
        alloc.decref(blk, retain=True)
        alloc.decref(blk, retain=True)
        assert index.evict_one(alloc) and alloc.n_free == alloc.capacity
        print("prefix-cache dry-run OK")
        return

    import jax

    from repro.configs import get_arch, reduced
    from repro.models import init
    from repro.serve import Request, ServeEngine

    cfg = reduced(get_arch("qwen3-0.6b")).replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, dtype="float32")
    params = init(jax.random.PRNGKey(0), cfg)
    sys_prompt, reqs = _trace(cfg)
    n_prompt = sum(len(r.prompt) for r in reqs)

    rows, tokens = [], {}
    for mode in ("cold", "warm"):
        engine = ServeEngine(cfg, params, max_slots=4, max_len=128,
                             paged=True, page_size=PAGE, prefill_chunk=16,
                             prefix_cache=(mode == "warm"))
        # warm the jit caches on BOTH engines (and, for `warm`, the prefix
        # index) before the timed runs, so the ratio measures serving work,
        # not compilation. Post-warm counters come off a registry snapshot:
        # each attempt's delta() isolates its own run, no hand-differencing
        engine.run([Request(uid=99, prompt=sys_prompt, max_new_tokens=2)])
        snap_warm = engine.metrics.snapshot()
        # best-of-3 timing damps shared-runner noise; the deterministic
        # counters (chunks, bytes, hits) come from the first attempt, and
        # greedy outputs must agree across every attempt
        best_dt, first = float("inf"), None
        for attempt in range(3):
            trace = [Request(uid=r.uid, prompt=r.prompt,
                             max_new_tokens=r.max_new_tokens) for r in reqs]
            t0 = time.perf_counter()
            results = engine.run(trace)
            dt = time.perf_counter() - t0
            assert all(r.finish_reason == "length" for r in results)
            toks = [r.tokens for r in results]
            if attempt == 0:
                d = engine.metrics.snapshot().delta(snap_warm)
                first = {
                    "chunks": int(d["prefill_chunks"]),
                    "hits": int(d["prefix_hits"]),
                    "hit_tokens": int(engine.stats["prefix_hit_tokens"]),
                    "kv_per_req": int(d["kv_bytes_alloc"]) // len(results),
                }
                tokens[mode] = toks
            assert toks == tokens[mode], "greedy outputs drifted across runs"
            best_dt = min(best_dt, dt)
        if mode == "warm":
            emit_metrics("prefix_cache", engine, extra={"mode": mode})
        new_tokens = sum(len(t) for t in tokens[mode])
        cached = (engine.prefix_index.n_evictable(engine.allocator)
                  if engine.prefix_index is not None else 0)
        assert engine.allocator.n_live == 0
        assert engine.allocator.n_free + cached == engine.allocator.capacity
        rows.append({
            "mode": mode,
            "requests": len(reqs),
            "prompt_tokens": n_prompt,
            "new_tokens": new_tokens,
            "tok_per_s": round((n_prompt + new_tokens) / best_dt, 1),
            "prefill_chunks": first["chunks"],
            "prefix_hits": first["hits"],
            "prefix_hit_tokens": first["hit_tokens"],
            "kv_bytes_per_request": first["kv_per_req"],
            "kv_bytes_cached": engine.stats["kv_bytes_cached"],
        })
    emit(rows, "prefix_cache")

    cold, warm = rows
    assert tokens["warm"] == tokens["cold"], \
        "prefix cache changed greedy outputs"
    assert warm["prefix_hits"] == N_REQS, \
        f"every request should hit the warmed prefix: {warm['prefix_hits']}"
    assert warm["prefix_hit_tokens"] >= N_REQS * SYS_LEN
    # deterministic gate first: matched pages skip their prefill chunks and
    # are never re-stored — these hold on any machine
    assert warm["prefill_chunks"] * 2 < cold["prefill_chunks"], (
        "prefix hits should collapse prefill to tail-only chunks: "
        f"{warm['prefill_chunks']} vs {cold['prefill_chunks']}")
    assert warm["kv_bytes_per_request"] < cold["kv_bytes_per_request"], (
        "shared pages should not be re-stored: "
        f"{warm['kv_bytes_per_request']} vs {cold['kv_bytes_per_request']}")
    speedup = warm["tok_per_s"] / cold["tok_per_s"]
    assert speedup >= 1.5, (
        f"prefix-hit throughput should be >= 1.5x cold prefill: "
        f"{warm['tok_per_s']} vs {cold['tok_per_s']} ({speedup:.2f}x)")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="import + prefix-index wiring check only (CI smoke)")
    args = ap.parse_args()
    main(dry_run=args.dry_run)

"""Paper Fig. 7 — crossbar (Occamy) vs mesh NoC (Ramora): latency, bandwidth
utilization, peak performance.

Framework analogue: the *flat* single-stage all-reduce (crossbar era) vs the
*hierarchical* staged reduce-scatter→inter-pod→all-gather schedule (mesh era,
C5a). We lower both on an 8-device (2 pod x 2 data x 2 model) mesh and count
HLO collective bytes: the staged schedule must shrink inter-pod ("D2D")
traffic by the intra-pod factor, which is exactly the paper's D2D win. The
hop-latency model reproduces Fig. 7a's crossover (mesh: lower average under
load, higher worst-case hop count).
"""
from __future__ import annotations

import json

from benchmarks._util import emit, run_subprocess

CODE = """
import json
import jax, jax.numpy as jnp
from repro.core.collectives import hierarchical_allreduce, flat_allreduce
from repro.core.roofline import parse_collectives

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
x = jnp.zeros((1024, 1024), jnp.float32)   # 4 MiB gradient shard

flat = jax.jit(lambda t: flat_allreduce(t, mesh, ("pod", "data"))) \
    .lower(x).compile().as_text()
hier = jax.jit(lambda t: hierarchical_allreduce(
    t, mesh, intra_axis="data", inter_axis="pod")).lower(x).compile().as_text()

print(json.dumps({"flat": parse_collectives(flat),
                  "hier": parse_collectives(hier)}))
"""


def hop_model() -> list[dict]:
    """Fig. 7a analogue: crossbar = 2 hops to a central switch but queueing
    grows with requestors (N); mesh = avg sqrt-N hops, distributed queueing."""
    import math
    rows = []
    for n in (16, 64, 256):
        side = int(math.sqrt(n))
        xbar_zero = 2
        mesh_zero = 2 * (side / 2)            # average Manhattan distance
        mesh_max = 2 * (side - 1)
        # under full load: crossbar serializes through one arbiter (O(N));
        # the mesh's per-link load stays O(sqrt N) (bisection-limited)
        xbar_full = 2 + 0.05 * n
        mesh_full = mesh_zero + 0.05 * side
        rows.append({"metric": "hop_latency_model", "chips": n,
                     "xbar_zero_load": round(xbar_zero, 1),
                     "mesh_zero_load": round(mesh_zero, 1),
                     "mesh_max": mesh_max,
                     "xbar_full_load": round(xbar_full, 1),
                     "mesh_full_load": round(mesh_full, 1)})
    return rows


def main() -> list[dict]:
    out = json.loads(run_subprocess(CODE).strip().splitlines()[-1])
    flat_b, hier_b = out["flat"], out["hier"]

    def kindsum(d, *kinds):
        return sum(d["bytes_by_kind"].get(k, 0) for k in kinds)

    rows = [{
        "metric": "collective_bytes", "schedule": "flat(occamy/crossbar)",
        "all_reduce": kindsum(flat_b, "all-reduce"),
        "reduce_scatter": kindsum(flat_b, "reduce-scatter"),
        "all_gather": kindsum(flat_b, "all-gather"),
        "total": flat_b["total_bytes"],
    }, {
        "metric": "collective_bytes", "schedule": "hierarchical(ramora/mesh)",
        "all_reduce": kindsum(hier_b, "all-reduce"),
        "reduce_scatter": kindsum(hier_b, "reduce-scatter"),
        "all_gather": kindsum(hier_b, "all-gather"),
        "total": hier_b["total_bytes"],
    }]
    # the staged schedule's all-reduce stage (the inter-pod / D2D component)
    # must be ~1/|intra| of the flat all-reduce bytes
    flat_ar = kindsum(flat_b, "all-reduce")
    hier_ar = kindsum(hier_b, "all-reduce")
    assert hier_ar <= flat_ar / 1.9, (flat_ar, hier_ar)
    rows.append({"metric": "d2d_bytes_reduction", "schedule": "hier/flat",
                 "all_reduce": round(flat_ar / max(hier_ar, 1), 2),
                 "reduce_scatter": "", "all_gather": "", "total": ""})
    rows += hop_model()
    emit(rows, "fig7")
    return rows


if __name__ == "__main__":
    main()

"""Serving benchmark: dense vs paged KV cache on a mixed-length trace.

Reports tokens/s and KV-bytes-per-request for the two cache layouts over an
identical greedy request trace, and asserts the paper-anchored directional
claims of the block-pool design:

  * paged and dense emit token-for-token identical greedy outputs,
  * paged KV bytes/request drops vs. dense at mixed prompt lengths
    (allocation tracks actual sequence lengths, not max_len x max_slots),
  * chunked prefill compiles ONE shape: ``prefill_recompiles`` stays
    constant no matter how many distinct prompt lengths the trace has.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks._util import emit


def _requests(cfg, n: int, seed: int = 0):
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    return [
        Request(uid=i,
                prompt=rng.integers(0, cfg.vocab_size,
                                    rng.integers(4, 40)).astype(np.int32),
                max_new_tokens=int(rng.integers(4, 12)))
        for i in range(n)
    ]


def main() -> None:
    import jax

    from repro.configs import get_arch, reduced
    from repro.models import init
    from repro.serve import Request, ServeEngine

    cfg = reduced(get_arch("qwen3-0.6b")).replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, dtype="float32")
    params = init(jax.random.PRNGKey(0), cfg)
    reqs = _requests(cfg, n=10)
    n_lengths = len({len(r.prompt) for r in reqs})

    rows, tokens = [], {}
    for layout in ("dense", "paged"):
        engine = ServeEngine(cfg, params, max_slots=4, max_len=96,
                             paged=(layout == "paged"), page_size=8,
                             prefill_chunk=16)
        trace = [Request(uid=r.uid, prompt=r.prompt,
                         max_new_tokens=r.max_new_tokens) for r in reqs]
        t0 = time.perf_counter()
        results = engine.run(trace)
        dt = time.perf_counter() - t0
        new_tokens = sum(len(r.tokens) for r in results)
        tokens[layout] = [r.tokens for r in results]
        rows.append({
            "layout": layout,
            "requests": len(results),
            "distinct_prompt_lengths": n_lengths,
            "new_tokens": new_tokens,
            "tok_per_s": round(new_tokens / dt, 1),
            "kv_bytes_per_request":
                engine.stats["kv_bytes_alloc"] // len(results),
            "prefill_chunks": engine.stats["prefill_chunks"],
            "prefill_recompiles": engine.stats["prefill_recompiles"],
            "decode_steps": engine.stats["decode_steps"],
        })
    emit(rows, "serve_throughput")

    dense, paged = rows
    assert tokens["paged"] == tokens["dense"], \
        "paged engine diverged from dense greedy outputs"
    assert paged["kv_bytes_per_request"] < dense["kv_bytes_per_request"], (
        "paged KV bytes/request should drop vs dense at mixed lengths: "
        f"{paged['kv_bytes_per_request']} vs {dense['kv_bytes_per_request']}")
    assert paged["prefill_recompiles"] == 1, (
        "chunked prefill must compile one shape across "
        f"{n_lengths} distinct prompt lengths")


if __name__ == "__main__":
    main()

"""Serving benchmark: dense vs paged KV, SPMD scale-out, split pools.

Three sections, all emitting into one ``serve_throughput.csv``:

* **layout** — dense vs paged KV cache on a mixed-length greedy trace:
  identical tokens, paged KV bytes/request drops at mixed lengths, chunked
  prefill compiles ONE shape.
* **scale-out** (``--devices N``) — subprocess runs with fake CPU devices:
  a KV-head-sharded pool under the same *per-device* HBM budget must admit
  >= 3x the concurrent requests of single-device serving (at N = 4) at
  <= 1.1x the per-device KV bytes per request, with exact greedy parity.
* **split pools** — disaggregated prefill/decode slot pools: the decode
  gap counter (engine steps where queued work exists but no decode was
  dispatched) must not grow with prompt length under ``split_pools``,
  while the unified engine's gap does.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks._util import emit, emit_metrics, run_subprocess

_COLS = ("mode", "layout", "devices", "kv_shard", "split_pools",
         "prompt_len", "requests", "new_tokens", "tok_per_s",
         "kv_bytes_per_request", "kv_bytes_per_request_dev",
         "max_concurrency", "decode_gap_steps", "handoffs",
         "prefill_chunks", "prefill_recompiles", "decode_steps",
         "mfu", "hbm_util")


def _row(**kw) -> dict:
    return {c: kw.get(c, "") for c in _COLS}


def _requests(cfg, n: int, seed: int = 0):
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    return [
        Request(uid=i,
                prompt=rng.integers(0, cfg.vocab_size,
                                    rng.integers(4, 40)).astype(np.int32),
                max_new_tokens=int(rng.integers(4, 12)))
        for i in range(n)
    ]


def _layout_rows() -> list[dict]:
    import jax

    from repro.configs import get_arch, reduced
    from repro.models import init
    from repro.obs import utilization_report
    from repro.serve import Request, ServeEngine

    cfg = reduced(get_arch("qwen3-0.6b")).replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, dtype="float32")
    params = init(jax.random.PRNGKey(0), cfg)
    reqs = _requests(cfg, n=10)
    n_lengths = len({len(r.prompt) for r in reqs})

    rows, tokens = [], {}
    for layout in ("dense", "paged"):
        engine = ServeEngine(cfg, params, max_slots=4, max_len=96,
                             paged=(layout == "paged"), page_size=8,
                             prefill_chunk=16)
        trace = [Request(uid=r.uid, prompt=r.prompt,
                         max_new_tokens=r.max_new_tokens) for r in reqs]
        # counters come off registry snapshots (delta over the run), not
        # hand-differenced stats-dict reads
        snap0 = engine.metrics.snapshot()
        t0 = time.perf_counter()
        results = engine.run(trace)
        dt = time.perf_counter() - t0
        d = engine.metrics.snapshot().delta(snap0)
        util = utilization_report(engine)
        new_tokens = sum(len(r.tokens) for r in results)
        tokens[layout] = [r.tokens for r in results]
        if layout == "paged":
            emit_metrics("serve_throughput", engine,
                         extra={"mode": "layout", "wall_s": round(dt, 3)})
        rows.append(_row(
            mode="layout", layout=layout, devices=1, kv_shard=1,
            split_pools=False, requests=len(results),
            new_tokens=new_tokens, tok_per_s=round(new_tokens / dt, 1),
            kv_bytes_per_request=int(d["kv_bytes_alloc"]) // len(results),
            kv_bytes_per_request_dev=(int(d["kv_bytes_alloc_dev"])
                                      // len(results)),
            max_concurrency=int(d["max_concurrency"]),
            decode_gap_steps=int(d["decode_gap_steps"]),
            handoffs=int(d["handoffs"]),
            prefill_chunks=int(d["prefill_chunks"]),
            prefill_recompiles=int(d["prefill_recompiles"]),
            decode_steps=int(d["decode_steps"]),
            mfu=util["mfu"], hbm_util=util["hbm_util"]))

    dense, paged = rows
    assert tokens["paged"] == tokens["dense"], \
        "paged engine diverged from dense greedy outputs"
    assert paged["kv_bytes_per_request"] < dense["kv_bytes_per_request"], (
        "paged KV bytes/request should drop vs dense at mixed lengths: "
        f"{paged['kv_bytes_per_request']} vs {dense['kv_bytes_per_request']}")
    assert paged["prefill_recompiles"] == 1, (
        "chunked prefill must compile one shape across "
        f"{n_lengths} distinct prompt lengths")
    return rows


# one subprocess per device count: jax locks the device count at first
# init, so 1-device and N-device engines cannot share an interpreter
_SCALE_SNIPPET = """
import json
import numpy as np
import jax

N_DEV = {n_dev}
KV_BUDGET = {budget}

from repro.configs import get_arch, reduced
from repro.models import init
from repro.serve import Request, ServeEngine

cfg = reduced(get_arch("qwen3-0.6b")).replace(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=8, head_dim=16,
    d_ff=128, vocab_size=512, dtype="float32")
params = init(jax.random.PRNGKey(0), cfg)
part = None
if N_DEV > 1:
    from repro.configs.base import StrategyConfig
    from repro.core.sharding import Partitioner
    mesh = jax.make_mesh((1, N_DEV), ("data", "model"))
    part = Partitioner(mesh,
                       StrategyConfig(name="ramora", tensor_parallel=True),
                       cfg, mode="serve")
engine = ServeEngine(cfg, params, max_slots=16, max_len=48, part=part,
                     paged=True, page_size=8, prefill_chunk=16,
                     kv_budget_bytes=KV_BUDGET)
rng = np.random.default_rng(0)
reqs = [Request(uid=i,
                prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                max_new_tokens=16) for i in range(20)]
results = engine.run(reqs)
assert engine.allocator.n_live == 0, "leaked blocks"
print(json.dumps({{
    "tokens": [r.tokens for r in results],
    "kv_shard": engine._kv_shard,
    "n_blocks": engine.n_blocks,
    "max_concurrency": engine.stats["max_concurrency"],
    "kv_bytes_per_request_dev":
        engine.stats["kv_bytes_alloc_dev"] // len(results),
}}))
"""


def _scale_rows(n_dev: int) -> list[dict]:
    # the same PER-DEVICE budget on both sides: 16 blocks' worth of a
    # 2-layer K=8 hd=16 fp32 pool (2 * 8rows * 8K * 16hd * 4B = 16 KiB
    # per block) — single-device serving admits 4 concurrent 32-token
    # requests; an N-way KV-head shard holds N x the blocks for the same
    # per-device bytes and admits up to the slot cap
    budget = 16 * 16384
    runs = {}
    for nd in (1, n_dev):
        out = run_subprocess(
            _SCALE_SNIPPET.format(n_dev=nd, budget=budget),
            n_devices=max(nd, 1))
        runs[nd] = json.loads(out.strip().splitlines()[-1])
    base, multi = runs[1], runs[n_dev]
    assert multi["tokens"] == base["tokens"], \
        "sharded serving diverged from single-device greedy outputs"
    assert multi["kv_shard"] == n_dev, (
        f"expected a {n_dev}-way KV shard, got {multi['kv_shard']} "
        "(KV heads must divide the model axis)")
    conc1, concN = base["max_concurrency"], multi["max_concurrency"]
    assert concN >= 3 * conc1, (
        f"scale-out must admit >= 3x the concurrency at the same "
        f"per-device budget: {concN} vs {conc1} x1")
    dev1 = base["kv_bytes_per_request_dev"]
    devN = multi["kv_bytes_per_request_dev"]
    assert devN <= 1.1 * dev1, (
        f"per-device KV bytes/request regressed: {devN} vs {dev1} x1")
    return [_row(mode="scale", layout="paged", devices=nd,
                 kv_shard=runs[nd]["kv_shard"], split_pools=False,
                 prompt_len=16, requests=20,
                 kv_bytes_per_request_dev=runs[nd]
                 ["kv_bytes_per_request_dev"],
                 max_concurrency=runs[nd]["max_concurrency"])
            for nd in (1, n_dev)]


def _gap_rows() -> list[dict]:
    """Unified vs split pools on a long-prefill trace: the decode gap
    (steps with queued work but no decode dispatched) grows with prompt
    length when prefills monopolize unified slots; dedicated decode slots
    keep it flat."""
    import jax

    from repro.configs import get_arch, reduced
    from repro.models import init
    from repro.serve import Request, ServeEngine

    cfg = reduced(get_arch("qwen3-0.6b")).replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, dtype="float32")
    params = init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)

    def trace(plen: int):
        # two short anchors seed the decode side, then a wave of long
        # prompts whose decode budget outlasts their own prefill
        reqs = [Request(uid=i, prompt=rng.integers(
            0, cfg.vocab_size, 4).astype(np.int32), max_new_tokens=40)
            for i in range(2)]
        reqs += [Request(uid=10 + i, prompt=rng.integers(
            0, cfg.vocab_size, plen).astype(np.int32), max_new_tokens=24)
            for i in range(8)]
        return reqs

    rows, gaps = [], {}
    for split in (False, True):
        for plen in (32, 128):
            engine = ServeEngine(cfg, params, max_slots=4, max_len=160,
                                 paged=True, page_size=8, prefill_chunk=8,
                                 split_pools=split,
                                 prefill_slots=2 if split else None)
            results = engine.run(trace(plen))
            assert all(r.finish_reason == "length" for r in results)
            gaps[(split, plen)] = engine.stats["decode_gap_steps"]
            rows.append(_row(
                mode="gap", layout="paged", devices=1, kv_shard=1,
                split_pools=split, prompt_len=plen, requests=10,
                max_concurrency=engine.stats["max_concurrency"],
                decode_gap_steps=engine.stats["decode_gap_steps"],
                handoffs=engine.stats["handoffs"],
                decode_steps=engine.stats["decode_steps"]))
    uni_growth = gaps[(False, 128)] - gaps[(False, 32)]
    split_growth = gaps[(True, 128)] - gaps[(True, 32)]
    assert uni_growth > 0, (
        f"unified engine should stall more at longer prompts: "
        f"{gaps[(False, 32)]} -> {gaps[(False, 128)]}")
    assert split_growth <= max(2, uni_growth // 4), (
        f"split-pool decode gap must not grow with prompt length: "
        f"{gaps[(True, 32)]} -> {gaps[(True, 128)]} "
        f"(unified grew {uni_growth})")
    return rows


def _trace_smoke(trace_out: str, metrics_out: str) -> None:
    """CI trace smoke: drive a compact mixed trace — prefix hits, a
    preemption, a COW fork, split-pool handoffs, and a speculative-decode
    turn — with the lifecycle tracer enabled, then validate the exported
    Chrome trace (every admitted request closes its ``request`` span, no
    orphan begin/end pairs) and round-trip the metrics JSON."""
    import jax

    from repro.configs import get_arch, reduced
    from repro.models import init
    from repro.obs import (Snapshot, Tracer, utilization_report,
                           validate_chrome_trace, write_metrics_json)
    from repro.serve import Request, ServeEngine

    cfg = reduced(get_arch("qwen3-0.6b")).replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, dtype="float32")
    params = init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    tracer = Tracer(buffer=16384)

    # engine A: prefix sharing + preemption + COW fork under one tight pool
    eng = ServeEngine(cfg, params, max_slots=2, max_len=64, paged=True,
                      page_size=8, max_blocks=7, prefix_cache=True,
                      preemption=True, tracer=tracer)
    shared = rng.integers(0, 256, 12).astype(np.int32)
    for uid in (0, 1):       # identical prompts: the second is a warm hit
        eng.submit(Request(uid=uid, prompt=shared.copy(), max_new_tokens=6))
    for _ in range(4):
        eng.step()
    eng.submit(Request(uid=2, prompt=rng.integers(0, 256, 8).astype(np.int32),
                       max_new_tokens=4, priority=5))  # forces a preemption
    steps = 0
    while eng._busy():
        eng.step()
        steps += 1
        assert steps < 5000, "smoke trace failed to drain"
    eng.run([Request(uid=3, prompt=shared.copy(), max_new_tokens=4,
                     temperature=0.8, seed=1, n=2)])   # COW fork
    assert eng.stats["prefix_hits"] >= 1, "no prefix hit in the smoke trace"
    assert eng.stats["preemptions"] >= 1, "no preemption in the smoke trace"
    assert eng.stats["forks"] >= 1, "no fork in the smoke trace"

    # engine B: disaggregated prefill/decode pools (handoff events)
    eng_b = ServeEngine(cfg, params, max_slots=4, max_len=64, paged=True,
                        page_size=8, split_pools=True, prefill_slots=2,
                        tracer=tracer)
    eng_b.run([Request(uid=10 + i,
                       prompt=rng.integers(0, 256, 10).astype(np.int32),
                       max_new_tokens=4) for i in range(3)])
    assert eng_b.stats["handoffs"] >= 1, "no handoff in the smoke trace"

    # engine C: speculative decoding (self-draft: every proposal accepts)
    eng_c = ServeEngine(cfg, params, max_slots=2, max_len=64, paged=True,
                        page_size=8, draft_model=cfg, draft_params=params,
                        spec_k=3, tracer=tracer)
    eng_c.run([Request(uid=20,
                       prompt=rng.integers(0, 256, 8).astype(np.int32),
                       max_new_tokens=6)])
    assert eng_c.stats["spec_turns"] >= 1, "no spec turn in the smoke trace"

    tracer.export(trace_out)
    with open(trace_out) as f:
        summary = validate_chrome_trace(json.load(f))
    assert summary["requests"] >= 7, summary   # 5 submitted + 2 fork children

    payload = write_metrics_json(metrics_out, suite="serve_throughput.smoke",
                                 snapshot=eng.metrics.snapshot(),
                                 utilization=utilization_report(eng))
    with open(metrics_out) as f:
        back = json.load(f)
    assert back["schema"] == "repro-metrics-report-v1"
    rt = Snapshot.from_json(json.dumps(back["snapshot"]))
    assert rt == eng.metrics.snapshot(), "metrics JSON round-trip drifted"
    assert payload["utilization"]["steps"] > 0
    print(f"trace smoke OK: {summary['events']} events, "
          f"{summary['requests']} closed request spans, "
          f"{summary['dropped']} dropped -> {trace_out}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=0,
                    help="also run the SPMD scale-out comparison: a "
                         "subprocess pair (1 vs N fake devices) under the "
                         "same per-device KV budget")
    ap.add_argument("--dry-run", action="store_true",
                    help="run the mixed-trace tracing smoke (prefix hits, "
                         "preemption, fork, split pools, spec decode) and "
                         "validate the exported trace instead of the full "
                         "benchmark")
    ap.add_argument("--trace-out", default="/tmp/serve_trace.json",
                    help="Chrome-trace output path for --dry-run")
    ap.add_argument("--metrics-out", default="/tmp/serve_metrics.json",
                    help="metrics-report output path for --dry-run")
    # parse_known_args: benchmarks.run invokes suite mains with run.py's own
    # argv still in sys.argv — ignore its flags instead of erroring
    args, _ = ap.parse_known_args(argv)

    if args.dry_run:
        _trace_smoke(args.trace_out, args.metrics_out)
        return

    rows = _layout_rows()
    rows += _gap_rows()
    if args.devices > 1:
        rows += _scale_rows(args.devices)
    emit(rows, "serve_throughput")


if __name__ == "__main__":
    main()

"""End-to-end training driver: train a ~25M-param qwen3-family model on the
deterministic synthetic Markov corpus for a few hundred steps, with
checkpointing, an injected mid-run fault (+automatic restart), and a loss
curve that must actually go down.

    PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]

This is the 'real' loop — same Trainer the production launcher uses.
"""
import argparse
import shutil
import tempfile

import numpy as np

from repro.configs import get_arch, reduced, strategy
from repro.configs.base import ShapeConfig
from repro.optim.optimizers import adamw
from repro.optim.schedules import cosine
from repro.train.trainer import FaultInjector, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = reduced(get_arch("qwen3-0.6b")).replace(
        name="tiny-lm", d_model=256, n_layers=4, n_heads=8, n_kv_heads=4,
        d_ff=512, vocab_size=2048)
    print(f"model: {cfg.param_count()['total']/1e6:.1f}M params")
    shape = ShapeConfig("tiny", "train", seq_len=args.seq,
                        global_batch=args.batch)

    ckpt_dir = tempfile.mkdtemp(prefix="repro_example_")
    tcfg = TrainerConfig(steps=args.steps, ckpt_dir=ckpt_dir,
                         ckpt_every=max(args.steps // 4, 10), seed=0)
    sched = cosine(3e-3, warmup=20, total=args.steps)
    trainer = Trainer(cfg, shape, strategy("ramora"), adamw(sched), tcfg,
                      fault=FaultInjector(at_step=args.steps // 2))

    out = trainer.run_with_restarts()
    losses = out["losses"]
    k = max(len(losses) // 10, 1)
    first, last = float(np.mean(losses[:k])), float(np.mean(losses[-k:]))
    print(f"steps={out['stopped_at']}  restarts={out['restarts']} "
          f"(fault injected at step {args.steps // 2})")
    print(f"loss: {first:.4f} -> {last:.4f}  "
          f"improvement {100 * (first - last) / first:.1f}%")
    assert last < first * 0.9, "model failed to learn"
    print("OK: loss decreased through a mid-run fault + restart")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()

"""Batched serving with continuous batching: more requests than slots, mixed
prompt lengths and budgets; verifies every request completes and that the
engine's decode output is identical to a naive sequential reference.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import decode_step, forward, init, logits_fn
from repro.models.cache import init_cache
from repro.serve import Request, ServeEngine


def reference_greedy(cfg, params, prompt, max_new, max_len):
    """Naive single-sequence greedy decode (the correctness oracle)."""
    cache_t = init_cache(cfg, 1, max_len)
    hidden, cache, _ = forward(params, cfg, jnp.asarray(prompt)[None],
                               cache=cache_t)
    logits = logits_fn(params, cfg, hidden[:, -1:, :])[..., :cfg.vocab_size]
    toks = [int(jnp.argmax(logits[0, 0]))]
    pos = len(prompt)
    for _ in range(max_new - 1):
        logits, cache = decode_step(params, cfg,
                                    cache, jnp.asarray([[toks[-1]]], jnp.int32),
                                    jnp.asarray(pos, jnp.int32))
        toks.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    return toks


def main():
    cfg = reduced(get_arch("gemma2-27b"))  # local+global, softcaps — the
    params = init(jax.random.PRNGKey(0), cfg)  # hardest cache layout
    rng = np.random.default_rng(0)

    reqs = []
    for uid in range(9):
        plen = int(rng.integers(3, 24))
        prompt = rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        reqs.append(Request(uid=uid, prompt=prompt,
                            max_new_tokens=int(rng.integers(4, 12))))

    engine = ServeEngine(cfg, params, max_slots=4, max_len=128)
    t0 = time.time()
    results = engine.run(reqs)
    dt = time.time() - t0
    total = sum(len(r.tokens) for r in results)
    print(f"{len(reqs)} requests on 4 slots: {total} tokens in {dt:.1f}s "
          f"({engine.stats['decode_steps']} batched decode steps)")

    # verify continuous batching == sequential decoding, request by request
    for r, req in zip(results, reqs):
        ref = reference_greedy(cfg, params, req.prompt, req.max_new_tokens, 128)
        assert r.tokens == ref, f"request {r.uid}: {r.tokens} != {ref}"
    print("OK: all requests complete; batched == sequential greedy decode")


if __name__ == "__main__":
    main()

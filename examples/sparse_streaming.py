"""The paper's irregular-workload story on this framework's kernels:
dense GEMM vs scatter-gather (packed vs naive) vs SpMM — paper Fig. 4a's
regular→irregular sweep, plus the Ogopogo packed-stream bandwidth win (C5c).

    PYTHONPATH=src python examples/sparse_streaming.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref, use_backend


def bench(fn, *args, n=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / n


def main():
    k = jax.random.PRNGKey(0)
    M = N = K = 256

    # 1) dense GEMM with fused in-stream epilogue (C1 + C5b)
    x = jax.random.normal(k, (M, K), jnp.float32)
    w = jax.random.normal(k, (K, N), jnp.float32)
    with use_backend("interpret"):
        out, t_gemm = bench(ops.gemm, x, w, scale=0.5, act="gelu")
    exp = ref.gemm_ref(x, w, scale=0.5, act="gelu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)
    print(f"dense GEMM + fused epilogue     {t_gemm*1e3:8.1f} ms   (exact)")

    # 2) irregular gather: naive one-row-at-a-time vs packed (8 rows / wide
    #    flit, index-sorted 'temporal coalescer') — the C5c mechanism
    table = jax.random.normal(k, (4096, 64), jnp.float32)
    idx = jax.random.randint(k, (2048,), 0, 4096)
    with use_backend("interpret"):
        g1, t_naive = bench(ops.gather_rows, table, idx)
        g2, t_packed = bench(ops.packed_gather_rows, table, idx, pack=8)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
    print(f"gather naive                    {t_naive*1e3:8.1f} ms")
    print(f"gather packed (8/flit, sorted)  {t_packed*1e3:8.1f} ms   (exact)")

    # 3) SpMM via the same gather+segment-sum streaming pattern (Fig. 4a's
    #    most irregular point): y[r] = sum_j A[r,j] * B[j]
    rng = np.random.default_rng(0)
    n_rows, nnz = 512, 8192
    rows = np.sort(rng.integers(0, n_rows, nnz))
    cols = rng.integers(0, 4096, nnz)
    vals = rng.standard_normal(nnz).astype(np.float32)
    y = ref.spmm_gather_ref(jnp.asarray(vals), jnp.asarray(cols), table,
                            jnp.asarray(rows), n_rows)
    dense_a = np.zeros((n_rows, 4096), np.float32)
    np.add.at(dense_a, (rows, cols), vals)
    np.testing.assert_allclose(np.asarray(y), dense_a @ np.asarray(table),
                               rtol=2e-3, atol=2e-3)
    print(f"SpMM gather+segsum              nnz={nnz}          (exact)")
    print("OK: regular -> irregular streaming paths all validate")


if __name__ == "__main__":
    main()

"""Quickstart: build a model from an assigned architecture config, run a
forward pass, take one training step, and inspect the sharding plan.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced, strategy
from repro.configs.base import ShapeConfig
from repro.models import init, lm_loss
from repro.optim.optimizers import adamw
from repro.train.train_step import make_train_step

# 1) Pick an assigned architecture and shrink it to laptop size (same family:
#    qk-norm GQA transformer — only widths/depth change).
cfg = reduced(get_arch("qwen3-0.6b"))
print(f"arch={cfg.name}  layers={cfg.n_layers}  d_model={cfg.d_model}  "
      f"params≈{cfg.param_count()['total']/1e6:.1f}M")

# 2) Initialize and run a forward pass.
params = init(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
tokens = rng.integers(0, cfg.vocab_size, size=(4, 64)).astype(np.int32)
loss = lm_loss(params, cfg, jnp.asarray(tokens), jnp.asarray(tokens))
print(f"initial loss: {float(loss):.4f}  (ln V = {np.log(cfg.vocab_size):.4f})")

# 3) One optimizer step through the production train-step factory.
opt = adamw(1e-3)
step_fn = jax.jit(make_train_step(cfg, opt, strategy("ramora")))
state = {"params": params, "opt": opt.init(params),
         "step": jnp.zeros((), jnp.int32)}
batch = {"tokens": jnp.asarray(tokens), "targets": jnp.asarray(tokens)}
state, metrics = step_fn(state, batch)
print(f"after 1 step: loss={float(metrics['loss']):.4f}  "
      f"grad_norm={float(metrics['grad_norm']):.4f}")

# 4) Show the production sharding plan (what the 16x16 dry-run uses) for a
#    few parameters — logical axes -> mesh axes, no devices needed.
from repro.core.sharding import Partitioner, abstract_mesh

full = get_arch("qwen3-0.6b")
shape = ShapeConfig("train_4k", "train", 4096, 256)
mesh = abstract_mesh((16, 16), ("data", "model"))
part = Partitioner(mesh, strategy("ramora"), full, shape)
print("\nproduction sharding plan (16x16 ramora):")
for path, shp in [("embed/table", (151936, 1024)),
                  ("blocks/attn/q_proj/kernel", (14, 1024, 2048)),
                  ("blocks/mlp/up/kernel", (14, 1024, 3072))]:
    spec = part._param_spec(path, len(shp), shp)
    print(f"  {path:34s} {str(shp):18s} -> {spec}")

# 5) Kernel-backend registry: every hot-spot op dispatches through
#    repro.kernels.dispatch — backends are negotiated per call (capability
#    predicates + priorities), with the ref oracle as the universal fallback.
from repro.kernels import ops
from repro.kernels.dispatch import registry, resolve_backend, use_backend

print(f"\nkernel registry (default backend: {resolve_backend().name}):")
for line in registry.describe().splitlines():
    print(f"  {line}")
x = jnp.ones((64, 32))
w = jnp.ones((32, 16))
with use_backend("interpret"):          # Pallas kernels, interpreted on CPU
    y = ops.gemm(x, w, act="gelu")
print(f"registry gemm (interpret backend): out={y.shape}, "
      f"mean={float(y.mean()):.3f}")
# pin kernel tiles per scope (or per StrategyConfig.kernel_blocks):
with use_backend("interpret", blocks={"gemm": {"block_m": 16}}):
    ops.gemm(x, w)

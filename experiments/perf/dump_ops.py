import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re, sys
from repro.configs import get_arch, get_shape, strategy
from repro.launch.dryrun import _compile
from repro.launch.mesh import make_production_mesh
from repro.core.roofline import _shape_bytes

arch, shape_name, strat_name = sys.argv[1], sys.argv[2], sys.argv[3]
cfg = get_arch(arch)
shape = get_shape(shape_name)
strat = strategy(strat_name)
mesh = make_production_mesh(multi_pod=False)
compiled = _compile(cfg.replace(remat=strat.remat), shape, mesh, strat)
txt = compiled.as_text()
# find computation boundaries to attribute ops to while bodies
cur_comp = ""
rows = []
for line in txt.splitlines():
    mm = re.match(r"%?([\w.\-]+) \(", line)
    if mm and not line.startswith(" "):
        cur_comp = mm.group(1)
    ls = line.strip()
    m = re.match(r"(?:ROOT )?%?([\w.\-]+) = (.+?) (all-reduce|all-gather|"
                 r"reduce-scatter|all-to-all|collective-permute)(-start)?\(", ls)
    if m and "-done(" not in ls:
        nbytes = _shape_bytes(m.group(2))
        meta = re.search(r'op_name="([^"]*)"', ls)
        rows.append((nbytes, m.group(3), cur_comp[:40], m.group(2)[:70],
                     (meta.group(1) if meta else "")[-140:]))
rows.sort(reverse=True)
for r in rows[:18]:
    print(f"{r[0]:.2e} {r[1]:<16} comp={r[2]:<38} {r[3]}")
    print(f"         {r[4]}")

"""Dump the top collective ops (by operand bytes) of one dry-run cell."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re, sys
from repro.configs import get_arch, get_shape, strategy
from repro.launch.dryrun import _compile, analysis_variant
from repro.launch.mesh import make_production_mesh
from repro.core.roofline import _shape_bytes

arch, shape_name, strat_name = sys.argv[1], sys.argv[2], sys.argv[3]
unroll = int(sys.argv[4]) if len(sys.argv) > 4 else 1
cfg = get_arch(arch)
shape = get_shape(shape_name)
strat = strategy(strat_name)
mesh = make_production_mesh(multi_pod=False)
compiled = _compile(cfg.replace(remat=strat.remat, scan_unroll=unroll), shape, mesh, strat)
ops = []
for line in compiled.as_text().splitlines():
    ls = line.strip()
    m = re.match(r"(?:ROOT )?%?([\w.\-]+) = (.+?) (all-reduce|all-gather|"
                 r"reduce-scatter|all-to-all|collective-permute)"
                 r"(-start)?\(", ls)
    if m and "-done(" not in ls:
        nbytes = _shape_bytes(m.group(2))
        ops.append((nbytes, m.group(3), m.group(1), m.group(2)[:90], ls[:260]))
ops.sort(reverse=True)
tot = sum(o[0] for o in ops)
print(f"total {tot:.3e} B across {len(ops)} ops")
for nbytes, kind, name, shp, ls in ops[:14]:
    meta = re.search(r"metadata=\{op_name=\"([^\"]{0,120})", ls)
    print(f"  {nbytes:.3e}  {kind:18s} {shp:60s} {meta.group(1) if meta else name}")
# also top 'while' body ops get multiplied by trip count — note which are in body
mem = compiled.memory_analysis()
print("peak GiB/dev:", (mem.argument_size_in_bytes + mem.output_size_in_bytes + mem.temp_size_in_bytes - mem.alias_size_in_bytes)/2**30)

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
from repro.configs import get_arch, get_shape, strategy
from repro.launch.dryrun import _compile, _cost_triple
from repro.launch.mesh import make_production_mesh

arch = sys.argv[1]
cfg = get_arch(arch)
shape = get_shape("train_4k")
strat = strategy("ramora")
mesh = make_production_mesh(multi_pod=False)
prev = None
for u in (1, 2, 3):
    c = _compile(cfg.replace(remat=strat.remat, scan_unroll=u), shape, mesh, strat)
    f, b, cb, _ = _cost_triple(c)
    marg = "" if prev is None else f"  marginal: cb {cb-prev[2]:.3e} b {b-prev[1]:.3e} f {f-prev[0]:.3e}"
    print(f"u={u}: flops {f:.3e}  bytes {b:.3e}  cbytes {cb:.3e}{marg}", flush=True)
    prev = (f, b, cb)

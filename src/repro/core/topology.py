"""Hardware topology model — the chiplet-system analogue for TPU pods.

The paper's hierarchy (worker core → cluster → group/chiplet → multi-chiplet
2.5D system) maps onto (MXU → TPU chip → ICI pod → multi-pod). This module
holds the constants used by the roofline analysis and the link-level model
used to split collective traffic into intra-pod (ICI, the "NoC/mesh") and
inter-pod (the "D2D link") components.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ChipSpec:
    """TPU v5e-class chip (the dry-run target)."""
    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12     # FLOP/s per chip
    peak_fp32_flops: float = 98.5e12    # MXU fp32 ~ half bf16
    hbm_bytes: float = 16 * 1024**3
    hbm_bw: float = 819e9               # B/s
    ici_link_bw: float = 50e9           # B/s per link (~ the paper's D2D PHY bundle)
    ici_links_per_chip: int = 4         # 2D torus: ±x, ±y
    vmem_bytes: float = 128 * 1024**2


@dataclass(frozen=True)
class PodSpec:
    chip: ChipSpec = ChipSpec()
    chips_x: int = 16
    chips_y: int = 16
    # inter-pod (DCN / "D2D") — slower than ICI, like Occamy's narrow D2D link
    interpod_bw_per_chip: float = 12.5e9  # B/s per chip of pod-to-pod bandwidth

    @property
    def n_chips(self) -> int:
        return self.chips_x * self.chips_y

    @property
    def peak_flops(self) -> float:
        return self.n_chips * self.chip.peak_bf16_flops


CHIP = ChipSpec()
POD = PodSpec()


def dtype_peak_flops(dtype: str) -> float:
    """Peak FLOP/s per chip for a compute dtype (paper Fig. 4b analogue:
    halving precision doubles throughput; fp8 feeds the MXU at 2x bf16)."""
    return {
        "float32": CHIP.peak_fp32_flops,
        "bfloat16": CHIP.peak_bf16_flops,
        "float16": CHIP.peak_bf16_flops,
        "float8_e4m3fn": 2 * CHIP.peak_bf16_flops,
        "float8_e5m2": 2 * CHIP.peak_bf16_flops,
    }.get(str(dtype), CHIP.peak_bf16_flops)


def roofline_time(flops: float, bytes_hbm: float, bytes_collective: float,
                  n_chips: int, compute_dtype: str = "bfloat16") -> dict:
    """The three roofline terms (seconds) from the prompt-mandated formulas."""
    peak = dtype_peak_flops(compute_dtype)
    return {
        "compute_s": flops / (n_chips * peak),
        "memory_s": bytes_hbm / (n_chips * CHIP.hbm_bw),
        "collective_s": bytes_collective / (n_chips * CHIP.ici_link_bw),
    }

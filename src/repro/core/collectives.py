"""Explicit collective schedules — Ogopogo's in-router collectives (C5a) and
packed-stream gradient compression (C5c applied to gradient sync).

The paper pushes multicast/broadcast/barrier *into the network* (fork/join in
the routers). On a factored TPU mesh the analogue is staging collectives per
axis so each byte crosses the slow (inter-pod / "D2D") links exactly once at
1/pod_size of the volume:

  hierarchical all-reduce =
      reduce-scatter(intra-pod ICI) → all-reduce(inter-pod) → all-gather(intra)

All primitives are shard_map bodies usable inside jit, differentiable where
needed, and unit-tested on a CPU device mesh.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

PyTree = Any


def shard_map_compat(body, *, mesh: Mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions: new API (full-manual via
    ``axis_names``, ``check_vma``) when present, else the
    ``jax.experimental.shard_map`` spelling (always manual over every mesh
    axis, ``check_rep`` instead of ``check_vma``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             axis_names=set(mesh.axis_names), check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


# --------------------------------------------------------------------------
# hierarchical (in-network style) all-reduce
# --------------------------------------------------------------------------
def hierarchical_allreduce(x: jnp.ndarray, mesh: Mesh, *,
                           intra_axis: str = "data",
                           inter_axis: str = "pod") -> jnp.ndarray:
    """All-reduce over (intra × inter) staged per axis.

    Equivalent to ``psum(x, (intra, inter))`` but the inter-pod stage moves
    1/|intra| of the bytes — the flat crossbar-vs-mesh distinction of the
    paper, measurable in the HLO (benchmarks/fig7).
    """
    n_intra = mesh.shape[intra_axis]

    def body(xl):
        flat = xl.reshape(-1)
        pad = (-flat.shape[0]) % n_intra
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
        # stage 1: reduce-scatter inside the pod (fast ICI)
        mine = jax.lax.psum_scatter(flat.reshape(n_intra, -1), intra_axis,
                                    scatter_dimension=0, tiled=False)
        # stage 2: all-reduce my shard across pods (slow D2D, 1/n bytes)
        if inter_axis in mesh.shape:
            mine = jax.lax.psum(mine, inter_axis)
        # stage 3: all-gather inside the pod
        full = jax.lax.all_gather(mine, intra_axis, axis=0, tiled=False)
        out = full.reshape(-1)
        if pad:
            out = out[:-pad]
        return out.reshape(xl.shape)

    spec = P()
    # full-manual shard_map: jax rejects out_specs=P() when axis_names is a
    # strict subset of the mesh axes; manual-ing every axis keeps semantics
    # (inputs here are replicated) and sidesteps the partial-manual limits.
    return shard_map_compat(body, mesh=mesh, in_specs=spec,
                            out_specs=spec)(x)


def flat_allreduce(x: jnp.ndarray, mesh: Mesh, axes: tuple[str, ...]):
    """Single-stage all-reduce over all axes at once — the Occamy-era
    crossbar baseline for benchmarks/fig7."""
    def body(xl):
        return jax.lax.psum(xl, axes)

    return shard_map_compat(body, mesh=mesh, in_specs=P(),
                            out_specs=P())(x)


# --------------------------------------------------------------------------
# multicast / barrier (fork-join analogues)
# --------------------------------------------------------------------------
def multicast(x: jnp.ndarray, mesh: Mesh, axis: str, root: int = 0):
    """Broadcast root's value along ``axis`` (in-router fork)."""
    def body(xl):
        full = jax.lax.all_gather(xl, axis, axis=0, tiled=False)
        return full[root]

    return shard_map_compat(body, mesh=mesh, in_specs=P(),
                            out_specs=P())(x)


def barrier(mesh: Mesh, axes: tuple[str, ...]):
    """Join-then-fork barrier: a 1-element psum every rank must reach."""
    def body(t):
        return jax.lax.psum(t, axes)

    tok = jnp.ones((), jnp.int32)
    return shard_map_compat(body, mesh=mesh, in_specs=P(),
                            out_specs=P())(tok)


# --------------------------------------------------------------------------
# int8 gradient compression with error feedback — packed irregular streams
# (C5c) applied to gradient sync: 4x fewer bytes over the links. The absmax
# quantizer itself is the quant subsystem's (one implementation for the
# gradient channel, the weight containers, and the KV pools — repro.quant).
# --------------------------------------------------------------------------
from repro.quant import quantize_int8 as _quantize_int8  # noqa: E402


def compressed_psum(x: jnp.ndarray, mesh: Mesh, axes: tuple[str, ...],
                    err: jnp.ndarray | None = None):
    """Mean over ``axes`` with int8 on-the-wire compression + error feedback.

    Returns (mean_estimate fp32, new_error). The residual (x+err − dequant)
    re-enters next step's gradients — standard EF-SGD, here framed as the
    paper's narrow-to-wide stream packing for the gradient channel.
    """
    if err is not None:
        x = x + err
    n = 1
    for a in axes:
        n *= mesh.shape[a]

    def body(xl):
        q, scale = _quantize_int8(xl)
        local_err = xl - q.astype(jnp.float32) * scale
        # int8 crosses the links (the HLO all-gather operand is s8 — 4x fewer
        # bytes than an f32 ring all-reduce), scales are scalars
        qs = jax.lax.all_gather(q, axes, axis=0, tiled=False)      # (n, ...)
        ss = jax.lax.all_gather(scale, axes, axis=0, tiled=False)  # (n,)
        ss = ss.reshape((n,) + (1,) * xl.ndim)
        mean = (qs.astype(jnp.float32) * ss).sum(0) / n
        return mean, local_err

    return shard_map_compat(body, mesh=mesh, in_specs=P(),
                            out_specs=(P(), P()))(x)

"""Analytic per-device HBM traffic floor for the TPU target.

Why this exists: XLA:CPU's float-normalization + convert round-trips inflate
``cost_analysis()['bytes accessed']`` ~5x for bf16 tensors (calibrated on a
4096^2 matmul: bf16 reports 5.0x its 3*n^2*2B ideal, f32 reports 1.0x). The
CPU number is therefore recorded as a *diagnostic upper bound*, while the
roofline memory term uses this floor: every tensor the deployable TPU
artifact must move through HBM, counted once per necessary crossing:

- weights: FSDP all-gathered compute copies read per pass (fwd, remat
  recompute, bwd), plus the gather write;
- gradients: reduce-scattered shard, written + read in fp32;
- optimizer: masters + both Adam moments, read + written, fp32;
- activations: every layer-boundary tensor written + read per pass at its
  sharded size (block remat => fwd tensors are re-materialized once more);
- attention: FlashAttention-2 streaming — K/V re-read once per query chunk
  (scores/probabilities stay in VMEM: that is the Pallas kernel's contract,
  tested against ref.py);
- MoE: routed blocks at capacity, shared experts dense;
- SSM/RG-LRU: conv + scan inputs/outputs, chunk-resident recurrence;
- embedding gather rows + vocab-sharded logits in fp32 (chunking changes
  residency, not traffic);
- decode: full weight + KV-cache read per token, single-slot write.

Everything is per device: global tensor bytes divided by the mesh axes that
shard them. The floor is intentionally conservative *upward* (counts remat
re-reads, fp32 states) so "memory_s_floor" is not gameable by dropping work.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import LayerSpec, ModelConfig, ShapeConfig
from repro.core.roofline import traffic_dtype_bytes


def _weight_traffic_bytes(cfg: ModelConfig, fallback: float = 2.0) -> float:
    """Per-element HBM width of the weight stream: quantized serving
    (cfg.weight_dtype) reads storage width (int8/fp8 = 1, packed int4 =
    0.5), else the compute width. ``cfg.weight_density`` < 1 discounts the
    stream further — block-pruned weights (gemm_sparse) only move their
    kept blocks through HBM."""
    return traffic_dtype_bytes(cfg.weight_dtype, fallback) * cfg.weight_density


def _kv_traffic_bytes(cfg: ModelConfig, fallback: float = 2.0) -> float:
    """Per-element HBM width of the KV-cache stream. Quantized paged KV
    adds the per-row float16 scale overhead (2 bytes / head_dim elements)."""
    if not cfg.kv_dtype:
        return fallback
    hd = max(cfg.resolved_head_dim, 1)
    return traffic_dtype_bytes(cfg.kv_dtype, fallback) + 2.0 / hd


@dataclass(frozen=True)
class MeshSizes:
    n_data: int
    n_model: int
    n_pod: int = 1

    @property
    def n_chips(self) -> int:
        return self.n_data * self.n_model * self.n_pod


def _div(n: int, k: int) -> float:
    """Sharded size: divide if divisible, else replicated (matches the
    Partitioner's divisibility rule)."""
    return n / k if k > 1 and n % k == 0 else n


def _layer_weight_params(spec: LayerSpec, cfg: ModelConfig) -> float:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    p = 0.0
    if spec.mixer in ("full", "local"):
        p += d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
        if cfg.encoder is not None:
            p += d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
    elif spec.mixer == "rglru":
        w = cfg.rglru.lru_width or d
        p += 3 * d * w + w * cfg.rglru.d_conv + w + 2 * w * (w // 8)
    elif spec.mixer == "mamba":
        di = cfg.ssm.expand * d
        dtr = cfg.ssm.dt_rank or -(-d // 16)
        p += (d * 2 * di + di * cfg.ssm.d_conv + di * (dtr + 2 * cfg.ssm.d_state)
              + dtr * di + di * cfg.ssm.d_state + di + di * d)
    mult = 3 if cfg.gated_mlp else 2
    if spec.mlp == "dense":
        p += mult * d * cfg.d_ff
    elif spec.mlp == "moe":
        m = cfg.moe
        p += m.n_experts * mult * d * m.d_expert + d * m.n_experts
        if m.shared_hidden:
            p += mult * d * m.shared_hidden
    return p


def _layer_act_bytes(spec: LayerSpec, cfg: ModelConfig, b_loc: float, s: int,
                     mesh: MeshSizes, abytes: int = 2) -> float:
    """Activation HBM bytes for ONE forward pass of one layer (per device):
    each boundary tensor written once + read once => 2x its size."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nm = mesh.n_model
    tok = b_loc * s
    total = 0.0

    def t(elems: float, n_rw: float = 2.0, dtype_bytes: int = abytes):
        nonlocal total
        total += elems * n_rw * dtype_bytes

    if spec.mixer in ("full", "local"):
        t(tok * d)                                  # pre-norm out
        q = _div(cfg.n_heads, nm) * hd
        kv = _div(cfg.n_kv_heads, nm) * hd
        t(tok * (q + 2 * kv))                       # q,k,v
        # flash: K/V streamed once per q-chunk
        window = cfg.window if (spec.mixer == "local" and cfg.window) else s
        n_q = max(1, -(-s // max(cfg.attn_chunk, 1)))
        kv_eff = min(window, s)
        t(b_loc * kv_eff * 2 * kv * n_q, n_rw=1.0)  # kv re-reads
        t(tok * q)                                  # attn out
        t(tok * d)                                  # o_proj out (+residual)
        if cfg.encoder is not None:
            t(tok * d * 3)                          # cross-attn boundaries
    elif spec.mixer == "rglru":
        w = _div(cfg.rglru.lru_width or d, nm)
        t(tok * d)                                  # pre-norm
        t(tok * w * 4)                              # x,z branches, conv, gates
        t(tok * w, dtype_bytes=4)                   # fp32 scan h
        t(tok * d)                                  # out
    elif spec.mixer == "mamba":
        di = _div(cfg.ssm.expand * cfg.d_model, nm)
        t(tok * d)                                  # pre-norm
        t(tok * di * 2)                             # x, z
        t(tok * di)                                 # conv out
        t(tok * di, dtype_bytes=4)                  # fp32 scan states (chunked)
        t(tok * d)                                  # out

    mult = 3 if cfg.gated_mlp else 2
    if spec.mlp == "dense":
        ff = _div(cfg.d_ff, nm)
        t(tok * d)                                  # mlp norm
        t(tok * ff * (mult - 1))                    # gate/up
        t(tok * ff)                                 # h
        t(tok * d)                                  # down out
    elif spec.mlp == "moe":
        m = cfg.moe
        cap = m.top_k * m.capacity_factor           # tokens replicated k ways
        ff = _div(m.d_expert, 1)                    # expert dff kept whole; EP shards E
        t(tok * d)                                  # norm
        t(tok * cap * d, n_rw=4.0)                  # pack + unpack blocks
        t(tok * cap * ff * mult / max(
            1, (mesh.n_model if m.n_experts % mesh.n_model == 0 else 1)))
        if m.shared_hidden:
            t(tok * _div(m.shared_hidden, nm) * mult)
        t(tok * d)                                  # combine out
    return total


def hbm_bytes_floor(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshSizes,
                    *, fsdp: bool = True, dp: int | None = None,
                    tp: int | None = None) -> dict:
    """Per-device HBM bytes per step for the TPU target. Returns components.

    ``dp``/``tp`` are the *strategy's* actual data- and tensor-parallel
    degrees (ramora: 16/16; fsdp2d: 256/1) — the floor must follow the
    partitioner, not assume the mesh axes' roles."""
    dp = dp or mesh.n_data * mesh.n_pod
    tp = tp or mesh.n_model
    mesh = MeshSizes(n_data=max(dp // mesh.n_pod, 1), n_model=tp,
                     n_pod=mesh.n_pod)
    abytes = 2                                      # bf16 activations/weights
    layers = cfg.all_layers()
    w_params = sum(_layer_weight_params(sp, cfg) for sp in layers)
    embed_params = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    w_shard = _div(w_params, mesh.n_model)          # post-gather compute copy
    w_state_shard = (w_params + embed_params) / (
        dp * tp if fsdp else tp)

    if shape.kind == "train":
        b_loc = _div(shape.global_batch, dp)
        tok = b_loc * shape.seq_len
        # weights: gather write + read in fwd, recompute, bwd
        weights = w_shard * abytes * (1 + 3)
        # grads (fp32 shard w+r) + optimizer (masters, mu, nu r+w fp32)
        grads = w_state_shard * 4 * 2
        optimizer = w_state_shard * 4 * 3 * 2
        acts_fwd = sum(_layer_act_bytes(sp, cfg, b_loc, shape.seq_len, mesh)
                       for sp in layers)
        acts = acts_fwd * (1 + 1 + 2)               # fwd + remat + bwd(2x)
        v_loc = _div(cfg.vocab_size, mesh.n_model)
        logits = tok * v_loc * 4 * 3                # write, softmax read, bwd
        embed = tok * cfg.d_model * abytes * 2 * 2  # gather out fwd+bwd
        total = weights + grads + optimizer + acts + logits + embed
        return {"weights": weights, "grads": grads, "optimizer": optimizer,
                "activations": acts, "logits": logits, "embed": embed,
                "total": total}

    if shape.kind == "prefill":
        b_loc = _div(shape.global_batch, dp)
        tok = b_loc * shape.seq_len
        wb = _weight_traffic_bytes(cfg, abytes)     # quantized: storage width
        weights = w_shard * wb * 2                  # gather write + fwd read
        acts = sum(_layer_act_bytes(sp, cfg, b_loc, shape.seq_len, mesh)
                   for sp in layers)
        cache = _cache_bytes(cfg, b_loc, shape.seq_len, mesh)  # written once
        v_loc = _div(cfg.vocab_size, mesh.n_model)
        logits = b_loc * v_loc * 4 * 2              # last position only
        embed = tok * cfg.d_model * abytes * 2
        total = weights + acts + cache + logits + embed
        return {"weights": weights, "activations": acts, "cache": cache,
                "logits": logits, "embed": embed, "total": total}

    # decode: one token for every sequence; weights + full cache read —
    # exactly the two terms weight/KV quantization narrows
    b_glob = shape.global_batch
    b_loc = _div(b_glob, dp)
    weights = w_shard * _weight_traffic_bytes(cfg, abytes)  # read once per step
    cache = _cache_bytes(cfg, b_loc, shape.seq_len, mesh)
    acts = b_loc * cfg.d_model * len(layers) * abytes * 8
    v_loc = _div(cfg.vocab_size, mesh.n_model)
    logits = b_loc * v_loc * 4 * 2
    embed_w = _div(cfg.vocab_size, mesh.n_model) * cfg.d_model * abytes
    total = weights + cache + acts + logits
    return {"weights": weights, "cache": cache, "activations": acts,
            "logits": logits, "total": total}


def hbm_peak_floor(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshSizes,
                   *, fsdp: bool = True, loss_chunk: int = 0,
                   seq_shard: bool = False, dp: int | None = None,
                   tp: int | None = None) -> dict:
    """Analytic per-device PEAK residency for the TPU target (bf16 stays
    bf16 — XLA:CPU's ``memory_analysis`` holds f32-promoted copies of bf16
    buffers, so its peak over-states the TPU footprint)."""
    dp = dp or mesh.n_data * mesh.n_pod
    tp = tp or mesh.n_model
    mesh = MeshSizes(n_data=max(dp // mesh.n_pod, 1), n_model=tp,
                     n_pod=mesh.n_pod)
    abytes = 2
    layers = cfg.all_layers()
    w_params = sum(_layer_weight_params(sp, cfg) for sp in layers)
    embed_params = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    all_params = w_params + embed_params
    n_state = dp * tp if fsdp else tp
    per_layer_w = w_params / max(len(layers), 1)

    if shape.kind == "train":
        b_loc = _div(shape.global_batch, dp)
        state = all_params / n_state * 4 * 4        # master + mu + nu + grads
        gathered = per_layer_w * 2 * abytes / max(
            1, 1)                                   # ~2 blocks' weights live
        # remat carries: residual per scanned period (seq-sharded if SP)
        carry = b_loc * shape.seq_len * cfg.d_model * abytes
        if seq_shard:
            carry /= tp
        prefix, pattern, n_rep, rem = cfg.layer_specs()
        carries = carry * max(n_rep, 1)
        lc = loss_chunk or shape.seq_len
        v_loc = _div(cfg.vocab_size, mesh.n_model)
        logits = b_loc * min(lc, shape.seq_len) * v_loc * 4 * 2
        embed_c = _div(cfg.vocab_size, mesh.n_model) * cfg.d_model * abytes
        work = b_loc * shape.seq_len * max(cfg.d_model, _div(cfg.d_ff or 0, mesh.n_model)) * abytes * 6
        total = state + gathered + carries + logits + embed_c + work
        return {"state": state, "gathered_weights": gathered,
                "remat_carries": carries, "logits": logits,
                "embed_copy": embed_c, "working_set": work, "total": total}

    b_loc = _div(shape.global_batch, dp)
    weights = _div(all_params, tp) * _weight_traffic_bytes(cfg, abytes)
    cache = _cache_bytes(cfg, b_loc, shape.seq_len, mesh)
    s_act = shape.seq_len if shape.kind == "prefill" else 1
    work = b_loc * s_act * cfg.d_model * abytes * 8
    total = weights + cache + work
    return {"weights": weights, "cache": cache, "working_set": work,
            "total": total}


def d2d_bytes_serve_decode(cfg: ModelConfig, batch: int, kv_shard: int,
                           *, abytes: int = 2) -> dict:
    """Per-device die-to-die interconnect bytes for ONE sharded decode step.

    KV-head-sharded serving (core/sharding.py ``mode="serve"``) keeps decode
    math communication-free *inside* the attention op — heads are a batch
    dim — so the only cross-die traffic per step is:

    - **attention partial outputs**: each attention layer's per-shard head
      slice is all-gathered before the (replicated) output projection. An
      all-gather of an ``N``-way-sharded tensor moves ``size × (N-1)/N``
      bytes through each device's links;
    - **sampled ids**: the fused sampler runs on replicated logits, so the
      per-step id exchange is one ``int32`` per sequence (bounded above by
      the same ``(N-1)/N`` all-gather factor — negligible next to the
      activation term, kept for completeness).

    Sharded-KV *reads* stay local HBM traffic by design (that is the point
    of sharding the pool by KV head) — they show up in ``_cache_bytes``
    divided by ``kv_shard``, never on the interconnect. ``kv_shard <= 1``
    returns zeros: replicated pools do no d2d work.
    """
    n = max(int(kv_shard), 1)
    if n == 1:
        return {"attn_out_allgather": 0.0, "sampled_ids": 0.0, "total": 0.0}
    frac = (n - 1) / n
    hd = cfg.resolved_head_dim
    n_attn = sum(1 for sp in cfg.all_layers() if sp.mixer in ("full", "local"))
    attn = batch * cfg.n_heads * hd * abytes * n_attn * frac
    ids = batch * 4 * frac
    return {"attn_out_allgather": attn, "sampled_ids": ids,
            "total": attn + ids}


def _cache_bytes(cfg: ModelConfig, b_loc: float, s: int, mesh: MeshSizes
                 ) -> float:
    """KV/recurrent cache bytes per device (read in decode / written in
    prefill). Honors window ring buffers, head/length sharding, and the
    quantized-KV storage width (cfg.kv_dtype, scale overhead included)."""
    hd = cfg.resolved_head_dim
    nm = mesh.n_model
    kvb = _kv_traffic_bytes(cfg, 2.0)
    total = 0.0
    for sp in cfg.all_layers():
        if sp.mixer in ("full", "local"):
            s_buf = min(cfg.window, s) if (sp.mixer == "local" and cfg.window) else s
            kv = cfg.n_kv_heads
            # only the paged full-attention pools store quantized KV
            # (models/cache.py); local ring buffers stay at compute width
            lb = kvb if sp.mixer == "full" else 2.0
            if kv % nm == 0:
                per = b_loc * s_buf * (kv / nm) * hd * 2 * lb
            else:
                per = b_loc * (s_buf / nm) * kv * hd * 2 * lb  # length-sharded
            total += per
            if cfg.encoder is not None:
                total += b_loc * cfg.encoder.n_frames * kv * hd * 2 * 2
        elif sp.mixer == "rglru":
            w = cfg.rglru.lru_width or cfg.d_model
            total += b_loc * (w / nm if w % nm == 0 else w) * (4 + 2 * cfg.rglru.d_conv)
        elif sp.mixer == "mamba":
            di = cfg.ssm.expand * cfg.d_model
            di_l = di / nm if di % nm == 0 else di
            total += b_loc * (di_l * cfg.ssm.d_state * 4 + di_l * cfg.ssm.d_conv * 2)
    return total

"""Logical-axis sharding rules → NamedShardings (the "NoC routing table").

The ``Partitioner`` maps logical tensor axes (batch/seq/heads/mlp/vocab/
experts/kv) and parameter paths to mesh axes according to the active strategy
(occamy = flat crossbar-era DP; ramora = factored 2D mesh TP+FSDP;
ogopogo = + pod axis, sequence sharding, hierarchical collectives).

Divisibility is checked per dim: when a dim does not divide by the assigned
mesh axes, the axis is dropped (replicated) rather than padded — e.g. qwen3's
8 KV heads on a 16-way model axis, or qwen2-moe's 60 experts.
"""
from __future__ import annotations

import logging
import math
import re
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, StrategyConfig

PyTree = Any

log = logging.getLogger("repro.sharding")


def abstract_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """``jax.sharding.AbstractMesh`` across jax versions: the new
    ``(shape, axis_names)`` spelling when accepted, else the 0.4.x
    ``((name, size), ...)`` shape-tuple form."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    return math.prod(mesh.shape[a] for a in axes)


class Partitioner:
    def __init__(self, mesh: Mesh, strategy: StrategyConfig, cfg: ModelConfig,
                 shape: ShapeConfig | None = None, mode: str = "train"):
        self.mesh = mesh
        self.strategy = strategy
        self.cfg = cfg
        self.shape = shape
        self.mode = mode
        # divisibility drops recorded per (label, dim): surfaced by
        # launch/serve.py so serve-mode misconfigs (e.g. 8 KV heads on a
        # 16-way model axis) are visible instead of silently replicating.
        self.dropped: list[dict] = []
        self._drop_seen: set = set()
        st = strategy
        have_pod = "pod" in mesh.shape
        if mode == "serve":
            # Serving data plane: params and activations replicated, the
            # paged KV block pools (and per-row quant scales) sharded by KV
            # head over 'model'. Block tables / lengths / per-slot scalars
            # stay replicated scalar-prefetch operands.
            pool = ("model",) if "model" in mesh.shape else None
            self.axis_map = {"batch": None, "seq": None, "heads": None,
                             "kv": None, "mlp": None, "vocab": None,
                             "experts": None, "fsdp": None, "tp": None,
                             "expert": None, "embed_fsdp": None,
                             "kv_pool": pool}
        elif st.name == "occamy":
            # flat crossbar-era: every chip is a DP rank, params replicated
            flat = tuple(a for a in (("pod",) if have_pod else ())
                         + ("data", "model"))
            self.axis_map = {"batch": flat, "seq": None, "heads": None,
                             "kv": None, "mlp": None, "vocab": None,
                             "experts": None, "fsdp": None, "tp": None,
                             "expert": None, "embed_fsdp": None}
        else:
            batch = (("pod", "data") if have_pod else ("data",))
            train_like = mode in ("train", "prefill")
            seq_shard = ("model",) if (st.seq_shard and train_like) else None
            fsdp = ("data",) if (st.fsdp and train_like) else None
            tp = ("model",) if st.tensor_parallel else None
            ep = None
            if (st.expert_parallel and cfg.moe is not None
                    and cfg.moe.n_experts % mesh.shape["model"] == 0):
                ep = ("model",)
            if not st.tensor_parallel and st.fsdp:
                # fsdp2d: the 'model' axis joins data parallelism — batch
                # over every axis, params fully sharded over both, zero
                # per-layer activation psums. MoE archs keep EP over 'model'
                # (2D-EP: the expert shard_map all-gathers its data-row's
                # tokens over 'model' and reduce-scatters outputs back).
                batch = batch + ("model",)
                if fsdp is not None:
                    fsdp = fsdp + ("model",)
            kv_axes: list[str] = []
            if (mode in ("decode", "prefill") and st.context_parallel_decode
                    and shape is not None):
                if shape.global_batch < _axes_size(mesh, ("data",)):
                    kv_axes.append("data")  # context-parallel cache (long_500k)
                if tp and cfg.n_kv_heads and cfg.n_kv_heads % mesh.shape["model"]:
                    # heads unshardable -> cache LENGTH over 'model' instead
                    # (prefill writes it, flash-decoding style reads it)
                    kv_axes.append("model")
            self.axis_map = {"batch": batch, "seq": seq_shard, "heads": tp,
                             "kv": tuple(kv_axes) or None, "mlp": tp,
                             "vocab": tp, "experts": ep, "fsdp": fsdp,
                             "tp": tp, "expert": ep, "embed_fsdp": fsdp,
                             "seq_cp": tp, "cap": tp}

    # ------------------------------------------------------------------
    # activations
    # ------------------------------------------------------------------
    def axis_size(self, name: str) -> int:
        return self.mesh.shape.get(name, 1)

    def logical_size(self, name: str) -> int:
        return _axes_size(self.mesh, self.axis_map.get(name))

    def spec(self, logical: tuple, shape: tuple | None = None,
             label: str | None = None) -> P:
        parts = []
        used: set = set()
        for i, name in enumerate(logical):
            axes = self.axis_map.get(name) if name else None
            if axes:
                # a mesh axis may appear once per spec: drop re-used axes
                # (e.g. fsdp2d expert weights: dim0 experts->model, dim1
                # fsdp->(data,model) -> dim1 keeps only 'data')
                axes = tuple(a for a in axes if a not in used)
            if axes and shape is not None and shape[i] % _axes_size(self.mesh, axes):
                self._note_drop(label or name, i, axes, shape[i])
                axes = None  # not divisible -> replicate
            if axes:
                parts.append(axes[0] if len(axes) == 1 else tuple(axes))
                used.update(axes)
            else:
                parts.append(None)
        return P(*parts)

    def _note_drop(self, label: str, dim: int, axes: tuple, size: int) -> None:
        key = (label, dim, axes)
        if key in self._drop_seen:
            return
        self._drop_seen.add(key)
        rec = {"label": label, "dim": dim, "axes": list(axes), "size": size,
               "axis_size": _axes_size(self.mesh, axes)}
        self.dropped.append(rec)
        log.warning("sharding drop: %s dim %d (size %d) not divisible by "
                    "mesh axes %s (x%d) -> replicated", label, dim, size,
                    axes, rec["axis_size"])

    def act(self, x: jnp.ndarray, logical: tuple) -> jnp.ndarray:
        s = self.spec(logical, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, s))

    def named(self, logical: tuple, shape: tuple | None = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape))

    # ------------------------------------------------------------------
    # parameters — path-based rules
    # ------------------------------------------------------------------
    # (regex on 'a/b/c' joined path) -> logical names per dim (trailing dims
    # beyond the rule are replicated). First match wins.
    PARAM_RULES: list[tuple[str, tuple]] = [
        (r"embed/table$", ("vocab", "embed_fsdp")),
        (r"pos_embed/table$", (None, "embed_fsdp")),
        (r"lm_head/kernel$", ("fsdp", "vocab")),
        (r"(q_proj|k_proj|v_proj)/kernel$", ("fsdp", "tp")),
        (r"o_proj/kernel$", ("tp", "fsdp")),
        (r"(up|gate)/kernel$", ("fsdp", "tp")),
        (r"down/kernel$", ("tp", "fsdp")),
        (r"router/kernel$", ("fsdp", None)),
        (r"experts/(gate|up)$", ("expert", "fsdp", "tp")),
        (r"experts/down$", ("expert", "tp", "fsdp")),
        (r"(x_proj|gate_proj|in_proj)/kernel$", ("fsdp", "tp")),
        (r"out_proj/kernel$", ("tp", "fsdp")),
        (r"conv/kernel$", ("tp", None)),
        (r"(a_gate|x_gate)/kernel$", (None, "fsdp", None)),
        (r"dt_proj/kernel$", ("fsdp", "tp")),
        (r"dt_proj/bias$", ("tp",)),
        (r"A_log$", ("tp", None)),
        (r"/(D|lam)$", ("tp",)),
    ]

    def _param_spec(self, path: str, ndim: int, shape: tuple,
                    drop: tuple = ()) -> P:
        # stacked scan blocks carry a leading n_rep dim not covered by rules
        lead: tuple = (None,) if path.startswith("blocks/") else ()
        for pat, logical in self.PARAM_RULES:
            if re.search(pat, path):
                logical = lead + logical
                logical = logical + (None,) * (ndim - len(logical))
                if (path.endswith(("experts/gate", "experts/up", "experts/down"))
                        and self.axis_map.get("expert")):
                    logical = tuple(None if l == "tp" else l for l in logical)
                if drop:
                    logical = tuple(None if l in drop else l for l in logical)
                return self.spec(logical[:ndim], shape)
        return P(*([None] * ndim))  # norms, small vectors

    def params_sharding(self, params_tree: PyTree) -> PyTree:
        def f(path, leaf):
            pstr = "/".join(_key_str(k) for k in path)
            return NamedSharding(self.mesh,
                                 self._param_spec(pstr, leaf.ndim, leaf.shape))
        return jax.tree_util.tree_map_with_path(f, params_tree)

    def gather_block(self, layer_params: PyTree, compute_dtype) -> PyTree:
        """ZeRO-3-style per-block weight gather: constrain the compute-dtype
        copy of each ≥2D weight to its FSDP-free sharding so XLA all-gathers
        the (small) weights once per block instead of partial-summing (large)
        activations. Paths here are relative to one layer."""
        def f(path, leaf):
            if leaf.ndim < 2:
                return leaf
            pstr = "/".join(_key_str(k) for k in path)
            spec = self._param_spec(pstr, leaf.ndim, leaf.shape,
                                    drop=("fsdp", "embed_fsdp"))
            if "kernel" in pstr or "experts/" in pstr:
                leaf = leaf.astype(compute_dtype)  # A_log/lam etc. stay fp32
            return jax.lax.with_sharding_constraint(
                leaf, NamedSharding(self.mesh, spec))
        return jax.tree_util.tree_map_with_path(f, layer_params)

    # ------------------------------------------------------------------
    # batches / caches
    # ------------------------------------------------------------------
    def batch_sharding(self, batch_tree: PyTree) -> PyTree:
        def f(leaf):
            logical = ("batch",) + (None,) * (leaf.ndim - 1)
            return self.named(logical, leaf.shape)
        return jax.tree.map(f, batch_tree)

    def cache_sharding(self, cache_tree: PyTree) -> PyTree:
        def f(path, leaf):
            pstr = "/".join(_key_str(k) for k in path)
            nd = leaf.ndim
            # stacked block caches have a leading n_rep dim
            stacked = "blocks" in pstr
            if re.search(r"(self|cross)/(k|v)$", pstr):
                base = ("batch", "kv", "heads", None)
            elif pstr.endswith("/h"):
                base = ("batch", "mlp")
            elif pstr.endswith("/conv"):
                base = ("batch", None, "mlp")
            else:
                base = ("batch",) + (None,) * 3
            logical = (((None,) + base) if stacked else base)[:nd]
            logical = logical + (None,) * (nd - len(logical))
            return self.named(logical, leaf.shape)
        return jax.tree_util.tree_map_with_path(f, cache_tree)

    def scalar_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    # ------------------------------------------------------------------
    # serving (mode="serve"): paged KV block pools
    # ------------------------------------------------------------------
    @property
    def kv_shard(self) -> int:
        """How many ways the paged KV pools shard over 'model' (by KV head).

        1 when the mode is not "serve", the mesh has no model axis, or the
        KV head count does not divide it (divisibility-drop -> replicated).
        """
        if self.mode != "serve" or "model" not in self.mesh.shape:
            return 1
        n = self.mesh.shape["model"]
        kv = self.cfg.n_kv_heads or self.cfg.n_heads
        return n if n > 1 and kv % n == 0 else 1

    def _pool_logical(self, path: str, shape: tuple) -> tuple | None:
        """Logical axes for a paged block-pool leaf, or None if not a pool.

        Pools are ``(n_blocks, page, K, hd)`` (+ a leading n_rep dim for
        scan-stacked blocks); per-row quant scales are ``(n_blocks, page,
        K)``. Both shard dim K ('kv_pool' -> model) by KV head.
        """
        kv = self.cfg.n_kv_heads or self.cfg.n_heads
        lead = ("blocks" in path)
        nd = len(shape) - (1 if lead else 0)
        if nd not in (3, 4) or shape[-1 if nd == 3 else -2] != kv:
            return None
        base = (None, None, "kv_pool") + ((None,) if nd == 4 else ())
        return ((None,) + base) if lead else base

    def serve_cache_sharding(self, cache_tree: PyTree,
                             n_blocks: int) -> PyTree:
        """NamedShardings for a serving cache: block pools (and quant
        scales) sharded by KV head over 'model', everything else (dense
        ring buffers, positions) replicated."""
        def f(path, leaf):
            pstr = "/".join(_key_str(k) for k in path)
            if n_blocks and leaf.ndim >= 3:
                lead = ("blocks" in pstr)
                if leaf.shape[1 if lead else 0] == n_blocks:
                    logical = self._pool_logical(pstr, leaf.shape)
                    if logical is not None:
                        return self.named(logical, leaf.shape)
            return NamedSharding(self.mesh, P(*([None] * leaf.ndim)))
        return jax.tree_util.tree_map_with_path(f, cache_tree)

    def serve_cache_constraint(self, cache_tree: PyTree,
                               shardings: PyTree) -> PyTree:
        """Pin a cache pytree to its serve shardings inside a jitted graph
        so donation keeps a stable layout across engine steps."""
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s),
            cache_tree, shardings)

    def serve_kv_scope(self):
        """Context manager advertising the sharded pool layout to the kernel
        registry (read by the sharded ``paged_attention`` ``supports()``).
        No-op (null context) when the pools are replicated."""
        import contextlib

        from repro.kernels import dispatch as kdispatch
        if self.kv_shard <= 1:
            return contextlib.nullcontext()
        return kdispatch.serve_mesh_scope(self.mesh, "model")


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)

"""Roofline analysis from compiled XLA artifacts.

compute term    = HLO_FLOPs / (chips × peak FLOP/s)
memory term     = HLO bytes accessed / (chips × HBM bw)
collective term = Σ collective operand bytes / (chips × link bw)

``cost_analysis()`` supplies FLOPs and bytes; collective bytes are parsed from
the post-SPMD HLO text (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute), since XLA's cost model does not expose them. We also
split collective traffic by replica-group span into intra-pod ("NoC/ICI") and
inter-pod ("D2D") components — the paper's two interconnect levels.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict

from repro.core.topology import CHIP, dtype_peak_flops

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                   "collective-permute")


def traffic_dtype_bytes(name: str, fallback: float = 2.0) -> float:
    """Bytes per element a tensor of dtype ``name`` moves through HBM.

    Accepts the quant subsystem's names and aliases ("int8", "fp8",
    "float8_e4m3fn") alongside the usual jnp dtype names; an empty name
    returns ``fallback`` (the bf16 compute width). This is what makes the
    analytic byte terms (core/memfloor.py) follow ``ModelConfig.weight_dtype``
    / ``kv_dtype`` instead of hardcoding the dense parameter width — the
    roofline's memory term then tracks quantized serving runs, where weight
    and KV traffic are exactly the terms quantization shrinks.
    """
    if not name:
        return fallback
    from repro.quant import dtype_bytes
    return float(dtype_bytes(name))


def sparse_gemm_terms(m: int, k: int, n: int, *, density: float = 1.0,
                      weight_bytes_elem: float = 2.0,
                      act_bytes_elem: float = 2.0,
                      mask_block: tuple[int, int] | None = None) -> dict:
    """Analytic FLOP/byte terms for one (block-)sparse GEMM ``(M,K)@(K,N)``.

    ``density`` is the kept fraction of weight blocks (1.0 = dense, 0.5 =
    2:4). FLOPs and the weight stream scale linearly with it — a skipped
    block is neither multiplied nor fetched — while activations and the
    output are dense either way. ``mask_block`` adds the (tiny) metadata
    stream: one byte per (bs_k, bs_n) block for a block mask, or for 2:4
    pass ``mask_block=None`` and the K/2×N int8 index plane is folded into
    ``weight_bytes``. Used by benchmarks/sparse_gemm.py to check that the
    measured kernel cost actually tracks density.
    """
    if not 0.0 < density <= 1.0:
        raise ValueError(f"density must be in (0, 1], got {density}")
    flops = 2.0 * m * k * n * density
    weight_bytes = k * n * weight_bytes_elem * density
    mask_bytes = 0.0
    if mask_block is not None:
        bs_k, bs_n = mask_block
        mask_bytes = math.ceil(k / bs_k) * math.ceil(n / bs_n) * 1.0
    act_bytes = m * k * act_bytes_elem
    out_bytes = m * n * act_bytes_elem
    total = weight_bytes + mask_bytes + act_bytes + out_bytes
    return {"flops": flops, "weight_bytes": weight_bytes,
            "mask_bytes": mask_bytes, "act_bytes": act_bytes,
            "out_bytes": out_bytes, "total_bytes": total,
            "intensity": flops / total if total else 0.0}


def _shape_bytes(shape_str: str) -> int:
    """'f32[16,128]' -> bytes. '(f32[..], u8[..])' handled by caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the (post-SPMD) HLO.

    Returns per-op-kind byte totals plus op counts. Operand bytes are taken
    from the op's *result* shape for all-reduce/permute (same size), and from
    result shape for all-gather (full gathered bytes) / reduce-scatter
    (pre-scatter bytes are result×group — we use the conservative result size
    and record group sizes separately).
    """
    per_kind_bytes: dict[str, int] = defaultdict(int)
    per_kind_count: dict[str, int] = defaultdict(int)
    groups_re = re.compile(r"replica_groups=\{?\{([^}]*)\}")
    lines_seen = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) (all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(?:-start|-done)?\(", ls)
        if not m:
            continue
        if "-done(" in ls:  # avoid double counting async start/done pairs
            continue
        shape_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_str)
        per_kind_bytes[kind] += nbytes
        per_kind_count[kind] += 1
        lines_seen += 1
    return {"bytes_by_kind": dict(per_kind_bytes),
            "count_by_kind": dict(per_kind_count),
            "total_bytes": int(sum(per_kind_bytes.values())),
            "n_ops": lines_seen}


def model_flops(arch: str, shape_name: str) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode counts one token per seq."""
    from repro.configs import get_arch, get_shape

    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    pc = cfg.param_count()
    n = pc["nonembed_active"] + pc["embedding"]  # lm head matmul counts
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # one new token per sequence
    return 2.0 * n * tokens


def analyze_costs(*, flops_per_dev: float, bytes_per_dev: float,
                  collective_bytes_per_dev: float, collectives: dict,
                  arch: str, shape: str, n_chips: int,
                  compute_dtype: str = "bfloat16",
                  memory_floor_bytes_per_dev: float | None = None,
                  d2d_bytes_per_dev: float | None = None) -> dict:
    """Roofline terms. Note: XLA ``cost_analysis()`` and the post-SPMD HLO are
    per-partition (per-device) quantities; globals are ×n_chips, so the
    prompt's "global / (chips × peak)" formulas reduce to per-device / peak.

    The memory term uses the analytic TPU floor (core/memfloor.py) when
    provided: XLA:CPU float-normalization inflates bf16 "bytes accessed" ~5x
    (calibrated), so the CPU number is kept as ``memory_s_xla_cpu_upper``.

    ``d2d_bytes_per_dev`` (analytic, ``memfloor.d2d_bytes_serve_decode``)
    adds a fourth **die-to-die interconnect** term for KV-head-sharded
    serving — the per-step all-gather of attention partial outputs and
    sampled ids over the ICI/D2D links; omit it (the default) and the
    roofline is exactly the three-term model.
    """
    flops_global = flops_per_dev * n_chips
    bytes_global = bytes_per_dev * n_chips
    cbytes_global = collective_bytes_per_dev * n_chips
    peak = dtype_peak_flops(compute_dtype)
    compute_s = flops_global / (n_chips * peak)
    memory_s_xla = bytes_global / (n_chips * CHIP.hbm_bw)
    memory_s = memory_s_xla
    if memory_floor_bytes_per_dev is not None:
        memory_s = memory_floor_bytes_per_dev / CHIP.hbm_bw
    collective_s = cbytes_global / (n_chips * CHIP.ici_link_bw)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    if d2d_bytes_per_dev is not None:
        terms["d2d_s"] = d2d_bytes_per_dev / CHIP.ici_link_bw
    bottleneck = max(terms, key=terms.get).replace("_s", "")
    step_s = max(terms.values())
    mf = model_flops(arch, shape)
    return {
        "cost": {"hlo_flops_global": flops_global,
                 "hlo_bytes_global": bytes_global,
                 "collective_bytes_global": cbytes_global,
                 "collectives_u1": collectives},
        "roofline": {**terms, "bottleneck": bottleneck,
                     "memory_s_xla_cpu_upper": memory_s_xla,
                     "memory_floor_bytes_per_dev": memory_floor_bytes_per_dev,
                     "step_time_lower_bound_s": step_s,
                     "roofline_fraction": (compute_s / step_s) if step_s else 0.0,
                     "model_flops": mf,
                     "useful_flops_ratio": (mf / flops_global) if flops_global
                     else 0.0},
    }

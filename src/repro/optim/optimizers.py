"""Optimizers (no external deps): AdamW, SGD-momentum, Adafactor-lite.

Functional API mirroring optax: ``opt.init(params) -> state``;
``opt.update(grads, state, params, step) -> (updates, state)``. Optimizer
states inherit the parameter shardings (FSDP/TP), so ZeRO-style sharded
optimizer state falls out of the partitioner for free.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jnp.ndarray], tuple[PyTree, PyTree]]
    name: str = "opt"


def _tree_zeros_like(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def adamw(lr: float | Callable = 1e-3, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          decay_mask: Callable | None = None) -> Optimizer:
    """AdamW with fp32 moments. ``lr`` may be a schedule fn(step)->lr."""
    def init(params):
        return {"mu": _tree_zeros_like(params), "nu": _tree_zeros_like(params)}

    def update(grads, state, params, step):
        lr_t = lr(step) if callable(lr) else lr
        b1t = 1.0 - b1 ** (step.astype(jnp.float32) + 1.0)
        b2t = 1.0 - b2 ** (step.astype(jnp.float32) + 1.0)

        def upd(g, mu, nu, p):
            g32 = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g32
            nu = b2 * nu + (1 - b2) * jnp.square(g32)
            mhat = mu / b1t
            nhat = nu / b2t
            step_v = mhat / (jnp.sqrt(nhat) + eps)
            wd = weight_decay
            if decay_mask is not None:
                wd = wd * decay_mask(p)
            step_v = step_v + wd * p.astype(jnp.float32)
            return (-lr_t * step_v).astype(p.dtype), mu, nu

        flat_u, flat_mu, flat_nu = [], [], []
        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_mu = treedef.flatten_up_to(state["mu"])
        leaves_nu = treedef.flatten_up_to(state["nu"])
        leaves_p = treedef.flatten_up_to(params)
        for g, mu, nu, p in zip(leaves_g, leaves_mu, leaves_nu, leaves_p):
            u, mu, nu = upd(g, mu, nu, p)
            flat_u.append(u)
            flat_mu.append(mu)
            flat_nu.append(nu)
        return (jax.tree.unflatten(treedef, flat_u),
                {"mu": jax.tree.unflatten(treedef, flat_mu),
                 "nu": jax.tree.unflatten(treedef, flat_nu)})

    return Optimizer(init=init, update=update, name="adamw")


def sgdm(lr: float | Callable = 1e-2, *, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"mom": _tree_zeros_like(params)}

    def update(grads, state, params, step):
        lr_t = lr(step) if callable(lr) else lr

        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return (-lr_t * m).astype(p.dtype), m

        pairs = jax.tree.map(upd, grads, state["mom"], params)
        updates = jax.tree.map(lambda t: t[0], pairs,
                               is_leaf=lambda t: isinstance(t, tuple))
        mom = jax.tree.map(lambda t: t[1], pairs,
                           is_leaf=lambda t: isinstance(t, tuple))
        return updates, {"mom": mom}

    return Optimizer(init=init, update=update, name="sgdm")


def adafactor_lite(lr: float | Callable = 1e-2, *, eps: float = 1e-30,
                   decay: float = 0.8) -> Optimizer:
    """Factored second-moment optimizer (memory-lean, for the largest archs)."""
    def init(params):
        def f(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return {"fac": jax.tree.map(f, params)}

    def update(grads, state, params, step):
        lr_t = lr(step) if callable(lr) else lr
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(g, s, p):
            g2 = jnp.square(g.astype(jnp.float32)) + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(-1)[..., None, None], eps))
                u = g.astype(jnp.float32) / jnp.sqrt(denom + eps)
                return (-lr_t * u).astype(p.dtype), {"vr": vr, "vc": vc}
            v = beta * s["v"] + (1 - beta) * g2
            u = g.astype(jnp.float32) / jnp.sqrt(v + eps)
            return (-lr_t * u).astype(p.dtype), {"v": v}

        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_s = treedef.flatten_up_to(state["fac"])
        leaves_p = treedef.flatten_up_to(params)
        us, ss = [], []
        for g, s, p in zip(leaves_g, leaves_s, leaves_p):
            u, s2 = upd(g, s, p)
            us.append(u)
            ss.append(s2)
        return (jax.tree.unflatten(treedef, us),
                {"fac": jax.tree.unflatten(treedef, ss)})

    return Optimizer(init=init, update=update, name="adafactor")


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def global_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def get_optimizer(name: str, lr) -> Optimizer:
    return {"adamw": adamw, "sgdm": sgdm, "adafactor": adafactor_lite}[name](lr)

"""LR schedules, including MiniCPM's WSD (warmup-stable-decay) [arXiv:2404.06395]."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup: int):
    return jnp.minimum(1.0, (step.astype(jnp.float32) + 1.0) / max(warmup, 1))


def cosine(base_lr: float, *, warmup: int, total: int, min_ratio: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        w = linear_warmup(step, warmup)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * w * cos
    return f


def wsd(base_lr: float, *, warmup: int, stable: int, decay: int,
        min_ratio: float = 0.01):
    """Warmup-Stable-Decay (MiniCPM): linear warmup, flat plateau, then
    exponential-style decay over the final ``decay`` steps."""
    def f(step):
        s = step.astype(jnp.float32)
        w = linear_warmup(step, warmup)
        in_decay = s > (warmup + stable)
        prog = jnp.clip((s - warmup - stable) / max(decay, 1), 0.0, 1.0)
        decay_mult = jnp.where(in_decay, min_ratio ** prog, 1.0)
        return base_lr * w * decay_mult
    return f


def constant(base_lr: float, *, warmup: int = 0):
    def f(step):
        return base_lr * linear_warmup(step, warmup)
    return f


def get_schedule(name: str, base_lr: float, total: int):
    if name == "wsd":
        return wsd(base_lr, warmup=total // 100 + 1, stable=int(total * 0.9),
                   decay=max(total // 10, 1))
    if name == "cosine":
        return cosine(base_lr, warmup=total // 100 + 1, total=total)
    return constant(base_lr)

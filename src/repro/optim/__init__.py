from repro.optim.optimizers import (Optimizer, adafactor_lite, adamw,
                                    apply_updates, clip_by_global_norm,
                                    get_optimizer, global_norm, sgdm)
from repro.optim.schedules import constant, cosine, get_schedule, wsd

__all__ = ["Optimizer", "adafactor_lite", "adamw", "apply_updates",
           "clip_by_global_norm", "constant", "cosine", "get_optimizer",
           "get_schedule", "global_norm", "sgdm", "wsd"]

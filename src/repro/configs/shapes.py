"""Assigned input shapes (identical across all 10 LM-family archs)."""
from __future__ import annotations

from repro.configs.base import ShapeConfig

TRAIN_4K = ShapeConfig("train_4k", kind="train", seq_len=4096, global_batch=256)
PREFILL_32K = ShapeConfig("prefill_32k", kind="prefill", seq_len=32768, global_batch=32)
DECODE_32K = ShapeConfig("decode_32k", kind="decode", seq_len=32768, global_batch=128)
LONG_500K = ShapeConfig("long_500k", kind="decode", seq_len=524288, global_batch=1)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]

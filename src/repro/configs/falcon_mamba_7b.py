"""Assigned architecture config (exact sizes from the assignment)."""
from repro.configs.base import (EncoderConfig, LayerSpec, ModelConfig,
                                MoEConfig, RGLRUConfig, SSMConfig)

# --------------------------------------------------------------------------
# ssm  [arXiv:2410.05355; hf tiiuae/falcon-mamba-7b] mamba1 arch
# --------------------------------------------------------------------------
FALCON_MAMBA_7B = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=65024,
    pattern=(LayerSpec("mamba", "none"),),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, dt_rank=256),
    use_rope=False,
)

CONFIG = FALCON_MAMBA_7B

"""Config dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; shapes
(train/prefill/decode/long-context) are ``ShapeConfig``; the distribution
strategy (occamy/ramora/ogopogo — the paper's three generations) is a
``StrategyConfig``.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field


# --------------------------------------------------------------------------
# Layer specs
# --------------------------------------------------------------------------
# mixer: "full" | "local" | "rglru" | "mamba" | "cross" (enc-dec decoder adds
#        cross attention automatically when cfg.encoder is set)
# mlp:   "dense" | "moe" | "none"
@dataclass(frozen=True)
class LayerSpec:
    mixer: str = "full"
    mlp: str = "dense"


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    n_shared: int = 0
    d_expert: int = 1408          # per-expert FFN hidden size
    d_shared: int = 0             # total shared-expert hidden (0 => n_shared*d_expert)
    capacity_factor: float = 1.25
    renorm_topk: bool = True      # renormalize top-k gate weights (deepseek: yes, qwen2moe: no)
    shared_gate: bool = False     # qwen2-moe gates the shared expert output
    router_dtype: str = "float32"

    @property
    def shared_hidden(self) -> int:
        return self.d_shared if self.d_shared else self.n_shared * self.d_expert


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 => ceil(d_model / 16)
    chunk: int = 256              # selective-scan chunk length


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0            # 0 => d_model
    d_conv: int = 4
    block_width: int = 0          # 0 => d_ff of the gated branch (uses cfg.d_ff)
    c_exponent: float = 8.0
    chunk: int = 256


@dataclass(frozen=True)
class EncoderConfig:
    n_layers: int = 4
    n_frames: int = 1500          # encoder sequence length (precomputed frontend frames)
    is_causal: bool = False


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"         # dense | hybrid | moe | ssm | audio | vlm
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 32000
    head_dim: int = 0             # 0 => d_model // n_heads
    # layer layout: prefix (unrolled) + pattern (scanned) + remainder (unrolled)
    prefix: tuple[LayerSpec, ...] = ()
    pattern: tuple[LayerSpec, ...] = (LayerSpec("full", "dense"),)
    # attention details
    window: int = 0               # sliding window for "local" mixers
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    attn_scale: float = 0.0       # 0 => 1/sqrt(head_dim); gemma2-27b: 144
    sandwich_norms: bool = False  # gemma2: pre+post norms around attn/mlp
    qk_norm: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True         # rotary positions
    learned_pos: bool = False     # whisper: learned absolute positions
    max_position: int = 1 << 16   # learned-position table size
    # blocks
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    encoder: EncoderConfig | None = None
    frontend: str | None = None   # None | "audio" | "vision"
    n_frontend_tokens: int = 0    # precomputed embedding tokens prepended (vlm)
    # misc
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    act: str = "silu"             # silu | gelu
    gated_mlp: bool = True
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    embed_scale: bool = False     # gemma-style sqrt(d_model) embedding scaling
    # compute / memory policy
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "float32"  # master params
    # multi-precision quantization (repro.quant) — the paper's 8-to-64-bit
    # axis. ``weight_dtype`` ("" | int8 | fp8 | float8_e4m3fn) selects
    # weight-only post-training quantization: quantize_params() wraps matmul
    # weights in QuantTensor containers (per-channel absmax scales;
    # ``quant_block`` > 0 adds per-block scales along the contraction axis)
    # and the gemm_wq registry op dequantizes in-tile. ``kv_dtype`` stores
    # the paged KV block pools at the narrow width with per-row scales
    # (paged layout only — dense buffers keep ``dtype``). Serving-side
    # knobs: training always uses the dense master params.
    # ``weight_dtype`` additionally accepts "int4" (nibble-packed, weight-
    # only: KV pools stay byte-addressable). ``weight_density`` is the
    # structured-sparsity fraction of weight blocks kept nonzero (1.0 =
    # dense) — a cost-model knob consumed by the memfloor/roofline byte and
    # FLOP terms for gemm_sparse serving paths.
    weight_dtype: str = ""        # "" | int8 | fp8 | float8_e4m3fn | int4
    kv_dtype: str = ""            # "" | int8 | fp8 | float8_e4m3fn
    quant_block: int = 0          # 0 => per-channel; else scale-block length
    weight_density: float = 1.0   # (0, 1] nonzero weight-block fraction
    remat: str = "block"          # none | block (remat each scanned block)
    scan_unroll: int = 1          # block-scan unroll factor. Analysis builds
                                  # lower u=1 and u=2 and extrapolate, since
                                  # XLA cost_analysis counts while-bodies once.
    attn_chunk: int = 1024        # q-chunk for the jnp flash attention
    loss_chunk: int = 0           # 0 => full logits; >0 => chunked vocab loss
    # paged-KV serving (repro.serve.engine). ``paged_kv`` selects the
    # block-pool cache layout for full-attention layers (local windows,
    # recurrent states, and cross caches stay dense); ``page_size`` is the
    # KV rows per block; ``prefill_chunk`` is the fixed token count of the
    # one compiled chunked-prefill step; ``max_blocks`` sizes the global
    # block pool (0 => the engine derives max_slots * ceil(max_len /
    # page_size) + 1, i.e. dense-equivalent capacity plus the null block).
    paged_kv: bool = False
    page_size: int = 16
    prefill_chunk: int = 64
    max_blocks: int = 0
    # prefix caching (repro.serve.prefix): share fully-written prompt pages
    # of the block pool across requests (refcounted, copy-on-write) and
    # skip prefill for matched pages. ``prefix_lru`` caps how many
    # refcount-0 cached blocks the index retains after their owners finish
    # (0 = bounded only by pool pressure). Paged all-full-attention decoder
    # configs only; others serve cold.
    prefix_cache: bool = False
    prefix_lru: int = 0
    # serving scheduler (repro.serve.scheduler): admission policy over the
    # waiting queue. ``sched_policy`` is "priority" (priority classes, EDF
    # on TTFT SLOs, multi-tenant fair queuing, skip-with-aging — FCFS-
    # equivalent when requests carry no priorities/users/SLOs) or "fcfs"
    # (strict arrival order, legacy no-overtaking behavior). ``sched_aging``
    # is the skipped-admission-pass count that promotes a blocked request to
    # a pool reservation (0 = never, unbounded overtaking). ``preemption``
    # lets a blocked higher-priority request evict a lower-priority slot
    # (paged layout only); ``overlap_decode`` double-buffers the decode
    # dispatch so host bookkeeping overlaps device compute (token streams
    # identical, ids surface one step later).
    sched_policy: str = "priority"
    sched_aging: int = 64
    preemption: bool = False
    overlap_decode: bool = False
    # disaggregated prefill/decode pools (repro.serve.engine): partition
    # the slot pool so ``prefill_slots`` slots only chunk-prefill and the
    # rest only decode; a finished prompt hands its KV to a decode slot by
    # republishing pages through the block table (zero tensor copies).
    # ``prefill_slots`` 0 => auto (max(1, max_slots // 4)).
    split_pools: bool = False
    prefill_slots: int = 0
    # speculative decoding (repro.spec): ``draft_model`` names a registry
    # arch whose (smaller) model proposes ``spec_k`` tokens per scheduler
    # turn from its own dense cache; the serving model verifies all of
    # them in one batched pass and commits a distribution-preserving
    # prefix (exact greedy parity at temperature 0). Paged local
    # all-full-attention configs only; "" disables. ``spec_k=0`` takes
    # the engine default (4).
    draft_model: str = ""
    spec_k: int = 0
    # kernel selection flows through the backend registry
    # (repro.kernels.dispatch): "" keeps the pure-XLA paths (the only option
    # for training — kernel backends are forward/inference paths); "auto"
    # opts into the Pallas kernels when the platform has them (TPU); "ref" |
    # "interpret" | "pallas" pin a registry backend for the whole model
    # graph. A use_backend(...) scope around the model call overrides this
    # field. Read through ``resolved_kernel_backend``.
    kernel_backend: str = ""      # "" | auto | ref | interpret | pallas
    # DEPRECATED: pre-registry attention switch. Non-default values emit a
    # DeprecationWarning and map onto the kernel backend ("pallas" ->
    # "pallas", "pallas_interpret" -> "interpret") unless kernel_backend is
    # set explicitly; resolution happens in ``resolved_kernel_backend`` so
    # replace(attention_impl="xla") round-trips back to the XLA paths.
    attention_impl: str = "xla"   # deprecated: xla | pallas | pallas_interpret

    _ATTENTION_IMPL_MAP = {"xla": "", "pallas": "pallas",
                           "pallas_interpret": "interpret"}

    def __post_init__(self):
        if self.kernel_backend not in ("", "auto", "ref", "interpret",
                                       "pallas"):
            raise ValueError(
                f"kernel_backend={self.kernel_backend!r}; expected '', "
                "'auto', 'ref', 'interpret', or 'pallas'")
        if self.page_size < 1 or self.prefill_chunk < 1:
            raise ValueError("page_size and prefill_chunk must be >= 1")
        if self.prefix_lru < 0:
            raise ValueError("prefix_lru must be >= 0")
        if self.sched_policy not in ("fcfs", "priority"):
            raise ValueError(
                f"sched_policy={self.sched_policy!r}; expected 'fcfs' or "
                "'priority'")
        if self.sched_aging < 0:
            raise ValueError("sched_aging must be >= 0")
        if self.spec_k < 0:
            raise ValueError("spec_k must be >= 0")
        if self.draft_model and not self.paged_kv:
            raise ValueError("draft_model requires paged_kv=True: "
                             "speculative rollback reclaims verifier pages "
                             "through the block allocator")
        if self.preemption and not self.paged_kv:
            raise ValueError("preemption requires paged_kv=True: dense "
                             "slots hold no reclaimable blocks")
        if self.split_pools and not self.paged_kv:
            raise ValueError("split_pools requires paged_kv=True: the "
                             "prefill->decode handoff republishes pool "
                             "pages through the block table")
        if self.prefill_slots < 0:
            raise ValueError("prefill_slots must be >= 0 (0 = auto)")
        _quant_names = ("", "int8", "fp8", "float8_e4m3fn")
        # int4 is weight-only: KV pool rows must stay byte-addressable
        if self.weight_dtype not in _quant_names + ("int4",):
            raise ValueError(
                f"weight_dtype={self.weight_dtype!r}; expected one of "
                f"{_quant_names + ('int4',)}")
        if self.kv_dtype not in _quant_names:
            raise ValueError(
                f"kv_dtype={self.kv_dtype!r}; expected one of {_quant_names}")
        if self.quant_block < 0:
            raise ValueError("quant_block must be >= 0")
        if not 0.0 < self.weight_density <= 1.0:
            raise ValueError("weight_density must be in (0, 1]")
        if self.attention_impl not in self._ATTENTION_IMPL_MAP:
            raise ValueError(
                f"attention_impl={self.attention_impl!r}; expected 'xla', "
                "'pallas', or 'pallas_interpret'")
        if self.attention_impl != "xla":
            import warnings
            warnings.warn(
                "ModelConfig.attention_impl is deprecated; use "
                "kernel_backend='pallas' / 'interpret' (kernel selection now "
                "flows through repro.kernels.dispatch)",
                DeprecationWarning, stacklevel=3)

    @property
    def resolved_kernel_backend(self) -> str:
        """Backend for model layers: explicit kernel_backend, else the
        deprecated attention_impl mapping, else "" (pure-XLA paths)."""
        return (self.kernel_backend
                or self._ATTENTION_IMPL_MAP[self.attention_impl])

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    def layer_specs(self) -> tuple[tuple[LayerSpec, ...], tuple[LayerSpec, ...], int,
                                   tuple[LayerSpec, ...]]:
        """Return (prefix, pattern, n_repeats, remainder) covering n_layers."""
        n_rest = self.n_layers - len(self.prefix)
        assert n_rest >= 0, "prefix longer than n_layers"
        per = len(self.pattern)
        n_rep = n_rest // per
        rem = self.pattern[: n_rest - n_rep * per]
        return self.prefix, self.pattern, n_rep, rem

    def all_layers(self) -> list[LayerSpec]:
        prefix, pattern, n_rep, rem = self.layer_specs()
        return list(prefix) + list(pattern) * n_rep + list(rem)

    def param_count(self) -> dict[str, float]:
        """Analytic parameter counts (total, active, embedding)."""
        d, hd = self.d_model, self.resolved_head_dim
        embed = self.vocab_size * d
        if not self.tie_embeddings:
            embed *= 2
        total = 0.0
        active = 0.0
        for spec in self.all_layers():
            # mixer
            if spec.mixer in ("full", "local"):
                p = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
                total += p
                active += p
            elif spec.mixer == "rglru":
                w = self.rglru.lru_width or d
                # two in-projections + out-projection + conv + Lambda
                p = 3 * d * w + w * self.rglru.d_conv + w
                p += 2 * w * (w // 8)  # block-diagonal (8 blocks) a/input gates
                total += p
                active += p
            elif spec.mixer == "mamba":
                di = self.ssm.expand * d
                dtr = self.ssm.dt_rank or math.ceil(d / 16)
                p = (d * 2 * di            # in_proj (x, z)
                     + di * self.ssm.d_conv
                     + di * (dtr + 2 * self.ssm.d_state)
                     + dtr * di
                     + di * self.ssm.d_state   # A_log
                     + di                       # D
                     + di * d)             # out_proj
                total += p
                active += p
            # mlp
            mult = 3 if self.gated_mlp else 2
            if spec.mlp == "dense":
                p = mult * d * self.d_ff
                total += p
                active += p
            elif spec.mlp == "moe":
                m = self.moe
                routed = m.n_experts * mult * d * m.d_expert
                shared = mult * d * m.shared_hidden if m.shared_hidden else 0
                router = d * m.n_experts
                total += routed + shared + router
                active += m.top_k * mult * d * m.d_expert + shared + router
            # cross attention (decoder of enc-dec)
            if self.encoder is not None and spec.mixer in ("full", "local"):
                p = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
                total += p
                active += p
        if self.encoder is not None:
            for _ in range(self.encoder.n_layers):
                p = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
                p += (3 if self.gated_mlp else 2) * d * self.d_ff
                total += p
                active += p
        total += embed
        active += embed
        return {"total": float(total), "active": float(active),
                "embedding": float(self.vocab_size * d),
                "nonembed_total": float(total - embed),
                "nonembed_active": float(active - embed)}

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------
# Shapes
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                     # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatches: int = 1         # grad-accumulation microbatches (train only)


# --------------------------------------------------------------------------
# Distribution strategies — the paper's three generations
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class StrategyConfig:
    name: str = "ramora"
    multi_pod: bool = False
    fsdp: bool = True               # shard params over 'data' (ZeRO-3)
    tensor_parallel: bool = True    # shard heads/d_ff/vocab over 'model'
    expert_parallel: bool = True    # shard experts over 'model' when divisible
    context_parallel_decode: bool = True  # shard KV length over 'data' for long decode
    seq_shard: bool = True          # sequence-parallel residual stream (Megatron-SP)
    hierarchical_collectives: bool = False  # ogopogo in-router analogue
    chunked_loss: bool = False      # ogopogo: chunked vocab xent
    grad_compression: str = "none"  # none | int8_ef
    overlap_microbatches: int = 1   # >1: grad-accum loop to overlap comm/compute
    remat: str = "block"
    # kernel block-size tuning overrides for the op registry: hashable tuple
    # of (op, bucket, ((kwarg, size), ...)) entries, bucket "*" = any shape
    # bucket. Decoded by repro.kernels.dispatch.blocks_from_pairs and applied
    # by call sites that own a strategy (e.g. the serve engine).
    kernel_blocks: tuple = ()

    @property
    def mesh_axes(self) -> tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")


OCCAMY = StrategyConfig(name="occamy", fsdp=False, tensor_parallel=False,
                        expert_parallel=False, context_parallel_decode=False,
                        seq_shard=False)
RAMORA = StrategyConfig(name="ramora")
OGOPOGO = StrategyConfig(name="ogopogo", multi_pod=True,
                         hierarchical_collectives=True, chunked_loss=True,
                         overlap_microbatches=1)
# Beyond-paper (perf hillclimb, EXPERIMENTS.md §Perf): for dense training the
# per-layer TP activation psums (2 x (B,S,d) x {fwd,remat,bwd}) dwarf the
# weight traffic whenever B_loc*S*d >> layer params; spreading the model axis
# into the data/FSDP dimension trades them for one weight all-gather per pass.
# MoE archs keep expert parallelism over 'model' (the paper's packed-stream
# dispatch) — only the dense TP psums are removed.
FSDP2D = StrategyConfig(name="fsdp2d", tensor_parallel=False, seq_shard=False,
                        chunked_loss=True)
FSDP2D_POD = dataclasses.replace(FSDP2D, multi_pod=True,
                                 hierarchical_collectives=True)


def strategy(name: str, multi_pod: bool | None = None) -> StrategyConfig:
    base = {"occamy": OCCAMY, "ramora": RAMORA, "ogopogo": OGOPOGO,
            "fsdp2d": FSDP2D}[name]
    if multi_pod is not None:
        base = dataclasses.replace(base, multi_pod=multi_pod)
    return base

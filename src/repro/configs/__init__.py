from repro.configs.archs import reduced
from repro.configs.base import (EncoderConfig, LayerSpec, ModelConfig,
                                MoEConfig, RGLRUConfig, SSMConfig, ShapeConfig,
                                StrategyConfig, strategy)
from repro.configs.registry import ARCHS, SKIPS, all_cells, get_arch, is_skipped
from repro.configs.shapes import SHAPES, get_shape

__all__ = [
    "ARCHS", "SHAPES", "SKIPS", "EncoderConfig", "LayerSpec", "ModelConfig",
    "MoEConfig", "RGLRUConfig", "SSMConfig", "ShapeConfig", "StrategyConfig",
    "all_cells", "get_arch", "get_shape", "is_skipped", "reduced", "strategy",
]

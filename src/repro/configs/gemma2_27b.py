"""Assigned architecture config (exact sizes from the assignment)."""
from repro.configs.base import (EncoderConfig, LayerSpec, ModelConfig,
                                MoEConfig, RGLRUConfig, SSMConfig)

# --------------------------------------------------------------------------
# dense
# --------------------------------------------------------------------------
# [arXiv:2408.00118; hf google/gemma-2-27b]
GEMMA2_27B = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000,
    pattern=(LayerSpec("local", "dense"), LayerSpec("full", "dense")),
    window=4096, attn_softcap=50.0, final_softcap=30.0,
    act="gelu", embed_scale=True, rope_theta=10000.0,
    attn_scale=144.0, sandwich_norms=True,
)

CONFIG = GEMMA2_27B

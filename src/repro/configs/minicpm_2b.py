"""Assigned architecture config (exact sizes from the assignment)."""
from repro.configs.base import (EncoderConfig, LayerSpec, ModelConfig,
                                MoEConfig, RGLRUConfig, SSMConfig)

# [arXiv:2404.06395; hf openbmb/MiniCPM-2B] llama-like; WSD schedule in optim/
MINICPM_2B = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab_size=122753,
    pattern=(LayerSpec("full", "dense"),),
)

CONFIG = MINICPM_2B

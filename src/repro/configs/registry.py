"""Architecture registry: aggregates the per-arch config modules."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.configs.deepseek_7b import DEEPSEEK_7B
from repro.configs.deepseek_moe_16b import DEEPSEEK_MOE_16B
from repro.configs.falcon_mamba_7b import FALCON_MAMBA_7B
from repro.configs.gemma2_27b import GEMMA2_27B
from repro.configs.llava_next_mistral_7b import LLAVA_NEXT_MISTRAL_7B
from repro.configs.minicpm_2b import MINICPM_2B
from repro.configs.qwen2_moe_a2_7b import QWEN2_MOE_A2_7B
from repro.configs.qwen3_0_6b import QWEN3_0_6B
from repro.configs.recurrentgemma_2b import RECURRENTGEMMA_2B
from repro.configs.whisper_tiny import WHISPER_TINY

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in (
        GEMMA2_27B, DEEPSEEK_7B, MINICPM_2B, QWEN3_0_6B, RECURRENTGEMMA_2B,
        WHISPER_TINY, LLAVA_NEXT_MISTRAL_7B, QWEN2_MOE_A2_7B, DEEPSEEK_MOE_16B,
        FALCON_MAMBA_7B,
    )
}

# (arch, shape) cells that are skipped, with the reason recorded here and in
# DESIGN.md §Arch-applicability. Everything else must dry-run.
SKIPS: dict[tuple[str, str], str] = {
    ("deepseek-7b", "long_500k"): "pure full attention (quadratic) — per assignment",
    ("minicpm-2b", "long_500k"): "pure full attention (quadratic) — per assignment",
    ("qwen3-0.6b", "long_500k"): "pure full attention (quadratic) — per assignment",
    ("whisper-tiny", "long_500k"): "enc-dec full attention; decoder max ctx 448 — per assignment",
    ("llava-next-mistral-7b", "long_500k"): "pure full attention (quadratic) — per assignment",
    ("qwen2-moe-a2.7b", "long_500k"): "pure full attention (quadratic) — per assignment",
    ("deepseek-moe-16b", "long_500k"): "pure full attention (quadratic) — per assignment",
}
# gemma2-27b long_500k RUNS: its local layers cap the KV cache at the 4096
# window and the global layers use a context-parallel (length-sharded) cache.
# recurrentgemma-2b / falcon-mamba-7b long_500k RUN: O(1) recurrent state.


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def is_skipped(arch: str, shape: str) -> str | None:
    return SKIPS.get((arch, shape))


def all_cells(include_skipped: bool = False) -> list[tuple[str, str]]:
    from repro.configs.shapes import SHAPES
    cells = []
    for a in ARCHS:
        for s in SHAPES:
            if include_skipped or (a, s) not in SKIPS:
                cells.append((a, s))
    return cells

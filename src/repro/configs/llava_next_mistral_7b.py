"""Assigned architecture config (exact sizes from the assignment)."""
from repro.configs.base import (EncoderConfig, LayerSpec, ModelConfig,
                                MoEConfig, RGLRUConfig, SSMConfig)

# --------------------------------------------------------------------------
# vlm  [hf llava-hf/llava-v1.6-mistral-7b-hf] — mistral backbone; anyres vision
# frontend is a STUB: input_specs() provides precomputed patch embeddings.
# --------------------------------------------------------------------------
LLAVA_NEXT_MISTRAL_7B = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    pattern=(LayerSpec("full", "dense"),),
    frontend="vision", n_frontend_tokens=576, rope_theta=1000000.0,
    tie_embeddings=False,
)

CONFIG = LLAVA_NEXT_MISTRAL_7B

"""Assigned architecture config (exact sizes from the assignment)."""
from repro.configs.base import (EncoderConfig, LayerSpec, ModelConfig,
                                MoEConfig, RGLRUConfig, SSMConfig)

# [hf Qwen/Qwen3-0.6B] qk-norm, GQA, head_dim 128
QWEN3_0_6B = ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=3072, vocab_size=151936,
    pattern=(LayerSpec("full", "dense"),),
    qk_norm=True, rope_theta=1000000.0,
)

CONFIG = QWEN3_0_6B

"""Assigned architecture config (exact sizes from the assignment)."""
from repro.configs.base import (EncoderConfig, LayerSpec, ModelConfig,
                                MoEConfig, RGLRUConfig, SSMConfig)

# [arXiv:2401.02954; hf deepseek-ai/deepseek-llm-7b-base] llama-arch MHA
DEEPSEEK_7B = ModelConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab_size=102400,
    pattern=(LayerSpec("full", "dense"),),
)

CONFIG = DEEPSEEK_7B

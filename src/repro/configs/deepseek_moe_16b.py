"""Assigned architecture config (exact sizes from the assignment)."""
from repro.configs.base import (EncoderConfig, LayerSpec, ModelConfig,
                                MoEConfig, RGLRUConfig, SSMConfig)

# [arXiv:2401.06066; hf deepseek-ai/deepseek-moe-16b-base]
# layer 0 dense (d_ff 10944), layers 1..27: 2 shared + 64 routed top-6
DEEPSEEK_MOE_16B = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944, vocab_size=102400,
    prefix=(LayerSpec("full", "dense"),),
    pattern=(LayerSpec("full", "moe"),),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408,
                  renorm_topk=True),
)

CONFIG = DEEPSEEK_MOE_16B

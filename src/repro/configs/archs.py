"""Back-compat aggregator — canonical definitions live in the per-arch modules
(one ``configs/<id>.py`` per assigned architecture) and ``registry.py``."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCHS, get_arch  # noqa: F401


def reduced(cfg: ModelConfig, *, n_layers: int | None = None) -> ModelConfig:
    """Small same-family config for CPU smoke tests."""
    per = len(cfg.pattern)
    nl = n_layers if n_layers is not None else len(cfg.prefix) + 2 * per
    kw: dict = dict(
        name=cfg.name + "-smoke",
        n_layers=nl,
        d_model=128,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=max(1, cfg.n_kv_heads * 4 // max(cfg.n_heads, 1)) if cfg.n_heads else 0,
        head_dim=32 if cfg.head_dim else 0,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        window=min(cfg.window, 64) if cfg.window else 0,
        attn_chunk=64,
        max_position=4096,
        loss_chunk=min(cfg.loss_chunk, 64) if cfg.loss_chunk else 0,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=8,
            top_k=min(cfg.moe.top_k, 2),
            d_expert=64,
            d_shared=128 if cfg.moe.d_shared else 0,
            n_shared=min(cfg.moe.n_shared, 2))
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=8, dt_rank=16, chunk=16)
    if cfg.rglru is not None:
        kw["rglru"] = dataclasses.replace(cfg.rglru, lru_width=128, chunk=16)
    if cfg.encoder is not None:
        kw["encoder"] = dataclasses.replace(cfg.encoder, n_layers=2, n_frames=32)
    if cfg.n_frontend_tokens:
        kw["n_frontend_tokens"] = 16
    return cfg.replace(**kw)

"""Assigned architecture config (exact sizes from the assignment)."""
from repro.configs.base import (EncoderConfig, LayerSpec, ModelConfig,
                                MoEConfig, RGLRUConfig, SSMConfig)

# --------------------------------------------------------------------------
# hybrid (Griffin / RecurrentGemma)  [arXiv:2402.19427; hf google/recurrentgemma-2b]
# --------------------------------------------------------------------------
RECURRENTGEMMA_2B = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000,
    pattern=(LayerSpec("rglru", "dense"), LayerSpec("rglru", "dense"),
             LayerSpec("local", "dense")),
    window=2048, act="gelu", embed_scale=True,
    rglru=RGLRUConfig(lru_width=2560, d_conv=4),
)

CONFIG = RECURRENTGEMMA_2B

"""Assigned architecture config (exact sizes from the assignment)."""
from repro.configs.base import (EncoderConfig, LayerSpec, ModelConfig,
                                MoEConfig, RGLRUConfig, SSMConfig)

# --------------------------------------------------------------------------
# audio (enc-dec)  [arXiv:2212.04356] — conv frontend is a STUB: input_specs()
# provides precomputed frame embeddings.
# --------------------------------------------------------------------------
WHISPER_TINY = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    pattern=(LayerSpec("full", "dense"),),
    encoder=EncoderConfig(n_layers=4, n_frames=1500),
    frontend="audio", norm="layernorm", act="gelu", gated_mlp=False,
    use_rope=False, learned_pos=True, max_position=1 << 16,
    tie_embeddings=True,
)

CONFIG = WHISPER_TINY

"""Assigned architecture config (exact sizes from the assignment)."""
from repro.configs.base import (EncoderConfig, LayerSpec, ModelConfig,
                                MoEConfig, RGLRUConfig, SSMConfig)

# --------------------------------------------------------------------------
# moe
# --------------------------------------------------------------------------
# [hf Qwen/Qwen1.5-MoE-A2.7B] 4 shared + 60 routed top-4, gate on shared expert
QWEN2_MOE_A2_7B = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=151936,
    pattern=(LayerSpec("full", "moe"),),
    moe=MoEConfig(n_experts=60, top_k=4, n_shared=4, d_expert=1408,
                  d_shared=5632, renorm_topk=False, shared_gate=True),
)

CONFIG = QWEN2_MOE_A2_7B

"""The model spine shared by all 10 assigned architectures.

Layer layout = unrolled ``prefix`` + ``pattern`` × n_repeats (stacked & scanned
with ``jax.lax.scan``) + unrolled remainder. Scanning the repeated pattern
keeps the HLO size O(pattern) instead of O(n_layers) — essential for
46-layer × 512-device dry-run compiles — and makes activation rematerialization
a per-block policy, mirroring the paper's per-cluster double-buffering.

Heterogeneous periods (gemma2's [local, global]; recurrentgemma's
[rglru, rglru, local]) scan over *pattern periods*: each scan step applies the
whole period, with per-position parameter slices stacked on the leading axis.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.kernels import dispatch as kdispatch
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import recurrent as rec_mod
from repro.models.layers import (Params, apply_mlp, apply_norm, dense_init,
                                 embed_init, mlp_init, norm_init, softcap)

PyTree = Any


# ==========================================================================
# init
# ==========================================================================
def _layer_init(rng, spec: LayerSpec, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(rng, 8)
    p: Params = {"pre_norm": norm_init(cfg.d_model, cfg.norm, dtype)}
    if spec.mixer in ("full", "local"):
        p["attn"] = attn_mod.attention_init(ks[0], cfg, dtype)
        if cfg.encoder is not None:  # decoder layer of an enc-dec model
            p["cross_norm"] = norm_init(cfg.d_model, cfg.norm, dtype)
            p["cross"] = attn_mod.attention_init(ks[1], cfg, dtype)
    elif spec.mixer == "rglru":
        p["rglru"] = rec_mod.rglru_init(ks[0], cfg, dtype)
    elif spec.mixer == "mamba":
        p["mamba"] = rec_mod.mamba_init(ks[0], cfg, dtype)
    else:
        raise ValueError(spec.mixer)
    if cfg.sandwich_norms:
        p["post_norm"] = norm_init(cfg.d_model, cfg.norm, dtype)
    if spec.mlp == "dense":
        p["mlp_norm"] = norm_init(cfg.d_model, cfg.norm, dtype)
        # deepseek-moe's dense prefix layer uses the full d_ff
        p["mlp"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)
        if cfg.sandwich_norms:
            p["mlp_post_norm"] = norm_init(cfg.d_model, cfg.norm, dtype)
    elif spec.mlp == "moe":
        p["mlp_norm"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["moe"] = moe_mod.moe_init(ks[2], cfg, dtype)
        if cfg.sandwich_norms:
            p["mlp_post_norm"] = norm_init(cfg.d_model, cfg.norm, dtype)
    return p


def _encoder_layer_init(rng, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(rng, 3)
    return {
        "pre_norm": norm_init(cfg.d_model, cfg.norm, dtype),
        "attn": attn_mod.attention_init(ks[0], cfg, dtype),
        "mlp_norm": norm_init(cfg.d_model, cfg.norm, dtype),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype),
    }


def init(rng, cfg: ModelConfig) -> PyTree:
    dtype = jnp.dtype(cfg.param_dtype)
    prefix, pattern, n_rep, rem = cfg.layer_specs()
    k_embed, k_pre, k_pat, k_rem, k_head, k_enc, k_pos = jax.random.split(rng, 7)
    params: Params = {
        "embed": {"table": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype)},
        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if cfg.learned_pos:
        params["pos_embed"] = {
            "table": embed_init(k_pos, min(cfg.max_position, 1 << 20), cfg.d_model,
                                dtype) * 0.02}
    if not cfg.tie_embeddings:
        params["lm_head"] = {"kernel": dense_init(k_head, cfg.d_model,
                                                  cfg.vocab_size, dtype)}
    if prefix:
        params["prefix"] = [
            _layer_init(k, spec, cfg, dtype)
            for k, spec in zip(jax.random.split(k_pre, len(prefix)), prefix)]
    if n_rep:
        def one_period(k):
            return [_layer_init(kk, spec, cfg, dtype)
                    for kk, spec in zip(jax.random.split(k, len(pattern)), pattern)]
        stacked = [one_period(k) for k in jax.random.split(k_pat, n_rep)]
        params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
    if rem:
        params["suffix"] = [
            _layer_init(k, spec, cfg, dtype)
            for k, spec in zip(jax.random.split(k_rem, len(rem)), rem)]
    if cfg.encoder is not None:
        enc_keys = jax.random.split(k_enc, cfg.encoder.n_layers + 2)
        params["encoder"] = {
            "layers": [_encoder_layer_init(k, cfg, dtype)
                       for k in enc_keys[:-2]],
            "final_norm": norm_init(cfg.d_model, cfg.norm, dtype),
            "pos_embed": {"table": embed_init(
                enc_keys[-1], cfg.encoder.n_frames, cfg.d_model, dtype) * 0.02},
        }
    return params


# ==========================================================================
# single-layer application
# ==========================================================================
def _slot_state(state, slot):
    """Slice one slot's recurrent state out of the pooled cache."""
    return jax.tree.map(
        lambda l: jax.lax.dynamic_slice_in_dim(l, slot, 1, 0), state)


def _merge_slot_state(pool, new, slot):
    """Write a batch-1 recurrent state back into slot ``slot`` of the pool."""
    return jax.tree.map(
        lambda b, s: jax.lax.dynamic_update_slice_in_dim(
            b, s.astype(b.dtype), slot, 0), pool, new)


def _mask_state(new, old, active):
    """Keep ``old`` state rows where ``active`` is False (slots that are not
    in the decode phase must not advance their recurrent carry)."""
    return jax.tree.map(
        lambda n, o: jnp.where(active.reshape((-1,) + (1,) * (n.ndim - 1)),
                               n, o.astype(n.dtype)), new, old)


def _apply_layer(lp: Params, spec: LayerSpec, cfg: ModelConfig, x, *,
                 positions, enc_out, cache, pos, mode: str, compute_dtype,
                 part=None, active=None, block_tables=None, slot=None,
                 n_valid=None, first_new_pos=0):
    """mode: 'full' (train/prefill, builds cache) | 'decode' (single step)
    | 'extend' (chunked prefill: T tokens for ONE slot of the pooled cache)
    | 'verify' (speculative decoding: T tokens for EVERY slot, per-slot
    ``pos``/``n_valid`` arrays, paged full-attention layers only).

    Decode extras: ``active`` ((B,) bool) gates per-slot cache writes;
    ``block_tables`` ((B, P) int32) selects the paged KV layout for full-
    attention layers. Extend extras: ``slot``/``n_valid``/``first_new_pos``
    (traced scalars) — ``first_new_pos`` is where this request's prefill
    started (> 0 when a prefix-cache hit mapped the head of the sequence
    from shared blocks). Returns (x, new_cache_entry, aux_loss).
    """
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}
    is_local = spec.mixer == "local"
    h = apply_norm(lp["pre_norm"], x, cfg.norm, cfg.norm_eps)
    if spec.mixer in ("full", "local"):
        bt = block_tables if spec.mixer == "full" else None
        if mode == "full":
            out, (k, v) = attn_mod.attention_forward(
                lp["attn"], cfg, h, is_local=is_local, positions=positions,
                compute_dtype=compute_dtype, part=part)
            if cache is not None:
                new_cache["self"] = _store_kv(cfg, k, v, is_local, cache["self"])
        elif mode == "extend":
            out, new_self = attn_mod.attention_extend(
                lp["attn"], cfg, h, cache["self"], is_local=is_local, pos=pos,
                n_valid=n_valid, slot=slot, compute_dtype=compute_dtype,
                block_tables=bt, first_new_pos=first_new_pos)
            new_cache["self"] = new_self
        elif mode == "verify":
            if bt is None:
                raise NotImplementedError(
                    "verify_step requires the paged layout on every "
                    "attention layer (speculative decoding is gated on "
                    "paged all-full-attention configs)")
            out, new_self = attn_mod.attention_verify(
                lp["attn"], cfg, h, cache["self"], pos=pos, n_valid=n_valid,
                active=active, block_tables=bt,
                compute_dtype=compute_dtype)
            new_cache["self"] = new_self
        else:
            out, new_self = attn_mod.attention_decode(
                lp["attn"], cfg, h, cache["self"], is_local=is_local, pos=pos,
                compute_dtype=compute_dtype, part=part, active=active,
                block_tables=bt)
            new_cache["self"] = new_self
    elif spec.mixer in ("rglru", "mamba"):
        if mode == "verify":
            raise NotImplementedError(
                "verify_step does not support recurrent mixers: speculative "
                "rollback cannot rewind a per-slot carry")
        fwd = rec_mod.rglru_forward if spec.mixer == "rglru" else rec_mod.mamba_forward
        key = spec.mixer
        if mode == "extend":
            st = _slot_state(cache["rec"], slot)
            # first chunk of a (possibly reused) slot starts from zero state
            # — KV rows are position-masked, but recurrent carries are not.
            # The first chunk starts at first_new_pos (0 without a
            # prefix-cache hit; recurrent layers are prefix-incapable, so
            # today this is always pos > 0, kept general for a future
            # carry-restoring cache)
            st = jax.tree.map(
                lambda l: jnp.where(pos > first_new_pos, l,
                                    jnp.zeros_like(l)), st)
            out, new_state = fwd(lp[key], cfg, h, state=st,
                                 compute_dtype=compute_dtype, part=part,
                                 single_step=False, valid_len=n_valid)
            new_cache["rec"] = _merge_slot_state(cache["rec"], new_state, slot)
        else:
            state = None if cache is None else cache["rec"]
            out, new_state = fwd(lp[key], cfg, h, state=state,
                                 compute_dtype=compute_dtype, part=part,
                                 single_step=(mode == "decode"))
            if cache is not None:
                if mode == "decode" and active is not None:
                    new_state = _mask_state(new_state, state, active)
                new_cache["rec"] = new_state
    if cfg.sandwich_norms:
        out = apply_norm(lp["post_norm"], out, cfg.norm, cfg.norm_eps)
    x = x + out

    # cross attention (decoder of enc-dec); enc_out: (B, S_enc, d) or KV cache
    if cfg.encoder is not None and spec.mixer in ("full", "local"):
        if mode in ("extend", "verify"):
            raise NotImplementedError(
                "chunked prefill (extend_step) and speculative verification "
                "(verify_step) do not support enc-dec models — the serve "
                "engine prefills those whole and decodes them one-by-one")
        h = apply_norm(lp["cross_norm"], x, cfg.norm, cfg.norm_eps)
        if mode == "full":
            out, (ck, cv) = attn_mod.attention_forward(
                lp["cross"], cfg, h, is_local=False, positions=None,
                compute_dtype=compute_dtype, causal=False, xkv=enc_out,
                positions_kv=None, part=part)
            if cache is not None:
                new_cache["cross"] = {"k": ck, "v": cv}
        else:
            out, _ = attn_mod.attention_decode(
                lp["cross"], cfg, h, cache["cross"], is_local=False, pos=pos,
                compute_dtype=compute_dtype, part=part, cross=True)
            new_cache["cross"] = cache["cross"]
        x = x + out

    if spec.mlp != "none":
        h = apply_norm(lp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
        if spec.mlp == "dense":
            out = apply_mlp(lp["mlp"], h, cfg.act, cfg.gated_mlp, compute_dtype,
                            part=part)
        else:
            out, aux = moe_mod.moe_forward(lp["moe"], cfg, h,
                                           compute_dtype=compute_dtype, part=part)
        if cfg.sandwich_norms:
            out = apply_norm(lp["mlp_post_norm"], out, cfg.norm, cfg.norm_eps)
        x = x + out
    if part is not None:
        # sequence-parallel residual stream between blocks: the scan carry
        # saved for backward shards over 'model' (Megatron-SP), collapsing
        # n_layers × (B,S,d) of per-device activation memory.
        x = part.act(x, ("batch", "seq", None))
    return x, new_cache, aux


def _store_kv(cfg: ModelConfig, k, v, is_local: bool, template):
    """Write prefill K/V into a decode cache buffer (template gives S_buf)."""
    S_buf = template["k"].shape[1]
    S = k.shape[1]
    if is_local and cfg.window and S_buf == cfg.window:
        # keep the last `window` positions, rotated so slot = pos % window
        start = max(S - S_buf, 0)
        tail_k, tail_v = k[:, start:], v[:, start:]
        idx = jnp.mod(jnp.arange(start, start + tail_k.shape[1]), S_buf)
        kb = jnp.zeros_like(template["k"]).at[:, idx].set(
            tail_k.astype(template["k"].dtype))
        vb = jnp.zeros_like(template["v"]).at[:, idx].set(
            tail_v.astype(template["v"].dtype))
        return {"k": kb, "v": vb}
    kb = jnp.zeros_like(template["k"]).at[:, :S].set(k.astype(template["k"].dtype))
    vb = jnp.zeros_like(template["v"]).at[:, :S].set(v.astype(template["v"].dtype))
    return {"k": kb, "v": vb}


# ==========================================================================
# stacked application over the layer layout
# ==========================================================================
def _apply_layers(params: Params, cfg: ModelConfig, x, *, positions, enc_out,
                  cache, pos, mode: str, part=None, active=None,
                  block_tables=None, slot=None, n_valid=None,
                  first_new_pos=0):
    compute_dtype = jnp.dtype(cfg.dtype)
    prefix, pattern, n_rep, rem = cfg.layer_specs()
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: dict = {}

    def run(lp, spec, x, centry):
        if part is not None:
            # ZeRO-3 style: gather this block's FSDP-sharded weights once,
            # in compute dtype, before use (paper C1: stage the tile, then
            # compute from fast memory).
            lp = part.gather_block(lp, compute_dtype)
        return _apply_layer(lp, spec, cfg, x, positions=positions,
                            enc_out=enc_out, cache=centry, pos=pos, mode=mode,
                            compute_dtype=compute_dtype, part=part,
                            active=active, block_tables=block_tables,
                            slot=slot, n_valid=n_valid,
                            first_new_pos=first_new_pos)

    if prefix:
        new_cache["prefix"] = []
        for i, spec in enumerate(prefix):
            centry = None if cache is None else cache["prefix"][i]
            x, nc, aux = run(params["prefix"][i], spec, x, centry)
            new_cache["prefix"].append(nc)
            aux_total += aux

    if n_rep:
        with_cache = cache is not None

        def period_body(carry, scanned):
            x, aux_acc = carry
            lps, centry = (scanned if with_cache else (scanned, None))
            ncs = []
            for j, spec in enumerate(pattern):
                ce = None if centry is None else centry[j]
                x, nc, aux = run(lps[j], spec, x, ce)
                ncs.append(nc)
                aux_acc = aux_acc + aux
            return (x, aux_acc), ncs

        body = period_body
        if cfg.remat == "block":
            body = jax.checkpoint(period_body, prevent_cse=False)
        xs = ((params["blocks"], cache["blocks"]) if with_cache
              else params["blocks"])
        (x, aux_total), ncs = jax.lax.scan(body, (x, aux_total), xs,
                                           unroll=min(cfg.scan_unroll, n_rep))
        new_cache["blocks"] = ncs

    if rem:
        new_cache["suffix"] = []
        for i, spec in enumerate(rem):
            centry = None if cache is None else cache["suffix"][i]
            x, nc, aux = run(params["suffix"][i], spec, x, centry)
            new_cache["suffix"].append(nc)
            aux_total += aux

    return x, new_cache, aux_total


def _has_entries(tree) -> bool:
    return len(jax.tree.leaves(tree)) > 0


# ==========================================================================
# encoder (enc-dec models; frontend embeddings are precomputed stubs)
# ==========================================================================
def encode(params: Params, cfg: ModelConfig, frames, *, part=None):
    """frames: (B, S_enc, d_model) precomputed frontend embeddings."""
    compute_dtype = jnp.dtype(cfg.dtype)
    enc = params["encoder"]
    S = frames.shape[1]
    x = frames + enc["pos_embed"]["table"][:S][None].astype(frames.dtype)
    for lp in enc["layers"]:
        h = apply_norm(lp["pre_norm"], x, cfg.norm, cfg.norm_eps)
        out, _ = attn_mod.attention_forward(
            lp["attn"], cfg, h, is_local=False, positions=None,
            compute_dtype=compute_dtype, causal=False, part=part)
        x = x + out
        h = apply_norm(lp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
        x = x + apply_mlp(lp["mlp"], h, cfg.act, cfg.gated_mlp, compute_dtype,
                          part=part)
    return apply_norm(enc["final_norm"], x, cfg.norm, cfg.norm_eps)


# ==========================================================================
# public entry points
# ==========================================================================
def embed_tokens(params, cfg: ModelConfig, tokens, extra_embeds=None):
    from repro.quant import QuantTensor

    table = params["embed"]["table"]
    dt = jnp.dtype(cfg.dtype)
    if isinstance(table, QuantTensor):
        # quantized table (per-row scales, axis=-1): gather the stored rows
        # and their scales FIRST, then dequantize (int4: unpack) only the
        # looked-up rows — never materialize the full (vocab, d) table
        x = table.take_rows(tokens, dtype=dt)
    else:
        if table.dtype != dt:
            # cast BEFORE the (vocab-sharded) gather: the lookup's masked
            # partial-gather + psum then moves compute-dtype bytes, not fp32
            table = table.astype(dt)
        x = table[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if extra_embeds is not None:  # vlm: prepend patch embeddings
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    return x


def logits_fn(params, cfg: ModelConfig, x, part=None):
    """Vocab-sharded logits. Odd vocab sizes (minicpm 122753, whisper 51865)
    are zero-padded to the 'model' axis and masked to -inf — exact for both
    cross-entropy and sampling; padded columns may be returned (callers that
    need exactly V slice, e.g. decode_step)."""
    compute_dtype = jnp.dtype(cfg.dtype)
    from repro.models.layers import grad_dtype_barrier
    x = grad_dtype_barrier(x)  # fp32 loss cotangents re-enter the scan in bf16
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    table = (params["lm_head"]["kernel"] if not cfg.tie_embeddings
             else params["embed"]["table"].T)
    V = cfg.vocab_size
    n_vocab = part.logical_size("vocab") if part is not None else 1
    v_pad = (-(-V // n_vocab) * n_vocab) - V
    table = table.astype(compute_dtype)
    if v_pad:
        table = jnp.pad(table, ((0, 0), (0, v_pad)))
    logits = jnp.einsum("bsd,dv->bsv", x.astype(compute_dtype), table,
                        preferred_element_type=jnp.float32)
    logits = softcap(logits, cfg.final_softcap)
    if part is not None:
        logits = part.act(logits, ("batch", None, "vocab"))
    if v_pad:
        mask = jnp.arange(V + v_pad) < V
        logits = jnp.where(mask[None, None, :], logits,
                           jnp.asarray(-1e30, logits.dtype))
    return logits


def _model_kernel_scope(cfg: ModelConfig, part):
    """Registry scope for a whole model graph: cfg.resolved_kernel_backend
    (or an enclosing use_backend scope, which wins) routes every kernelized
    layer — attention, dense/MLP, recurrences, MoE gathers — through the op
    registry. Local path only: under SPMD any kernel scope is *neutralized*
    (not just skipped) so no layer traces a pallas_call inside pjit."""
    if part is not None:
        return kdispatch.spmd_xla_scope()
    be = kdispatch.negotiated_model_backend(cfg.resolved_kernel_backend)
    if be is not None:
        return kdispatch.use_backend(be)
    return contextlib.nullcontext()


def forward(params, cfg: ModelConfig, tokens, *, extra_embeds=None, frames=None,
            cache=None, part=None):
    """Full-sequence forward (training / prefill).

    tokens: (B, S) int32. extra_embeds: (B, S_img, d) for vlm. frames:
    (B, S_enc, d) for enc-dec. cache: decode-cache template to fill (prefill).
    Returns (hidden (B, S_tot, d), new_cache, aux_loss).
    """
    with _model_kernel_scope(cfg, part):
        return _forward(params, cfg, tokens, extra_embeds=extra_embeds,
                        frames=frames, cache=cache, part=part)


def _forward(params, cfg: ModelConfig, tokens, *, extra_embeds=None,
             frames=None, cache=None, part=None):
    x = embed_tokens(params, cfg, tokens, extra_embeds)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    if cfg.learned_pos and "pos_embed" in params:
        x = x + params["pos_embed"]["table"][:S][None].astype(x.dtype)
    if part is not None:
        x = part.act(x, ("batch", None, None))
    enc_out = None
    if cfg.encoder is not None:
        enc_out = encode(params, cfg, frames, part=part)
    x, new_cache, aux = _apply_layers(params, cfg, x, positions=positions,
                                      enc_out=enc_out, cache=cache, pos=None,
                                      mode="full", part=part)
    return x, new_cache, aux


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, *, part=None,
                active=None, block_tables=None):
    """One decode step. tokens: (B, 1) int32; pos: scalar int32 (absolute,
    all sequences aligned) or (B,) int32 (per-slot continuous batching).

    ``active`` ((B,) bool): gate cache writes per slot — slots not in the
    decode phase (free, or mid chunked-prefill) keep their cache/state
    untouched. ``block_tables`` ((B, P) int32): paged KV layout (the cache's
    full-attention leaves are global block pools). Returns
    (logits (B, 1, V), new_cache).
    """
    with _model_kernel_scope(cfg, part):
        return _decode_step(params, cfg, cache, tokens, pos, part=part,
                            active=active, block_tables=block_tables)


def _decode_step(params, cfg: ModelConfig, cache, tokens, pos, *, part=None,
                 active=None, block_tables=None):
    x = embed_tokens(params, cfg, tokens)
    if cfg.learned_pos and "pos_embed" in params:
        tab = params["pos_embed"]["table"]
        if jnp.ndim(pos) > 0:
            x = x + tab[pos][:, None].astype(x.dtype)
        else:
            x = x + jax.lax.dynamic_slice_in_dim(tab, pos, 1, 0)[None].astype(x.dtype)
    x, new_cache, _ = _apply_layers(params, cfg, x, positions=None,
                                    enc_out=None, cache=cache, pos=pos,
                                    mode="decode", part=part, active=active,
                                    block_tables=block_tables)
    logits = logits_fn(params, cfg, x, part)[..., :cfg.vocab_size]
    return logits, new_cache


def extend_step(params, cfg: ModelConfig, cache, tokens, pos, n_valid, slot,
                *, block_tables=None, first_new_pos=0, part=None):
    """Chunked-prefill step: extend ONE slot of the pooled cache by up to T
    tokens. tokens: (1, T) int32 at absolute positions ``pos..pos+T-1``;
    ``n_valid`` (traced scalar) marks the ragged tail — padded positions
    write nothing and never contaminate valid state (attention is causal,
    recurrences take identity steps past ``n_valid``). ``slot`` (traced
    scalar) selects the slot; ``block_tables`` selects the paged layout.
    ``first_new_pos`` (traced scalar) is where this request's prefill
    started: > 0 when a prefix-cache hit mapped positions below it from
    shared pool blocks, so the first chunk begins mid-sequence and the
    paged snapshot below ``first_new_pos`` is readable.

    All of pos/n_valid/slot/first_new_pos trace as scalars, so ONE compiled
    shape serves every chunk of every prompt length, cached prefix or not.
    ``part`` (serve-mode partitioner): the chunk runs under SPMD with the
    pool scatters/gathers partitioned by KV head — the per-layer math is
    identical, so sharded chunked prefill is token-exact with local.
    Returns (logits (1, 1, V) at the last valid position, new_cache).
    """
    with _model_kernel_scope(cfg, part):
        return _extend_step(params, cfg, cache, tokens, pos, n_valid, slot,
                            block_tables=block_tables,
                            first_new_pos=first_new_pos, part=part)


def _extend_step(params, cfg: ModelConfig, cache, tokens, pos, n_valid, slot,
                 *, block_tables=None, first_new_pos=0, part=None):
    x = embed_tokens(params, cfg, tokens)
    T = x.shape[1]
    if cfg.learned_pos and "pos_embed" in params:
        positions = pos + jnp.arange(T, dtype=jnp.int32)
        x = x + params["pos_embed"]["table"][positions][None].astype(x.dtype)
    x, new_cache, _ = _apply_layers(params, cfg, x, positions=None,
                                    enc_out=None, cache=cache, pos=pos,
                                    mode="extend", part=None,
                                    block_tables=block_tables, slot=slot,
                                    n_valid=n_valid,
                                    first_new_pos=first_new_pos)
    h_last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, 1)
    logits = logits_fn(params, cfg, h_last, part)[..., :cfg.vocab_size]
    return logits, new_cache


def verify_step(params, cfg: ModelConfig, cache, tokens, pos, n_valid, *,
                active=None, block_tables=None, part=None):
    """Speculative-verification step: score T tokens for EVERY slot in one
    pass. tokens: (B, T) int32 — slot b's rows sit at absolute positions
    ``pos[b] .. pos[b]+T-1``; ``n_valid`` ((B,) int32) marks each slot's
    ragged tail (padded rows write nothing); ``active`` ((B,) bool) gates
    whole slots exactly like ``decode_step``. Paged all-full-attention
    configs only (the serve engine gates speculation on the same predicate
    as the prefix cache). T is static, so one compiled shape serves every
    scheduler turn at a given ``spec_k``.

    Returns (logits (B, T, V) — row t scores position ``pos+t``'s NEXT
    token — and the new cache with all T KV rows written; the engine rolls
    uncommitted rows back by never advancing ``slot_pos`` past the accepted
    prefix, and releasing any speculative pages through the allocator).
    """
    with _model_kernel_scope(cfg, part):
        return _verify_step(params, cfg, cache, tokens, pos, n_valid,
                            active=active, block_tables=block_tables,
                            part=part)


def _verify_step(params, cfg: ModelConfig, cache, tokens, pos, n_valid, *,
                 active=None, block_tables=None, part=None):
    x = embed_tokens(params, cfg, tokens)
    B, T = tokens.shape
    if cfg.learned_pos and "pos_embed" in params:
        positions = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
        x = x + params["pos_embed"]["table"][positions].astype(x.dtype)
    x, new_cache, _ = _apply_layers(params, cfg, x, positions=None,
                                    enc_out=None, cache=cache, pos=pos,
                                    mode="verify", part=None, active=active,
                                    block_tables=block_tables,
                                    n_valid=n_valid)
    logits = logits_fn(params, cfg, x, part)[..., :cfg.vocab_size]
    return logits, new_cache


def lm_loss(params, cfg: ModelConfig, tokens, targets, *, extra_embeds=None,
            frames=None, part=None, loss_chunk: int | None = None):
    """Next-token cross-entropy. targets: (B, S_txt) aligned to the text part.

    With ``loss_chunk``, logits are computed and reduced per sequence chunk
    (never materializing (B, S, V)) — the ogopogo memory optimization.
    """
    hidden, _, aux = forward(params, cfg, tokens, extra_embeds=extra_embeds,
                             frames=frames, part=part)
    if extra_embeds is not None:
        hidden = hidden[:, extra_embeds.shape[1]:]
    lc = cfg.loss_chunk if loss_chunk is None else loss_chunk

    if not lc or lc >= hidden.shape[1]:
        logits = logits_fn(params, cfg, hidden, part)
        loss = _xent(logits, targets)
    else:
        B, S, d = hidden.shape
        n = S // lc
        hs = hidden[:, :n * lc].reshape(B, n, lc, d).transpose(1, 0, 2, 3)
        ts = targets[:, :n * lc].reshape(B, n, lc).transpose(1, 0, 2)

        def body(acc, ht):
            h, t = ht
            lg = logits_fn(params, cfg, h, part)
            return acc + _xent(lg, t) * t.size, None

        # remat the chunk: recompute (B, lc, V) logits in backward instead of
        # letting scan save every chunk's logits (which would defeat chunking)
        body = jax.checkpoint(body, prevent_cse=False)
        tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ts))
        loss = tot / (B * n * lc)
        if S > n * lc:  # ragged tail
            lg = logits_fn(params, cfg, hidden[:, n * lc:], part)
            loss = (loss * (B * n * lc) + _xent(lg, targets[:, n * lc:])
                    * (B * (S - n * lc))) / (B * S)
    return loss + 0.01 * aux


def _xent(logits, targets):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)

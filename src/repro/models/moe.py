"""Mixture-of-Experts: shared + routed experts with top-k gating.

Dispatch is the framework's software analogue of Ogopogo's *packed irregular
streams* (paper §IV-A): each token emits k narrow "requests" (its expert
assignments); we pack them into dense, MXU-aligned per-expert blocks
``[E, C, d]`` before the grouped GEMM, exactly as the paper's DMA extension
packs narrow indexed accesses into wide NoC flits. Tokens are grouped along
the batch axis so the sort/pack stays within a data shard (no cross-device
traffic for routing metadata); the all-to-all happens once, on the packed
blocks, when experts are sharded over the 'model' axis (expert parallelism).

Two dispatch paths:
  * ``dispatch="sort"`` (default): argsort-based packing with capacity drop —
    the paper-faithful packed-stream analogue.
  * ``dispatch="dense"``: one-hot einsum dispatch (GShard-style) — simpler,
    used as the correctness oracle in tests.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.kernels import dispatch as kdispatch
from repro.kernels import ops as kops
from repro.models.layers import Params, apply_mlp, dense_init, mlp_init


def moe_init(rng, cfg: ModelConfig, dtype) -> Params:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(rng, 6)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(m.d_expert)
    p: Params = {
        "router": {"kernel": dense_init(ks[0], d, m.n_experts, jnp.float32)},
        "experts": {
            "gate": (jax.random.normal(ks[1], (m.n_experts, d, m.d_expert), jnp.float32)
                     * scale_in).astype(dtype),
            "up": (jax.random.normal(ks[2], (m.n_experts, d, m.d_expert), jnp.float32)
                   * scale_in).astype(dtype),
            "down": (jax.random.normal(ks[3], (m.n_experts, m.d_expert, d), jnp.float32)
                     * scale_out).astype(dtype),
        },
    }
    if m.shared_hidden:
        p["shared"] = mlp_init(ks[4], d, m.shared_hidden, True, dtype)
        if m.shared_gate:
            p["shared_gate"] = {"kernel": dense_init(ks[5], d, 1, dtype)}
    return p


def capacity(m: MoEConfig, tokens_per_group: int) -> int:
    c = int(math.ceil(tokens_per_group * m.top_k / m.n_experts * m.capacity_factor))
    return max(c, 1)


def _route(p: Params, m: MoEConfig, x_f32: jnp.ndarray):
    """x_f32: (G, T, d) -> (gate_weights (G,T,k), expert_idx (G,T,k), aux_loss)."""
    logits = x_f32 @ p["router"]["kernel"].astype(jnp.float32)   # (G, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, m.top_k)                    # (G, T, k)
    if m.renorm_topk:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss (mean over groups)
    me = probs.mean(axis=1)                                       # (G, E)
    ce = jnp.zeros_like(me)
    ce = ce.at[jnp.arange(me.shape[0])[:, None, None],
               idx].add(1.0 / (idx.shape[1] * idx.shape[2]))
    aux = (me * ce).sum(-1).mean() * m.n_experts
    return gate, idx, aux


def _dispatch_sort(x, gate, idx, C: int, E: int):
    """Pack tokens into per-expert blocks. x: (T, d); gate/idx: (T, k).

    Returns (xe (E, C, d), combine meta) for one group.
    """
    T, k = idx.shape
    flat_e = idx.reshape(T * k)
    order = jnp.argsort(flat_e)                     # stable
    sorted_e = flat_e[order]
    sorted_tok = order // k
    # position within expert segment = i - first index of that expert value
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    seg_pos = jnp.arange(T * k) - first
    keep = seg_pos < C
    dest = jnp.where(keep, sorted_e * C + seg_pos, E * C)  # overflow row dropped
    xe_flat = jnp.zeros((E * C + 1, x.shape[-1]), x.dtype)
    # the token-row stream is an indexed gather — the paper's packed
    # irregular streams, issued through the *packed* gather (rows coalesced
    # into wide flits in index order, unpermuted after). Registry-dispatched
    # only under an explicit use_backend scope (forward/inference): the
    # Pallas gather defines no JVP, and ambient auto-detection must never
    # reroute a training graph.
    if kdispatch.kernel_scope_active():
        gathered = kops.packed_gather_rows(x, sorted_tok)
    else:
        gathered = x[sorted_tok]
    xe_flat = xe_flat.at[dest].set(gathered)
    xe = xe_flat[: E * C].reshape(E, C, x.shape[-1])
    meta = (dest, sorted_tok, order)
    return xe, meta


def _combine_sort(ye, meta, gate, T: int):
    dest, sorted_tok, order = meta
    E, C, d = ye.shape
    ye_flat = jnp.concatenate([ye.reshape(E * C, d),
                               jnp.zeros((1, d), ye.dtype)], axis=0)
    y_sorted = ye_flat[dest]                         # (T*k, d)
    gate_sorted = gate.reshape(-1)[order].astype(ye.dtype)
    out = jnp.zeros((T, d), ye.dtype)
    out = out.at[sorted_tok].add(y_sorted * gate_sorted[:, None])
    return out


def _expert_ffn_wq(p: Params, xe, compute_dtype):
    """Quantized expert FFN under a kernel scope: each expert's (d, f)
    int8/fp8 weight slab dispatches the ``gemm_wq`` registry op (in-tile
    dequant, fused silu epilogue) — the per-expert grouped GEMM as E
    weight-quantized streaming GEMMs. xe: (G, E, C, d) -> (G, E, C, d)."""
    G, E, C, d = xe.shape
    wg, wu, wd = (p["experts"]["gate"], p["experts"]["up"],
                  p["experts"]["down"])
    outs = []
    for e in range(E):
        x_e = xe[:, e].reshape(G * C, d).astype(compute_dtype)
        h = (kops.gemm_wq(x_e, wg.q[e], wg.scales[e], act="silu")
             * kops.gemm_wq(x_e, wu.q[e], wu.scales[e])).astype(compute_dtype)
        y = kops.gemm_wq(h, wd.q[e], wd.scales[e])
        outs.append(y.reshape(G, C, d))
    return jnp.stack(outs, axis=1).astype(compute_dtype)


def sparsify_experts(p: Params, density: float,
                     *, block: tuple[int, int] = (16, 16)) -> Params:
    """Magnitude block-prune the routed expert FFN weights to ``density``.

    Returns a new params tree whose ``experts/{gate,up,down}`` slabs are
    hard-zeroed outside the kept blocks (so the XLA einsum path and the
    ``gemm_sparse`` kernel path compute the *same* function) plus matching
    per-expert block masks under ``experts/{gate,up,down}_mask`` — the
    operand :func:`_expert_ffn` dispatches through the block-skipping
    kernel under a kernel scope. ``block`` is the (K, N) prune granularity.
    """
    from repro.kernels.gemm_sparse import (apply_block_mask,
                                           block_mask_from_weight)
    ex = dict(p["experts"])
    for name in ("gate", "up", "down"):
        w = ex[name]
        masks = jax.vmap(
            lambda we: block_mask_from_weight(we, block[0], block[1],
                                              density))(w)
        ex[name] = jax.vmap(apply_block_mask)(w, masks).astype(w.dtype)
        ex[name + "_mask"] = masks
    out = dict(p)
    out["experts"] = ex
    return out


def _expert_ffn_sparse(p: Params, xe, compute_dtype):
    """Block-sparse expert FFN under a kernel scope: each expert's pruned
    (d, f) slab dispatches ``gemm_sparse`` with its block mask — masked
    blocks skip the MXU issue entirely (the paper's SpMM utilization arc).
    xe: (G, E, C, d) -> (G, E, C, d)."""
    G, E, C, d = xe.shape
    ex = p["experts"]
    outs = []
    for e in range(E):
        x_e = xe[:, e].reshape(G * C, d).astype(compute_dtype)
        h = (kops.gemm_sparse(x_e, ex["gate"][e].astype(compute_dtype),
                              ex["gate_mask"][e], act="silu")
             * kops.gemm_sparse(x_e, ex["up"][e].astype(compute_dtype),
                                ex["up_mask"][e])).astype(compute_dtype)
        y = kops.gemm_sparse(h, ex["down"][e].astype(compute_dtype),
                             ex["down_mask"][e])
        outs.append(y.reshape(G, C, d))
    return jnp.stack(outs, axis=1).astype(compute_dtype)


def _expert_ffn(p: Params, xe, act: str, compute_dtype, part=None):
    """xe: (G, E, C, d) -> (G, E, C, d) through per-expert gated FFN.

    Sharding: expert-parallel over 'model' when E divides the axis (deepseek-
    moe's 64); otherwise the packed capacity dim is sharded instead (qwen2-
    moe's 60 experts) — C is rounded up to the axis size by the caller.
    Quantized expert weights (QuantTensor — see repro.quant) dequantize via
    ``astype`` on the XLA path; under an explicit kernel scope the local
    path dispatches the weight-quantized grouped GEMM instead. Block-pruned
    experts (:func:`sparsify_experts`) dispatch the block-skipping
    ``gemm_sparse`` under a kernel scope; on the XLA path their hard-zeroed
    slabs make the einsum numerically identical.
    """
    from repro.quant import QuantTensor

    if (part is None and isinstance(p["experts"]["gate"], QuantTensor)
            and kdispatch.kernel_scope_active()):
        return _expert_ffn_wq(p, xe.astype(compute_dtype), compute_dtype)
    if (part is None and "gate_mask" in p["experts"]
            and kdispatch.kernel_scope_active()):
        return _expert_ffn_sparse(p, xe.astype(compute_dtype), compute_dtype)
    w_g = p["experts"]["gate"].astype(compute_dtype)
    w_u = p["experts"]["up"].astype(compute_dtype)
    w_d = p["experts"]["down"].astype(compute_dtype)
    xe = xe.astype(compute_dtype)
    spec = ("batch", "experts", None, None)
    if part is not None:
        if part.logical_size("experts") <= 1:
            spec = ("batch", None, "cap", None)
        xe = part.act(xe, spec)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, w_g)) * jnp.einsum(
        "gecd,edf->gecf", xe, w_u)
    ye = jnp.einsum("gecf,efd->gecd", h, w_d)
    if part is not None:
        ye = part.act(ye, spec)
    return ye


# --------------------------------------------------------------------------
# expert-parallel shard_map dispatch — the paper's "packed irregular streams"
# (C5c) made explicit: tokens' narrow per-slot requests are packed into dense
# per-expert blocks, routed to the expert's shard, and the combine returns as
# an in-network reduction (psum over 'model'), like Ogopogo's in-router joins.
# --------------------------------------------------------------------------
def _slots_for_experts(idx, gate, C: int, E_pad: int):
    """Per group: build (E_pad, C) slot->token and slot->gate maps. idx/gate:
    (T, k). Token index T means 'empty slot'."""
    T, k = idx.shape
    flat_e = idx.reshape(T * k)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    sorted_tok = (order // k).astype(jnp.int32)
    sorted_gate = gate.reshape(T * k)[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    seg_pos = jnp.arange(T * k) - first
    # slot (e, c) <- sorted position p where sorted_e[p] == e and seg_pos == c
    dest = jnp.where(seg_pos < C, sorted_e * C + seg_pos, E_pad * C)
    slot_tok = jnp.zeros((E_pad * C + 1,), jnp.int32).at[dest].set(sorted_tok)
    filled = jnp.zeros((E_pad * C + 1,), jnp.bool_).at[dest].set(True)
    slot_tok = jnp.where(filled, slot_tok, T)[:E_pad * C]
    slot_gate = jnp.zeros((E_pad * C + 1,), jnp.float32).at[dest].set(
        sorted_gate.astype(jnp.float32))[:E_pad * C]
    return slot_tok.reshape(E_pad, C), slot_gate.reshape(E_pad, C)


def moe_forward_ep(p: Params, cfg: ModelConfig, x, *, compute_dtype, part):
    """shard_map expert-parallel MoE: experts (padded up to the 'model' axis
    size) live on their shard; packed per-expert blocks are gathered locally
    and partial outputs joined with one psum (Ogopogo's in-router join)."""
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    mesh = part.mesh
    n_model = mesh.shape["model"]
    batch_axes = part.axis_map["batch"]
    # 2D-EP (fsdp2d): batch is ALSO sharded over 'model'. Each expert shard
    # all-gathers its data-row's token groups over 'model', runs its local
    # experts on all of them, and reduce-scatters the combined outputs back —
    # the paper's packed-stream dispatch staged over both mesh axes.
    two_d = "model" in (batch_axes or ())
    B, S, d = x.shape
    G = B if S > 1 else 1
    T = (B * S) // G
    E, k = m.n_experts, m.top_k
    E_pad = -(-E // n_model) * n_model
    e_loc = E_pad // n_model
    n_batch_shards = part.logical_size("batch")
    if S > 1:
        C = capacity(m, T)
        bspec = P(batch_axes, None, None)
    else:
        # decode: one group; tokens shard over the batch axes; drop-free C
        C = max(1, T // max(n_batch_shards, 1))
        bspec = P(None, batch_axes, None)
    xc = x.reshape(G, T, d)

    # pad expert weights to E_pad on the compute-dtype copies
    def padw(w):
        w = w.astype(compute_dtype)
        if E_pad > E:
            w = jnp.concatenate(
                [w, jnp.zeros((E_pad - E,) + w.shape[1:], w.dtype)], axis=0)
        return w

    wg, wu, wd = (padw(p["experts"]["gate"]), padw(p["experts"]["up"]),
                  padw(p["experts"]["down"]))
    router = p["router"]["kernel"].astype(jnp.float32)
    wspec = P("model", None, None)

    def body(xl, rl, wgl, wul, wdl):
        if two_d and S > 1:
            # gather this data-row's groups from every model shard
            xl = jax.lax.all_gather(xl, "model", axis=0, tiled=True)
        gl, tl, _ = xl.shape
        logits = xl.astype(jnp.float32) @ rl                   # (gl, tl, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, idx = jax.lax.top_k(probs, k)
        if m.renorm_topk:
            gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
        me = probs.mean(axis=1)
        ce = jnp.zeros_like(me).at[
            jnp.arange(gl)[:, None, None], idx].add(1.0 / (tl * k))
        aux_g = (me * ce).sum(-1) * E                          # (gl,)

        slot_tok, slot_gate = jax.vmap(
            lambda ii, gg: _slots_for_experts(ii, gg, C, E_pad))(idx, gate)
        e0 = jax.lax.axis_index("model") * e_loc
        my_tok = jax.lax.dynamic_slice_in_dim(slot_tok, e0, e_loc, axis=1)
        my_gate = jax.lax.dynamic_slice_in_dim(slot_gate, e0, e_loc, axis=1)

        # pack: gather tokens into my experts' dense blocks (empty slot -> 0)
        xpad = jnp.concatenate(
            [xl, jnp.zeros((gl, 1, d), xl.dtype)], axis=1)     # row tl = zeros
        xe = jax.vmap(lambda xg, tk: xg[tk])(xpad, my_tok)     # (gl, e_loc, C, d)
        xe = xe.astype(compute_dtype)
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, wgl)) * jnp.einsum(
            "gecd,edf->gecf", xe, wul)
        ye = jnp.einsum("gecf,efd->gecd", h, wdl)              # (gl, e_loc, C, d)
        ye = ye * my_gate[..., None].astype(ye.dtype)

        # combine: scatter-add my experts' slots back, join across shards
        def comb(yg, tk):
            return jnp.zeros((tl + 1, d), ye.dtype).at[
                tk.reshape(-1)].add(yg.reshape(-1, d))[:tl]
        y = jax.vmap(comb)(ye, my_tok)                         # (gl, tl, d)
        if two_d and S > 1:
            # in-network join + return each group to its model shard
            y = jax.lax.psum_scatter(y, "model", scatter_dimension=0,
                                     tiled=True)
            j = jax.lax.axis_index("model")
            g_per = gl // jax.lax.psum(1, "model")
            aux_g = jax.lax.dynamic_slice_in_dim(aux_g, j * g_per, g_per, 0)
        else:
            y = jax.lax.psum(y, "model")
        return y, aux_g

    from repro.core.collectives import shard_map_compat
    y, aux_g = shard_map_compat(
        body, mesh=mesh,
        in_specs=(bspec, P(None, None), wspec, wspec, wspec),
        out_specs=(bspec, P(bspec[0] if G > 1 else None)))(xc, router, wg, wu, wd)
    return y.reshape(B, S, d).astype(x.dtype), aux_g.mean()


def moe_forward(p: Params, cfg: ModelConfig, x, *, compute_dtype, part=None,
                dispatch: str = "sort"):
    """x: (B, S, d). Returns (y, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    if (part is not None and dispatch == "sort"
            and part.axis_size("model") > 1 and part.strategy.expert_parallel):
        y, aux = moe_forward_ep(p, cfg, x, compute_dtype=compute_dtype,
                                part=part)
        return _add_shared(p, cfg, x, y, compute_dtype, part), aux
    G = B if S > 1 else 1                   # group along batch; decode: one group
    T = (B * S) // G
    xg = x.reshape(G, T, d)
    gate, idx, aux = _route(p, m, xg.astype(jnp.float32))
    C = capacity(m, T)
    E = m.n_experts
    if part is not None and part.logical_size("experts") <= 1:
        mult = part.logical_size("cap")
        if mult > 1:  # round capacity up so the packed dim shards evenly
            C = -(-C // mult) * mult

    if dispatch == "dense":
        onehot = jax.nn.one_hot(idx, E, dtype=compute_dtype)      # (G, T, k, E)
        comb = (onehot * gate[..., None].astype(compute_dtype)).sum(2)  # (G, T, E)
        xe = jnp.einsum("gtd,gte->getd", xg.astype(compute_dtype), onehot.sum(2))
        ye = _expert_ffn(p, xe, cfg.act, compute_dtype, part)
        y = jnp.einsum("getd,gte->gtd", ye, comb)
    else:
        xe, meta = jax.vmap(lambda xx, gg, ii: _dispatch_sort(xx, gg, ii, C, E))(
            xg, gate, idx)
        ye = _expert_ffn(p, xe, cfg.act, compute_dtype, part)
        y = jax.vmap(lambda yy, mm_a, mm_b, mm_c, gg: _combine_sort(
            yy, (mm_a, mm_b, mm_c), gg, T))(ye, *meta, gate)

    y = y.reshape(B, S, d).astype(x.dtype)
    return _add_shared(p, cfg, x, y, compute_dtype, part), aux


def _add_shared(p: Params, cfg: ModelConfig, x, y, compute_dtype, part=None):
    m = cfg.moe
    if not m.shared_hidden:
        return y
    ys = apply_mlp(p["shared"], x, cfg.act, True, compute_dtype, part=part)
    if m.shared_gate:
        g = jax.nn.sigmoid((x.astype(compute_dtype)
                            @ p["shared_gate"]["kernel"].astype(compute_dtype)))
        ys = (ys.astype(compute_dtype) * g).astype(x.dtype)
    return y + ys

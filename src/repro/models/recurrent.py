"""Recurrent mixers: RG-LRU (Griffin / RecurrentGemma) and Mamba-1 SSM.

Both reduce to the diagonal linear recurrence ``h_t = a_t * h_{t-1} + b_t``.
``diag_scan`` evaluates it chunked: an outer ``lax.scan`` over sequence chunks
(carrying the state) with an inner ``associative_scan`` within each chunk.
This is the paper's C1 recipe (keep the working set in SPM / VMEM, stream
tiles, double-buffer) applied to a recurrence — and it is the oracle for the
Pallas ``lru_scan`` kernel.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import dispatch as kdispatch
from repro.models.layers import Params, causal_conv1d, dense_init


# --------------------------------------------------------------------------
# diagonal recurrence
# --------------------------------------------------------------------------
def diag_scan(a, b, h0, chunk: int):
    """h_t = a_t * h_{t-1} + b_t along axis 1. a, b: (B, L, D) fp32.

    Returns (h (B, L, D), h_last (B, D)). Chunked: memory ~ O(B*chunk*D).
    """
    B, L, D = a.shape
    if kdispatch.kernel_scope_active():
        # registry-dispatched Pallas scan (forward/inference scopes). The
        # kernel runs from a zero state, so the carry-in is absorbed into the
        # first step: h_1 = a_1*h_0 + b_1.
        from repro.kernels import ops as kops
        b0 = b.at[:, 0].add(a[:, 0] * h0.astype(b.dtype))
        h = kops.lru_scan(a, b0, chunk=chunk)
        return h, h[:, -1]
    chunk = min(chunk, L)
    n = -(-L // chunk)
    pad = n * chunk - L
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    a = a.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    b = b.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    def body(h, ab):
        ac, bc = ab                                   # (B, chunk, D)
        A, Bc = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = A * h[:, None, :] + Bc                # prefix-applied to carry
        return h_all[:, -1, :], h_all

    if n > 1:
        body = jax.checkpoint(body, prevent_cse=False)
    h_last, hs = jax.lax.scan(body, h0, (a, b))
    h = hs.transpose(1, 0, 2, 3).reshape(B, n * chunk, D)
    return h[:, :L], h_last


def diag_scan_step(a, b, h):
    """Single decode step."""
    return a * h + b


# --------------------------------------------------------------------------
# RG-LRU block (Griffin recurrent block)
# --------------------------------------------------------------------------
N_BLOCKS = 8  # block-diagonal gate structure (Griffin §2.4)


def rglru_init(rng, cfg: ModelConfig, dtype) -> Params:
    r = cfg.rglru
    d = cfg.d_model
    w = r.lru_width or d
    bs = w // N_BLOCKS
    ks = jax.random.split(rng, 7)
    sc = 1.0 / math.sqrt(bs)
    p = {
        "x_proj": {"kernel": dense_init(ks[0], d, w, dtype)},
        "gate_proj": {"kernel": dense_init(ks[1], d, w, dtype)},
        "out_proj": {"kernel": dense_init(ks[2], w, d, dtype)},
        "conv": {"kernel": (jax.random.normal(ks[3], (w, r.d_conv), jnp.float32)
                            / math.sqrt(r.d_conv)).astype(dtype)},
        "a_gate": {"kernel": (jax.random.normal(ks[4], (N_BLOCKS, bs, bs), jnp.float32)
                              * sc).astype(dtype)},
        "x_gate": {"kernel": (jax.random.normal(ks[5], (N_BLOCKS, bs, bs), jnp.float32)
                              * sc).astype(dtype)},
        # Lambda: init so that a = sigmoid(lambda) ** c is in ~(0.9, 0.999)
        "lam": jnp.asarray(jax.random.uniform(
            ks[6], (w,), jnp.float32, 2.0, 6.0), jnp.float32),
    }
    return p


def _block_diag_mm(x, w_blocks, compute_dtype):
    """x: (..., W); w_blocks: (NB, bs, bs) -> (..., W)."""
    nb, bs, _ = w_blocks.shape
    xs = x.reshape(x.shape[:-1] + (nb, bs)).astype(compute_dtype)
    y = jnp.einsum("...nb,nbc->...nc", xs, w_blocks.astype(compute_dtype))
    return y.reshape(x.shape)


def rglru_mix(p: Params, cfg: ModelConfig, xw, *, h0, compute_dtype,
              single_step: bool, valid=None):
    """Core RG-LRU on pre-conv features xw: (B, L, W) -> (y, h_last).

    ``valid`` ((L,) bool, full path only): invalid steps become the identity
    (a=1, b=0), so ``h_last`` equals the state at the last valid position —
    chunked prefill's ragged tail leaves the carry exact."""
    r = cfg.rglru
    c = r.c_exponent
    rt = jax.nn.sigmoid(_block_diag_mm(xw, p["a_gate"]["kernel"], compute_dtype)
                        .astype(jnp.float32))
    it = jax.nn.sigmoid(_block_diag_mm(xw, p["x_gate"]["kernel"], compute_dtype)
                        .astype(jnp.float32))
    log_a = -c * rt * jax.nn.softplus(p["lam"].astype(jnp.float32))  # log sigmoid**c
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * it * xw.astype(jnp.float32)
    if valid is not None:
        vm = valid[None, :, None]
        log_a = jnp.where(vm, log_a, 0.0)
        b = b * vm
    a = jnp.exp(log_a)
    if single_step:
        h = diag_scan_step(a[:, 0], b[:, 0], h0)
        return h[:, None, :], h
    h, h_last = diag_scan(a, b, h0, r.chunk)
    return h, h_last


def rglru_forward(p: Params, cfg: ModelConfig, x, *, state=None, compute_dtype,
                  part=None, single_step: bool = False, valid_len=None):
    """Full Griffin recurrent block. x: (B, L, d).

    state: None or {"h": (B, W), "conv": (B, K-1, W)}. ``valid_len`` (traced
    scalar, full path): only the first valid_len tokens are real — carries
    (h, conv) come out exact at that position (chunked-prefill ragged tail).
    Returns (out, new_state).
    """
    r = cfg.rglru
    B, L, d = x.shape
    w = r.lru_width or d
    xc = x.astype(compute_dtype)
    xb = xc @ p["x_proj"]["kernel"].astype(compute_dtype)         # (B, L, W)
    gb = xc @ p["gate_proj"]["kernel"].astype(compute_dtype)
    if part is not None:
        xb = part.act(xb, ("batch", None, "mlp"))
        gb = part.act(gb, ("batch", None, "mlp"))
    conv_state = None if state is None else state["conv"]
    xw, new_conv = causal_conv1d(xb, p["conv"]["kernel"], conv_state,
                                 valid_len=valid_len)
    h0 = (jnp.zeros((B, w), jnp.float32) if state is None
          else state["h"].astype(jnp.float32))
    valid = (None if valid_len is None or single_step
             else jnp.arange(L) < valid_len)
    h, h_last = rglru_mix(p, cfg, xw, h0=h0, compute_dtype=compute_dtype,
                          single_step=single_step, valid=valid)
    y = h.astype(compute_dtype) * jax.nn.gelu(gb, approximate=True)
    out = (y @ p["out_proj"]["kernel"].astype(compute_dtype)).astype(x.dtype)
    new_state = {"h": h_last.astype(jnp.float32), "conv": new_conv}
    return out, new_state


# --------------------------------------------------------------------------
# Mamba-1 block
# --------------------------------------------------------------------------
def mamba_init(rng, cfg: ModelConfig, dtype) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    dtr = s.dt_rank or math.ceil(d / 16)
    ks = jax.random.split(rng, 6)
    # S4D-real initialization for A
    A = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32)[None, :], (di, 1))
    p = {
        "in_proj": {"kernel": dense_init(ks[0], d, 2 * di, dtype)},
        "conv": {"kernel": (jax.random.normal(ks[1], (di, s.d_conv), jnp.float32)
                            / math.sqrt(s.d_conv)).astype(dtype)},
        "x_proj": {"kernel": dense_init(ks[2], di, dtr + 2 * s.d_state, dtype)},
        "dt_proj": {"kernel": dense_init(ks[3], dtr, di, dtype),
                    "bias": jnp.full((di,), -4.6, jnp.float32)},  # softplus≈0.01
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": {"kernel": dense_init(ks[4], di, d, dtype)},
    }
    return p


def _ssm_scan_chunked(xw, p, s, compute_dtype, h0, single_step: bool,
                      valid_len=None):
    """xw: (B, L, DI) post-conv post-silu. Returns (y (B,L,DI), h_last).

    The (dt, B, C) projections and the (DI, N)-expanded recurrence inputs are
    computed per chunk inside the scan so the O(L*DI*N) tensors never
    materialize for the full sequence. ``valid_len`` (traced scalar): steps
    past it are identity, so h_last is the state at the last valid position.
    """
    B, L, DI = xw.shape
    N = s.d_state
    dtr = p["x_proj"]["kernel"].shape[-1] - 2 * N
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (DI, N)

    def chunk_ssm(xc, h, valid=None):
        # xc: (B, c, DI); valid: (c,) bool or None — padded steps must be identity
        proj = xc @ p["x_proj"]["kernel"].astype(compute_dtype)   # (B, c, dtr+2N)
        dt, Bm, Cm = jnp.split(proj.astype(jnp.float32), [dtr, dtr + N], axis=-1)
        dt = jax.nn.softplus(dt @ p["dt_proj"]["kernel"].astype(jnp.float32)
                             + p["dt_proj"]["bias"])              # (B, c, DI)
        if valid is not None:
            dt = dt * valid[None, :, None].astype(jnp.float32)    # a->1, b->0 on pads
        a = jnp.exp(dt[..., None] * A)                            # (B, c, DI, N)
        xb = dt * xc.astype(jnp.float32)                          # (B, c, DI)
        b = xb[..., None] * Bm[:, :, None, :]                     # (B, c, DI, N)
        c_len = xc.shape[1]
        if single_step:
            h_new = a[:, 0].reshape(B, DI * N) * h + b[:, 0].reshape(B, DI * N)
            hs = h_new[:, None, :]
        else:
            hs, h_new = diag_scan(a.reshape(B, c_len, DI * N),
                                  b.reshape(B, c_len, DI * N), h, c_len)
        y = jnp.einsum("blds,bls->bld", hs.reshape(B, c_len, DI, N), Cm)
        y = y + p["D"].astype(jnp.float32) * xc.astype(jnp.float32)
        return y, h_new

    if single_step or L <= s.chunk:
        valid = (None if valid_len is None or single_step
                 else jnp.arange(L) < valid_len)
        y, h_last = chunk_ssm(xw, h0, valid)
        return y, h_last

    n = -(-L // s.chunk)
    pad = n * s.chunk - L
    xp = jnp.pad(xw, ((0, 0), (0, pad), (0, 0))) if pad else xw
    xs = xp.reshape(B, n, s.chunk, DI).transpose(1, 0, 2, 3)
    lim = L if valid_len is None else jnp.minimum(valid_len, L)
    valid = (jnp.arange(n * s.chunk) < lim).reshape(n, s.chunk)

    def body(h, xc_valid):
        xc, vd = xc_valid
        y, h_new = chunk_ssm(xc, h, vd)
        return h_new, y

    if n > 1:
        body = jax.checkpoint(body, prevent_cse=False)
    h_last, ys = jax.lax.scan(body, h0, (xs, valid))
    y = ys.transpose(1, 0, 2, 3).reshape(B, n * s.chunk, DI)[:, :L]
    return y, h_last


def mamba_forward(p: Params, cfg: ModelConfig, x, *, state=None, compute_dtype,
                  part=None, single_step: bool = False, valid_len=None):
    """Mamba-1 block. x: (B, L, d). state: {"h": (B, DI*N), "conv": (B, K-1, DI)}.

    ``valid_len``: see :func:`rglru_forward` — exact carries for chunked
    prefill's ragged tail."""
    s = cfg.ssm
    B, L, d = x.shape
    DI = s.expand * d
    xz = x.astype(compute_dtype) @ p["in_proj"]["kernel"].astype(compute_dtype)
    xi, z = jnp.split(xz, 2, axis=-1)                             # (B, L, DI)
    if part is not None:
        xi = part.act(xi, ("batch", None, "mlp"))
        z = part.act(z, ("batch", None, "mlp"))
    conv_state = None if state is None else state["conv"]
    xw, new_conv = causal_conv1d(xi, p["conv"]["kernel"], conv_state,
                                 valid_len=valid_len)
    xw = jax.nn.silu(xw.astype(jnp.float32)).astype(compute_dtype)
    h0 = (jnp.zeros((B, DI * s.d_state), jnp.float32) if state is None
          else state["h"].astype(jnp.float32))
    y, h_last = _ssm_scan_chunked(xw, p, s, compute_dtype, h0, single_step,
                                  valid_len=valid_len)
    y = y.astype(compute_dtype) * jax.nn.silu(z)
    out = (y @ p["out_proj"]["kernel"].astype(compute_dtype)).astype(x.dtype)
    return out, {"h": h_last.astype(jnp.float32), "conv": new_conv}

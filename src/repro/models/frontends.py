"""Modality frontend STUBS (per assignment: [audio]/[vlm] entries specify the
transformer backbone only; ``input_specs()`` provides precomputed frame/patch
embeddings).

The stubs define the *interface contract* (shapes/dtypes of the precomputed
embeddings) plus a deterministic synthetic generator used by smoke tests and
examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def frontend_spec(cfg: ModelConfig, batch: int):
    """ShapeDtypeStruct for the precomputed frontend embeddings, or None."""
    dt = jnp.dtype(cfg.dtype)
    if cfg.frontend == "audio":
        return jax.ShapeDtypeStruct((batch, cfg.encoder.n_frames, cfg.d_model), dt)
    if cfg.frontend == "vision":
        return jax.ShapeDtypeStruct((batch, cfg.n_frontend_tokens, cfg.d_model), dt)
    return None


def synth_frontend(cfg: ModelConfig, batch: int, seed: int = 0):
    spec = frontend_spec(cfg, batch)
    if spec is None:
        return None
    k = jax.random.PRNGKey(seed)
    return (jax.random.normal(k, spec.shape, jnp.float32) * 0.02).astype(spec.dtype)

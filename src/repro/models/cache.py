"""Decode-cache construction (KV buffers, recurrent states).

Two layouts share one pytree *structure* (so jitted decode graphs are
layout-agnostic up to leaf shapes):

* **dense** — every slot statically reserves ``max_len`` KV rows per
  full-attention layer: leaves ``(batch, S_buf, K, hd)``.
* **paged** — full-attention layers share a global pool of fixed-size
  blocks, ``(n_blocks, page_size, K, hd)``, addressed through per-slot
  block tables (``(batch, P)`` int32, owned by the serve engine and passed
  alongside the cache). Block 0 is the *null block*: never allocated,
  it absorbs masked/inactive writes. Local (sliding-window) ring buffers,
  recurrent (RG-LRU / Mamba) states, and cross-attention caches stay dense
  in both layouts — they are already O(window) / O(1) per slot.

With ``cfg.kv_dtype`` set to a quantized dtype ("int8" / "fp8"), the paged
pools store K/V at the narrow width plus per-row float16 absmax scales
(``k_scale``/``v_scale`` leaves, shape ``(n_blocks, page_size, K)``) —
writes quantize per token row, reads dequantize through the
``paged_attention`` registry op. The sizing helpers (:func:`kv_bytes`,
:func:`kv_block_bytes`, :func:`n_blocks_for_bytes`) count the storage
dtype, so the same HBM budget admits proportionally more blocks
(docs/quantization.md). Quantized KV is a paged-layout feature; dense
buffers keep the compute dtype.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.quant import canonical_dtype, is_quant_dtype

#: dtype of the paged pools' per-row absmax scales.
KV_SCALE_DTYPE = jnp.float16


def _layer_cache(spec: LayerSpec, cfg: ModelConfig, batch: int, max_len: int,
                 dtype, n_blocks: int = 0, page_size: int = 0) -> dict:
    c: dict = {}
    hd, K = cfg.resolved_head_dim, cfg.n_kv_heads
    if spec.mixer in ("full", "local"):
        if spec.mixer == "full" and n_blocks:
            # block-pool layout: global pool, no batch dim (slots address it
            # through block tables)
            kv_dt = dtype
            if is_quant_dtype(cfg.kv_dtype):
                kv_dt = jnp.dtype(canonical_dtype(cfg.kv_dtype))
            c["self"] = {"k": jnp.zeros((n_blocks, page_size, K, hd), kv_dt),
                         "v": jnp.zeros((n_blocks, page_size, K, hd), kv_dt)}
            if kv_dt != dtype:
                shp = (n_blocks, page_size, K)
                c["self"]["k_scale"] = jnp.zeros(shp, KV_SCALE_DTYPE)
                c["self"]["v_scale"] = jnp.zeros(shp, KV_SCALE_DTYPE)
        else:
            s_buf = max_len
            if spec.mixer == "local" and cfg.window:
                s_buf = min(cfg.window, max_len)
            c["self"] = {"k": jnp.zeros((batch, s_buf, K, hd), dtype),
                         "v": jnp.zeros((batch, s_buf, K, hd), dtype)}
        if cfg.encoder is not None:
            c["cross"] = {"k": jnp.zeros((batch, cfg.encoder.n_frames, K, hd), dtype),
                          "v": jnp.zeros((batch, cfg.encoder.n_frames, K, hd), dtype)}
    elif spec.mixer == "rglru":
        w = cfg.rglru.lru_width or cfg.d_model
        c["rec"] = {"h": jnp.zeros((batch, w), jnp.float32),
                    "conv": jnp.zeros((batch, cfg.rglru.d_conv - 1, w), dtype)}
    elif spec.mixer == "mamba":
        di = cfg.ssm.expand * cfg.d_model
        c["rec"] = {"h": jnp.zeros((batch, di * cfg.ssm.d_state), jnp.float32),
                    "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, di), dtype)}
    return c


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               n_blocks: int = 0, page_size: int = 0) -> Any:
    """Build the zeroed cache pytree matching the model's layer layout.

    ``n_blocks``/``page_size`` > 0 selects the paged (block-pool) layout for
    full-attention layers; everything else stays dense.
    """
    dtype = jnp.dtype(cfg.dtype)
    prefix, pattern, n_rep, rem = cfg.layer_specs()

    def mk(spec):
        return _layer_cache(spec, cfg, batch, max_len, dtype,
                            n_blocks=n_blocks, page_size=page_size)

    cache: dict = {}
    if prefix:
        cache["prefix"] = [mk(s) for s in prefix]
    if n_rep:
        per = [mk(s) for s in pattern]
        cache["blocks"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_rep,) + x.shape), per)
    if rem:
        cache["suffix"] = [mk(s) for s in rem]
    return cache


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def kv_bytes(cache, *, pool_n_blocks: int | None = None) -> int:
    """Bytes of self-attention KV storage (dense buffers + paged pools);
    recurrent states and cross caches excluded. With ``pool_n_blocks``,
    count only the paged pool leaves (those sized ``n_blocks`` on their
    batch-position axis)."""
    total = 0

    def f(path, leaf):
        nonlocal total
        keys = [getattr(k, "key", None) for k in path]
        if "self" not in keys:
            return
        if pool_n_blocks is not None:
            axis = 1 if "blocks" in keys else 0
            if leaf.shape[axis] != pool_n_blocks:
                return
        total += leaf.size * leaf.dtype.itemsize

    jax.tree_util.tree_map_with_path(f, cache)
    return total


def copy_block(cache, src, dst, n_blocks: int):
    """Copy pool block ``src`` into ``dst`` across every paged pool leaf —
    K, V, *and* the per-row quantization scales, which is what lets shared
    quantized pages round-trip exactly through prefix-cache copy-on-write.

    ``src``/``dst`` may be traced scalars (the serve engine jits this with
    the cache donated, so the copy cost is one page's rows, not the pool).
    Non-pool leaves (dense KV, ring buffers, recurrent states, cross
    caches) pass through untouched.
    """

    def f(path, leaf):
        keys = [getattr(k, "key", None) for k in path]
        if "self" not in keys:
            return leaf
        axis = 1 if "blocks" in keys else 0
        if leaf.shape[axis] != n_blocks:
            return leaf
        if axis == 0:
            return leaf.at[dst].set(leaf[src])
        return leaf.at[:, dst].set(leaf[:, src])

    return jax.tree_util.tree_map_with_path(f, cache)


def pages_per_slot(max_len: int, page_size: int) -> int:
    return -(-max_len // page_size)


def default_n_blocks(max_slots: int, max_len: int, page_size: int) -> int:
    """Dense-equivalent pool capacity plus the reserved null block."""
    return max_slots * pages_per_slot(max_len, page_size) + 1


def kv_block_bytes(cfg: ModelConfig, page_size: int) -> int:
    """KV bytes of ONE pool block summed over the paged (global-attention)
    layers, honoring ``cfg.kv_dtype`` — quantized pools count the storage
    width plus the per-row scale overhead (``2 × K`` fp16 scalars per row).
    """
    hd, K = cfg.resolved_head_dim, cfg.n_kv_heads
    kv_bytes_elem = 2 * jnp.dtype(cfg.dtype).itemsize          # K and V
    scale_bytes_row = 0
    if is_quant_dtype(cfg.kv_dtype):
        kv_bytes_elem = 2 * jnp.dtype(canonical_dtype(cfg.kv_dtype)).itemsize
        scale_bytes_row = 2 * jnp.dtype(KV_SCALE_DTYPE).itemsize
    n_paged = sum(1 for sp in cfg.all_layers() if sp.mixer == "full")
    per_row = K * (hd * kv_bytes_elem + scale_bytes_row)
    return n_paged * page_size * per_row


def n_blocks_for_bytes(cfg: ModelConfig, hbm_bytes: int, page_size: int,
                       kv_shard: int = 1) -> int:
    """Pool blocks (null block included) a *per-device* KV-HBM budget
    admits — the precision dividend: int8/fp8 KV roughly doubles/quadruples
    the blocks the same budget holds vs bf16/fp32. ``kv_shard`` (> 1 when a
    serve-mode partitioner shards the pools by KV head over the model axis)
    is the capacity dividend of scale-out: each block costs every device
    only ``1/kv_shard`` of its bytes, so the same per-device budget holds
    ``kv_shard×`` the blocks."""
    per_block = kv_block_bytes(cfg, page_size) // max(kv_shard, 1)
    return max(int(hbm_bytes // max(per_block, 1)), 1) + 1

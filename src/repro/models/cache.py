"""Decode-cache construction (KV buffers, recurrent states).

Two layouts share one pytree *structure* (so jitted decode graphs are
layout-agnostic up to leaf shapes):

* **dense** — every slot statically reserves ``max_len`` KV rows per
  full-attention layer: leaves ``(batch, S_buf, K, hd)``.
* **paged** — full-attention layers share a global pool of fixed-size
  blocks, ``(n_blocks, page_size, K, hd)``, addressed through per-slot
  block tables (``(batch, P)`` int32, owned by the serve engine and passed
  alongside the cache). Block 0 is the *null block*: never allocated,
  it absorbs masked/inactive writes. Local (sliding-window) ring buffers,
  recurrent (RG-LRU / Mamba) states, and cross-attention caches stay dense
  in both layouts — they are already O(window) / O(1) per slot.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig


def _layer_cache(spec: LayerSpec, cfg: ModelConfig, batch: int, max_len: int,
                 dtype, n_blocks: int = 0, page_size: int = 0) -> dict:
    c: dict = {}
    hd, K = cfg.resolved_head_dim, cfg.n_kv_heads
    if spec.mixer in ("full", "local"):
        if spec.mixer == "full" and n_blocks:
            # block-pool layout: global pool, no batch dim (slots address it
            # through block tables)
            c["self"] = {"k": jnp.zeros((n_blocks, page_size, K, hd), dtype),
                         "v": jnp.zeros((n_blocks, page_size, K, hd), dtype)}
        else:
            s_buf = max_len
            if spec.mixer == "local" and cfg.window:
                s_buf = min(cfg.window, max_len)
            c["self"] = {"k": jnp.zeros((batch, s_buf, K, hd), dtype),
                         "v": jnp.zeros((batch, s_buf, K, hd), dtype)}
        if cfg.encoder is not None:
            c["cross"] = {"k": jnp.zeros((batch, cfg.encoder.n_frames, K, hd), dtype),
                          "v": jnp.zeros((batch, cfg.encoder.n_frames, K, hd), dtype)}
    elif spec.mixer == "rglru":
        w = cfg.rglru.lru_width or cfg.d_model
        c["rec"] = {"h": jnp.zeros((batch, w), jnp.float32),
                    "conv": jnp.zeros((batch, cfg.rglru.d_conv - 1, w), dtype)}
    elif spec.mixer == "mamba":
        di = cfg.ssm.expand * cfg.d_model
        c["rec"] = {"h": jnp.zeros((batch, di * cfg.ssm.d_state), jnp.float32),
                    "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, di), dtype)}
    return c


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               n_blocks: int = 0, page_size: int = 0) -> Any:
    """Build the zeroed cache pytree matching the model's layer layout.

    ``n_blocks``/``page_size`` > 0 selects the paged (block-pool) layout for
    full-attention layers; everything else stays dense.
    """
    dtype = jnp.dtype(cfg.dtype)
    prefix, pattern, n_rep, rem = cfg.layer_specs()

    def mk(spec):
        return _layer_cache(spec, cfg, batch, max_len, dtype,
                            n_blocks=n_blocks, page_size=page_size)

    cache: dict = {}
    if prefix:
        cache["prefix"] = [mk(s) for s in prefix]
    if n_rep:
        per = [mk(s) for s in pattern]
        cache["blocks"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_rep,) + x.shape), per)
    if rem:
        cache["suffix"] = [mk(s) for s in rem]
    return cache


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def kv_bytes(cache, *, pool_n_blocks: int | None = None) -> int:
    """Bytes of self-attention KV storage (dense buffers + paged pools);
    recurrent states and cross caches excluded. With ``pool_n_blocks``,
    count only the paged pool leaves (those sized ``n_blocks`` on their
    batch-position axis)."""
    total = 0

    def f(path, leaf):
        nonlocal total
        keys = [getattr(k, "key", None) for k in path]
        if "self" not in keys:
            return
        if pool_n_blocks is not None:
            axis = 1 if "blocks" in keys else 0
            if leaf.shape[axis] != pool_n_blocks:
                return
        total += leaf.size * leaf.dtype.itemsize

    jax.tree_util.tree_map_with_path(f, cache)
    return total


def pages_per_slot(max_len: int, page_size: int) -> int:
    return -(-max_len // page_size)


def default_n_blocks(max_slots: int, max_len: int, page_size: int) -> int:
    """Dense-equivalent pool capacity plus the reserved null block."""
    return max_slots * pages_per_slot(max_len, page_size) + 1

"""Decode-cache construction (KV buffers, recurrent states)."""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig


def _layer_cache(spec: LayerSpec, cfg: ModelConfig, batch: int, max_len: int,
                 dtype) -> dict:
    c: dict = {}
    hd, K = cfg.resolved_head_dim, cfg.n_kv_heads
    if spec.mixer in ("full", "local"):
        s_buf = max_len
        if spec.mixer == "local" and cfg.window:
            s_buf = min(cfg.window, max_len)
        c["self"] = {"k": jnp.zeros((batch, s_buf, K, hd), dtype),
                     "v": jnp.zeros((batch, s_buf, K, hd), dtype)}
        if cfg.encoder is not None:
            c["cross"] = {"k": jnp.zeros((batch, cfg.encoder.n_frames, K, hd), dtype),
                          "v": jnp.zeros((batch, cfg.encoder.n_frames, K, hd), dtype)}
    elif spec.mixer == "rglru":
        w = cfg.rglru.lru_width or cfg.d_model
        c["rec"] = {"h": jnp.zeros((batch, w), jnp.float32),
                    "conv": jnp.zeros((batch, cfg.rglru.d_conv - 1, w), dtype)}
    elif spec.mixer == "mamba":
        di = cfg.ssm.expand * cfg.d_model
        c["rec"] = {"h": jnp.zeros((batch, di * cfg.ssm.d_state), jnp.float32),
                    "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, di), dtype)}
    return c


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    """Build the zeroed cache pytree matching the model's layer layout."""
    dtype = jnp.dtype(cfg.dtype)
    prefix, pattern, n_rep, rem = cfg.layer_specs()
    cache: dict = {}
    if prefix:
        cache["prefix"] = [_layer_cache(s, cfg, batch, max_len, dtype)
                           for s in prefix]
    if n_rep:
        per = [_layer_cache(s, cfg, batch, max_len, dtype) for s in pattern]
        cache["blocks"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_rep,) + x.shape), per)
    if rem:
        cache["suffix"] = [_layer_cache(s, cfg, batch, max_len, dtype)
                           for s in rem]
    return cache


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))

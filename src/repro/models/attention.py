"""Attention: GQA with RoPE, sliding windows, logit softcaps, qk-norm.

Training/prefill uses a chunked FlashAttention-2-style online-softmax scan in
pure jnp (``flash_attention_xla``) — this is both the production XLA path for
the CPU dry-run and the numerical oracle for the Pallas kernel
(kernels/flash_attention.py). The paper itself leverages FlashAttention-2 for
its GPT-J inference evaluation (§II-C), so this layer is paper-faithful.

Decode uses a single-query scoring path against a (possibly length-sharded)
KV cache — the context-parallel cache is the framework's analogue of spreading
Occamy's HBM channels across Ramora's mesh edge routers.
"""
from __future__ import annotations

import contextlib
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import dispatch as kdispatch
from repro.models.layers import Params, apply_norm, dense_init, norm_init, rope, softcap

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------
def attention_init(rng, cfg: ModelConfig, dtype) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(rng, 4)
    p = {
        "q_proj": {"kernel": dense_init(ks[0], d, cfg.n_heads * hd, dtype)},
        "k_proj": {"kernel": dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype)},
        "v_proj": {"kernel": dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype)},
        "o_proj": {"kernel": dense_init(ks[3], cfg.n_heads * hd, d, dtype)},
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_init(hd, "rmsnorm", dtype)
        p["k_norm"] = norm_init(hd, "rmsnorm", dtype)
    return p


def _scale(cfg: ModelConfig) -> float:
    s = cfg.attn_scale if cfg.attn_scale else cfg.resolved_head_dim
    return 1.0 / math.sqrt(s)


# --------------------------------------------------------------------------
# core chunked flash (online softmax) — jnp
# --------------------------------------------------------------------------
def flash_attention_xla(q, k, v, *, causal: bool, window: int, cap: float,
                        scale: float, q_chunk: int, kv_chunk: int,
                        q_offset=0, kv_lens=None, qc_constraint=None):
    """q: (B, Sq, K, G, D); k, v: (B, Skv, K, D). Returns (B, Sq, K, G, D).

    Online-softmax two-level scan (FlashAttention-2 schedule): outer over query
    chunks, inner over KV chunks with running (max, sum, acc) carried in fp32.
    ``window > 0`` masks to a sliding window; ``kv_lens`` (B,) masks ragged KV.
    ``q_offset`` is the absolute position of q[0] (decode/chunked prefill).
    """
    B, Sq, K, G, D = q.shape
    Skv = k.shape[1]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    n_q = -(-Sq // q_chunk)
    n_kv = -(-Skv // kv_chunk)
    pad_q = n_q * q_chunk - Sq
    pad_kv = n_kv * kv_chunk - Skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    kv_valid = Skv if kv_lens is None else kv_lens  # scalar or (B,)

    qs = q.reshape(B, n_q, q_chunk, K, G, D).transpose(1, 0, 3, 4, 2, 5)
    ks = k.reshape(B, n_kv, kv_chunk, K, D).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(B, n_kv, kv_chunk, K, D).transpose(1, 0, 3, 2, 4)

    def q_body(_, qi_qc):
        qi, qc = qi_qc  # qc: (B, K, G, q_chunk, D)
        if qc_constraint is not None:
            qc = qc_constraint(qc)  # context-parallel: shard the q-chunk dim
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_body(carry, ki_kc):
            m, l, acc = carry
            ki, kc, vc = ki_kc  # (B, K, kv_chunk, D)
            kv_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bkgqd,bkcd->bkgqc", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            s = softcap(s, cap)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= q_pos[:, None] >= kv_pos[None, :]
            if window:
                mask &= q_pos[:, None] - kv_pos[None, :] < window
            valid = kv_pos < (kv_valid if jnp.ndim(kv_valid) == 0
                              else kv_valid[:, None, None, None, None])
            if jnp.ndim(kv_valid) == 0:
                mask &= valid[None, :]
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            else:
                s = jnp.where(mask[None, None, None] & valid, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, K, G, q_chunk, D), jnp.float32)
        # checkpoint: recompute s/p per KV chunk in backward instead of saving
        # the (q_chunk, kv_chunk) probability tiles — the FlashAttention trade.
        body = (jax.checkpoint(kv_body, prevent_cse=False)
                if n_kv > 1 else kv_body)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0), (jnp.arange(n_kv), ks, vs))
        out = acc / jnp.maximum(l[..., None], 1e-37)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_body, None, (jnp.arange(n_q), qs))
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, n_q * q_chunk, K, G, D)
    return out[:, :Sq]


# --------------------------------------------------------------------------
# layer-level apply (projections + rope + attention)
# --------------------------------------------------------------------------
def _project_qkv(p: Params, cfg: ModelConfig, x, xkv, positions_q, positions_kv,
                 compute_dtype):
    B, Sq, _ = x.shape
    Skv = xkv.shape[1]
    hd, H, K = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    G = H // K
    xc = x.astype(compute_dtype)
    xkvc = xkv.astype(compute_dtype)
    q = (xc @ p["q_proj"]["kernel"].astype(compute_dtype)).reshape(B, Sq, K, G, hd)
    k = (xkvc @ p["k_proj"]["kernel"].astype(compute_dtype)).reshape(B, Skv, K, hd)
    v = (xkvc @ p["v_proj"]["kernel"].astype(compute_dtype)).reshape(B, Skv, K, hd)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm", cfg.norm_eps)
        k = apply_norm(p["k_norm"], k, "rmsnorm", cfg.norm_eps)
    if cfg.use_rope and positions_q is not None:
        qf = q.reshape(B, Sq, K * G, hd)
        qf = rope(qf, positions_q, cfg.rope_theta)
        q = qf.reshape(B, Sq, K, G, hd)
        k = rope(k, positions_kv, cfg.rope_theta)
    return q, k, v


def attention_forward(p: Params, cfg: ModelConfig, x, *, is_local: bool,
                      positions, compute_dtype, causal: bool = True,
                      xkv=None, positions_kv=None, part=None):
    """Full-sequence attention (training / prefill / encoder / cross).

    Returns (out, (k, v)) — k/v are RoPE-applied and cacheable.
    """
    xkv = x if xkv is None else xkv
    positions_kv = positions if positions_kv is None else positions_kv
    q, k, v = _project_qkv(p, cfg, x, xkv, positions, positions_kv, compute_dtype)
    k_cache, v_cache = k, v  # un-repeated, for the decode cache
    n_heads_eff = cfg.n_heads
    if part is not None:
        # GQA tensor-parallel layout selection:
        #  1. kv-heads divisible by 'model'  -> shard kv heads (grouped layout)
        #  2. q-heads divisible              -> repeat-KV to H heads, shard those
        #  3. otherwise -> repeat-KV, zero-pad heads up to the axis, shard
        #     (padded heads are sliced off before o_proj: exact)
        n_model = part.logical_size("heads")
        B_, Sq, K, G, D = q.shape
        if n_model > 1 and K % n_model == 0:
            q = part.act(q, ("batch", None, "heads", None, None))
            k = part.act(k, ("batch", None, "heads", None))
            v = part.act(v, ("batch", None, "heads", None))
        elif n_model > 1:
            H = K * G
            h_pad = (-(-H // n_model) * n_model) - H
            q = q.reshape(B_, Sq, H, 1, D)
            if G > 1:
                k = jnp.repeat(k, G, axis=2)
                v = jnp.repeat(v, G, axis=2)
            if h_pad:
                zq = ((0, 0), (0, 0), (0, h_pad), (0, 0), (0, 0))
                zk = ((0, 0), (0, 0), (0, h_pad), (0, 0))
                q = jnp.pad(q, zq)
                k = jnp.pad(k, zk)
                v = jnp.pad(v, zk)
                n_heads_eff = H + h_pad
            q = part.act(q, ("batch", None, "heads", None, None))
            k = part.act(k, ("batch", None, "heads", None))
            v = part.act(v, ("batch", None, "heads", None))
    window = cfg.window if is_local else 0
    backend = kdispatch.negotiated_model_backend(cfg.resolved_kernel_backend)
    if part is None and backend is not None:
        # registry-dispatched kernel (kernels/flash_attention.py) — local
        # path; the SPMD path uses the numerically-identical XLA flash
        # (tested equal), since a pallas_call inside pjit would need
        # shard_map. Shapes the kernel can't serve negotiate down to ref.
        from repro.kernels.ops import flash_attention as _reg_fa
        B_, Sq_, K_, G_, D_ = q.shape
        Skv_ = k.shape[1]
        qf = q.transpose(0, 2, 3, 1, 4).reshape(B_ * K_ * G_, Sq_, D_)
        kf = k.transpose(0, 2, 1, 3).reshape(B_ * K_, Skv_, D_)
        vf = v.transpose(0, 2, 1, 3).reshape(B_ * K_, Skv_, D_)
        with kdispatch.use_backend(backend):
            of = _reg_fa(qf, kf, vf, causal=causal, window=window,
                         cap=cfg.attn_softcap, scale=_scale(cfg))
        out = of.reshape(B_, K_, G_, Sq_, D_).transpose(0, 3, 1, 2, 4)
        out = out.astype(q.dtype)
    else:
        out = flash_attention_xla(
            q, k, v, causal=causal, window=window, cap=cfg.attn_softcap,
            scale=_scale(cfg), q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk)
    B, S = x.shape[:2]
    hd = cfg.resolved_head_dim
    out = out.reshape(B, S, -1, hd)[:, :, :cfg.n_heads].reshape(
        B, S, cfg.n_heads * hd)
    if part is not None:
        out = part.act(out, ("batch", None, "mlp"))
    out = (out @ p["o_proj"]["kernel"].astype(compute_dtype)).astype(x.dtype)
    return out, (k_cache, v_cache)


def attention_decode(p: Params, cfg: ModelConfig, x, cache: dict, *,
                     is_local: bool, pos, compute_dtype, part=None,
                     cross: bool = False, active=None, block_tables=None):
    """Single-token decode against a cache.

    cache: {"k": (B, S_buf, K, D), "v": ..., ["slot_pos": (S_buf,) implicit]}
    For local layers S_buf == window (ring buffer); global layers S_buf == max
    sequence length, optionally sharded over 'data' (context parallelism).
    ``pos``: absolute position of the incoming token — scalar int32 (all
    sequences aligned, the dry-run path) or (B,) int32 (per-slot positions,
    the continuous-batching serve path).

    ``block_tables`` ((B, P) int32) selects the *paged* layout: cache k/v are
    global (n_blocks, page, K, D) pools and position ``p`` of slot ``b``
    lives at row ``p % page`` of block ``tables[b, p // page]``; the read
    dispatches through the ``paged_attention`` registry op. ``active``
    ((B,) bool) gates cache writes per slot — inactive/prefilling slots
    route their write out of bounds (dropped), so a pooled decode step never
    scribbles on a slot that is not in the decode phase.
    Returns (out, new_cache).
    """
    vec_pos = jnp.ndim(pos) > 0  # per-slot positions (continuous batching)
    B = x.shape[0]
    hd, H, K = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    G = H // K
    xc = x.astype(compute_dtype)  # (B, 1, d)
    q = (xc @ p["q_proj"]["kernel"].astype(compute_dtype)).reshape(B, 1, K, G, hd)
    if cross:
        k_all, v_all = cache["k"], cache["v"]
        new_cache = cache
        if cfg.qk_norm:
            q = apply_norm(p["q_norm"], q, "rmsnorm", cfg.norm_eps)
        S_buf = k_all.shape[1]
        slot_pos = jnp.arange(S_buf)
        valid = slot_pos[None, :] < cache.get("len", S_buf)
    else:
        k = (xc @ p["k_proj"]["kernel"].astype(compute_dtype)).reshape(B, 1, K, hd)
        v = (xc @ p["v_proj"]["kernel"].astype(compute_dtype)).reshape(B, 1, K, hd)
        if cfg.qk_norm:
            q = apply_norm(p["q_norm"], q, "rmsnorm", cfg.norm_eps)
            k = apply_norm(p["k_norm"], k, "rmsnorm", cfg.norm_eps)
        if cfg.use_rope:
            posb = (pos[:, None].astype(jnp.int32) if vec_pos
                    else jnp.full((B, 1), pos, jnp.int32))
            qf = rope(q.reshape(B, 1, H, hd), posb, cfg.rope_theta)
            q = qf.reshape(B, 1, K, G, hd)
            k = rope(k, posb, cfg.rope_theta)
        if block_tables is not None:
            posv = pos if vec_pos else jnp.full((B,), pos, jnp.int32)
            return _paged_decode(p, cfg, q, k, v, cache, pos=posv,
                                 active=active, block_tables=block_tables,
                                 compute_dtype=compute_dtype, x_dtype=x.dtype,
                                 part=part)
        S_buf = cache["k"].shape[1]
        is_ring = is_local and cfg.window and S_buf == cfg.window
        if is_ring:
            slot = jnp.mod(pos, S_buf)
            # ring buffer: slot j holds absolute position p = pos - ((pos - j) mod S_buf)
            j = jnp.arange(S_buf)
            if vec_pos:
                slot_pos = pos[:, None] - jnp.mod(pos[:, None] - j[None, :], S_buf)
                slot_pos = jnp.where(j[None, :] == slot[:, None],
                                     pos[:, None], slot_pos)
            else:
                slot_pos = pos - jnp.mod(pos - j, S_buf)
                slot_pos = jnp.where(j == slot, pos, slot_pos)
        else:
            slot = pos
            slot_pos = jnp.arange(S_buf)
            if vec_pos:
                slot_pos = jnp.broadcast_to(slot_pos[None, :], (B, S_buf))
        if vec_pos:
            # per-slot write positions -> batched scatter; slots not in the
            # decode phase route their write out of bounds (dropped)
            bidx = jnp.arange(B)
            slot_w = slot if active is None else jnp.where(active, slot, S_buf)
            k_all = cache["k"].at[bidx, slot_w].set(
                k[:, 0].astype(cache["k"].dtype), mode="drop")
            v_all = cache["v"].at[bidx, slot_w].set(
                v[:, 0].astype(cache["v"].dtype), mode="drop")
        else:
            k_all = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
            v_all = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        new_cache = {"k": k_all, "v": v_all}
        posc = pos[:, None] if vec_pos else pos
        valid = (slot_pos <= posc) & (slot_pos >= 0)
        if is_local and cfg.window:
            valid &= slot_pos > posc - cfg.window
        if not vec_pos:
            valid = valid[None, :]
    if part is not None:
        axis = "kv" if (not cross and not (is_local and S_buf == cfg.window)) else None
        k_all = part.act(k_all, ("batch", axis, "heads", None))
        v_all = part.act(v_all, ("batch", axis, "heads", None))
    s = jnp.einsum("bokgd,bskd->bkgos", q, k_all.astype(compute_dtype),
                   preferred_element_type=jnp.float32) * _scale(cfg)
    s = softcap(s, cfg.attn_softcap)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgos,bskd->bokgd", w.astype(compute_dtype),
                     v_all.astype(compute_dtype),
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, H * hd).astype(compute_dtype)
    out = (out @ p["o_proj"]["kernel"].astype(compute_dtype)).astype(x.dtype)
    return out, new_cache


def _paged_decode(p: Params, cfg: ModelConfig, q, k, v, cache, *, pos, active,
                  block_tables, compute_dtype, x_dtype, part=None):
    """Single-token decode against the block-pool (paged) KV layout.

    q: (B, 1, K, G, D), k/v: (B, 1, K, D) — already projected, normed, and
    RoPE'd by ``attention_decode``; pos: (B,) int32. cache:
    {"k"/"v": (N, page, K, D)} global pools. The new token's K/V scatter
    into the slot's current block (inactive slots dropped via an
    out-of-bounds block id); the read gathers the slot's pages through
    ``ops.paged_attention``.

    Quantized pools (cache also carries ``k_scale``/``v_scale`` — see
    ``models/cache.py``): the incoming row is quantized per (slot, head)
    with its own absmax scale before the scatter, and the registry read
    dequantizes — KV crosses HBM at storage width both ways.
    """
    B = q.shape[0]
    hd, H = cfg.resolved_head_dim, cfg.n_heads
    pool_k, pool_v = cache["k"], cache["v"]
    quantized = "k_scale" in cache
    n_blocks, page = pool_k.shape[:2]
    blk = jnp.take_along_axis(block_tables, (pos // page)[:, None],
                              axis=1)[:, 0]
    if active is not None:
        blk = jnp.where(active, blk, n_blocks)  # OOB -> write dropped
    row = pos % page
    k_sc = v_sc = None
    if quantized:
        from repro.quant import quantize_kv
        kq, ks = quantize_kv(k[:, 0], str(cfg.kv_dtype))      # (B,K,hd),(B,K)
        vq, vs = quantize_kv(v[:, 0], str(cfg.kv_dtype))
        pool_k = pool_k.at[blk, row].set(kq.astype(pool_k.dtype), mode="drop")
        pool_v = pool_v.at[blk, row].set(vq.astype(pool_v.dtype), mode="drop")
        k_sc = cache["k_scale"].at[blk, row].set(
            ks.astype(cache["k_scale"].dtype), mode="drop")
        v_sc = cache["v_scale"].at[blk, row].set(
            vs.astype(cache["v_scale"].dtype), mode="drop")
    else:
        pool_k = pool_k.at[blk, row].set(k[:, 0].astype(pool_k.dtype),
                                         mode="drop")
        pool_v = pool_v.at[blk, row].set(v[:, 0].astype(pool_v.dtype),
                                         mode="drop")
    # registry read: an enclosing use_backend scope / cfg.kernel_backend
    # routes through the Pallas kernel; otherwise pin the gather-based ref
    # oracle (the XLA path) — ambient selection (env var / TPU auto) must
    # not reroute a model graph without explicit opt-in
    from repro.kernels.ops import paged_attention as _reg_pa
    be = (kdispatch.negotiated_model_backend(cfg.resolved_kernel_backend)
          or "ref")
    # serve-mode partitioner with KV-head-sharded pools: advertise the
    # layout so negotiation picks the shard_map'd impl (communication-free
    # per-shard reads); replicated pools fall through to the local paths
    serve_kv = (part.serve_kv_scope() if part is not None
                and getattr(part, "mode", None) == "serve"
                else contextlib.nullcontext())
    with serve_kv, kdispatch.use_backend(be):
        out = _reg_pa(q[:, 0], pool_k, pool_v, block_tables, pos + 1,
                      k_sc, v_sc, scale=_scale(cfg), cap=cfg.attn_softcap)
    out = out.reshape(B, 1, H * hd).astype(compute_dtype)
    out = (out @ p["o_proj"]["kernel"].astype(compute_dtype)).astype(x_dtype)
    new_cache = {"k": pool_k, "v": pool_v}
    if quantized:
        new_cache["k_scale"] = k_sc
        new_cache["v_scale"] = v_sc
    return out, new_cache


def attention_verify(p: Params, cfg: ModelConfig, x, cache: dict, *,
                     pos, n_valid, active, block_tables, compute_dtype):
    """Score T tokens per slot in ONE pass against the paged pools — the
    verifier side of speculative decoding, and the fork re-decode.

    x: (B, T, d) — slot b's tokens sit at absolute positions
    ``pos[b] .. pos[b]+T-1``; only the first ``n_valid[b]`` are real (the
    rest are padding whose writes drop and whose outputs are junk).
    ``active`` ((B,) bool) gates whole slots exactly like decode. Paged
    pools only: this is ``attention_extend``'s scatter/snapshot scheme
    batched over slots, with per-slot masks replacing the traced scalars.
    Rows past a slot's table (positions beyond ``P * page``) also drop, so
    a speculative chunk near ``max_len`` cannot scribble out of range.
    Returns (out (B, T, d), new_cache).
    """
    B, T = x.shape[:2]
    hd, H, K = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    G = H // K
    xc = x.astype(compute_dtype)
    q = (xc @ p["q_proj"]["kernel"].astype(compute_dtype)).reshape(B, T, K, G, hd)
    k = (xc @ p["k_proj"]["kernel"].astype(compute_dtype)).reshape(B, T, K, hd)
    v = (xc @ p["v_proj"]["kernel"].astype(compute_dtype)).reshape(B, T, K, hd)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm", cfg.norm_eps)
        k = apply_norm(p["k_norm"], k, "rmsnorm", cfg.norm_eps)
    positions = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None]  # (B, T)
    if cfg.use_rope:
        qf = rope(q.reshape(B, T, H, hd), positions, cfg.rope_theta)
        q = qf.reshape(B, T, K, G, hd)
        k = rope(k, positions, cfg.rope_theta)

    pool_k, pool_v = cache["k"], cache["v"]
    n_blocks, page = pool_k.shape[:2]
    n_pages = block_tables.shape[1]
    i = jnp.arange(T)[None, :]                                # (1, T)
    valid_q = (i < n_valid[:, None]) & (positions < n_pages * page)
    if active is not None:
        valid_q &= active[:, None]
    pg = jnp.clip(positions // page, 0, n_pages - 1)
    blk = jnp.take_along_axis(block_tables, pg, axis=1)       # (B, T)
    blk_w = jnp.where(valid_q, blk, n_blocks)                 # pads dropped
    rows = positions % page
    if "k_scale" in cache:
        from repro.quant import dequantize_kv, quantize_kv
        kq, ksc = quantize_kv(k, str(cfg.kv_dtype))   # (B,T,K,hd), (B,T,K)
        vq, vsc = quantize_kv(v, str(cfg.kv_dtype))
        new_cache = {
            "k": pool_k.at[blk_w, rows].set(kq.astype(pool_k.dtype),
                                            mode="drop"),
            "v": pool_v.at[blk_w, rows].set(vq.astype(pool_v.dtype),
                                            mode="drop"),
            "k_scale": cache["k_scale"].at[blk_w, rows].set(
                ksc.astype(cache["k_scale"].dtype), mode="drop"),
            "v_scale": cache["v_scale"].at[blk_w, rows].set(
                vsc.astype(cache["v_scale"].dtype), mode="drop"),
        }
        k_old = dequantize_kv(pool_k[block_tables],
                              cache["k_scale"][block_tables],
                              compute_dtype).reshape(B, n_pages * page, K, hd)
        v_old = dequantize_kv(pool_v[block_tables],
                              cache["v_scale"][block_tables],
                              compute_dtype).reshape(B, n_pages * page, K, hd)
    else:
        new_cache = {
            "k": pool_k.at[blk_w, rows].set(k.astype(pool_k.dtype),
                                            mode="drop"),
            "v": pool_v.at[blk_w, rows].set(v.astype(pool_v.dtype),
                                            mode="drop"),
        }
        k_old = pool_k[block_tables].reshape(B, n_pages * page, K, hd)
        v_old = pool_v[block_tables].reshape(B, n_pages * page, K, hd)
    old_pos = jnp.arange(n_pages * page)

    # per-slot masks: snapshot rows strictly below the slot's own pos (the
    # prefix-shared head is readable from 0, as in extend), intra-chunk
    # causal over the new keys with the ragged tail masked out
    mask_old = jnp.broadcast_to(
        (old_pos[None, None, :] < pos[:, None, None]),
        (B, T, old_pos.shape[0]))
    j = jnp.arange(T)
    mask_new = ((j[None, None, :] <= j[None, :, None])
                & (j[None, None, :] < n_valid[:, None, None]))
    s_old = jnp.einsum("btkgd,bskd->bkgts", q, k_old.astype(compute_dtype),
                       preferred_element_type=jnp.float32) * _scale(cfg)
    s_new = jnp.einsum("btkgd,bskd->bkgts", q, k.astype(compute_dtype),
                       preferred_element_type=jnp.float32) * _scale(cfg)
    s = softcap(jnp.concatenate([s_old, s_new], axis=-1), cfg.attn_softcap)
    mask = jnp.concatenate([mask_old, mask_new], axis=-1)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    vv = jnp.concatenate([v_old, v], axis=1).astype(compute_dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", w.astype(compute_dtype), vv,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, T, H * hd).astype(compute_dtype)
    out = (out @ p["o_proj"]["kernel"].astype(compute_dtype)).astype(x.dtype)
    return out, new_cache


def attention_extend(p: Params, cfg: ModelConfig, x, cache: dict, *,
                     is_local: bool, pos, n_valid, slot, compute_dtype,
                     block_tables=None, first_new_pos=0):
    """Extend ONE slot's cache by up to T tokens (chunked prefill).

    x: (1, T, d) tokens at absolute positions ``pos .. pos+T-1``; the first
    ``n_valid`` are real, the rest are ragged-tail padding — their cache
    writes are dropped (out-of-bounds scatter) and their outputs are junk
    that the caller slices off. ``cache`` is the POOL entry: dense
    ``(B, S_buf, K, D)`` buffers, or paged ``(n_blocks, page, K, D)`` pools
    with ``block_tables`` ((B, P) int32). ``slot`` is this request's slot.

    Attention reads combine a pre-write snapshot of the slot's cache (old
    positions ``< pos``) with the chunk's own K/V under an intra-chunk
    causal (and sliding-window) mask — so ring buffers stay exact even when
    the chunk wraps the window.

    ``first_new_pos`` (traced scalar) is the absolute position prefill
    started at: with prefix caching the paged snapshot rows below it were
    *mapped* from shared blocks (valid, readable mid-sequence), while in
    the dense layout nothing below it was ever written by this request —
    the snapshot mask keeps those stale rows of a reused slot out of the
    scores. Returns (out (1, T, d), new_cache).
    """
    T = x.shape[1]
    hd, H, K = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    G = H // K
    xc = x.astype(compute_dtype)
    q = (xc @ p["q_proj"]["kernel"].astype(compute_dtype)).reshape(1, T, K, G, hd)
    k = (xc @ p["k_proj"]["kernel"].astype(compute_dtype)).reshape(1, T, K, hd)
    v = (xc @ p["v_proj"]["kernel"].astype(compute_dtype)).reshape(1, T, K, hd)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm", cfg.norm_eps)
        k = apply_norm(p["k_norm"], k, "rmsnorm", cfg.norm_eps)
    positions = pos + jnp.arange(T, dtype=jnp.int32)          # (T,) absolute
    if cfg.use_rope:
        qf = rope(q.reshape(1, T, H, hd), positions[None], cfg.rope_theta)
        q = qf.reshape(1, T, K, G, hd)
        k = rope(k, positions[None], cfg.rope_theta)
    i = jnp.arange(T)
    valid_q = i < n_valid

    if block_tables is not None:
        # paged pools: scatter the chunk rows through the slot's block table
        pool_k, pool_v = cache["k"], cache["v"]
        n_blocks, page = pool_k.shape[:2]
        n_pages = block_tables.shape[1]
        table_row = jax.lax.dynamic_slice(
            block_tables, (slot, 0), (1, n_pages))[0]         # (P,)
        blk = table_row[positions // page]
        blk_w = jnp.where(valid_q, blk, n_blocks)             # pads dropped
        rows = positions % page
        if "k_scale" in cache:
            # quantized pools: per-row absmax quantize the chunk before the
            # scatter; the pre-write snapshot dequantizes at read
            from repro.quant import dequantize_kv, quantize_kv
            kq, ksc = quantize_kv(k[0], str(cfg.kv_dtype))    # (T,K,hd),(T,K)
            vq, vsc = quantize_kv(v[0], str(cfg.kv_dtype))
            new_cache = {
                "k": pool_k.at[blk_w, rows].set(kq.astype(pool_k.dtype),
                                                mode="drop"),
                "v": pool_v.at[blk_w, rows].set(vq.astype(pool_v.dtype),
                                                mode="drop"),
                "k_scale": cache["k_scale"].at[blk_w, rows].set(
                    ksc.astype(cache["k_scale"].dtype), mode="drop"),
                "v_scale": cache["v_scale"].at[blk_w, rows].set(
                    vsc.astype(cache["v_scale"].dtype), mode="drop"),
            }
            k_old = dequantize_kv(pool_k[table_row],
                                  cache["k_scale"][table_row],
                                  compute_dtype).reshape(
                                      1, n_pages * page, K, hd)
            v_old = dequantize_kv(pool_v[table_row],
                                  cache["v_scale"][table_row],
                                  compute_dtype).reshape(
                                      1, n_pages * page, K, hd)
        else:
            new_cache = {
                "k": pool_k.at[blk_w, rows].set(k[0].astype(pool_k.dtype),
                                                mode="drop"),
                "v": pool_v.at[blk_w, rows].set(v[0].astype(pool_v.dtype),
                                                mode="drop"),
            }
            k_old = pool_k[table_row].reshape(1, n_pages * page, K, hd)
            v_old = pool_v[table_row].reshape(1, n_pages * page, K, hd)
        old_pos = jnp.arange(n_pages * page)                  # absolute
    else:
        S_buf = cache["k"].shape[1]
        is_ring = is_local and cfg.window and S_buf == cfg.window
        k_slot = jax.lax.dynamic_slice(cache["k"], (slot, 0, 0, 0),
                                       (1, S_buf, K, hd))
        v_slot = jax.lax.dynamic_slice(cache["v"], (slot, 0, 0, 0),
                                       (1, S_buf, K, hd))
        k_old, v_old = k_slot, v_slot
        j = jnp.arange(S_buf)
        if is_ring:
            # ring slot j held absolute position (pos-1) - ((pos-1-j) mod W)
            # before this chunk; only the last min(W, n_valid) chunk rows
            # are written (earlier rows would be overwritten by the wrap)
            old_pos = (pos - 1) - jnp.mod(pos - 1 - j, S_buf)
            w_ok = valid_q & (i >= n_valid - S_buf)
            rows = jnp.where(w_ok, positions % S_buf, S_buf)
        else:
            old_pos = j
            rows = jnp.where(valid_q, positions, S_buf)
        k_new = k_slot.at[0, rows].set(k[0].astype(k_slot.dtype), mode="drop")
        v_new = v_slot.at[0, rows].set(v[0].astype(v_slot.dtype), mode="drop")
        new_cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], k_new,
                                              (slot, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], v_new,
                                              (slot, 0, 0, 0)),
        }

    # scores over [old snapshot | chunk] keys; masks are (T, S_old) / (T, T)
    # — paged snapshots are readable from position 0 (prefix-shared blocks
    # hold valid rows below first_new_pos); dense snapshots only from
    # first_new_pos (rows below it belong to the slot's previous occupant)
    snap_lo = 0 if block_tables is not None else first_new_pos
    mask_old = ((old_pos >= snap_lo) & (old_pos < pos))[None, :]
    mask_old = jnp.broadcast_to(mask_old, (T, old_pos.shape[0]))
    mask_new = i[None, :] <= i[:, None]                       # intra-chunk
    if is_local and cfg.window:
        mask_old = mask_old & (old_pos[None, :] > positions[:, None] - cfg.window)
        mask_new = mask_new & (i[:, None] - i[None, :] < cfg.window)
    s_old = jnp.einsum("btkgd,bskd->bkgts", q, k_old.astype(compute_dtype),
                       preferred_element_type=jnp.float32) * _scale(cfg)
    s_new = jnp.einsum("btkgd,bskd->bkgts", q, k.astype(compute_dtype),
                       preferred_element_type=jnp.float32) * _scale(cfg)
    s = softcap(jnp.concatenate([s_old, s_new], axis=-1), cfg.attn_softcap)
    mask = jnp.concatenate([mask_old, mask_new], axis=-1)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    vv = jnp.concatenate([v_old, v], axis=1).astype(compute_dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", w.astype(compute_dtype), vv,
                     preferred_element_type=jnp.float32)
    out = out.reshape(1, T, H * hd).astype(compute_dtype)
    out = (out @ p["o_proj"]["kernel"].astype(compute_dtype)).astype(x.dtype)
    return out, new_cache

"""Shared layer primitives: norms, RoPE, MLPs, initializers.

All modules are functional: ``*_init(rng, ...) -> params`` and a matching
apply function. Params are plain dict pytrees so they stack cleanly under
``jax.lax.scan`` and shard via path-based rules (core/sharding.py).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import dispatch as kdispatch
from repro.quant import QuantTensor

Params = dict[str, Any]


def weight(kernel, compute_dtype):
    """Resolve a parameter leaf for a matmul: quantized containers pass
    through untouched (``dense`` dispatches the weight-quantized GEMM),
    dense arrays cast to the compute dtype as before."""
    if isinstance(kernel, QuantTensor):
        return kernel
    return kernel.astype(compute_dtype)


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------
def dense_init(rng, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def embed_init(rng, vocab: int, d: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d)
    return (jax.random.normal(rng, (vocab, d), dtype=jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def norm_init(d: int, kind: str, dtype) -> Params:
    p = {"scale": jnp.zeros((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jnp.ndarray, kind: str, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        x = x * jax.lax.rsqrt(var + eps)
        out = x * (1.0 + p["scale"].astype(jnp.float32))
    else:  # layernorm
        mean = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        x = (x - mean) * jax.lax.rsqrt(var + eps)
        out = x * (1.0 + p["scale"].astype(jnp.float32)) + p["bias"].astype(jnp.float32)
    return out.astype(dt)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, n_heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freq = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]   # (..., seq, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP (gated and plain)
# --------------------------------------------------------------------------
def mlp_init(rng, d: int, d_ff: int, gated: bool, dtype) -> Params:
    ks = jax.random.split(rng, 3)
    p = {"up": {"kernel": dense_init(ks[0], d, d_ff, dtype)},
         "down": {"kernel": dense_init(ks[1], d_ff, d, dtype)}}
    if gated:
        p["gate"] = {"kernel": dense_init(ks[2], d, d_ff, dtype)}
    return p


def _act(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(kind)


def dense(x: jnp.ndarray, w, *, act: str | None = None) -> jnp.ndarray:
    """Linear layer (optionally activation-fused) through the kernel registry.

    A :class:`~repro.quant.QuantTensor` weight dispatches the
    weight-quantized ``ops.gemm_wq`` op (int8/fp8 weights dequantized
    in-tile, fused epilogue) on *every* backend — the ref oracle is the
    dequantize-then-GEMM XLA path, so quantized layers need no call-site
    opt-in. Under an explicit ``use_backend`` kernel scope dense-float
    weights route through ``ops.gemm`` — the Pallas streaming GEMM with its
    fused in-stream epilogue (paper C5b) — with leading dims flattened into
    the row dim. Otherwise it is the plain jnp matmul, bit-identical to the
    historical path.
    """
    if isinstance(w, QuantTensor) and x.ndim >= 2 and w.ndim == 2:
        from repro.kernels import ops
        lead = x.shape[:-1]
        y = ops.gemm_wq(x.reshape(-1, x.shape[-1]), w.q, w.scales, act=act)
        return y.reshape(*lead, w.shape[-1]).astype(x.dtype)
    if isinstance(w, QuantTensor):
        w = w.dequantize(x.dtype)
    if kdispatch.kernel_scope_active() and x.ndim >= 2:
        from repro.kernels import ops
        lead = x.shape[:-1]
        y = ops.gemm(x.reshape(-1, x.shape[-1]), w, act=act)
        return y.reshape(*lead, w.shape[-1]).astype(x.dtype)
    y = x @ w
    return _act(y, act) if act else y


def apply_mlp(p: Params, x: jnp.ndarray, act: str, gated: bool,
              compute_dtype, part=None) -> jnp.ndarray:
    xc = x.astype(compute_dtype)
    if part is None:
        # local path: registry-dispatched dense (kernel backends fuse the
        # activation into the GEMM epilogue; QuantTensor weights dispatch
        # the weight-quantized gemm_wq with in-tile dequant)
        wu = weight(p["up"]["kernel"], compute_dtype)
        if gated:
            h = dense(xc, weight(p["gate"]["kernel"], compute_dtype),
                      act=act) * dense(xc, wu)
        else:
            h = dense(xc, wu, act=act)
        out = dense(h.astype(compute_dtype),
                    weight(p["down"]["kernel"], compute_dtype))
        return out.astype(x.dtype)
    up = xc @ p["up"]["kernel"].astype(compute_dtype)
    up = part.act(up, ("batch",) + (None,) * (up.ndim - 2) + ("mlp",))
    if gated:
        gate = xc @ p["gate"]["kernel"].astype(compute_dtype)
        h = _act(gate, act) * up
    else:
        h = _act(up, act)
    out = h @ p["down"]["kernel"].astype(compute_dtype)
    out = part.act(out, ("batch",) + (None,) * (out.ndim - 1))
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# misc
# --------------------------------------------------------------------------
from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(1,))
def _grad_dtype_barrier(x, dtype_name: str):
    return x


def _gdb_fwd(x, dtype_name):
    return x, None


def _gdb_bwd(dtype_name, _res, ct):
    return (ct.astype(dtype_name),)


_grad_dtype_barrier.defvjp(_gdb_fwd, _gdb_bwd)


def grad_dtype_barrier(x):
    """Identity whose COTANGENT is forced back to x's dtype.

    jnp's no-op casts (x.astype(dt) when x.dtype == dt) record nothing, so an
    f32 cotangent born in the fp32 loss/logits einsum flows *unconverted* into
    the bf16 layer-stack scan, silently doubling every backward activation
    collective and remat buffer (seen as f32[B,S,d] all-reduces in the dry-run
    HLO). This barrier pins the backward boundary to the compute dtype."""
    return _grad_dtype_barrier(x, str(x.dtype))


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, state: jnp.ndarray | None = None,
                  valid_len=None):
    """Depthwise causal conv. x: (B, L, C); w: (C, K). Returns (y, new_state)
    where state holds the trailing K-1 inputs for streaming decode.

    ``valid_len`` (traced scalar): only the first ``valid_len`` inputs are
    real (chunked prefill with a ragged tail) — new_state then holds the
    K-1 inputs *preceding position valid_len*, not the padded tail."""
    k = w.shape[-1]
    if state is None:
        pad = jnp.zeros(x.shape[:-2] + (k - 1, x.shape[-1]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=-2)                    # (B, L+K-1, C)
    # depthwise conv as sum of shifted slices (K is tiny: 4)
    L = x.shape[-2]
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        y = y + xp[..., i:i + L, :].astype(jnp.float32) * w[:, i].astype(jnp.float32)
    if valid_len is None:
        new_state = xp[..., L:, :]                              # last K-1 inputs
    else:
        new_state = jax.lax.dynamic_slice_in_dim(xp, valid_len, k - 1,
                                                 xp.ndim - 2)
    return y.astype(x.dtype), new_state

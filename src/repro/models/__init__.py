from repro.models import attention, cache, frontends, layers, moe, recurrent
from repro.models.transformer import (decode_step, extend_step, forward, init,
                                      lm_loss, logits_fn, verify_step)

__all__ = ["attention", "cache", "decode_step", "extend_step", "forward",
           "frontends", "init", "layers", "lm_loss", "logits_fn", "moe",
           "recurrent", "verify_step"]

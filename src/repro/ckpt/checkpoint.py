"""Fault-tolerant checkpointing: atomic, async, mesh-agnostic (elastic).

Layout (one directory per step, atomic tmp+rename — a crash mid-save never
corrupts the latest checkpoint, the paper's D2D channel-allocator philosophy
applied to state durability):

    ckpt_dir/
      step_00000042/
        manifest.json          # leaf paths, shapes, dtypes, user metadata
        000_params.embed.table.npy
        001_... .npy

Leaves are saved as *full* (unsharded) arrays with ``np.asarray`` — on a real
multihost fleet this becomes a per-shard write with the same manifest; the
mesh-agnostic full-array format is what makes **elastic restarts** trivial:
``restore`` device_puts every leaf with the *target* mesh's NamedSharding,
whatever its shape (tested 8→4 and 4→8 device resharding).

``AsyncCheckpointer`` snapshots to host memory synchronously (cheap) and
writes to disk on a background thread, so the train loop never blocks on IO —
the analogue of Occamy's DMA engine decoupling bulk movement from compute.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np

PyTree = Any

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _flatten_with_paths(tree: PyTree) -> tuple[list[tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = ".".join(_key_str(k) for k in path)
        out.append((key, leaf))
    return out, treedef


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def save_checkpoint(ckpt_dir: str | os.PathLike, step: int, state: PyTree,
                    *, metadata: dict | None = None) -> Path:
    """Atomic synchronous save. Returns the final directory path."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, _ = _flatten_with_paths(state)
    manifest = {"step": int(step), "metadata": metadata or {}, "leaves": []}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)  # gathers sharded arrays on CPU; per-shard on fleets
        fname = f"{i:03d}_{key[:180]}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append({"key": key, "file": fname,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic publish
    return final


def restore_checkpoint(ckpt_dir: str | os.PathLike, template: PyTree,
                       step: int | None = None,
                       shardings: PyTree | None = None) -> tuple[PyTree, dict]:
    """Restore (optionally onto different shardings — elastic resize).

    ``template`` fixes the treedef; leaves are matched by flattened path key,
    so adding/removing siblings between save and restore fails loudly.
    Returns (state, manifest metadata).
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    by_key = {l["key"]: l for l in manifest["leaves"]}
    tmpl_leaves, treedef = _flatten_with_paths(template)
    sh_leaves = (jax.tree.leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None else [None] * len(tmpl_leaves))
    out = []
    for (key, tmpl), sh in zip(tmpl_leaves, sh_leaves):
        if key not in by_key:
            raise KeyError(f"checkpoint at step {step} missing leaf {key!r}")
        arr = np.load(d / by_key[key]["file"])
        want = tuple(getattr(tmpl, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"leaf {key!r}: ckpt shape {arr.shape} != "
                             f"template {want}")
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    state = jax.tree_util.tree_unflatten(treedef, out)
    return state, manifest["metadata"]


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(m.group(1)) for p in ckpt_dir.iterdir()
             if (m := _STEP_RE.match(p.name))]
    return max(steps) if steps else None


def gc_checkpoints(ckpt_dir: str | os.PathLike, keep_last: int = 3):
    ckpt_dir = Path(ckpt_dir)
    steps = sorted(int(m.group(1)) for p in ckpt_dir.iterdir()
                   if (m := _STEP_RE.match(p.name)))
    for s in steps[:-keep_last] if keep_last else steps:
        shutil.rmtree(ckpt_dir / f"step_{s:08d}", ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-synchronously, write-asynchronously checkpointer."""

    def __init__(self, ckpt_dir: str | os.PathLike, *, keep_last: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, state: PyTree, *, metadata: dict | None = None,
             blocking: bool = False):
        self.wait()  # one in-flight save at a time
        # snapshot to host memory NOW (device buffers may be donated next step)
        snap = jax.tree.map(np.asarray, state)

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, snap, metadata=metadata)
                gc_checkpoints(self.ckpt_dir, self.keep_last)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def latest_step(self) -> int | None:
        return latest_step(self.ckpt_dir)

from repro.ckpt.checkpoint import (AsyncCheckpointer, gc_checkpoints,
                                   latest_step, restore_checkpoint,
                                   save_checkpoint)

__all__ = ["AsyncCheckpointer", "gc_checkpoints", "latest_step",
           "restore_checkpoint", "save_checkpoint"]

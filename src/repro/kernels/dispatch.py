"""Unified kernel-backend registry: capability-negotiating op dispatch.

The paper's layered-openness thesis (one ISA surface, many implementations —
Occamy's 8-to-64-bit multi-precision FPU; Occamy -> Ramora -> Ogopogo swapping
interconnect layers under an unchanged programming model) applied to the
software stack: every hot-spot op (``gemm``, ``flash_attention``, ``lru_scan``,
``packed_gather_rows``, ``instream_scale_reduce``, ...) is a *name* in an
``OpRegistry``; concrete kernels register against that name with a
``supports(request)`` capability predicate and a priority. Call sites never
pick an implementation — they dispatch through the registry, which negotiates:

  1. Resolve the active :class:`Backend` — an explicit ``backend=`` argument,
     the innermost :func:`use_backend` context, the ``REPRO_KERNEL_BACKEND``
     environment variable, or auto-detection from ``jax.default_backend()``
     (TPU -> ``pallas``, anything else -> ``ref``).
  2. Walk the op's implementations in priority order, keeping those that list
     the active backend and whose ``supports`` predicate accepts the request's
     shapes/dtypes/platform/params.
  3. Fall back to the universal ``ref`` oracle when no kernel can serve the
     request (GQA head counts the kernel layout can't express, tiny dims, ...)
     — unsupported shapes *negotiate down*, they never error.

Block/tile sizes live in a per-op tuning table keyed by (op, shape bucket),
overridable per scope (``use_backend(blocks=...)``) or per distribution
strategy (``StrategyConfig.kernel_blocks``). Adding a backend, an op variant,
or per-shape tuning is a registry entry — not a cross-cutting edit.
"""
from __future__ import annotations

import contextlib
import contextvars
import inspect
import os
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Mapping

import jax

__all__ = [
    "BACKENDS", "Backend", "BlockSpec", "KERNEL_BACKENDS", "OpImpl",
    "OpRequest", "OpRegistry", "blocks_from_pairs", "default_backend_name",
    "kernel_scope_active", "negotiated_model_backend", "registry",
    "requested_backend", "resolve_backend", "serve_mesh", "serve_mesh_scope",
    "spmd_xla_scope", "use_backend",
]

#: Valid backend names. ``ref`` is the pure-jnp oracle, ``interpret`` runs the
#: Pallas kernels through the interpreter (CPU validation), ``pallas`` is the
#: compiled TPU path. ``auto`` (accepted everywhere a name is) resolves per
#: platform.
BACKENDS = ("ref", "interpret", "pallas")

_ENV_VAR = "REPRO_KERNEL_BACKEND"


# --------------------------------------------------------------------------
# backend resolution
# --------------------------------------------------------------------------
#: Backends that execute the Pallas kernels (vs the jnp oracle).
KERNEL_BACKENDS = ("interpret", "pallas")


@dataclass(frozen=True)
class Backend:
    """A resolved execution backend for kernel dispatch."""
    name: str                     # ref | interpret | pallas
    platform: str                 # jax.default_backend(): cpu | gpu | tpu

    @property
    def interpret(self) -> bool:
        return self.name == "interpret"

    @property
    def compiled_available(self) -> bool:
        """Whether compiled (non-interpreted) Pallas kernels can run here."""
        return self.platform == "tpu"


_active: contextvars.ContextVar[str | None] = contextvars.ContextVar(
    "repro_kernel_backend", default=None)
_block_overrides: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "repro_kernel_blocks", default=())


def default_backend_name() -> str:
    """Platform-derived default: compiled kernels on TPU, oracle elsewhere.

    ``REPRO_KERNEL_BACKEND`` overrides (used by CI to force ``interpret``)."""
    env = os.environ.get(_ENV_VAR, "").strip()
    if env and env != "auto":
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _validate(name: str) -> None:
    if name not in BACKENDS and name != "auto":
        raise ValueError(
            f"unknown kernel backend {name!r}; expected one of "
            f"{BACKENDS + ('auto',)}")


def resolve_backend(name: str | None = None) -> Backend:
    """Explicit arg > ``use_backend`` context > env var / platform auto."""
    n = name or _active.get() or "auto"
    _validate(n)
    if n == "auto":
        n = default_backend_name()
        _validate(n)
    return Backend(n, jax.default_backend())


def requested_backend() -> str | None:
    """The innermost *explicitly requested* backend (``use_backend`` scope),
    or None. Model layers use this: platform auto-detection alone must not
    reroute a training graph through a forward-only kernel path."""
    return _active.get()


def kernel_scope_active() -> bool:
    """True inside an explicit ``use_backend`` scope that selects the Pallas
    kernels. The one predicate model call sites (dense, MoE gather,
    diag_scan) gate on — ambient auto-detection never flips it."""
    return requested_backend() in KERNEL_BACKENDS


def spmd_xla_scope():
    """Scope for partitioned (SPMD) model graphs: neutralizes any enclosing
    kernel scope so no ``pallas_call`` is traced inside pjit — a raw kernel
    on sharded activations would need shard_map. Sharded graphs keep the XLA
    collectives-aware paths; the model entry points (``forward`` /
    ``decode_step``) apply this whenever a partitioner is in play."""
    if kernel_scope_active():
        return use_backend("ref")
    return contextlib.nullcontext()


_serve_mesh: contextvars.ContextVar[tuple | None] = contextvars.ContextVar(
    "repro_serve_mesh", default=None)


@contextlib.contextmanager
def serve_mesh_scope(mesh, axis: str):
    """Advertise a sharded serving layout to ``supports()`` predicates.

    Opened (at trace time) by the model layer around registry dispatches
    whose pool operands are sharded over ``mesh`` axis ``axis`` — e.g. the
    paged KV block pools sharded by KV head. Implementations that can run
    the op under ``shard_map`` on that layout key their ``supports()`` off
    :func:`serve_mesh`; everything else sees the operands as global arrays
    and negotiation falls through to the local/ref paths unchanged.
    """
    tok = _serve_mesh.set((mesh, axis))
    try:
        yield
    finally:
        _serve_mesh.reset(tok)


def serve_mesh() -> tuple | None:
    """The active ``(mesh, axis)`` serving layout, or None outside a
    :func:`serve_mesh_scope`."""
    return _serve_mesh.get()


def negotiated_model_backend(cfg_backend: str) -> str | None:
    """Backend a model layer should route its kernels through, or None for
    the default XLA path. A ``use_backend`` scope wins over the config field;
    ``auto`` only opts in on TPU (the CPU/GPU production path stays XLA)."""
    be = requested_backend() or cfg_backend or None
    if not be:
        return None
    _validate(be)
    if be == "auto":
        return "pallas" if jax.default_backend() == "tpu" else None
    return be


@contextlib.contextmanager
def use_backend(name: str | None = None, *,
                blocks: Mapping[Any, Mapping[str, int]] | None = None):
    """Context-scoped backend and/or block-size override.

        with use_backend("interpret"):
            y = ops.gemm(x, w)                  # Pallas kernel, interpreted
        with use_backend(blocks={"gemm": {"block_m": 64}}):
            y = ops.gemm(x, w)                  # default backend, tuned tiles

    ``blocks`` keys are an op name (all shape buckets) or ``(op, bucket)``;
    values map kernel tile kwargs to sizes. Scopes nest; the innermost wins.
    Yields the resolved :class:`Backend`.

    The scope is read at *trace* time and is not part of any jit cache key:
    a scope around a ``jax.jit`` function that already traced reuses the
    cached executable unchanged. Open the scope around the *first* call (as
    ``ServeEngine`` does, pinning one backend for its lifetime), or keep the
    jit inside the scope.
    """
    if name is not None:
        _validate(name)
    tok = _active.set(name) if name is not None else None
    btok = (_block_overrides.set(_block_overrides.get() + (dict(blocks),))
            if blocks else None)
    try:
        yield resolve_backend(name)
    finally:
        if btok is not None:
            _block_overrides.reset(btok)
        if tok is not None:
            _active.reset(tok)


def blocks_from_pairs(pairs: Iterable) -> dict:
    """Decode ``StrategyConfig.kernel_blocks`` — a hashable tuple of
    ``(op, bucket, ((name, size), ...))`` entries (bucket ``"*"`` = any) —
    into the mapping form ``use_backend(blocks=...)`` takes."""
    out: dict = {}
    for op, bucket, sizes in pairs:
        key = op if bucket in ("*", None) else (op, bucket)
        out[key] = dict(sizes)
    return out


# --------------------------------------------------------------------------
# requests, capabilities, block tuning
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class OpRequest:
    """What a call site is asking for: shapes/dtypes of the array operands,
    the target platform, and the static op params. ``supports`` predicates
    and shape-bucket functions see exactly this."""
    op: str
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[str, ...]
    platform: str
    params: tuple[tuple[str, Any], ...] = ()

    def param(self, key: str, default: Any = None) -> Any:
        return dict(self.params).get(key, default)

    @property
    def max_dim(self) -> int:
        return max((d for s in self.shapes for d in s), default=0)

    def floating(self) -> bool:
        return all(("float" in d) or ("bf16" in d) for d in self.dtypes)


@dataclass(frozen=True)
class BlockSpec:
    """Per-op tile-size bundle: kernel kwarg name -> size. (Distinct from
    ``pl.BlockSpec`` — this is the *tuning table entry* that ends up as the
    kernel wrapper's ``block_*`` keyword arguments.)"""
    sizes: tuple[tuple[str, int], ...] = ()

    @classmethod
    def of(cls, **sizes: int) -> "BlockSpec":
        return cls(tuple(sorted(sizes.items())))

    def asdict(self) -> dict[str, int]:
        return dict(self.sizes)


@dataclass(frozen=True)
class OpImpl:
    """One registered implementation of an op."""
    op: str
    name: str
    fn: Callable
    backends: frozenset[str]
    supports: Callable[[OpRequest], bool] | None = None
    priority: int = 0
    pass_interpret: bool = False  # fn takes interpret= from the backend

    def accepts(self, req: OpRequest) -> bool:
        return self.supports is None or bool(self.supports(req))


def _default_bucket(req: OpRequest) -> str:
    """Coarse shape bucket: pad-friendly small tiles below one MXU-ish edge,
    full 128-multiples above."""
    return "small" if req.max_dim <= 256 else "large"


class OpRegistry:
    """Name -> prioritized implementations + block-size tuning table."""

    def __init__(self):
        self._impls: dict[str, list[OpImpl]] = {}
        self._blocks: dict[tuple[str, str], BlockSpec] = {}
        self._bucket_fns: dict[str, Callable[[OpRequest], str]] = {}
        self._sig_cache: dict[Callable, tuple[frozenset[str], bool]] = {}

    # ---- registration ----------------------------------------------------
    def register(self, op: str, name: str, *, backends: Iterable[str],
                 supports: Callable[[OpRequest], bool] | None = None,
                 priority: int = 0, pass_interpret: bool = False):
        """Decorator: register ``fn`` as implementation ``name`` of ``op``.

        ``backends`` lists the backend names this impl can serve. A kernel
        impl typically registers ``("pallas", "interpret")`` with
        ``pass_interpret=True`` (it receives ``interpret=`` from the resolved
        backend); the oracle registers all three backends at priority 0 so it
        doubles as the negotiation fallback.
        """
        bset = frozenset(backends)
        unknown = bset - set(BACKENDS)
        if unknown:
            raise ValueError(f"unknown backends {sorted(unknown)} for {op}")

        def deco(fn):
            entry = OpImpl(op=op, name=name, fn=fn, backends=bset,
                           supports=supports, priority=priority,
                           pass_interpret=pass_interpret)
            impls = self._impls.setdefault(op, [])
            impls[:] = [e for e in impls if e.name != name] + [entry]
            impls.sort(key=lambda e: -e.priority)
            return fn

        return deco

    def register_blocks(self, op: str, bucket: str, **sizes: int) -> None:
        """Default tile sizes for (op, shape bucket); bucket "*" = any."""
        self._blocks[(op, bucket)] = BlockSpec.of(**sizes)

    def set_bucket_fn(self, op: str, fn: Callable[[OpRequest], str]) -> None:
        self._bucket_fns[op] = fn

    # ---- introspection ---------------------------------------------------
    def ops(self) -> list[str]:
        return sorted(self._impls)

    def implementations(self, op: str) -> list[OpImpl]:
        return list(self._impls.get(op, ()))

    def request(self, op: str, *args, **params) -> OpRequest:
        """Build the OpRequest ``dispatch`` would see (introspection/tests)."""
        platform = jax.default_backend()
        shapes = tuple(tuple(a.shape) for a in args if hasattr(a, "shape"))
        dtypes = tuple(str(a.dtype) for a in args if hasattr(a, "dtype"))
        static = tuple(sorted((k, v) for k, v in params.items()
                              if isinstance(v, (int, float, str, bool,
                                                type(None)))))
        return OpRequest(op, shapes, dtypes, platform, static)

    def describe(self) -> str:
        lines = []
        for op in self.ops():
            impls = ", ".join(
                f"{e.name}[{'/'.join(sorted(e.backends))}] p{e.priority}"
                for e in self._impls[op])
            lines.append(f"{op}: {impls}")
        return "\n".join(lines)

    # ---- negotiation -----------------------------------------------------
    def select(self, op: str, req: OpRequest, backend: Backend) -> OpImpl:
        """Highest-priority impl serving ``backend`` that supports ``req``;
        negotiates down to the ``ref`` oracle instead of erroring. A
        ``pallas`` backend on a platform with no compiled kernels (CPU/GPU)
        treats every kernel impl as unsupported — pinning ``pallas`` on a
        dev box falls back to the oracle rather than crashing in
        ``pallas_call``."""
        impls = self._impls.get(op)
        if not impls:
            raise KeyError(f"no implementations registered for op {op!r}")
        for entry in impls:
            if (entry.pass_interpret and backend.name == "pallas"
                    and not backend.compiled_available):
                continue
            if backend.name in entry.backends and entry.accepts(req):
                return entry
        for entry in impls:  # negotiate down: the universal oracle
            if "ref" in entry.backends and entry.accepts(req):
                return entry
        raise NotImplementedError(
            f"op {op!r}: no implementation supports {req} on backend "
            f"{backend.name!r} and no ref fallback is registered")

    def blocks_for(self, op: str, req: OpRequest) -> dict[str, int]:
        """Tuning-table tile sizes for this request: (op, "*") then
        (op, bucket) defaults, then context/strategy overrides, innermost
        last (later wins)."""
        bucket = self._bucket_fns.get(op, _default_bucket)(req)
        out: dict[str, int] = {}
        for key in ((op, "*"), (op, bucket)):
            if key in self._blocks:
                out.update(self._blocks[key].asdict())
        for scope in _block_overrides.get():
            for key in (op, (op, bucket)):
                if key in scope:
                    out.update(scope[key])
        return out

    # ---- dispatch --------------------------------------------------------
    def _signature(self, fn: Callable) -> tuple[frozenset[str], bool]:
        if fn not in self._sig_cache:
            sig = inspect.signature(fn)
            names = frozenset(
                p.name for p in sig.parameters.values()
                if p.kind in (p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY))
            var_kw = any(p.kind == p.VAR_KEYWORD
                         for p in sig.parameters.values())
            self._sig_cache[fn] = (names, var_kw)
        return self._sig_cache[fn]

    def _op_kwargs(self, op: str) -> frozenset[str]:
        """Union of kwarg names accepted by any of the op's impls."""
        names: set[str] = {"interpret"}
        for entry in self._impls.get(op, ()):
            names |= self._signature(entry.fn)[0]
        return frozenset(names)

    def dispatch(self, op: str, *args, backend: str | None = None, **kwargs):
        """The one negotiation path every public op flows through."""
        be = resolve_backend(backend)
        req = self.request(op, *args, **kwargs)
        impl = self.select(op, req, be)
        # typo'd kwargs must fail loudly, as the pre-registry jitted ops did;
        # only *tuning-table defaults* are filtered per-impl below (the ref
        # oracle legitimately ignores the kernel's tile sizes)
        unknown = set(kwargs) - self._op_kwargs(op)
        if unknown:
            raise TypeError(
                f"op {op!r}: unknown keyword argument(s) {sorted(unknown)}; "
                f"accepted: {sorted(self._op_kwargs(op))}")
        call_kw = dict(self.blocks_for(op, req))
        call_kw.update(kwargs)
        if impl.pass_interpret:
            call_kw["interpret"] = be.interpret
        names, var_kw = self._signature(impl.fn)
        if not var_kw:
            call_kw = {k: v for k, v in call_kw.items() if k in names}
        # the chosen impl shows up by name in profiler timelines (Perfetto /
        # jax.profiler), so a trace answers "which kernel actually ran?"
        with jax.named_scope(f"repro.{op}.{impl.name}"):
            return impl.fn(*args, **call_kw)


#: Process-wide registry. ``repro.kernels.ops`` populates it at import.
registry = OpRegistry()

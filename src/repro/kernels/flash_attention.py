"""FlashAttention-2 Pallas TPU kernel (paper §II-C uses FlashAttention-2).

Layout: q (BH, Sq, D), k/v (BK, Skv, D) with BH = BK·G (GQA: the k/v block
index_map divides the head index, so kv tiles are shared across the G query
heads of a group — no repeated kv in HBM).

Grid: (BH, Sq/bq, Skv/bk), kv innermost (sequential): running (m, l, acc)
live in VMEM scratch across the kv pass — the paper's "keep the working set
in SPM, stream the tiles" (C1) applied to attention. Causal/window masking is
applied per-tile; fully-masked tiles are skipped with ``pl.when`` (the
sliding-window compute saving of gemma2/recurrentgemma local layers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               n_kv: int, bq: int, bk: int, scale: float, cap: float,
               causal: bool, window: int, kv_len: int, out_dtype):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(1)
    q_start = i * bq
    k_start = j * bk
    # tile-level skip: fully-masked kv tiles do no work (C1/C5 data-movement
    # frugality; gives local attention its sub-quadratic compute)
    live = jnp.bool_(True)
    if causal:
        live &= q_start + bq - 1 >= k_start
    if window:
        live &= q_start < k_start + bk + window - 1

    @pl.when(live)
    def _tile():
        q = q_ref[0]                                 # (bq, D)
        k = k_ref[0]                                 # (bk, D)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if cap:
            s = jnp.tanh(s / cap) * cap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len  # padded KV rows masked out
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev[:, 0], s.max(axis=-1))[:, None]
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)               # (bq, 1)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)[:, None]
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-37)).astype(out_dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    cap: float = 0.0, scale: float | None = None,
                    kv_len: int = 0, block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (BH, Sq, D); k, v: (BK, Skv, D) with BH % BK == 0 (GQA groups).
    ``kv_len``: true (unpadded) KV length; 0 means Skv."""
    BH, Sq, D = q.shape
    BK, Skv, _ = k.shape
    assert BH % BK == 0
    G = BH // BK
    scale = (1.0 / (D ** 0.5)) if scale is None else scale
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, "pad in ops.py first"
    n_kv = Skv // bk
    grid = (BH, Sq // bq, n_kv)
    kernel = functools.partial(
        _fa_kernel, n_kv=n_kv, bq=bq, bk=bk, scale=scale, cap=cap,
        causal=causal, window=window, kv_len=(kv_len or Skv),
        out_dtype=q.dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j, g=G: (b // g, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j, g=G: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

"""Public kernel ops, dispatched through the backend registry.

Each op here is a *name* in :data:`repro.kernels.dispatch.registry`. The
Pallas entry pads its operands to kernel-aligned tiles (sizes negotiated from
the per-op tuning table), runs the kernel (compiled on TPU, interpreted for
CPU validation), and unpads only when padding actually happened; the pure-jnp
oracle in :mod:`repro.kernels.ref` is registered alongside it as the universal
fallback. There are no ``impl=`` switches — select a backend with
``dispatch.use_backend(...)`` (or let platform auto-detection pick), and
requests a kernel can't serve (GQA head counts outside the kernel layout,
sub-lane head dims, integer dtypes) negotiate down to the oracle instead of
erroring. See docs/backends.md.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.dispatch import (OpRequest, registry, serve_mesh,
                                    use_backend)
from repro.kernels.flash_attention import flash_attention as _fa
from repro.kernels.gemm import gemm as _gemm
from repro.kernels.gemm_sparse import gemm_sparse as _gemm_sparse
from repro.kernels.gemm_sparse import gemm_sparse_24 as _gemm_sparse_24
from repro.kernels.gemm_wq import gemm_wq as _gemm_wq
from repro.kernels.instream import instream_scale_reduce as _instream
from repro.kernels.lru_scan import lru_scan as _lru
from repro.kernels.packed_gather import gather_rows as _gather
from repro.kernels.packed_gather import packed_gather_rows as _packed_gather
from repro.kernels.paged_attention import paged_attention as _pa

__all__ = ["flash_attention", "gather_rows", "gemm", "gemm_sparse",
           "gemm_sparse_24", "gemm_wq", "instream_scale_reduce", "lru_scan",
           "packed_gather_rows", "paged_attention", "registry", "use_backend"]

#: Storage dtype names of quantized weight/KV operands (str(jnp.dtype)) —
#: the quant subsystem's canonical list, not a private copy.
from repro.quant import QUANT_DTYPES as _QUANT_DTYPES  # noqa: E402


def _is_float(d: str) -> bool:
    """True for *dense* float dtypes (fp8 storage dtypes excluded)."""
    return (("float" in d) or ("bf16" in d)) and d not in _QUANT_DTYPES


def _pad_to(x, mults, axes):
    pads = [(0, 0)] * x.ndim
    padded = False
    for ax, m in zip(axes, mults):
        r = (-x.shape[ax]) % m
        if r:
            pads[ax] = (0, r)
            padded = True
    return (jnp.pad(x, pads), True) if padded else (x, False)


# --------------------------------------------------------------------------
# gemm — streaming tiled GEMM with fused epilogue (paper C1 + C5b)
# --------------------------------------------------------------------------
def _gemm_supports(req: OpRequest) -> bool:
    return (len(req.shapes) >= 2 and all(len(s) == 2 for s in req.shapes[:2])
            and req.floating())


@registry.register("gemm", "pallas", backends=("pallas", "interpret"),
                   supports=_gemm_supports, priority=10, pass_interpret=True)
@partial(jax.jit, static_argnames=("scale", "act", "block_m", "block_n",
                                   "block_k", "interpret"))
def _gemm_kernel(x, w, bias=None, *, scale: float = 1.0, act: str | None = None,
                 block_m: int = 128, block_n: int = 128, block_k: int = 128,
                 interpret: bool = False):
    M, K = x.shape
    N = w.shape[1]
    xp, px = _pad_to(x, (block_m, block_k), (0, 1))
    wp, pw = _pad_to(w, (block_k, block_n), (0, 1))
    bp = None
    if bias is not None:
        bp, _ = _pad_to(bias, (block_n,), (0,))
    out = _gemm(xp, wp, bias=bp, scale=scale, act=act, block_m=block_m,
                block_n=block_n, block_k=block_k, interpret=interpret)
    return out[:M, :N] if (px or pw) else out


@registry.register("gemm", "ref", backends=("ref", "interpret", "pallas"))
@partial(jax.jit, static_argnames=("scale", "act"))
def _gemm_ref(x, w, bias=None, *, scale: float = 1.0, act: str | None = None):
    return _ref.gemm_ref(x, w, bias=bias, scale=scale, act=act)


registry.register_blocks("gemm", "small", block_m=32, block_n=32, block_k=32)
registry.register_blocks("gemm", "large", block_m=128, block_n=128,
                         block_k=128)


def gemm(x, w, bias=None, *, scale: float = 1.0, act: str | None = None,
         **blocks):
    """x: (M, K) @ w: (K, N) with fused scale/bias/activation epilogue.

    Tile sizes come from the tuning table; pass ``block_m``/``block_n``/
    ``block_k`` to pin them for this call.
    """
    return registry.dispatch("gemm", x, w, bias, scale=scale, act=act,
                             **blocks)


# --------------------------------------------------------------------------
# gemm_wq — weight-quantized GEMM, dequantized in-tile (paper Fig. 4b:
# halving precision doubles density; weights stream HBM at storage width)
# --------------------------------------------------------------------------
def _gemm_wq_supports(req: OpRequest) -> bool:
    if len(req.shapes) < 3 or any(len(s) != 2 for s in req.shapes[:3]):
        return False
    (M, K), (K2, N), (nb, N2) = req.shapes[:3]
    if not (N == N2 and nb >= 1 and K % nb == 0
            and _is_float(req.dtypes[0])):
        return False
    if K == K2:
        return req.dtypes[1] in _QUANT_DTYPES
    # nibble-packed int4: the weight's K axis is physically halved, and a
    # quant block must hold a whole number of bytes so K-tiles stay packed
    return (K2 * 2 == K and req.dtypes[1] == "int8"
            and (K // nb) % 2 == 0)


@registry.register("gemm_wq", "pallas", backends=("pallas", "interpret"),
                   supports=_gemm_wq_supports, priority=10,
                   pass_interpret=True)
@partial(jax.jit, static_argnames=("scale", "act", "block_m", "block_n",
                                   "block_k", "interpret"))
def _gemm_wq_kernel(x, qw, scales, bias=None, *, scale: float = 1.0,
                    act: str | None = None, block_m: int = 128,
                    block_n: int = 128, block_k: int = 128,
                    interpret: bool = False):
    import math

    M, K = x.shape                     # logical K (int4: qw rows are K/2)
    N = qw.shape[1]
    pack = 2 if qw.shape[0] * 2 == K else 1
    nb = scales.shape[0]
    qb = K // nb                       # quant-block length along K
    # a K-tile must never straddle a quant block: largest block_k-compatible
    # divisor of qb (K % bk == 0 follows since bk | qb | K — no K padding)
    bk = math.gcd(block_k, qb)
    if pack == 2 and bk % 2:
        # packed tiles hold whole bytes; qb is even (supports()), so this
        # stays a divisor of qb
        bk = math.gcd(2 * bk, qb)
    n_k = K // bk
    # one dequant-scale row per K-tile, pre-gathered so the kernel's scale
    # BlockSpec is a plain (k, j) index map
    tile_scales = scales.astype(jnp.float32)[
        (jnp.arange(n_k) * bk) // qb]
    xp, px = _pad_to(x, (block_m,), (0,))
    qp, pw = _pad_to(qw, (block_n,), (1,))
    sp, _ = _pad_to(tile_scales, (block_n,), (1,))
    bp = None
    if bias is not None:
        bp, _ = _pad_to(bias, (block_n,), (0,))
    out = _gemm_wq(xp, qp, sp, bias=bp, scale=scale, act=act,
                   block_m=block_m, block_n=block_n, block_k=bk,
                   interpret=interpret, pack=pack)
    return out[:M, :N] if (px or pw) else out


@registry.register("gemm_wq", "ref", backends=("ref", "interpret", "pallas"))
@partial(jax.jit, static_argnames=("scale", "act"))
def _gemm_wq_ref(x, qw, scales, bias=None, *, scale: float = 1.0,
                 act: str | None = None):
    return _ref.gemm_wq_ref(x, qw, scales, bias=bias, scale=scale, act=act)


registry.register_blocks("gemm_wq", "small", block_m=32, block_n=32,
                         block_k=32)
registry.register_blocks("gemm_wq", "large", block_m=128, block_n=128,
                         block_k=128)


def gemm_wq(x, qw, scales, bias=None, *, scale: float = 1.0,
            act: str | None = None, **blocks):
    """Weight-quantized x: (M, K) @ qw: (K, N) int8/fp8 — or (K/2, N) int8
    nibble-packed int4, recognized by the half-K shape relation — with
    per-block dequant scales (nb, N), nb | K (nb == 1 => per-channel), and
    the same fused scale/bias/activation epilogue as ``gemm``.

    The Pallas entry dequantizes (int4: unpacks, then dequantizes) weight
    tiles in-register after the DMA; requests the kernel layout can't
    express (odd ranks, dense-float weights, odd-byte quant blocks)
    negotiate down to the dequantize-then-``gemm`` oracle.
    """
    return registry.dispatch("gemm_wq", x, qw, scales, bias, scale=scale,
                             act=act, **blocks)


# --------------------------------------------------------------------------
# gemm_sparse — structured-sparse GEMM (paper's SpMM/STC arc, arXiv:2406.15068:
# sparsity coarse enough that the FPU still streams dense inner tiles)
# --------------------------------------------------------------------------
def _gemm_sparse_block_supports(req: OpRequest) -> bool:
    """Block-sparse layout: (M, K) x, (K, N) float w, (K/bs_k, N/bs_n)
    bool/int block mask."""
    if len(req.shapes) < 3 or any(len(s) != 2 for s in req.shapes[:3]):
        return False
    (M, K), (K2, N), (kb, nb) = req.shapes[:3]
    return (K == K2 and kb >= 1 and nb >= 1 and K % kb == 0 and N % nb == 0
            and _is_float(req.dtypes[0]) and _is_float(req.dtypes[1])
            and ("bool" in req.dtypes[2] or "int" in req.dtypes[2]))


def _gemm_sparse_24_supports(req: OpRequest) -> bool:
    """2:4 layout: (M, K) x, (K/2, N) float vals, (K/2, N) int8 indices."""
    if len(req.shapes) < 3 or any(len(s) != 2 for s in req.shapes[:3]):
        return False
    (M, K), (Kh, N), idx_shape = req.shapes[:3]
    return (Kh * 2 == K and K % 4 == 0 and idx_shape == (Kh, N)
            and _is_float(req.dtypes[0]) and _is_float(req.dtypes[1])
            and req.dtypes[2] == "int8")


@registry.register("gemm_sparse", "pallas_block",
                   backends=("pallas", "interpret"),
                   supports=_gemm_sparse_block_supports, priority=10,
                   pass_interpret=True)
@partial(jax.jit, static_argnames=("scale", "act", "block_m", "block_n",
                                   "block_k", "interpret"))
def _gemm_sparse_block_kernel(x, w, mask, *, scale: float = 1.0,
                              act: str | None = None, block_m: int = 128,
                              block_n: int = 128, block_k: int = 128,
                              interpret: bool = False):
    import math

    M, K = x.shape
    N = w.shape[1]
    kb, nb = mask.shape
    bs_k, bs_n = K // kb, N // nb
    # kernel tiles must divide the mask blocks (and the mask blocks divide
    # K/N), so shrinking via gcd removes any need for K/N padding
    bk = math.gcd(block_k, bs_k)
    bn = math.gcd(block_n, bs_n)
    xp, px = _pad_to(x, (block_m,), (0,))
    out = _gemm_sparse(xp, w, mask, scale=scale, act=act, block_m=block_m,
                       block_n=bn, block_k=bk, interpret=interpret)
    return out[:M] if px else out


@registry.register("gemm_sparse", "pallas_24",
                   backends=("pallas", "interpret"),
                   supports=_gemm_sparse_24_supports, priority=10,
                   pass_interpret=True)
@partial(jax.jit, static_argnames=("scale", "act", "block_m", "block_n",
                                   "block_k", "interpret"))
def _gemm_sparse_24_kernel(x, vals, idx, *, scale: float = 1.0,
                           act: str | None = None, block_m: int = 128,
                           block_n: int = 128, block_k: int = 128,
                           interpret: bool = False):
    import math

    M, K = x.shape
    N = vals.shape[1]
    bk = math.gcd(block_k, K)
    if bk % 4:                         # tiles hold whole 2:4 groups
        bk = math.gcd(4 * bk, K)
    xp, px = _pad_to(x, (block_m,), (0,))
    vp, pn = _pad_to(vals, (block_n,), (1,))
    # zero-padded idx columns pair zero vals: the densified tile stays zero
    ip, _ = _pad_to(idx, (block_n,), (1,))
    out = _gemm_sparse_24(xp, vp, ip, scale=scale, act=act, block_m=block_m,
                          block_n=block_n, block_k=bk, interpret=interpret)
    return out[:M, :N] if (px or pn) else out


@registry.register("gemm_sparse", "ref",
                   backends=("ref", "interpret", "pallas"))
@partial(jax.jit, static_argnames=("scale", "act"))
def _gemm_sparse_ref(x, w_or_vals, mask_or_idx, *, scale: float = 1.0,
                     act: str | None = None):
    return _ref.gemm_sparse_ref(x, w_or_vals, mask_or_idx, scale=scale,
                                act=act)


registry.register_blocks("gemm_sparse", "small", block_m=32, block_n=32,
                         block_k=32)
registry.register_blocks("gemm_sparse", "large", block_m=128, block_n=128,
                         block_k=128)


def gemm_sparse(x, w, mask, *, scale: float = 1.0, act: str | None = None,
                **blocks):
    """Block-sparse x: (M, K) @ w: (K, N) gated by a (K/bs_k, N/bs_n)
    bool/int block mask: masked weight blocks are skipped — no MXU issue,
    no FLOPs — and the epilogue matches ``gemm``. Layouts the kernel can't
    express negotiate down to the dense-mask oracle (exact parity: the
    oracle zeroes the same blocks and runs the plain GEMM)."""
    return registry.dispatch("gemm_sparse", x, w, mask, scale=scale,
                             act=act, **blocks)


def gemm_sparse_24(x, vals, idx, *, scale: float = 1.0,
                   act: str | None = None, **blocks):
    """2:4 fine-grained sparse GEMM: ``vals``/``idx`` (K/2, N) from
    ``gemm_sparse.sparsify_24`` — 2 survivors per 4 consecutive K elements.
    Weight HBM traffic halves; the kernel densifies in-tile (iota-compare
    scatter) and runs dense MXU tiles. Same op name as ``gemm_sparse``:
    the registry picks the layout by operand shapes/dtypes."""
    return registry.dispatch("gemm_sparse", x, vals, idx, scale=scale,
                             act=act, **blocks)


# --------------------------------------------------------------------------
# flash_attention — FlashAttention-2 schedule (paper §II-C)
# --------------------------------------------------------------------------
def _fa_supports(req: OpRequest) -> bool:
    if len(req.shapes) < 3 or any(len(s) != 3 for s in req.shapes[:3]):
        return False
    (BH, _, D), (BK, _, _) = req.shapes[0], req.shapes[1]
    # kernel layout: kv tiles shared across each GQA group (BH = BK*G), and
    # the head dim must fill at least one sublane — else negotiate to ref
    return BH % BK == 0 and D >= 8 and req.floating()


@registry.register("flash_attention", "pallas",
                   backends=("pallas", "interpret"), supports=_fa_supports,
                   priority=10, pass_interpret=True)
@partial(jax.jit, static_argnames=("causal", "window", "cap", "scale",
                                   "block_q", "block_k", "interpret"))
def _fa_kernel(q, k, v, *, causal: bool = True, window: int = 0,
               cap: float = 0.0, scale: float | None = None,
               block_q: int = 128, block_k: int = 128,
               interpret: bool = False):
    Sq = q.shape[1]
    Skv = k.shape[1]
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    qp, pq = _pad_to(q, (bq,), (1,))
    kp, _ = _pad_to(k, (bk,), (1,))
    vp, _ = _pad_to(v, (bk,), (1,))
    out = _fa(qp, kp, vp, causal=causal, window=window, cap=cap, scale=scale,
              kv_len=Skv, block_q=bq, block_k=bk, interpret=interpret)
    return out[:, :Sq] if pq else out


@registry.register("flash_attention", "ref",
                   backends=("ref", "interpret", "pallas"))
@partial(jax.jit, static_argnames=("causal", "window", "cap", "scale"))
def _fa_ref(q, k, v, *, causal: bool = True, window: int = 0, cap: float = 0.0,
            scale: float | None = None):
    # ref.flash_attention_ref handles GQA with a grouped reshape — no
    # jnp.repeat'd K/V materialization at high group counts
    return _ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                    cap=cap, scale=scale)


registry.register_blocks("flash_attention", "small", block_q=32, block_k=32)
registry.register_blocks("flash_attention", "large", block_q=128, block_k=128)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    cap: float = 0.0, scale: float | None = None, **blocks):
    """q: (BH, Sq, D); k, v: (BK, Skv, D) with BH % BK == 0 (GQA groups).

    Head counts or dims outside the kernel layout negotiate down to the
    grouped oracle. ``block_q``/``block_k`` pin tile sizes for this call.
    """
    return registry.dispatch("flash_attention", q, k, v, causal=causal,
                             window=window, cap=cap, scale=scale, **blocks)


# --------------------------------------------------------------------------
# paged_attention — block-pool KV decode attention (serving)
# --------------------------------------------------------------------------
def _pa_supports(req: OpRequest) -> bool:
    if len(req.shapes) < 5:
        return False
    if len(req.shapes[0]) != 4 or any(len(s) != 4 for s in req.shapes[1:3]):
        return False
    (B, K, G, D) = req.shapes[0]
    (N, page, Kp, Dp) = req.shapes[1]
    # kernel layout: pool heads/dims must match q, and the head dim must
    # fill at least one sublane — else negotiate down to the gather oracle
    if not (Kp == K and Dp == D and D >= 8 and _is_float(req.dtypes[0])
            and all("int" in d for d in req.dtypes[3:5])):
        return False
    if len(req.shapes) >= 7:
        # quantized pools: int8/fp8 storage + (N, page, K) per-row scales
        return (all(d in _QUANT_DTYPES for d in req.dtypes[1:3])
                and req.shapes[5] == (N, page, K) == req.shapes[6]
                and all(_is_float(d) for d in req.dtypes[5:7]))
    return all(_is_float(d) for d in req.dtypes[1:3])


@registry.register("paged_attention", "pallas",
                   backends=("pallas", "interpret"), supports=_pa_supports,
                   priority=10, pass_interpret=True)
@partial(jax.jit, static_argnames=("scale", "cap", "interpret"))
def _pa_kernel(q, k_pool, v_pool, block_tables, lengths, k_scale=None,
               v_scale=None, *, scale: float | None = None, cap: float = 0.0,
               interpret: bool = False):
    return _pa(q, k_pool, v_pool, block_tables, lengths, k_scale, v_scale,
               scale=scale, cap=cap, interpret=interpret)


@registry.register("paged_attention", "ref",
                   backends=("ref", "interpret", "pallas"))
@partial(jax.jit, static_argnames=("scale", "cap"))
def _pa_ref(q, k_pool, v_pool, block_tables, lengths, k_scale=None,
            v_scale=None, *, scale: float | None = None, cap: float = 0.0):
    return _ref.paged_attention_ref(q, k_pool, v_pool, block_tables, lengths,
                                    k_scale, v_scale, scale=scale, cap=cap)


def _pa_sharded_supports(req: OpRequest) -> bool:
    """Sharded layout negotiation: only inside a ``serve_mesh_scope`` (the
    model layer advertising KV-head-sharded pools), and only when the KV
    head count divides the mesh axis — otherwise the pools were replicated
    by the divisibility-drop rule and the local paths serve unchanged."""
    sm = serve_mesh()
    if sm is None or len(req.shapes) < 5:
        return False
    if len(req.shapes[0]) != 4 or any(len(s) != 4 for s in req.shapes[1:3]):
        return False
    (B, K, G, D) = req.shapes[0]
    (N, page, Kp, Dp) = req.shapes[1]
    if not (Kp == K and Dp == D
            and all("int" in d for d in req.dtypes[3:5])):
        return False
    if len(req.shapes) >= 7 and not (req.shapes[5] == (N, page, K)
                                     == req.shapes[6]):
        return False
    mesh, axis = sm
    n = mesh.shape.get(axis, 1)
    return n > 1 and K % n == 0


@registry.register("paged_attention", "sharded",
                   backends=("ref", "interpret", "pallas"),
                   supports=_pa_sharded_supports, priority=20)
def _pa_sharded(q, k_pool, v_pool, block_tables, lengths, k_scale=None,
                v_scale=None, *, scale: float | None = None,
                cap: float = 0.0):
    from repro.kernels.paged_attention import paged_attention_sharded
    mesh, axis = serve_mesh()
    return paged_attention_sharded(q, k_pool, v_pool, block_tables, lengths,
                                   k_scale, v_scale, mesh=mesh, axis=axis,
                                   scale=scale, cap=cap)


def paged_attention(q, k_pool, v_pool, block_tables, lengths, k_scale=None,
                    v_scale=None, *, scale: float | None = None,
                    cap: float = 0.0, **blocks):
    """Block-pool decode attention. q: (B, K, G, D) one token per slot;
    k/v pools: (N, page, K, D); block_tables: (B, P) int32; lengths: (B,)
    int32 valid tokens per slot. ``k_scale``/``v_scale`` ((N, page, K)
    float) mark quantized (int8/fp8) pools — rows dequantize at read with
    their per-row absmax scales. Pool layouts the kernel can't express
    negotiate down to the gather-based oracle."""
    if str(k_pool.dtype) in _QUANT_DTYPES and k_scale is None:
        # negotiation falls back to *correct* paths only: attention over
        # raw int8/fp8 codes would be silent garbage, not a fallback
        raise ValueError(
            f"paged_attention: quantized pools ({k_pool.dtype}) require "
            "k_scale/v_scale per-row dequant scales")
    return registry.dispatch("paged_attention", q, k_pool, v_pool,
                             block_tables, lengths, k_scale, v_scale,
                             scale=scale, cap=cap, **blocks)


# --------------------------------------------------------------------------
# lru_scan — diagonal linear recurrence (RG-LRU / Mamba foundation)
# --------------------------------------------------------------------------
def _lru_supports(req: OpRequest) -> bool:
    return (len(req.shapes) >= 2 and all(len(s) == 3 for s in req.shapes[:2])
            and req.floating())


@registry.register("lru_scan", "pallas", backends=("pallas", "interpret"),
                   supports=_lru_supports, priority=10, pass_interpret=True)
@partial(jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def _lru_kernel(a, b, *, block_d: int = 512, chunk: int = 256,
                interpret: bool = False):
    B, L, D = a.shape
    bd = min(block_d, D)
    ck = min(chunk, L)
    # pad time with identity (a=1, b=0), channels with zeros
    ap, pt = _pad_to(a, (ck,), (1,))
    if pt:
        ap = ap.at[:, L:, :].set(1.0)
    bp, _ = _pad_to(b, (ck,), (1,))
    ap, pd = _pad_to(ap, (bd,), (2,))
    bp, _ = _pad_to(bp, (bd,), (2,))
    out = _lru(ap, bp, block_d=bd, chunk=ck, interpret=interpret)
    return out[:, :L, :D] if (pt or pd) else out


@registry.register("lru_scan", "ref", backends=("ref", "interpret", "pallas"))
@jax.jit
def _lru_ref(a, b):
    return _ref.lru_scan_ref(a, b)


registry.register_blocks("lru_scan", "*", block_d=512, chunk=256)


def lru_scan(a, b, **blocks):
    """h_t = a_t * h_{t-1} + b_t over axis 1 (zero initial state).

    a, b: (B, L, D). ``block_d``/``chunk`` pin kernel tile sizes.
    """
    return registry.dispatch("lru_scan", a, b, **blocks)


# --------------------------------------------------------------------------
# gather_rows / packed_gather_rows — indexed streams (paper C2 / C5c)
# --------------------------------------------------------------------------
def _gather_supports(req: OpRequest) -> bool:
    return (len(req.shapes) >= 2 and len(req.shapes[0]) == 2
            and len(req.shapes[1]) == 1 and "int" in req.dtypes[1])


@registry.register("gather_rows", "pallas", backends=("pallas", "interpret"),
                   supports=_gather_supports, priority=10, pass_interpret=True)
@partial(jax.jit, static_argnames=("interpret",))
def _gather_kernel(table, idx, *, interpret: bool = False):
    return _gather(table, idx, interpret=interpret)


@registry.register("gather_rows", "ref",
                   backends=("ref", "interpret", "pallas"))
@jax.jit
def _gather_ref(table, idx):
    return _ref.gather_rows_ref(table, idx)


def gather_rows(table, idx):
    """out[i] = table[idx[i]] — the narrow-stream baseline."""
    return registry.dispatch("gather_rows", table, idx)


@registry.register("packed_gather_rows", "pallas",
                   backends=("pallas", "interpret"),
                   supports=_gather_supports, priority=10, pass_interpret=True)
@partial(jax.jit, static_argnames=("pack", "sort", "interpret"))
def _packed_gather_kernel(table, idx, *, pack: int = 8, sort: bool = True,
                          interpret: bool = False):
    M = idx.shape[0]
    r = (-M) % pack
    order = jnp.argsort(idx) if sort else jnp.arange(M)
    sidx = idx[order]
    if r:
        sidx = jnp.concatenate([sidx, jnp.full((r,), sidx[-1], sidx.dtype)])
    out = _packed_gather(table, sidx, pack=pack, window=table.shape[0],
                         interpret=interpret)[:M]
    inv = jnp.argsort(order) if sort else order
    return out[inv]


registry.register("packed_gather_rows", "ref",
                  backends=("ref", "interpret", "pallas"))(_gather_ref)
registry.register_blocks("packed_gather_rows", "*", pack=8)


def packed_gather_rows(table, idx, *, sort: bool = True, **blocks):
    """Packed/coalesced indexed stream. With ``sort`` (the temporal
    coalescer), gathers are issued in index order and unpermuted after.
    ``pack`` (tuning table, default 8) sets rows per wide flit."""
    return registry.dispatch("packed_gather_rows", table, idx, sort=sort,
                             **blocks)


# --------------------------------------------------------------------------
# instream_scale_reduce — in-stream DMA ops (paper C5b)
# --------------------------------------------------------------------------
def _instream_supports(req: OpRequest) -> bool:
    return (len(req.shapes) >= 1 and len(req.shapes[0]) == 2
            and req.floating())


@registry.register("instream_scale_reduce", "pallas",
                   backends=("pallas", "interpret"),
                   supports=_instream_supports, priority=10,
                   pass_interpret=True)
@partial(jax.jit, static_argnames=("scale", "shift", "block", "interpret"))
def _instream_kernel(x, *, scale: float = 1.0, shift: float = 0.0,
                     block: int = 1024, interpret: bool = False):
    M, D = x.shape
    bm = min(block, M)
    xp, padded = _pad_to(x, (bm,), (0,))
    y, s = _instream(xp, scale=scale, shift=shift, block=bm,
                     interpret=interpret)
    if padded:
        y = y[:M]
        s = s - shift * (xp.shape[0] - M) * D
    return y, s


@registry.register("instream_scale_reduce", "ref",
                   backends=("ref", "interpret", "pallas"))
@partial(jax.jit, static_argnames=("scale", "shift"))
def _instream_ref(x, *, scale: float = 1.0, shift: float = 0.0):
    return _ref.instream_scale_reduce_ref(x, scale=scale, shift=shift)


registry.register_blocks("instream_scale_reduce", "*", block=1024)


def instream_scale_reduce(x, *, scale: float = 1.0, shift: float = 0.0,
                          **blocks):
    """x: (M, D) -> (scale*x + shift, global sum) in one stream pass."""
    return registry.dispatch("instream_scale_reduce", x, scale=scale,
                             shift=shift, **blocks)

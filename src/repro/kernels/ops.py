"""Public jit'd wrappers for the Pallas kernels.

Each op pads its inputs to kernel-aligned shapes, dispatches to the Pallas
kernel (``impl='pallas'`` on TPU, ``impl='interpret'`` for CPU validation) or
the pure-jnp oracle (``impl='ref'``), and unpads. The model layers call these
through ``cfg.attention_impl``-style switches.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention as _fa
from repro.kernels.gemm import gemm as _gemm
from repro.kernels.instream import instream_scale_reduce as _instream
from repro.kernels.lru_scan import lru_scan as _lru
from repro.kernels.packed_gather import gather_rows as _gather
from repro.kernels.packed_gather import packed_gather_rows as _packed_gather


def _pad_to(x, mults, axes):
    pads = [(0, 0)] * x.ndim
    padded = False
    for ax, m in zip(axes, mults):
        r = (-x.shape[ax]) % m
        if r:
            pads[ax] = (0, r)
            padded = True
    return (jnp.pad(x, pads), True) if padded else (x, False)


@partial(jax.jit, static_argnames=("scale", "act", "impl", "block_m",
                                   "block_n", "block_k"))
def gemm(x, w, bias=None, *, scale: float = 1.0, act: str | None = None,
         impl: str = "interpret", block_m: int = 128, block_n: int = 128,
         block_k: int = 128):
    if impl == "ref":
        return _ref.gemm_ref(x, w, bias=bias, scale=scale, act=act)
    M, K = x.shape
    N = w.shape[1]
    xp, _ = _pad_to(x, (block_m, block_k), (0, 1))
    wp, _ = _pad_to(w, (block_k, block_n), (0, 1))
    bp = None
    if bias is not None:
        bp, _ = _pad_to(bias, (block_n,), (0,))
    out = _gemm(xp, wp, bias=bp, scale=scale, act=act, block_m=block_m,
                block_n=block_n, block_k=block_k,
                interpret=(impl == "interpret"))
    return out[:M, :N]


@partial(jax.jit, static_argnames=("causal", "window", "cap", "scale", "impl",
                                   "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    cap: float = 0.0, scale: float | None = None,
                    impl: str = "interpret", block_q: int = 128,
                    block_k: int = 128):
    """q: (BH, Sq, D); k, v: (BK, Skv, D), BH % BK == 0."""
    if impl == "ref":
        G = q.shape[0] // k.shape[0]
        kr = jnp.repeat(k, G, 0) if G > 1 else k
        vr = jnp.repeat(v, G, 0) if G > 1 else v
        return _ref.flash_attention_ref(q, kr, vr, causal=causal,
                                        window=window, cap=cap, scale=scale)
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    qp, _ = _pad_to(q, (bq,), (1,))
    kp, _ = _pad_to(k, (bk,), (1,))
    vp, _ = _pad_to(v, (bk,), (1,))
    out = _fa(qp, kp, vp, causal=causal, window=window, cap=cap, scale=scale,
              kv_len=Skv, block_q=bq, block_k=bk,
              interpret=(impl == "interpret"))
    return out[:, :Sq]


@partial(jax.jit, static_argnames=("impl", "block_d", "chunk"))
def lru_scan(a, b, *, impl: str = "interpret", block_d: int = 512,
             chunk: int = 256):
    if impl == "ref":
        return _ref.lru_scan_ref(a, b)
    B, L, D = a.shape
    bd = min(block_d, D)
    ck = min(chunk, L)
    # pad time with identity (a=1, b=0), channels with zeros
    ap, _ = _pad_to(a, (ck,), (1,))
    if ap.shape[1] != L:
        ap = ap.at[:, L:, :].set(1.0)
    bp, _ = _pad_to(b, (ck,), (1,))
    ap, _ = _pad_to(ap, (bd,), (2,))
    bp, _ = _pad_to(bp, (bd,), (2,))
    out = _lru(ap, bp, block_d=bd, chunk=ck, interpret=(impl == "interpret"))
    return out[:, :L, :D]


@partial(jax.jit, static_argnames=("impl",))
def gather_rows(table, idx, *, impl: str = "interpret"):
    if impl == "ref":
        return _ref.gather_rows_ref(table, idx)
    return _gather(table, idx, interpret=(impl == "interpret"))


@partial(jax.jit, static_argnames=("impl", "pack", "sort"))
def packed_gather_rows(table, idx, *, impl: str = "interpret", pack: int = 8,
                       sort: bool = True):
    """Packed/coalesced indexed stream. With ``sort`` (the temporal
    coalescer), gathers are issued in index order and unpermuted after."""
    if impl == "ref":
        return _ref.gather_rows_ref(table, idx)
    M = idx.shape[0]
    r = (-M) % pack
    order = jnp.argsort(idx) if sort else jnp.arange(M)
    sidx = idx[order]
    if r:
        sidx = jnp.concatenate([sidx, jnp.full((r,), sidx[-1], sidx.dtype)])
    out = _packed_gather(table, sidx, pack=pack, window=table.shape[0],
                         interpret=(impl == "interpret"))[:M]
    inv = jnp.argsort(order) if sort else order
    return out[inv]


@partial(jax.jit, static_argnames=("scale", "shift", "impl", "block"))
def instream_scale_reduce(x, *, scale: float = 1.0, shift: float = 0.0,
                          impl: str = "interpret", block: int = 1024):
    if impl == "ref":
        return _ref.instream_scale_reduce_ref(x, scale=scale, shift=shift)
    M, D = x.shape
    bm = min(block, M)
    xp, padded = _pad_to(x, (bm,), (0,))
    y, s = _instream(xp, scale=scale, shift=shift, block=bm,
                     interpret=(impl == "interpret"))
    if padded:
        y = y[:M]
        s = s - shift * (xp.shape[0] - M) * D
    return y, s

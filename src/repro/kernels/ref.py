"""Pure-jnp oracles for every Pallas kernel (the source of truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm_ref(x, w, *, bias=None, scale=1.0, act=None):
    """Streaming GEMM with fused in-stream epilogue (paper C5b)."""
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    if scale != 1.0:
        out = out * scale
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    if act == "gelu":
        out = jax.nn.gelu(out, approximate=True)
    elif act == "silu":
        out = jax.nn.silu(out)
    return out


def gemm_wq_ref(x, qw, scales, bias=None, *, scale=1.0, act=None):
    """Dequantize-then-GEMM oracle for the weight-quantized ``gemm_wq``.

    qw: (K, N) int8/fp8 storage — or (K/2, N) int8 nibble-packed int4,
    recognized by the half-K shape relation against ``x`` and unpacked
    first; scales: (nb, N) fp32 per-block absmax scales with nb dividing K
    (nb == 1 => per-channel). The dequantized weight is materialized in
    fp32 — the negotiation fallback and the numerical source of truth for
    the in-tile-dequant Pallas kernel."""
    if qw.shape[0] * 2 == x.shape[-1] and qw.dtype == jnp.int8:
        from repro.quant.tensor import unpack_int4
        qw = unpack_int4(qw, axis=0)
    K, N = qw.shape
    nb = scales.shape[0]
    w = (qw.astype(jnp.float32).reshape(nb, K // nb, N)
         * scales.astype(jnp.float32)[:, None, :]).reshape(K, N)
    return gemm_ref(x, w, bias=bias, scale=scale, act=act)


def gemm_sparse_ref(x, w_or_vals, mask_or_idx, bias=None, *, scale=1.0,
                    act=None):
    """Dense-mask oracle for ``gemm_sparse`` — both structured layouts.

    Block-sparse: ``(x, w (K, N) float, mask (K/bs, N/bs) bool/int)`` —
    masked blocks zeroed, then the plain GEMM. 2:4: ``(x, vals (K/2, N),
    idx (K/2, N) int8)`` — densified with zeros at pruned positions. Either
    way the oracle materializes the exact dense weight the kernel consumes
    tile-by-tile, so parity is exact (identical per-element reassociation).
    """
    from repro.kernels.gemm_sparse import apply_block_mask, densify_24
    if (mask_or_idx.dtype == jnp.int8
            and mask_or_idx.shape == w_or_vals.shape):
        w = densify_24(w_or_vals, mask_or_idx)
    else:
        w = apply_block_mask(w_or_vals.astype(jnp.float32),
                             mask_or_idx != 0)
    return gemm_ref(x, w, bias=bias, scale=scale, act=act)


def flash_attention_ref(q, k, v, *, causal=True, window=0, cap=0.0, scale=None):
    """q: (BH, Sq, D); k, v: (BK, Skv, D) with BH % BK == 0. Plain softmax
    attention. GQA (BH = BK*G) is handled by a grouped reshape of q — the
    shared K/V heads are never materialized per query head."""
    BH, Sq, D = q.shape
    BK, Skv, _ = k.shape
    assert BH % BK == 0, (q.shape, k.shape)
    G = BH // BK
    scale = (1.0 / jnp.sqrt(D)) if scale is None else scale
    qg = q.reshape(BK, G, Sq, D)
    s = jnp.einsum("bgqd,bkd->bgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if cap:
        s = jnp.tanh(s / cap) * cap
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgqk,bkd->bgqd", p, v.astype(jnp.float32))
    return out.reshape(BH, Sq, D)


def paged_attention_ref(q, k_pool, v_pool, block_tables, lengths,
                        k_scale=None, v_scale=None, *, scale=None, cap=0.0):
    """Gather-based paged decode attention. q: (B, K, G, D) one token per
    slot; k/v pools: (N, page, K, D); block_tables: (B, P) int32 pool block
    ids; lengths: (B,) int32 valid tokens (current included). The slot's
    sequence is materialized by gathering its pages — row ``p`` of the
    logical sequence is ``pool[table[b, p // page], p % page]``.

    ``k_scale``/``v_scale`` ((N, page, K) float) mark *quantized* pools
    (int8/fp8 storage with per-row absmax scales): gathered rows are
    dequantized before scoring — the read-side half of the quantized paged
    KV cache (docs/quantization.md)."""
    B, K, G, D = q.shape
    page = k_pool.shape[1]
    P = block_tables.shape[1]
    k = k_pool[block_tables].reshape(B, P * page, K, D)
    v = v_pool[block_tables].reshape(B, P * page, K, D)
    if k_scale is not None:
        k = k.astype(jnp.float32) * k_scale[block_tables].reshape(
            B, P * page, K).astype(jnp.float32)[..., None]
    if v_scale is not None:
        v = v.astype(jnp.float32) * v_scale[block_tables].reshape(
            B, P * page, K).astype(jnp.float32)[..., None]
    scale = (1.0 / jnp.sqrt(D)) if scale is None else scale
    s = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if cap:
        s = jnp.tanh(s / cap) * cap
    valid = jnp.arange(P * page)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def lru_scan_ref(a, b, h0=None):
    """Diagonal recurrence h_t = a_t*h_{t-1} + b_t. a, b: (B, L, D)."""
    B, L, D = a.shape
    h0 = jnp.zeros((B, D), jnp.float32) if h0 is None else h0

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                         (a.astype(jnp.float32).transpose(1, 0, 2),
                          b.astype(jnp.float32).transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)


def gather_rows_ref(table, idx):
    """Indexed row stream (paper C2/C5c): out[i] = table[idx[i]]."""
    return table[idx]


def instream_scale_reduce_ref(x, *, scale=1.0, shift=0.0):
    """In-stream DMA ops (paper C5b): y = scale*x + shift computed 'during the
    transfer', plus an in-stream arithmetic reduction (global sum)."""
    y = x.astype(jnp.float32) * scale + shift
    return y, jnp.sum(y)


def spmm_gather_ref(values, col_idx, dense, seg_ids, n_rows):
    """SpMM via gather + segment-sum (COO rows sorted): out[r] = Σ v·B[col]."""
    gathered = dense[col_idx] * values[:, None].astype(dense.dtype)
    return jax.ops.segment_sum(gathered, seg_ids, num_segments=n_rows)

"""Structured-sparse GEMM kernels (Pallas TPU): block-sparse and 2:4.

The Occamy stencil/sparse companion (arXiv:2406.15068) holds 42-83% FPU
utilization on SpMM/STC by keeping the *structure* of the sparsity coarse
enough that the FPU still streams dense inner tiles. Both kernels here
follow that recipe — sparsity lives at a granularity the MXU can exploit,
never per-scalar:

* **block-sparse** — a ``(K/bs_k, N/bs_n)`` boolean block mask gates whole
  ``(bs_k, bs_n)`` weight tiles. The kernel keeps the dense gemm schedule
  (grid ``(M/bm, N/bn, K/bk)``, K innermost, VMEM fp32 accumulator) and
  skips the MXU issue for masked tiles via ``pl.when`` — zero blocks cost
  a (1, 1) SMEM-sized mask read instead of a (bk, bn) FLOP tile.
* **2:4 fine-grained** — every group of 4 consecutive K elements keeps its
  2 largest-magnitude values. Storage is ``(K/2, N)`` values + ``(K/2, N)``
  int8 column-local indices; the kernel densifies in-tile with an
  iota-compare scatter (the same trick sparse tensor cores implement in
  silicon) and runs a dense (bk, bn) MXU tile — HBM traffic halves, the
  in-register FLOPs stay dense.

The dense-mask ref oracle lives in ref.py (``gemm_sparse_ref``): it
materializes the masked/densified weight and calls the plain jnp GEMM, so
kernel-vs-ref parity is *exact* (same reassociation per output element).

Helpers (:func:`block_mask_from_weight`, :func:`apply_block_mask`,
:func:`sparsify_24`, :func:`densify_24`) are the pruning front-end shared
by the MoE consumer (models/moe.py) and the benchmarks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# --------------------------------------------------------------------------
# pruning helpers (host-side front-end, plain jnp)
# --------------------------------------------------------------------------
def block_mask_from_weight(w, bs_k: int, bs_n: int, density: float):
    """Magnitude prune ``w: (K, N)`` to a ``(K/bs_k, N/bs_n)`` bool block
    mask keeping the ``density`` fraction of blocks with largest L2 norm."""
    K, N = w.shape
    if K % bs_k or N % bs_n:
        raise ValueError(f"block {bs_k}x{bs_n} must tile {w.shape}")
    kb, nb = K // bs_k, N // bs_n
    norms = (w.astype(jnp.float32) ** 2).reshape(
        kb, bs_k, nb, bs_n).sum(axis=(1, 3))
    n_keep = max(1, min(kb * nb, round(density * kb * nb)))
    thresh = jnp.sort(norms.reshape(-1))[kb * nb - n_keep]
    return norms >= thresh


def apply_block_mask(w, mask):
    """Zero the masked-out blocks of ``w`` (the dense oracle's weight)."""
    K, N = w.shape
    kb, nb = mask.shape
    bs_k, bs_n = K // kb, N // nb
    wm = w.reshape(kb, bs_k, nb, bs_n) * mask[:, None, :, None].astype(
        w.dtype)
    return wm.reshape(K, N)


def sparsify_24(w):
    """2:4 magnitude prune ``w: (K, N)`` (K % 4 == 0): per group of 4
    consecutive K rows keep the 2 largest-|w|. Returns ``(vals (K/2, N),
    idx (K/2, N) int8)`` with in-group positions 0..3, ascending per pair."""
    K, N = w.shape
    if K % 4:
        raise ValueError(f"2:4 needs K % 4 == 0, got K={K}")
    g = w.reshape(K // 4, 4, N)
    order = jnp.argsort(-jnp.abs(g.astype(jnp.float32)), axis=1)[:, :2, :]
    idx = jnp.sort(order, axis=1)                      # deterministic layout
    vals = jnp.take_along_axis(g, idx, axis=1)
    return (vals.reshape(K // 2, N).astype(w.dtype),
            idx.reshape(K // 2, N).astype(jnp.int8))


def densify_24(vals, idx):
    """Scatter 2:4 storage back to the dense ``(K, N)`` weight (zeros at
    pruned positions) — the ref oracle's weight and the iota-compare
    pattern the kernel runs per tile."""
    Kh, N = vals.shape
    v = vals.astype(jnp.float32).reshape(Kh // 2, 2, N)
    i = idx.astype(jnp.int32).reshape(Kh // 2, 2, N)
    iota = jax.lax.broadcasted_iota(jnp.int32, (Kh // 2, 4, N), 1)
    dense = ((iota == i[:, 0:1]) * v[:, 0:1]
             + (iota == i[:, 1:2]) * v[:, 1:2])
    return dense.reshape(Kh * 2, N)


def _epilogue(out, scale, act, out_dtype):
    if scale != 1.0:
        out = out * scale
    if act == "gelu":
        out = jax.nn.gelu(out, approximate=True)
    elif act == "silu":
        out = jax.nn.silu(out)
    return out.astype(out_dtype)


# --------------------------------------------------------------------------
# block-sparse kernel
# --------------------------------------------------------------------------
def _bs_kernel(x_ref, w_ref, m_ref, o_ref, acc_ref, *, n_k: int,
               scale: float, act: str | None, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # the (1, 1) mask tile gates the whole MXU issue for this K step
    @pl.when(m_ref[0, 0] != 0)
    def _accum():
        acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                                w_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _fin():
        o_ref[...] = _epilogue(acc_ref[...], scale, act, out_dtype)


def gemm_sparse(x, w, mask, *, scale: float = 1.0, act: str | None = None,
                block_m: int = 128, block_n: int = 128, block_k: int = 128,
                out_dtype=jnp.float32, interpret: bool = False):
    """x: (M, K) @ block-masked w: (K, N) -> (M, N); mask (K/bs_k, N/bs_n)
    bool/int gates whole weight blocks. Kernel tile sizes must divide the
    mask block sizes (the wrapper shrinks them via gcd); shapes must be
    pre-padded to the block multiples."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    kb, nb = mask.shape
    bs_k, bs_n = K // kb, N // nb
    assert bs_k % block_k == 0 and bs_n % block_n == 0, (
        "kernel tiles must divide mask blocks", (bs_k, bs_n),
        (block_k, block_n))
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        "pad in ops.py first", (M, K, N), (block_m, block_k, block_n))
    n_k = K // block_k
    grid = (M // block_m, N // block_n, n_k)
    rk, rn = bs_k // block_k, bs_n // block_n     # kernel tiles per block

    kernel = functools.partial(_bs_kernel, n_k=n_k, scale=scale, act=act,
                               out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (k // rk, j // rn)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w, mask.astype(jnp.int32))


# --------------------------------------------------------------------------
# 2:4 fine-grained kernel
# --------------------------------------------------------------------------
def _s24_kernel(x_ref, v_ref, i_ref, o_ref, acc_ref, *, n_k: int,
                scale: float, act: str | None, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # in-tile densify: (bk/2, bn) vals+idx crossed HBM at half the dense
    # bytes; the iota-compare scatter rebuilds the (bk, bn) dense tile in
    # VMEM (what a sparse tensor core does in its operand mux)
    bk2, bn = v_ref.shape
    v = v_ref[...].astype(jnp.float32).reshape(bk2 // 2, 2, bn)
    i = i_ref[...].astype(jnp.int32).reshape(bk2 // 2, 2, bn)
    iota = jax.lax.broadcasted_iota(jnp.int32, (bk2 // 2, 4, bn), 1)
    w = ((iota == i[:, 0:1]) * v[:, 0:1]
         + (iota == i[:, 1:2]) * v[:, 1:2]).reshape(bk2 * 2, bn)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _fin():
        o_ref[...] = _epilogue(acc_ref[...], scale, act, out_dtype)


def gemm_sparse_24(x, vals, idx, *, scale: float = 1.0,
                   act: str | None = None, block_m: int = 128,
                   block_n: int = 128, block_k: int = 128,
                   out_dtype=jnp.float32, interpret: bool = False):
    """x: (M, K) @ 2:4-compressed w -> (M, N). ``vals``/``idx``: (K/2, N)
    from :func:`sparsify_24`. ``block_k`` counts logical K elements and
    must be a multiple of 4; shapes pre-padded to the block multiples."""
    M, K = x.shape
    Kh, N = vals.shape
    assert Kh * 2 == K, (x.shape, vals.shape)
    assert idx.shape == vals.shape, (idx.shape, vals.shape)
    assert block_k % 4 == 0, block_k
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        "pad in ops.py first", (M, K, N), (block_m, block_k, block_n))
    n_k = K // block_k
    grid = (M // block_m, N // block_n, n_k)

    kernel = functools.partial(_s24_kernel, n_k=n_k, scale=scale, act=act,
                               out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k // 2, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_k // 2, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, vals, idx)

"""Paged decode-attention Pallas TPU kernel (block-pool KV cache).

The serving cache is a global pool of fixed-size KV blocks — the software
analogue of Occamy's banked TCDM: many independent in-flight streams each own
a handful of fixed-size blocks instead of a statically reserved ``max_len``
region. Each decode query reads its sequence through a per-slot *block table*
(``(B, P)`` int32 of pool block ids, position ``p`` lives at row ``p %
page_size`` of block ``table[b, p // page_size]``).

Kernel layout: q ``(B, K, G, D)`` (one token per slot, GQA groups G), pools
``(N, page, K, D)``. Grid ``(B, K, P)`` with the page dimension innermost and
sequential; the block table and sequence lengths ride in as *scalar-prefetch*
operands (``pltpu.PrefetchScalarGridSpec``) so the K/V BlockSpec index maps
can chase the table — the pool block for grid step ``(b, k, j)`` is
``table[b, j]``, fetched by DMA like any dense operand. Running ``(m, l,
acc)`` live in VMEM scratch across the page pass (FlashAttention-style online
softmax); pages wholly beyond the sequence length are skipped with
``pl.when``, so decode cost scales with *allocated* pages, not table capacity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pa_body(tbl_ref, len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
             m_ref, l_ref, acc_ref, *, page: int, n_pages: int,
             scale: float, cap: float, out_dtype):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    base = j * page

    # pages beyond the sequence do no work: decode cost follows the block
    # table's allocated prefix, not its (max_len-sized) capacity
    @pl.when(base < length)
    def _page():
        q = q_ref[0, 0]                              # (G, D)
        k = k_ref[0, :, 0, :]                        # (page, D)
        v = v_ref[0, :, 0, :]
        if ks_ref is not None:
            # quantized pool, end-to-end: QK^T runs *on the storage codes*
            # via a mixed-input native dot (f32 x int8/fp8 -> f32) and the
            # per-row absmax scale — constant along D — factors out of the
            # contraction onto the (G, page) score matrix. No fp32/bf16
            # copy of the (page, D) tile is ever materialized.
            s = jax.lax.dot_general(
                q.astype(jnp.float32), k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            s = s * ks_ref[0, :, 0][None, :].astype(jnp.float32) * scale
        else:
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale   # (G, page)
        if cap:
            s = jnp.tanh(s / cap) * cap
        pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[...]                          # (G, 1)
        m_new = jnp.maximum(m_prev[:, 0], s.max(axis=-1))[:, None]
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)[:, None]
        if vs_ref is not None:
            # fold the per-v-row scale into the small (G, page) probability
            # matrix, then contract directly against the storage codes
            pv = p * vs_ref[0, :, 0][None, :].astype(jnp.float32)
            acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
                pv, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        else:
            acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
                p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-37)).astype(out_dtype)


def _pa_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
               m_ref, l_ref, acc_ref, **kw):
    _pa_body(tbl_ref, len_ref, q_ref, k_ref, v_ref, None, None, o_ref,
             m_ref, l_ref, acc_ref, **kw)


def _pa_kernel_quant(tbl_ref, len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                     o_ref, m_ref, l_ref, acc_ref, **kw):
    _pa_body(tbl_ref, len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
             m_ref, l_ref, acc_ref, **kw)


def paged_attention(q, k_pool, v_pool, block_tables, lengths,
                    k_scale=None, v_scale=None, *,
                    scale: float | None = None, cap: float = 0.0,
                    interpret: bool = False):
    """q: (B, K, G, D) single decode token per slot; k/v pools
    (N, page, K, D); block_tables: (B, P) int32 pool block ids; lengths:
    (B,) int32 valid tokens per slot (current token included). With
    ``k_scale``/``v_scale`` ((N, page, K) float) the pools are *quantized*
    (int8/fp8 storage) and the kernel contracts *directly against the
    storage codes* with mixed-input native dots, folding the per-row absmax
    scales into the (G, page) score/probability matrices — no bf16/fp32
    page-sized copy is ever materialized; the scale tiles chase the block
    table exactly like the pools. Returns (B, K, G, D)."""
    B, K, G, D = q.shape
    N, page = k_pool.shape[:2]
    P = block_tables.shape[1]
    scale = (1.0 / (D ** 0.5)) if scale is None else scale
    quant = k_scale is not None
    kernel = functools.partial(
        _pa_kernel_quant if quant else _pa_kernel, page=page, n_pages=P,
        scale=scale, cap=cap, out_dtype=q.dtype)
    pool_spec = pl.BlockSpec((1, page, 1, D),
                             lambda b, k, j, tbl, ln: (tbl[b, j], 0, k, 0))
    in_specs = [
        pl.BlockSpec((1, 1, G, D), lambda b, k, j, tbl, ln: (b, k, 0, 0)),
        pool_spec,
        pool_spec,
    ]
    args = [q, k_pool, v_pool]
    if quant:
        scale_spec = pl.BlockSpec(
            (1, page, 1), lambda b, k, j, tbl, ln: (tbl[b, j], 0, k))
        in_specs += [scale_spec, scale_spec]
        args += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                       # block_tables, lengths
        grid=(B, K, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, k, j, tbl, ln: (b, k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), *args)


def paged_attention_sharded(q, k_pool, v_pool, block_tables, lengths,
                            k_scale=None, v_scale=None, *, mesh, axis: str,
                            scale: float | None = None, cap: float = 0.0):
    """Sharded paged decode attention: ``shard_map`` over mesh ``axis``.

    Serving shards the block pools by KV head over the model axis (the
    per-chiplet HBM slice of the paper's scale-out arc): pools arrive
    ``(N, page, K/n, D)`` per shard, q replicated, block tables and lengths
    replicated scalar-prefetch operands. Each shard runs the *local* paged
    read over its own KV heads — heads are batch-like in decode attention,
    so the pass is communication-free; the (B, K, G, D) output shards over
    K and all-gathers only where downstream math (o_proj) needs the full
    head dim, which GSPMD inserts outside this body. Quantized pools carry
    their per-row scales sharded identically.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.collectives import shard_map_compat
    from repro.kernels.ref import paged_attention_ref

    head_spec = P(None, axis, None, None)
    pool_spec = P(None, None, axis, None)
    scale_spec = P(None, None, axis)
    quant = k_scale is not None

    if quant:
        def body(ql, kl, vl, tbl, ln, ksl, vsl):
            return paged_attention_ref(ql, kl, vl, tbl, ln, ksl, vsl,
                                       scale=scale, cap=cap)
        sm = shard_map_compat(
            body, mesh=mesh,
            in_specs=(head_spec, pool_spec, pool_spec, P(), P(),
                      scale_spec, scale_spec),
            out_specs=head_spec)
        return sm(q, k_pool, v_pool, block_tables, lengths, k_scale, v_scale)

    def body(ql, kl, vl, tbl, ln):
        return paged_attention_ref(ql, kl, vl, tbl, ln,
                                   scale=scale, cap=cap)
    sm = shard_map_compat(
        body, mesh=mesh,
        in_specs=(head_spec, pool_spec, pool_spec, P(), P()),
        out_specs=head_spec)
    return sm(q, k_pool, v_pool, block_tables, lengths)

"""Streaming tiled GEMM with fused in-stream epilogue (Pallas TPU).

The Occamy cluster recipe (paper C1): double-buffered HBM→SPM tiles feeding a
dense compute unit — here, ``BlockSpec``-pipelined HBM→VMEM tiles feeding the
MXU, with the K-loop accumulating in a VMEM fp32 scratch (the paper's
expanding/widening accumulation, C2). The epilogue (scale/bias/activation) is
applied while the tile is still in VMEM — Ogopogo's in-stream DMA ops (C5b):
no second pass over HBM for the elementwise work.

Grid: (M/bm, N/bn, K/bk) with K innermost (sequential on TPU), so the output
tile stays resident while input tiles stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int, scale: float,
                 act: str | None, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        out = acc_ref[...]
        if scale != 1.0:
            out = out * scale
        if act == "gelu":
            out = jax.nn.gelu(out, approximate=True)
        elif act == "silu":
            out = jax.nn.silu(out)
        o_ref[...] = out.astype(out_dtype)


def _gemm_bias_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_k: int,
                      scale: float, act: str | None, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        out = acc_ref[...] * scale + b_ref[...].astype(jnp.float32)
        if act == "gelu":
            out = jax.nn.gelu(out, approximate=True)
        elif act == "silu":
            out = jax.nn.silu(out)
        o_ref[...] = out.astype(out_dtype)


def gemm(x, w, *, bias=None, scale: float = 1.0, act: str | None = None,
         block_m: int = 128, block_n: int = 128, block_k: int = 128,
         out_dtype=jnp.float32, interpret: bool = False):
    """x: (M, K) @ w: (K, N) -> (M, N) with fused epilogue.

    Blocks are MXU-aligned (multiples of 128); non-divisible edges fall back
    to smaller aligned blocks chosen by the wrapper (ops.py pads instead).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        "pad in ops.py first", (M, K, N), (block_m, block_k, block_n))
    n_k = K // block_k
    grid = (M // block_m, N // block_n, n_k)

    if bias is None:
        kernel = functools.partial(_gemm_kernel, n_k=n_k, scale=scale, act=act,
                                   out_dtype=out_dtype)
        in_specs = [
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ]
        args = (x, w)
    else:
        kernel = functools.partial(_gemm_bias_kernel, n_k=n_k, scale=scale,
                                   act=act, out_dtype=out_dtype)
        in_specs = [
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ]
        args = (x, w, bias.reshape(1, N))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(*args)

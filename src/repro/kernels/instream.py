"""In-stream DMA operations (paper C5b) as a Pallas TPU kernel.

Ogopogo extends the cluster DMA engines with in-stream vector units that
scale/shift elements and compute arithmetic reductions *while the data is in
flight*. TPU analogue: a streaming copy kernel whose grid pipelines HBM→VMEM
tiles; the elementwise op is applied in VMEM during the copy and a running
reduction accumulates in scratch — one pass over HBM instead of
(copy, scale, reduce) = three.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _instream_kernel(x_ref, y_ref, sum_ref, acc_ref, *, n: int, scale: float,
                     shift: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    y = x_ref[...].astype(jnp.float32) * scale + shift
    y_ref[...] = y
    acc_ref[...] += jnp.sum(y, axis=0, keepdims=True)

    @pl.when(i == n - 1)
    def _finish():
        sum_ref[...] = jnp.sum(acc_ref[...], axis=-1, keepdims=True)


def instream_scale_reduce(x, *, scale: float = 1.0, shift: float = 0.0,
                          block: int = 1024, interpret: bool = False):
    """x: (M, D). Returns (scale*x + shift, global sum) in one stream pass."""
    M, D = x.shape
    bm = min(block, M)
    assert M % bm == 0, "pad in ops.py first"
    n = M // bm
    kernel = functools.partial(_instream_kernel, n=n, scale=scale, shift=shift)
    y, s = pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((bm, D), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, D), lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, D), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((1, D), jnp.float32)],
        interpret=interpret,
    )(x)
    return y, s[0, 0]

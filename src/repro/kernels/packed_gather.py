"""Packed irregular streams (paper C5c) / SU indirect streams (C2) on TPU.

The paper's streaming units issue index-driven accesses that the Ogopogo
extension packs into wide NoC flits with an HBM-side coalescer. The TPU
analogue: a *scalar-prefetched* index array drives the ``BlockSpec``
``index_map`` — the indices arrive ahead of the data (exactly an SU's index
FIFO) and each grid step DMAs ``pack`` table rows as one wide, lane-aligned
VMEM tile. The ops.py wrapper optionally sorts indices first (the temporal
coalescer), turning random narrow reads into near-sequential wide ones.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, table_ref, o_ref):
    # the whole block was DMA'd by the index_map; plain copy through VMEM
    o_ref[...] = table_ref[...]


def gather_rows(table, idx, *, interpret: bool = False):
    """out[i] = table[idx[i]]  — one row per grid step, index-driven DMA.

    table: (N, D); idx: (M,) int32. The narrow-stream baseline (8 B–wide
    requests in the paper; one D-row here).
    """
    N, D = table.shape
    M = idx.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M,),
        in_specs=[pl.BlockSpec((1, D), lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=pl.BlockSpec((1, D), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, D), table.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), table)


def _packed_kernel(idx_ref, table_ref, o_ref, *, pack: int, window: int):
    # gather `pack` rows from the VMEM-resident window into one wide tile
    i = pl.program_id(0)
    base = (idx_ref[i * pack] // window) * window  # staged window start
    for r in range(pack):
        src = idx_ref[i * pack + r] - base         # offset within window
        o_ref[r, :] = table_ref[src, :]


def packed_gather_rows(table, idx, *, pack: int = 8, window: int = 256,
                       interpret: bool = False):
    """Packed variant: ``pack`` indexed rows per grid step, fetched from a
    ``window``-row table tile staged in VMEM (the wide-flit + coalescer pair).
    Requires indices pre-sorted (ops.py does this) so each pack's rows fall
    within one window: idx[i*pack+r] - idx[i*pack] < window.
    """
    N, D = table.shape
    M = idx.shape[0]
    assert M % pack == 0, "pad in ops.py first"
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M // pack,),
        in_specs=[pl.BlockSpec(
            (window, D),
            # stage the window containing this pack's first row
            lambda i, idx_ref: (idx_ref[i * pack] // window, 0))],
        out_specs=pl.BlockSpec((pack, D), lambda i, idx_ref: (i, 0)),
    )
    kernel = functools.partial(_packed_kernel, pack=pack, window=window)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, D), table.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), table)

"""Weight-quantized streaming GEMM with in-tile dequantization (Pallas TPU).

Same schedule as the dense ``gemm`` kernel (gemm.py): grid
``(M/bm, N/bn, K/bk)`` with K innermost and a VMEM fp32 accumulator — the
Occamy cluster recipe (C1) — but the weight operand streams through HBM at
its *storage* width (int8, fp8-e4m3, or nibble-packed int4: half / quarter /
eighth the bf16 bytes — the paper's precision-halving bandwidth double) and
is dequantized **in-tile**, right after the DMA, the way Ogopogo's in-stream
DMA ops (C5b) apply elementwise work during the transfer.

``pack=2`` selects the int4 layout: the weight operand is ``(K/2, N)`` int8
bytes carrying two codes each (lo nibble = even K row, hi = odd), the tile
crosses HBM at half-byte-per-element width, and the kernel sign-extends the
nibbles with a shift pair before the dequant multiply — unpack happens in
VMEM, never in HBM.

Scales arrive pre-gathered per K-tile: the wrapper (ops.py) turns the
``(n_blocks, N)`` per-block scales into ``(n_k_tiles, N)`` rows — one row
per K grid step — so the kernel reads a ``(1, bn)`` scale tile with a plain
``(k, j)`` index map and never straddles a quant-block boundary (the
wrapper picks ``block_k`` to divide the quant block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dequant_tile(q_ref, s_ref, pack: int):
    """(bk/pack, bn) storage tile -> (bk, bn) fp32 weight tile."""
    q = q_ref[...]
    if pack == 2:
        # sign-extending nibble unpack: lo via shift-up/arith-shift-down,
        # hi via arithmetic shift; interleave restores the logical K order
        lo = (q << 4).astype(jnp.int8) >> 4
        hi = q >> 4
        q = jnp.stack([lo, hi], axis=1).reshape(q.shape[0] * 2, q.shape[1])
    return q.astype(jnp.float32) * s_ref[...].astype(jnp.float32)


def _wq_kernel(x_ref, q_ref, s_ref, o_ref, acc_ref, *, n_k: int, scale: float,
               act: str | None, out_dtype, pack: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # in-tile dequant: the weight tile crossed HBM at storage width
    w = _dequant_tile(q_ref, s_ref, pack)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        out = acc_ref[...]
        if scale != 1.0:
            out = out * scale
        if act == "gelu":
            out = jax.nn.gelu(out, approximate=True)
        elif act == "silu":
            out = jax.nn.silu(out)
        o_ref[...] = out.astype(out_dtype)


def _wq_bias_kernel(x_ref, q_ref, s_ref, b_ref, o_ref, acc_ref, *, n_k: int,
                    scale: float, act: str | None, out_dtype, pack: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _dequant_tile(q_ref, s_ref, pack)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        out = acc_ref[...] * scale + b_ref[...].astype(jnp.float32)
        if act == "gelu":
            out = jax.nn.gelu(out, approximate=True)
        elif act == "silu":
            out = jax.nn.silu(out)
        o_ref[...] = out.astype(out_dtype)


def gemm_wq(x, qw, tile_scales, *, bias=None, scale: float = 1.0,
            act: str | None = None, block_m: int = 128, block_n: int = 128,
            block_k: int = 128, out_dtype=jnp.float32,
            interpret: bool = False, pack: int = 1):
    """x: (M, K) float @ qw: (K/pack, N) int8/fp8 -> (M, N), fused epilogue.

    ``tile_scales``: (K // block_k, N) fp32 — one dequant-scale row per
    K-tile (the wrapper expands per-block scales; a tile never straddles a
    quant block). ``pack=2`` marks int4 nibble-packed ``qw`` (unpacked
    in-tile). Shapes must already be padded to the block multiples; block
    sizes are in *logical* K elements, so ``block_k % pack == 0``.
    """
    M, K = x.shape
    Kq, N = qw.shape
    assert Kq * pack == K, (x.shape, qw.shape, pack)
    assert block_k % pack == 0, (block_k, pack)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        "pad in ops.py first", (M, K, N), (block_m, block_k, block_n))
    n_k = K // block_k
    assert tile_scales.shape == (n_k, N), (tile_scales.shape, n_k, N)
    grid = (M // block_m, N // block_n, n_k)
    bkq = block_k // pack          # storage rows per weight tile

    if bias is None:
        kernel = functools.partial(_wq_kernel, n_k=n_k, scale=scale, act=act,
                                   out_dtype=out_dtype, pack=pack)
        in_specs = [
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((bkq, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (k, j)),
        ]
        args = (x, qw, tile_scales)
    else:
        kernel = functools.partial(_wq_bias_kernel, n_k=n_k, scale=scale,
                                   act=act, out_dtype=out_dtype, pack=pack)
        in_specs = [
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((bkq, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ]
        args = (x, qw, tile_scales, bias.reshape(1, N))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(*args)

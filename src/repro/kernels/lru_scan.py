"""Diagonal linear recurrence kernel: h_t = a_t ⊙ h_{t-1} + b_t.

Serves RG-LRU (RecurrentGemma) and the Mamba-1 selective scan (flattened
(d_inner, d_state) channels). TPU adaptation of the paper's C1 recipe for a
recurrence: the time loop runs *inside* the kernel over a VMEM-resident chunk
(sequential in t, vectorized across the lane dimension D), while the grid
streams (batch × channel-tile × chunk) blocks HBM→VMEM; the carry ``h`` lives
in a VMEM scratch across the chunk dimension. A GPU implementation would use
a warp-parallel associative scan; on TPU the VPU prefers a dense sequential
loop over lanes — this is the hardware adaptation, not a port.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lru_kernel(a_ref, b_ref, o_ref, h_ref, *, chunk: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def step(t, h):
        at = a_ref[0, t, :]
        bt = b_ref[0, t, :]
        h = at * h + bt
        o_ref[0, t, :] = h
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


def lru_scan(a, b, *, block_d: int = 512, chunk: int = 256,
             interpret: bool = False):
    """a, b: (B, L, D) fp32 -> h: (B, L, D) fp32 (zero initial state)."""
    B, L, D = a.shape
    bd = min(block_d, D)
    ck = min(chunk, L)
    assert D % bd == 0 and L % ck == 0, "pad in ops.py first"
    grid = (B, D // bd, L // ck)
    kernel = functools.partial(_lru_kernel, chunk=ck)
    spec = pl.BlockSpec((1, ck, bd), lambda i, j, c: (i, c, j))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, L, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd,), jnp.float32)],
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))

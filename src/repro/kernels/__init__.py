"""Kernel layer: Pallas hot-spot kernels behind a backend registry.

Public surface:
  * :mod:`repro.kernels.ops` — the registry-dispatched ops (``gemm``,
    ``gemm_wq``, ``flash_attention``, ``paged_attention``, ``lru_scan``,
    ``gather_rows``, ``packed_gather_rows``, ``instream_scale_reduce``).
  * :mod:`repro.kernels.dispatch` — ``OpRegistry``, ``use_backend``,
    capability negotiation, block-size tuning (re-exported here).
  * :mod:`repro.kernels.ref` — the pure-jnp oracles (registered as the
    universal negotiation fallback).

Per-kernel modules (gemm.py, flash_attention.py, ...) hold the raw
``pallas_call`` wrappers; add new kernels there and register them in ops.py.
See docs/backends.md.
"""
from repro.kernels.dispatch import (BACKENDS, KERNEL_BACKENDS, Backend,
                                    BlockSpec, OpRegistry,
                                    kernel_scope_active, registry,
                                    requested_backend, resolve_backend,
                                    use_backend)

__all__ = ["BACKENDS", "KERNEL_BACKENDS", "Backend", "BlockSpec",
           "OpRegistry", "kernel_scope_active", "registry",
           "requested_backend", "resolve_backend", "use_backend"]

"""Post-load parameter quantization keyed off ``ModelConfig`` knobs.

``quantize_params(params, cfg)`` walks a model's parameter pytree and wraps
every matmul weight in a :class:`~repro.quant.tensor.QuantTensor` according
to ``cfg.weight_dtype`` (int8 / fp8-e4m3) and ``cfg.quant_block`` (0 =
per-channel, > 0 = per-block scales along the contraction axis). It is a
*serving-side* transform: training and SPMD graphs keep the dense master
weights (the Pallas kernels and the dequant paths are forward-only).

What gets quantized:

* every ``.../<module>/kernel`` leaf with ndim >= 2 — attention q/k/v/o
  projections, dense and MoE-shared MLPs, lm_head, recurrent in/out/gate
  projections (block-diagonal gates included; they are matmul weights too);
* the stacked MoE expert tensors ``experts/{gate,up,down}`` (per-expert,
  per-channel scales);
* the embedding table (``embed/table``), quantized **per row** (axis=-1) so
  the token gather dequantizes row-local scales and — for tied embeddings —
  the ``table.T`` lm-head matmul sees per-output-channel scales.

What stays dense: norms, biases, depthwise-conv kernels (indexed per tap,
not matmul'd), the MoE router (routing argmax is precision-sensitive and
the tensor is tiny), recurrent Lambda/A_log/D vectors, positional tables.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.quant.tensor import (QuantTensor, canonical_dtype, is_quant_dtype,
                                quantize_tensor)

PyTree = Any

#: Module keys whose "kernel" leaf must stay dense.
_SKIP_MODULES = frozenset({"conv", "router"})


def _path_keys(path) -> list[str]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        else:
            out.append(str(k))
    return out


def _should_quantize(keys: list[str], leaf) -> tuple[bool, int]:
    """-> (quantize?, contraction axis)."""
    if getattr(leaf, "ndim", 0) < 2:
        return False, -2
    last = keys[-1]
    if last == "kernel":
        if any(k in _SKIP_MODULES for k in keys):
            return False, -2
        return True, -2
    if last == "table" and "embed" in keys and "pos_embed" not in keys \
            and "encoder" not in keys:
        return True, -1                       # per-row embedding scales
    if keys[-1] in ("gate", "up", "down") and "experts" in keys:
        return True, -2                       # stacked (E, d, f) experts
    return False, -2


def quantize_params(params: PyTree, cfg=None, *, dtype: str | None = None,
                    block: int | None = None,
                    include_embed: bool = True) -> PyTree:
    """Wrap matmul weights in :class:`QuantTensor` containers.

    ``cfg`` supplies ``weight_dtype`` / ``quant_block`` (overridable by the
    explicit kwargs). Idempotent: already-wrapped leaves pass through. A
    no-op (returns ``params``) when no quant dtype is configured.
    """
    dtype = dtype if dtype is not None else getattr(cfg, "weight_dtype", "")
    block = block if block is not None else getattr(cfg, "quant_block", 0)
    if not dtype:
        return params
    dtype = canonical_dtype(dtype)

    def f(path, leaf):
        if isinstance(leaf, QuantTensor):
            return leaf
        keys = _path_keys(path)
        do, axis = _should_quantize(keys, leaf)
        if not do or (axis == -1 and not include_embed):
            return leaf
        # the embedding table is strictly per-row (one scale per token id):
        # the gather path multiplies q[tokens] by scales[tokens] directly
        return quantize_tensor(leaf, dtype,
                               block=0 if axis == -1 else block, axis=axis)

    return jax.tree_util.tree_map_with_path(
        f, params, is_leaf=lambda x: isinstance(x, QuantTensor))


def is_quantized(params: PyTree) -> bool:
    return any(isinstance(x, QuantTensor)
               for x in jax.tree.leaves(
                   params, is_leaf=lambda x: isinstance(x, QuantTensor)))


def param_bytes(params: PyTree) -> int:
    """Storage bytes of a parameter tree (QuantTensor counts q + scales)."""
    total = 0
    for leaf in jax.tree.leaves(
            params, is_leaf=lambda x: isinstance(x, QuantTensor)):
        if isinstance(leaf, QuantTensor):
            total += leaf.nbytes
        else:
            total += int(leaf.size * leaf.dtype.itemsize)
    return total


__all__ = ["is_quant_dtype", "is_quantized", "param_bytes", "quantize_params"]

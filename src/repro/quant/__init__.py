"""Multi-precision quantization subsystem (paper: 8-to-64-bit compute).

Public surface:

* :class:`QuantTensor` — weight-only quantized parameter container (pytree;
  ``astype`` dequantizes so existing call sites work unchanged);
* :func:`quantize_params` — post-load transform keyed off
  ``ModelConfig.weight_dtype`` / ``quant_block``;
* absmax quantizers: :func:`quantize_weight` / :func:`quantize_tensor`
  (per-channel / per-block), :func:`quantize_kv` / :func:`dequantize_kv`
  (per-row, the paged KV cache), :func:`quantize_int8` (whole-tensor scalar
  scale — shared with ``core/collectives.py`` gradient compression);
* sizing helpers: :func:`dtype_bytes`, :func:`param_bytes`.

The matching compute paths live in the kernel registry (``gemm_wq``,
quantized ``paged_attention`` — see docs/backends.md) and the cache layout
in ``models/cache.py`` (see docs/quantization.md).
"""
from repro.quant.params import (is_quantized, param_bytes, quantize_params)
from repro.quant.tensor import (QUANT_DTYPES, QuantTensor, canonical_dtype,
                                dequantize_kv, dequantize_weight, dtype_bytes,
                                is_quant_dtype, pack_int4, quantize_int8,
                                quantize_kv, quantize_tensor, quantize_weight,
                                unpack_int4)

__all__ = [
    "QUANT_DTYPES", "QuantTensor", "canonical_dtype", "dequantize_kv",
    "dequantize_weight", "dtype_bytes", "is_quant_dtype", "is_quantized",
    "pack_int4", "param_bytes", "quantize_int8", "quantize_kv",
    "quantize_params", "quantize_tensor", "quantize_weight", "unpack_int4",
]

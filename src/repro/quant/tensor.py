"""Quantized parameter containers and absmax quantizers.

Occamy's defining capability is 8-to-64-bit multi-precision compute: the
silicon doubles throughput every time precision halves (paper Fig. 4b / the
Ogopogo compute-density argument). The software analogue here is *weight-only
post-training quantization*: master weights stay fp32/bf16 for training, and
a post-load transform (:func:`repro.quant.params.quantize_params`) wraps the
matmul weights in :class:`QuantTensor` — int8 or fp8-e4m3 storage plus
per-channel (optionally per-block) fp32 absmax scales.

``QuantTensor`` is a registered JAX pytree whose ``astype`` *dequantizes*, so
every existing call site of the form ``p["q_proj"]["kernel"].astype(dtype)``
keeps working unchanged (weight-only quantization: compute happens at the
activation dtype). Call sites that want the fused in-tile dequant path
(``models/layers.py:dense``, the MoE expert FFN) detect the container and
dispatch the ``gemm_wq`` registry op instead.

Calibration is plain absmax (symmetric, zero-point-free):

  * int8: ``scale = amax / 127``, values rounded and clipped to [-127, 127];
  * fp8-e4m3: ``scale = amax / 448`` (e4m3's max normal), values cast.

``block > 0`` splits the contraction axis into ``K // block`` groups with one
scale each — narrower groups bound the absmax blast radius of outlier
channels, the usual int8 accuracy knob.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

#: Storage dtypes the subsystem understands, with accepted aliases.
QUANT_DTYPES = ("int8", "float8_e4m3fn")
_ALIASES = {"fp8": "float8_e4m3fn", "e4m3": "float8_e4m3fn",
            "float8": "float8_e4m3fn", "int8": "int8",
            "float8_e4m3fn": "float8_e4m3fn"}
#: Largest representable magnitude per storage dtype.
_QMAX = {"int8": 127.0, "float8_e4m3fn": 448.0}
_EPS = 1e-12


def canonical_dtype(name: str) -> str:
    """Normalize a quant dtype alias ("fp8" -> "float8_e4m3fn")."""
    if name not in _ALIASES:
        raise ValueError(f"unknown quant dtype {name!r}; expected one of "
                         f"{sorted(set(_ALIASES))}")
    return _ALIASES[name]


def is_quant_dtype(name: str) -> bool:
    return bool(name) and name in _ALIASES


def dtype_bytes(name: str) -> int:
    """Storage bytes per element for any dtype name (quant aliases included).
    Used by the roofline/memfloor byte terms (core/roofline.py)."""
    if is_quant_dtype(name):
        name = canonical_dtype(name)
    return jnp.dtype(name).itemsize


def _storage_dtype(name: str):
    return jnp.dtype(canonical_dtype(name))


def _cast_q(x, dtype: str):
    """fp32 scaled values -> storage dtype (round+clip for int8, cast for
    fp8: the e4m3 cast saturates)."""
    if dtype == "int8":
        return jnp.clip(jnp.round(x), -127, 127).astype(jnp.int8)
    return x.astype(jnp.float8_e4m3fn)


# --------------------------------------------------------------------------
# scalar-scale int8 — the one absmax implementation shared with
# core/collectives.py's gradient compression (one quantizer, many callers)
# --------------------------------------------------------------------------
def quantize_int8(x: jnp.ndarray):
    """Whole-tensor absmax int8: returns (q int8, scalar fp32 scale)."""
    amax = jnp.max(jnp.abs(x)) + _EPS
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


# --------------------------------------------------------------------------
# weight quantization (per-channel / per-block along a contraction axis)
# --------------------------------------------------------------------------
def quantize_weight(w, dtype: str = "int8", *, block: int = 0,
                    axis: int = -2):
    """Quantize ``w`` along ``axis`` (the matmul contraction axis).

    Returns ``(q, scales)`` where ``q`` has ``w``'s shape in the storage
    dtype and ``scales`` (float16 — its rounding is ~8x below the int8
    step, and narrow scales keep the container's byte overhead at
    ``2 / block`` per element) has the same shape except ``axis`` reduced
    to ``n_blocks`` (= 1 per-channel, or ``K // block`` when ``block``
    divides the axis; a non-dividing ``block`` falls back to per-channel).
    """
    dtype = canonical_dtype(dtype)
    axis = axis % w.ndim
    K = w.shape[axis]
    nb = K // block if block and K % block == 0 else 1
    kb = K // nb
    wf = w.astype(jnp.float32)
    # view blocks: (..., nb, kb, ...) with the block pair at `axis`
    shape = w.shape[:axis] + (nb, kb) + w.shape[axis + 1:]
    wb = wf.reshape(shape)
    amax = jnp.max(jnp.abs(wb), axis=axis + 1) + _EPS      # (..., nb, ...)
    scales = (amax / _QMAX[dtype]).astype(jnp.float16)
    q = _cast_q(wb / jnp.expand_dims(scales.astype(jnp.float32), axis + 1),
                dtype)
    return q.reshape(w.shape), scales


def dequantize_weight(q, scales, *, axis: int = -2, dtype=jnp.float32):
    """Inverse of :func:`quantize_weight` (up to quantization error)."""
    axis = axis % q.ndim
    nb = scales.shape[axis]
    kb = q.shape[axis] // nb
    shape = q.shape[:axis] + (nb, kb) + q.shape[axis + 1:]
    out = q.astype(jnp.float32).reshape(shape) * jnp.expand_dims(
        scales.astype(jnp.float32), axis + 1)
    return out.reshape(q.shape).astype(dtype)


# --------------------------------------------------------------------------
# KV-row quantization (paged cache): one scale per written row per head
# --------------------------------------------------------------------------
def quantize_kv(x, dtype: str = "int8"):
    """x: (..., hd) float K/V rows -> (q (..., hd), scales (...) float16).

    One absmax scale per (row, head): decode writes one token at a time, so
    per-row scales need no calibration pass and stay exact under incremental
    writes. Scales are stored float16 — the pool bookkeeping overhead is
    ``2 / head_dim`` bytes per element.
    """
    dtype = canonical_dtype(dtype)
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1) + _EPS
    scales = (amax / _QMAX[dtype]).astype(jnp.float16)
    q = _cast_q(xf / scales.astype(jnp.float32)[..., None], dtype)
    return q, scales


def dequantize_kv(q, scales, dtype=jnp.float32):
    """Inverse of :func:`quantize_kv`: (..., hd) q + (...) scales."""
    return (q.astype(jnp.float32)
            * scales.astype(jnp.float32)[..., None]).astype(dtype)


# --------------------------------------------------------------------------
# the container
# --------------------------------------------------------------------------
@jax.tree_util.register_pytree_with_keys_class
class QuantTensor:
    """Weight-only quantized parameter: storage values + absmax scales.

    A registered pytree (leaves ``q`` and ``scales``), so it flows through
    ``jax.tree`` maps, ``jax.lax.scan`` over stacked layer blocks (both
    leaves slice on the leading axis together), jit argument flattening, and
    path-based checkpointing (leaf keys ``....q`` / ``....scales``) without
    special cases. ``axis`` (static aux data) is the contraction axis the
    scales reduce, counted from the end: -2 for ``(K, N)`` matmul kernels,
    -1 for the per-row-quantized embedding table.
    """

    def __init__(self, q, scales, axis: int = -2):
        self.q = q
        self.scales = scales
        self.axis = axis

    # ---- pytree protocol --------------------------------------------------
    def tree_flatten_with_keys(self):
        return (((jax.tree_util.GetAttrKey("q"), self.q),
                 (jax.tree_util.GetAttrKey("scales"), self.scales)),
                self.axis)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scales = children
        return cls(q, scales, axis=aux)

    # ---- array-like surface (what model call sites touch) ----------------
    @property
    def shape(self):
        return self.q.shape

    @property
    def ndim(self):
        return self.q.ndim

    @property
    def dtype(self):
        return self.q.dtype

    @property
    def nbytes(self) -> int:
        return int(self.q.size * self.q.dtype.itemsize
                   + self.scales.size * self.scales.dtype.itemsize)

    @property
    def n_blocks(self) -> int:
        return self.scales.shape[self.axis % self.q.ndim]

    def dequantize(self, dtype=jnp.float32):
        return dequantize_weight(self.q, self.scales, axis=self.axis,
                                 dtype=dtype)

    def astype(self, dtype):
        """Dequantize — keeps ``p[...]["kernel"].astype(compute_dtype)``
        call sites working unchanged (weight-only quantization)."""
        return self.dequantize(dtype)

    @property
    def T(self):
        """Dequantized transpose (tied-embedding logits: ``embed.table.T``)."""
        return self.dequantize(jnp.float32).T

    def __repr__(self):
        return (f"QuantTensor(shape={tuple(self.q.shape)}, "
                f"dtype={self.q.dtype}, n_blocks={self.n_blocks}, "
                f"axis={self.axis})")


def quantize_tensor(w, dtype: str = "int8", *, block: int = 0,
                    axis: int = -2) -> QuantTensor:
    q, scales = quantize_weight(w, dtype, block=block, axis=axis)
    return QuantTensor(q, scales, axis=axis)

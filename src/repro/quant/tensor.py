"""Quantized parameter containers and absmax quantizers.

Occamy's defining capability is 8-to-64-bit multi-precision compute: the
silicon doubles throughput every time precision halves (paper Fig. 4b / the
Ogopogo compute-density argument). The software analogue here is *weight-only
post-training quantization*: master weights stay fp32/bf16 for training, and
a post-load transform (:func:`repro.quant.params.quantize_params`) wraps the
matmul weights in :class:`QuantTensor` — int8, fp8-e4m3, or packed int4
storage plus per-channel (optionally per-block) absmax scales.

``QuantTensor`` is a registered JAX pytree whose ``astype`` *dequantizes*, so
every existing call site of the form ``p["q_proj"]["kernel"].astype(dtype)``
keeps working unchanged (weight-only quantization: compute happens at the
activation dtype). Call sites that want the fused in-tile dequant path
(``models/layers.py:dense``, the MoE expert FFN) detect the container and
dispatch the ``gemm_wq`` registry op instead.

Calibration is plain absmax (symmetric, zero-point-free):

  * int8: ``scale = amax / 127``, values rounded and clipped to [-127, 127];
  * fp8-e4m3: ``scale = amax / 448`` (e4m3's max normal), values clipped to
    [-448, 448] then cast — the raw e4m3 cast only saturates within rounding
    distance of the boundary and produces NaN beyond it, so the clip is
    load-bearing;
  * int4: ``scale = amax / 7``, values rounded and clipped to [-7, 7], then
    two codes packed per int8 byte along the quantization axis (lo nibble =
    even logical index, hi nibble = odd). ``QuantTensor.pack == 2`` marks
    the packed layout; the logical (unpacked) shape is what ``.shape``
    reports.

Absmax is floored at ``_EPS`` so all-zero rows/blocks (padding rows, the
block-0 null write-sink pages) quantize to exact zeros: an unfloored
``amax == 0`` underflows to a 0.0 float16 scale and ``0 / 0`` stores NaN.

``block > 0`` splits the contraction axis into ``K // block`` groups with one
scale each — narrower groups bound the absmax blast radius of outlier
channels, the usual int8 accuracy knob.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

#: Storage dtypes the subsystem understands, with accepted aliases.
QUANT_DTYPES = ("int8", "float8_e4m3fn", "int4")
_ALIASES = {"fp8": "float8_e4m3fn", "e4m3": "float8_e4m3fn",
            "float8": "float8_e4m3fn", "int8": "int8",
            "float8_e4m3fn": "float8_e4m3fn", "int4": "int4"}
#: Largest representable magnitude per storage dtype.
_QMAX = {"int8": 127.0, "float8_e4m3fn": 448.0, "int4": 7.0}
#: Absmax floor. Chosen so the float16-stored scale survives the cast even
#: for the widest code range: 1e-4 / 448 ≈ 2.2e-7 is still a representable
#: fp16 subnormal (min 6e-8), whereas the old additive 1e-12 underflowed to
#: a 0.0 scale on all-zero rows and stored NaN.
_EPS = 1e-4


def canonical_dtype(name: str) -> str:
    """Normalize a quant dtype alias ("fp8" -> "float8_e4m3fn")."""
    if name not in _ALIASES:
        raise ValueError(f"unknown quant dtype {name!r}; expected one of "
                         f"{sorted(set(_ALIASES))}")
    return _ALIASES[name]


def is_quant_dtype(name: str) -> bool:
    return bool(name) and name in _ALIASES


def dtype_bytes(name: str) -> float:
    """Storage bytes per element for any dtype name (quant aliases included).
    Used by the roofline/memfloor byte terms (core/roofline.py). Packed int4
    is half a byte per logical element."""
    if is_quant_dtype(name):
        name = canonical_dtype(name)
        if name == "int4":
            return 0.5
    return jnp.dtype(name).itemsize


def _storage_dtype(name: str):
    name = canonical_dtype(name)
    # int4 codes live two-per-byte in an int8 container
    return jnp.dtype("int8" if name == "int4" else name)


def _cast_q(x, dtype: str):
    """fp32 scaled values -> storage dtype (round+clip for the int rungs,
    clip+cast for fp8 — the e4m3 cast only saturates at the boundary and
    NaNs past ~±464, so out-of-range values must be clipped first)."""
    if dtype == "int8":
        return jnp.clip(jnp.round(x), -127, 127).astype(jnp.int8)
    if dtype == "int4":
        # unpacked codes; pack_int4 interleaves them two per byte
        return jnp.clip(jnp.round(x), -7, 7).astype(jnp.int8)
    return jnp.clip(x, -448.0, 448.0).astype(jnp.float8_e4m3fn)


# --------------------------------------------------------------------------
# int4 nibble packing
# --------------------------------------------------------------------------
def pack_int4(codes, axis: int = -2):
    """Pack int8 codes in [-7, 7] two-per-byte along ``axis`` (which must be
    even-length): byte ``i`` holds logical element ``2i`` in its low nibble
    and ``2i + 1`` in the high nibble."""
    axis = axis % codes.ndim
    K = codes.shape[axis]
    if K % 2:
        raise ValueError(f"int4 packing needs an even axis length, got {K}")
    shape = codes.shape[:axis] + (K // 2, 2) + codes.shape[axis + 1:]
    c = codes.astype(jnp.int8).reshape(shape)
    lo = jax.lax.index_in_dim(c, 0, axis + 1, keepdims=False)
    hi = jax.lax.index_in_dim(c, 1, axis + 1, keepdims=False)
    return ((lo & jnp.int8(0x0F)) | (hi << 4)).astype(jnp.int8)


def unpack_int4(packed, axis: int = -2):
    """Inverse of :func:`pack_int4`: int8 nibble pairs -> int8 codes with
    ``axis`` doubled. Sign-extends via shift pairs (arithmetic ``>>``)."""
    axis = axis % packed.ndim
    lo = (packed << 4).astype(jnp.int8) >> 4
    hi = packed >> 4
    st = jnp.stack([lo, hi], axis=axis + 1)        # (..., K/2, 2, ...)
    shape = (packed.shape[:axis] + (packed.shape[axis] * 2,)
             + packed.shape[axis + 1:])
    return st.reshape(shape)


# --------------------------------------------------------------------------
# scalar-scale int8 — the one absmax implementation shared with
# core/collectives.py's gradient compression (one quantizer, many callers)
# --------------------------------------------------------------------------
def quantize_int8(x: jnp.ndarray):
    """Whole-tensor absmax int8: returns (q int8, scalar fp32 scale)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), _EPS)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


# --------------------------------------------------------------------------
# weight quantization (per-channel / per-block along a contraction axis)
# --------------------------------------------------------------------------
def quantize_weight(w, dtype: str = "int8", *, block: int = 0,
                    axis: int = -2):
    """Quantize ``w`` along ``axis`` (the matmul contraction axis).

    Returns ``(q, scales)`` where ``q`` has ``w``'s shape in the storage
    dtype — except for ``dtype="int4"`` where the quantization axis is
    nibble-packed to half length (two codes per int8 byte) — and ``scales``
    (float16 — its rounding is ~8x below the int8 step, and narrow scales
    keep the container's byte overhead at ``2 / block`` per element) has the
    same shape except ``axis`` reduced to ``n_blocks`` (= 1 per-channel, or
    ``K // block`` when ``block`` divides the axis; a non-dividing ``block``
    falls back to per-channel).
    """
    dtype = canonical_dtype(dtype)
    axis = axis % w.ndim
    K = w.shape[axis]
    nb = K // block if block and K % block == 0 else 1
    kb = K // nb
    wf = w.astype(jnp.float32)
    # view blocks: (..., nb, kb, ...) with the block pair at `axis`
    shape = w.shape[:axis] + (nb, kb) + w.shape[axis + 1:]
    wb = wf.reshape(shape)
    amax = jnp.maximum(jnp.max(jnp.abs(wb), axis=axis + 1), _EPS)
    scales = (amax / _QMAX[dtype]).astype(jnp.float16)
    q = _cast_q(wb / jnp.expand_dims(scales.astype(jnp.float32), axis + 1),
                dtype)
    q = q.reshape(w.shape)
    if dtype == "int4":
        q = pack_int4(q, axis)
    return q, scales


def dequantize_weight(q, scales, *, axis: int = -2, dtype=jnp.float32,
                      pack: int = 1):
    """Inverse of :func:`quantize_weight` (up to quantization error).
    ``pack=2`` unpacks int4 nibbles along ``axis`` first."""
    axis = axis % q.ndim
    if pack == 2:
        q = unpack_int4(q, axis)
    nb = scales.shape[axis]
    kb = q.shape[axis] // nb
    shape = q.shape[:axis] + (nb, kb) + q.shape[axis + 1:]
    out = q.astype(jnp.float32).reshape(shape) * jnp.expand_dims(
        scales.astype(jnp.float32), axis + 1)
    return out.reshape(q.shape).astype(dtype)


# --------------------------------------------------------------------------
# KV-row quantization (paged cache): one scale per written row per head
# --------------------------------------------------------------------------
def quantize_kv(x, dtype: str = "int8"):
    """x: (..., hd) float K/V rows -> (q (..., hd), scales (...) float16).

    One absmax scale per (row, head): decode writes one token at a time, so
    per-row scales need no calibration pass and stay exact under incremental
    writes. Scales are stored float16 — the pool bookkeeping overhead is
    ``2 / head_dim`` bytes per element. int4 is weight-only: the paged
    pools and attention kernels take byte-addressable rows.
    """
    dtype = canonical_dtype(dtype)
    if dtype == "int4":
        raise ValueError("int4 is weight-only; KV pools support int8/fp8")
    xf = x.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), _EPS)
    scales = (amax / _QMAX[dtype]).astype(jnp.float16)
    q = _cast_q(xf / scales.astype(jnp.float32)[..., None], dtype)
    return q, scales


def dequantize_kv(q, scales, dtype=jnp.float32):
    """Inverse of :func:`quantize_kv`: (..., hd) q + (...) scales."""
    return (q.astype(jnp.float32)
            * scales.astype(jnp.float32)[..., None]).astype(dtype)


# --------------------------------------------------------------------------
# the container
# --------------------------------------------------------------------------
@jax.tree_util.register_pytree_with_keys_class
class QuantTensor:
    """Weight-only quantized parameter: storage values + absmax scales.

    A registered pytree (leaves ``q`` and ``scales``), so it flows through
    ``jax.tree`` maps, ``jax.lax.scan`` over stacked layer blocks (both
    leaves slice on the leading axis together), jit argument flattening, and
    path-based checkpointing (leaf keys ``....q`` / ``....scales``) without
    special cases. ``axis`` (static aux data) is the contraction axis the
    scales reduce, counted from the end: -2 for ``(K, N)`` matmul kernels,
    -1 for the per-row-quantized embedding table. ``pack`` (also aux) is 1
    for byte-addressable storage and 2 for the int4 nibble-packed layout,
    where ``q``'s quantization axis is physically half the logical length;
    ``shape`` always reports the *logical* shape so matmul call sites keyed
    off ``w.shape`` stay layout-agnostic.
    """

    def __init__(self, q, scales, axis: int = -2, pack: int = 1):
        self.q = q
        self.scales = scales
        self.axis = axis
        self.pack = pack

    # ---- pytree protocol --------------------------------------------------
    def tree_flatten_with_keys(self):
        return (((jax.tree_util.GetAttrKey("q"), self.q),
                 (jax.tree_util.GetAttrKey("scales"), self.scales)),
                (self.axis, self.pack))

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scales = children
        if isinstance(aux, tuple):
            axis, pack = aux
        else:                       # pre-int4 checkpoints: bare axis int
            axis, pack = aux, 1
        return cls(q, scales, axis=axis, pack=pack)

    # ---- array-like surface (what model call sites touch) ----------------
    @property
    def shape(self):
        if self.pack == 1:
            return self.q.shape
        axis = self.axis % self.q.ndim
        return (self.q.shape[:axis] + (self.q.shape[axis] * self.pack,)
                + self.q.shape[axis + 1:])

    @property
    def ndim(self):
        return self.q.ndim

    @property
    def dtype(self):
        return self.q.dtype

    @property
    def nbytes(self) -> int:
        """Physical storage bytes (packed int4 counts half a byte per
        logical element)."""
        return int(self.q.size * self.q.dtype.itemsize
                   + self.scales.size * self.scales.dtype.itemsize)

    @property
    def n_blocks(self) -> int:
        return self.scales.shape[self.axis % self.q.ndim]

    def dequantize(self, dtype=jnp.float32):
        return dequantize_weight(self.q, self.scales, axis=self.axis,
                                 dtype=dtype, pack=self.pack)

    def take_rows(self, idx, dtype=jnp.float32):
        """Gather + dequantize leading-axis rows (the embedding lookup):
        only the touched rows are unpacked/dequantized, never the full
        table. Requires ``axis == -1`` (per-row scales)."""
        if self.axis % self.q.ndim != self.q.ndim - 1:
            raise ValueError("take_rows needs per-row scales (axis=-1)")
        return dequantize_weight(self.q[idx], self.scales[idx], axis=-1,
                                 dtype=dtype, pack=self.pack)

    def astype(self, dtype):
        """Dequantize — keeps ``p[...]["kernel"].astype(compute_dtype)``
        call sites working unchanged (weight-only quantization)."""
        return self.dequantize(dtype)

    @property
    def T(self):
        """Dequantized transpose (tied-embedding logits: ``embed.table.T``)."""
        return self.dequantize(jnp.float32).T

    def __repr__(self):
        return (f"QuantTensor(shape={tuple(self.shape)}, "
                f"dtype={self.q.dtype}, n_blocks={self.n_blocks}, "
                f"axis={self.axis}, pack={self.pack})")


def quantize_tensor(w, dtype: str = "int8", *, block: int = 0,
                    axis: int = -2) -> QuantTensor:
    q, scales = quantize_weight(w, dtype, block=block, axis=axis)
    pack = 2 if canonical_dtype(dtype) == "int4" else 1
    return QuantTensor(q, scales, axis=axis, pack=pack)

"""Distribution-preserving speculative acceptance (Leviathan-style
rejection sampling).

One verifier pass scores ``k + 1`` positions; this module decides, inside
the jitted step, how many of the k draft tokens survive and what the first
non-draft token is. The rule per position i (0-based):

* draw u_i ~ U[0,1); accept draft token x_i when
  ``u_i < p_i(x_i) / q_i(x_i)`` where p is the verifier's (filtered)
  distribution and q the draft's;
* at the first rejection, resample from the *residual*
  ``norm(max(p_i - q_i, 0))`` — the correction that makes the committed
  marginal exactly p_i regardless of q;
* when all k accept, the bonus token samples from p_k directly (q is
  extended with a zero row, so the bonus falls out of the same residual
  formula: ``max(p_k - 0, 0) = p_k``).

At temperature 0 both p and q are one-hot (see
:func:`repro.spec.sampling.filtered_probs`), the ratio test reduces to
argmax equality, and the committed chain is exactly the verifier's greedy
chain — speculative decoding is then a pure latency optimization with
token-for-token parity, which the benchmark gates on.

Everything is batched over slots and branch-free: slots with fewer valid
draft tokens (``n_draft < k``) force rejection at the first invalid
position, which makes the per-slot commit count ``n_accept + 1`` uniform
across the pool.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.spec.sampling import filtered_probs


def speculative_accept(verify_logits, draft_tokens, draft_probs, temps,
                       top_k, top_p, keys, *, n_draft=None):
    """Batched acceptance over one verify pass.

    verify_logits: (B, k+1, V) — verifier logits at positions
        ``L-1 .. L+k-1`` (position i scores draft token i; the last row
        scores the bonus token).
    draft_tokens: (B, k) int32 — the draft's proposals.
    draft_probs: (B, k, V) float32 — the draft's *filtered* per-step
        distributions q_i (as sampled from, temperature/top-k/top-p
        applied; one-hot for greedy rows).
    temps/top_k/top_p: (B,) sampling knobs (the verifier's — both models
        must sample through the same filters for the ratio test to hold).
    keys: (B, 2) uint32 per-slot PRNG keys.
    n_draft: (B,) int32 — valid draft tokens per slot (None = all k).

    Returns ``(tokens (B, k+1) int32, n_accept (B,) int32)``: committed
    output is ``tokens[:, : n_accept + 1]`` — the accepted draft prefix
    plus the residual/bonus token.
    """
    B, k1, V = verify_logits.shape
    k = k1 - 1
    p = filtered_probs(verify_logits.reshape(B * k1, V),
                       jnp.repeat(temps, k1), jnp.repeat(top_k, k1),
                       jnp.repeat(top_p, k1)).reshape(B, k1, V)
    # pad q with a zero row at index k: the bonus position's residual
    # max(p - 0, 0) is p itself, so one formula serves accept and bonus
    q = jnp.concatenate(
        [draft_probs, jnp.zeros((B, 1, V), draft_probs.dtype)], axis=1)
    valid = (jnp.arange(k)[None, :] <
             (jnp.full((B,), k, jnp.int32) if n_draft is None
              else n_draft)[:, None])                       # (B, k)

    ku, kr = jax.vmap(lambda kk: tuple(jax.random.split(kk)))(keys)
    u = jax.vmap(lambda kk: jax.random.uniform(kk, (k,)))(ku)  # (B, k)
    p_x = jnp.take_along_axis(p[:, :k], draft_tokens[..., None],
                              axis=-1)[..., 0]              # (B, k)
    q_x = jnp.take_along_axis(q[:, :k], draft_tokens[..., None],
                              axis=-1)[..., 0]
    ratio = p_x / jnp.maximum(q_x, 1e-20)
    accept = (u < ratio) & valid & (q_x > 0)
    n_accept = jnp.cumprod(accept.astype(jnp.int32),
                           axis=-1).sum(-1)                 # (B,)

    # residual at the rejection position a = n_accept (== k => bonus row)
    p_a = jnp.take_along_axis(p, n_accept[:, None, None],
                              axis=1)[:, 0]                 # (B, V)
    q_a = jnp.take_along_axis(q, n_accept[:, None, None], axis=1)[:, 0]
    res = jnp.maximum(p_a - q_a, 0.0)
    mass = res.sum(-1, keepdims=True)
    # degenerate q >= p everywhere (numerical ties): fall back to p itself
    res = jnp.where(mass > 1e-20, res / jnp.maximum(mass, 1e-20), p_a)
    greedy = jnp.argmax(res, axis=-1)
    drawn = jax.vmap(
        lambda kk, row: jax.random.categorical(kk, jnp.log(
            jnp.maximum(row, 1e-30))))(kr, res)
    extra = jnp.where(temps <= 0, greedy, drawn).astype(jnp.int32)

    # committed stream: draft tokens below n_accept, the residual/bonus
    # token at n_accept, junk above (callers slice by n_accept + 1)
    idx = jnp.arange(k1)[None, :]
    toks = jnp.concatenate(
        [draft_tokens, jnp.zeros((B, 1), jnp.int32)], axis=1)
    out = jnp.where(idx < n_accept[:, None], toks, extra[:, None])
    return out.astype(jnp.int32), n_accept.astype(jnp.int32)

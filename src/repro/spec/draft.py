"""Draft-model worker for speculative decoding.

The draft is a second, smaller model (e.g. ``qwen3_0_6b`` proposing for
``deepseek_7b``) with its own *dense* KV cache over the same slot pool —
dense because draft state is cheap (small model, per-slot rows) and must
survive speculative rollback without touching the verifier's block
allocator. The worker owns three jitted graphs, all with static shapes so
one compilation serves the whole run:

* ``prefill`` — chunked ``extend_step`` over the draft cache, advanced in
  lockstep with the engine's verifier prefill (the draft always prefills
  from position 0: prefix-cache hits are a verifier-pool concept);
* ``propose`` — a ``lax.scan`` of k batched ``decode_step``s that feeds the
  last two *committed* tokens and then its own samples, collecting k draft
  tokens and their filtered probability rows (kept on device — the engine
  never syncs a (B, k, V) tensor);
* ``fork`` — copy one slot's dense cache rows into another (COW-forked
  parallel sampling: children start from the parent's draft state).

Resync after a verify turn needs no KV surgery: every ``propose`` re-feeds
from the committed stream, and rows the draft wrote past the commit point
hold garbage that is never attended (the dense decode path masks positions
above the feed position), then get overwritten in place on the next turn.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, extend_step, init as model_init
from repro.models.cache import init_cache
from repro.spec.sampling import P_DRAFT, filtered_probs, fold_keys

PyTree = Any


class DraftWorker:
    """Small-model proposer bound to the engine's slot pool."""

    def __init__(self, cfg: ModelConfig, params: PyTree | None, *,
                 max_slots: int, max_len: int, k: int,
                 prefill_chunk: int = 64, seed: int = 0):
        if k < 1:
            raise ValueError("spec_k must be >= 1")
        self.cfg = cfg
        self.k = k
        self.max_slots, self.max_len = max_slots, max_len
        self.chunk = prefill_chunk
        self.params = (params if params is not None
                       else model_init(jax.random.PRNGKey(seed), cfg))
        self.cache = init_cache(cfg, max_slots, max_len)
        #: draft prefill offset per slot (host; -1 = slot not draft-owned)
        self.off = np.full(max_slots, -1, np.int64)
        self._chunk_fn = jax.jit(self._chunk, donate_argnums=(0,))
        self._propose_fn = jax.jit(self._propose, donate_argnums=(0,),
                                   static_argnames=("temps_only",))
        self._fork_fn = jax.jit(self._fork, donate_argnums=(0,))

    # ---- jitted graphs ------------------------------------------------
    def _chunk(self, cache, tokens, pos, n_valid, slot):
        _, cache = extend_step(self.params, self.cfg, cache, tokens, pos,
                               n_valid, slot)
        return cache

    def _propose(self, cache, feed0, feed1, pos0, active, temps, top_k,
                 top_p, keys, ctrs, temps_only=False):
        """k+1 chained decode steps: feed the last two committed tokens
        (the first rewrites an already-correct row — the resync no-op),
        then the draft's own samples. Collects k sampled tokens and their
        filtered probability rows.

        feed0/feed1: (B, 1) int32 committed tokens at positions
        ``pos0 - 1`` / ``pos0``; active: (B,) bool; keys/ctrs: the raw
        per-slot base keys and dispatch counters — folded to draft-purpose
        stream keys here, inside the jit, so the engine never pays an
        eager vmap per turn. ``temps_only`` is unused (kept so the jit key
        distinguishes future sampler variants).
        Returns (draft_tokens (B, k), draft_probs (B, k, V) float32, cache).
        """
        del temps_only
        B = feed0.shape[0]
        keys = fold_keys(keys, ctrs, P_DRAFT)
        # resync feed: rewrite row pos0-1 (token feed0 was committed there
        # on an earlier turn or diverged after a rejection — identical
        # token, identical KV, so this is idempotent where it matters)
        _, cache = decode_step(self.params, self.cfg, cache, feed0,
                               jnp.maximum(pos0 - 1, 0),
                               active=active & (pos0 > 0))

        def body(carry, kk):
            cache, tok, pos = carry
            logits, cache = decode_step(self.params, self.cfg, cache, tok,
                                        pos, active=active)
            row = logits[:, 0]
            probs = filtered_probs(row, temps, top_k, top_p)
            ks = jax.vmap(jax.random.fold_in)(keys, jnp.full((B,), kk))
            greedy = jnp.argmax(row, axis=-1)
            drawn = jax.vmap(lambda s, pr: jax.random.categorical(
                s, jnp.log(jnp.maximum(pr, 1e-30))))(ks, probs)
            nxt = jnp.where(temps <= 0, greedy, drawn).astype(jnp.int32)
            return (cache, nxt[:, None], pos + 1), (nxt, probs)

        (cache, _, _), (toks, probs) = jax.lax.scan(
            body, (cache, feed1, pos0), jnp.arange(self.k))
        return (jnp.transpose(toks, (1, 0)),
                jnp.transpose(probs, (1, 0, 2)), cache)

    def _fork(self, cache, src, dst):
        def f(leaf):
            row = jax.lax.dynamic_slice_in_dim(leaf, src, 1, 0)
            return jax.lax.dynamic_update_slice_in_dim(leaf, row, dst, 0)
        return jax.tree.map(f, cache)

    # ---- host-side API ------------------------------------------------
    def begin(self, slot: int) -> None:
        """Claim a slot: its draft prefill starts from position 0."""
        self.off[slot] = 0

    def drop(self, slot: int) -> None:
        self.off[slot] = -1

    def ready(self, slot: int, prompt_len: int) -> bool:
        """True once the slot's draft cache covers the whole prompt."""
        return self.off[slot] >= prompt_len

    def prefill_chunk(self, slot: int, prompt: np.ndarray) -> None:
        """Advance one chunk of the draft's own prefill for ``slot``."""
        off = int(self.off[slot])
        t = min(self.chunk, len(prompt) - off)
        if t <= 0:
            return
        buf = np.zeros((1, self.chunk), np.int32)
        buf[0, :t] = prompt[off:off + t]
        self.cache = self._chunk_fn(self.cache, jnp.asarray(buf),
                                    np.int32(off), np.int32(t),
                                    np.int32(slot))
        self.off[slot] = off + t

    def propose(self, feed0, feed1, pos0, active, temps, top_k, top_p,
                keys, ctrs):
        """One speculative turn: k draft tokens + their distributions."""
        toks, probs, self.cache = self._propose_fn(
            self.cache, feed0, feed1, pos0, active, temps, top_k, top_p,
            keys, ctrs)
        return toks, probs

    def fork_slot(self, src: int, dst: int) -> None:
        """Copy ``src``'s dense draft rows into ``dst`` (parallel-sampling
        fork: the child diverges from the parent's draft state)."""
        self.cache = self._fork_fn(self.cache, np.int32(src), np.int32(dst))
        self.off[dst] = self.off[src]

"""Shared sampling transforms for the serve engine and the speculative
acceptance rule.

The serve engine's fused sampler used to be temperature-only; the
speculative-decoding residual distribution ``norm(max(p - q, 0))`` is only
well-defined when the draft and the verifier agree on the *support* of
their per-step distributions, so top-k / top-p filtering has to live in one
place both can call. Everything here runs inside jitted graphs: shapes are
static, knobs ride in as traced per-row arrays (``top_k == 0`` and
``top_p >= 1`` disable filtering for that row, so one compiled graph serves
every knob combination).

Greedy rows (temperature <= 0) bypass sampling entirely in
:func:`sample_tokens`, so filtering can never perturb greedy parity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


#: PRNG purpose tags folded into per-slot stream keys so the same
#: (request, step) never reuses a key across the decode sampler, the
#: draft proposer, the acceptance rule, and fork derivation
P_SAMPLE, P_DRAFT, P_ACCEPT, P_FORK = 0, 1, 2, 3


def fold_keys(keys, ctrs, purpose):
    """Per-row stream keys: fold each slot's dispatch counter, then a
    purpose tag, into its base key. The resulting stream depends only on
    (request seed, step index, purpose) — never on which other requests
    share the batch or on admission order. Must run inside a jitted graph:
    an eager vmap re-traces on every call, which is milliseconds of host
    work per decode turn."""
    kk = jax.vmap(jax.random.fold_in)(keys, ctrs)
    return jax.vmap(jax.random.fold_in)(
        kk, jnp.full(ctrs.shape, purpose, jnp.uint32))


def filter_logits(logits, top_k, top_p):
    """Mask ``logits`` outside the per-row top-k / top-p (nucleus) sets.

    logits: (B, V) float; top_k: (B,) int32 (0 = off); top_p: (B,) float32
    (>= 1 = off). The most probable token always survives (top-1 is kept
    even when a degenerate ``top_p ~ 0`` would otherwise empty the nucleus),
    so the filtered distribution is never all ``-inf``. Sort-based: O(V log
    V) per row, fine at serving vocab sizes and trivially jittable.
    """
    B, V = logits.shape
    neg = jnp.asarray(-1e30, logits.dtype)
    order = jnp.argsort(logits, axis=-1)[:, ::-1]          # descending
    ranked = jnp.take_along_axis(logits, order, axis=-1)
    rank = jnp.arange(V)[None, :]
    # top-k: keep ranks < k (k == 0 disables)
    keep = jnp.where(top_k[:, None] > 0, rank < top_k[:, None], True)
    # top-p: keep the smallest prefix whose probability mass reaches p.
    # Rank r survives when the mass *before* it is still < p (the token that
    # crosses the threshold is included, per the usual nucleus definition).
    probs = jax.nn.softmax(ranked.astype(jnp.float32), axis=-1)
    prior = jnp.cumsum(probs, axis=-1) - probs             # mass before rank
    keep &= jnp.where(top_p[:, None] < 1.0,
                      prior < top_p[:, None], True)
    keep = keep.at[:, 0].set(True)                         # top-1 always
    ranked = jnp.where(keep, ranked, neg)
    # undo the sort: scatter the masked values back to vocab order
    inv = jnp.argsort(order, axis=-1)
    return jnp.take_along_axis(ranked, inv, axis=-1)


def filtered_probs(logits, temps, top_k, top_p):
    """Per-row sampling distribution after temperature + top-k/top-p.

    logits: (B, V); temps: (B,). Greedy rows (temp <= 0) get a one-hot on
    the argmax — the distribution a temperature-0 sampler draws from — so
    the speculative acceptance rule covers both regimes with one formula.
    Returns (B, V) float32 probabilities.
    """
    t = jnp.where(temps <= 0, 1.0, temps)[:, None]
    f = filter_logits(logits.astype(jnp.float32) / t, top_k, top_p)
    probs = jax.nn.softmax(f, axis=-1)
    onehot = jax.nn.one_hot(jnp.argmax(logits, axis=-1), logits.shape[-1],
                            dtype=jnp.float32)
    return jnp.where((temps <= 0)[:, None], onehot, probs)


def sample_tokens(logits, temps, top_k, top_p, keys):
    """Fused per-row sampler: greedy where temp <= 0, filtered categorical
    otherwise. keys: (B, 2) uint32 — one legacy PRNG key per row, so
    concurrent requests draw from independent, order-independent streams.
    Returns (B,) int32.
    """
    greedy = jnp.argmax(logits, axis=-1)
    t = jnp.where(temps <= 0, 1.0, temps)[:, None]
    f = filter_logits(logits.astype(jnp.float32) / t, top_k, top_p)
    sampled = jax.vmap(
        lambda k, row: jax.random.categorical(k, row))(keys, f)
    return jnp.where(temps <= 0, greedy, sampled).astype(jnp.int32)

"""Speculative decoding: draft proposer, acceptance rule, fused sampler.

Two consumers share this package (see docs/serving.md):

* draft-verify speculative decoding — ``DraftWorker`` proposes k tokens
  per scheduler turn from its own dense cache; the verifier scores all
  k+1 positions in one batched ``verify_step`` pass against its paged
  cache; ``speculative_accept`` commits a distribution-preserving prefix
  (exact greedy parity at temperature 0);
* COW-forked parallel sampling — ``Request(n=4)`` forks a prefilled slot
  into n children that share all common pages read-only and diverge
  through the engine's copy-on-write guard.
"""
from repro.spec.accept import speculative_accept
from repro.spec.draft import DraftWorker
from repro.spec.sampling import filter_logits, filtered_probs, sample_tokens

__all__ = ["DraftWorker", "filter_logits", "filtered_probs",
           "sample_tokens", "speculative_accept"]

"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state. The production target is one 16×16 v5e pod
(256 chips) or two pods (512 chips) with a leading "pod" axis — the paper's
dual-chiplet D2D topology scaled to pods.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # axis_types landed after jax 0.4.x; Auto is the default there anyway
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    return _make_mesh(shape, axes)


def mesh_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n

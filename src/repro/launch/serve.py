"""Serving launcher: continuous-batching engine over synthetic requests.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --requests 12 --slots 4 --max-new 24

``--devices N`` serves SPMD: the paged KV pools shard by KV head over an
N-way model axis (fake CPU devices when no accelerator is attached — the
flag must therefore be handled *before* jax initializes, which is why the
heavy imports live inside :func:`main`). ``--split-pools`` disaggregates
the slot pool into prefill and decode halves (see docs/serving.md).
"""
from __future__ import annotations

import argparse
import json
import os
import time


def _pct_ms(vals, q):
    import numpy as np
    vals = [v for v in vals if v is not None]
    return round(float(np.percentile(vals, q)) * 1e3, 1) if vals else None


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kernel-backend", default=None,
                    choices=("auto", "ref", "interpret", "pallas"),
                    help="registry backend for the engine's jitted graphs "
                         "(default: cfg.kernel_backend / XLA paths)")
    ap.add_argument("--paged", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="block-pool (paged) KV cache layout "
                         "(default: cfg.paged_kv; --no-paged forces dense)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="KV rows per block (default: cfg.page_size)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked-prefill token count (default: "
                         "cfg.prefill_chunk)")
    ap.add_argument("--max-blocks", type=int, default=None,
                    help="global KV block-pool size (default: dense-"
                         "equivalent capacity)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="share fully-written prompt pages across requests "
                         "(refcounted copy-on-write; requires --paged and "
                         "an all-full-attention config; default: "
                         "cfg.prefix_cache)")
    ap.add_argument("--prefix-lru", type=int, default=None,
                    help="max refcount-0 cached blocks retained after "
                         "their owners finish (0 = bounded only by pool "
                         "pressure; default: cfg.prefix_lru)")
    ap.add_argument("--weight-dtype", default=None,
                    choices=("int8", "fp8", "int4"),
                    help="weight-only quantization (repro.quant): wraps "
                         "matmul weights post-load, dispatches gemm_wq "
                         "(int4 packs two nibbles per byte)")
    ap.add_argument("--kv-dtype", default=None, choices=("int8", "fp8"),
                    help="quantized paged KV pools (requires --paged)")
    ap.add_argument("--quant-block", type=int, default=None,
                    help="per-block weight-scale length (0 = per-channel)")
    ap.add_argument("--sched", default=None, choices=("fcfs", "priority"),
                    help="admission policy (default: cfg.sched_policy): "
                         "'priority' = classes + EDF TTFT deadlines + "
                         "fair queuing + skip-with-aging; 'fcfs' = strict "
                         "arrival order")
    ap.add_argument("--sched-aging", type=int, default=None,
                    help="skipped passes before a blocked request reserves "
                         "the pool (0 = never; default: cfg.sched_aging)")
    ap.add_argument("--preemption", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="let a blocked higher-priority request evict a "
                         "lower-priority slot; its pages are kept in the "
                         "prefix index so resumption is a warm hit "
                         "(requires --paged; default: cfg.preemption)")
    ap.add_argument("--overlap", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="double-buffer decode: dispatch step N+1 before "
                         "syncing step N's ids (token-identical; default: "
                         "cfg.overlap_decode)")
    ap.add_argument("--draft-model", default=None,
                    help="registry arch of a smaller draft model: enables "
                         "speculative decoding (requires --paged and an "
                         "all-full-attention config; --reduced applies to "
                         "the draft too)")
    ap.add_argument("--spec-k", type=int, default=None,
                    help="draft tokens proposed per speculative turn "
                         "(default: cfg.spec_k, engine default 4)")
    ap.add_argument("--n", type=int, default=1,
                    help="parallel samples per request: fork the prefilled "
                         "slot into n sequences sharing common KV pages "
                         "copy-on-write (requires --paged)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k sampling filter (0 = disabled)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling filter (1.0 = disabled)")
    ap.add_argument("--request-seeds", action="store_true",
                    help="stamp Request.seed = uid on every request: each "
                         "sampling stream becomes reproducible across runs "
                         "and independent of batch composition")
    ap.add_argument("--priority", type=int, default=0,
                    help="priority class stamped on every synthetic "
                         "request (larger = more urgent)")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="time-to-first-token SLO target stamped on every "
                         "synthetic request (drives EDF + goodput)")
    ap.add_argument("--slo-itl-ms", type=float, default=None,
                    help="mean inter-token SLO target stamped on every "
                         "synthetic request")
    ap.add_argument("--devices", type=int, default=0,
                    help="serve SPMD over an N-way model axis: the paged "
                         "KV pools shard by KV head (replicated fallback "
                         "when the head count does not divide). Forces N "
                         "fake CPU devices when jax sees fewer real ones "
                         "(0 = single-device serving)")
    ap.add_argument("--split-pools", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="disaggregate the slot pool: dedicated prefill "
                         "slots hand finished prompts to decode slots by "
                         "republishing pool pages (requires --paged; "
                         "default: cfg.split_pools)")
    ap.add_argument("--prefill-slots", type=int, default=None,
                    help="prefill-pool size under --split-pools "
                         "(default: cfg.prefill_slots, 0 = slots // 4)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the engine's full metrics snapshot plus the "
                         "utilization report as JSON "
                         "(schema repro-metrics-report-v1)")
    ap.add_argument("--trace-out", default=None,
                    help="enable the request-lifecycle tracer and export "
                         "Chrome-trace JSON here (load in "
                         "https://ui.perfetto.dev)")
    ap.add_argument("--trace-buffer", type=int, default=65536,
                    help="tracer ring-buffer capacity in events; overflow "
                         "drops oldest and is counted in the export")
    args = ap.parse_args(argv)

    if args.devices > 1:
        # must land before jax initializes its backend: fake CPU devices
        # are minted at first import when no accelerator provides enough
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()
    import jax
    import numpy as np

    from repro.configs import get_arch, reduced
    from repro.models import init as model_init
    from repro.serve import Request, ServeEngine

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    quant_kw = {k: v for k, v in (("weight_dtype", args.weight_dtype),
                                  ("kv_dtype", args.kv_dtype),
                                  ("quant_block", args.quant_block))
                if v is not None}
    if quant_kw:
        cfg = cfg.replace(**quant_kw)
    params = model_init(jax.random.PRNGKey(args.seed), cfg)
    draft_cfg = draft_params = None
    if args.draft_model:
        draft_cfg = get_arch(args.draft_model)
        if args.reduced:
            draft_cfg = reduced(draft_cfg)
        if draft_cfg.vocab_size != cfg.vocab_size:
            draft_cfg = draft_cfg.replace(vocab_size=cfg.vocab_size)
        draft_params = model_init(jax.random.PRNGKey(args.seed + 1),
                                  draft_cfg)
    part = None
    if args.devices > 1:
        from repro.configs.base import StrategyConfig
        from repro.core.sharding import Partitioner
        if len(jax.devices()) < args.devices:
            raise SystemExit(
                f"--devices {args.devices} but jax sees "
                f"{len(jax.devices())} (XLA_FLAGS was set too late?)")
        mesh = jax.make_mesh((1, args.devices), ("data", "model"))
        part = Partitioner(mesh,
                           StrategyConfig(name="ramora",
                                          tensor_parallel=True),
                           cfg, mode="serve")
    tracer = None
    if args.trace_out:
        from repro.obs import Tracer
        tracer = Tracer(buffer=args.trace_buffer)
    engine = ServeEngine(cfg, params, max_slots=args.slots, tracer=tracer,
                         max_len=args.max_len, seed=args.seed, part=part,
                         kernel_backend=args.kernel_backend,
                         paged=args.paged, page_size=args.page_size,
                         prefill_chunk=args.prefill_chunk,
                         max_blocks=args.max_blocks,
                         prefix_cache=args.prefix_cache,
                         prefix_lru=args.prefix_lru,
                         sched=args.sched, sched_aging=args.sched_aging,
                         preemption=args.preemption, overlap=args.overlap,
                         draft_model=draft_cfg, draft_params=draft_params,
                         spec_k=args.spec_k, split_pools=args.split_pools,
                         prefill_slots=args.prefill_slots)

    rng = np.random.default_rng(args.seed)
    reqs = []
    for uid in range(args.requests):
        plen = int(rng.integers(4, 32))
        prompt = rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        frames = extra = None
        if cfg.frontend == "audio":
            frames = rng.standard_normal(
                (cfg.encoder.n_frames, cfg.d_model)).astype(np.float32)
        if cfg.frontend == "vision":
            extra = rng.standard_normal(
                (cfg.n_frontend_tokens, cfg.d_model)).astype(np.float32)
        reqs.append(Request(uid=uid, prompt=prompt,
                            max_new_tokens=args.max_new,
                            temperature=args.temperature,
                            top_k=args.top_k, top_p=args.top_p, n=args.n,
                            seed=uid if args.request_seeds else None,
                            frames=frames, extra_embeds=extra,
                            priority=args.priority,
                            slo_ttft_ms=args.slo_ttft_ms,
                            slo_itl_ms=args.slo_itl_ms))

    t0 = time.time()
    results = engine.run(reqs)
    dt = time.time() - t0
    new_tokens = sum(len(r.tokens) for r in results)
    new_tokens += sum(len(c.tokens) for r in results for c in r.children)
    # analytic d2d floor: per-device interconnect seconds per decode step
    # under the KV-head shard (zeros on one device / replicated pools)
    from repro.core.memfloor import d2d_bytes_serve_decode
    from repro.core.topology import CHIP
    from repro.obs import utilization_report, write_metrics_json
    d2d = d2d_bytes_serve_decode(cfg, engine.max_slots, engine._kv_shard)
    # measured-window utilization: MFU + bandwidth fractions joined from
    # the engine's decode_window_* metrics and the memfloor model
    util = utilization_report(engine)
    if args.metrics_out:
        write_metrics_json(args.metrics_out, suite="launch.serve",
                           snapshot=engine.metrics.snapshot(),
                           utilization=util,
                           extra={"arch": cfg.name,
                                  "requests": len(results),
                                  "wall_s": round(dt, 3)})
    if tracer is not None:
        tracer.export(args.trace_out)
    print(json.dumps({
        "arch": cfg.name, "requests": len(results),
        "completed": sum(1 for r in results if r.finish_reason),
        "new_tokens": new_tokens, "wall_s": round(dt, 2),
        "tok_per_s": round(new_tokens / dt, 1),
        "decode_steps": engine.stats["decode_steps"],
        "prefill_chunks": engine.stats["prefill_chunks"],
        "prefill_recompiles": engine.stats["prefill_recompiles"],
        "paged": engine.paged,
        "prefix_cache": engine.prefix_cache,
        "prefix_hits": engine.stats["prefix_hits"],
        "prefix_hit_tokens": engine.stats["prefix_hit_tokens"],
        "prefix_cow": engine.stats["prefix_cow"],
        "kv_bytes_cached": engine.stats["kv_bytes_cached"],
        "kv_bytes_per_request": (engine.stats["kv_bytes_alloc"]
                                 // max(len(results), 1)),
        "devices": args.devices or 1,
        "kv_shard": engine._kv_shard,
        # divisibility drops (e.g. KV heads not dividing the model axis)
        # replicate silently inside the Partitioner — surface them here so
        # a misconfigured mesh is visible in the run record
        "dropped_axes": (part.dropped if part is not None else []),
        "kv_bytes_per_request_dev": (engine.stats["kv_bytes_alloc_dev"]
                                     // max(len(results), 1)),
        "d2d_bytes_per_step_dev": round(d2d["total"], 1),
        "d2d_s_floor_per_step": d2d["total"] / CHIP.ici_link_bw,
        "utilization": util,
        "split_pools": engine.split_pools,
        "prefill_slots": engine.prefill_slots,
        "handoffs": engine.stats["handoffs"],
        "handoff_wait_steps": engine.stats["handoff_wait_steps"],
        "decode_gap_steps": engine.stats["decode_gap_steps"],
        "max_concurrency": engine.stats["max_concurrency"],
        "sched": engine.scheduler.policy,
        "sched_skips": engine.stats["sched_skips"],
        "sched_requeues": engine.scheduler.stats["requeues"],
        "preemptions": engine.stats["preemptions"],
        "spec_k": engine.spec_k if engine.draft is not None else None,
        "spec_turns": engine.stats["spec_turns"],
        "spec_accept_rate": (round(engine.stats["spec_accepted"]
                                   / max(engine.stats["spec_proposed"], 1),
                                   3)
                             if engine.draft is not None else None),
        "forks": engine.stats["forks"],
        "fork_shared_blocks": engine.stats["fork_shared_blocks"],
        "ttft_p50_ms": _pct_ms([r.ttft_s for r in results], 50),
        "ttft_p99_ms": _pct_ms([r.ttft_s for r in results], 99),
        "goodput": (round(engine.stats["slo_met"]
                          / max(engine.stats["slo_met"]
                                + engine.stats["slo_missed"], 1), 3)
                    if args.slo_ttft_ms is not None
                    or args.slo_itl_ms is not None else None),
    }, indent=1))
    assert all(r.finish_reason for r in results), "unfinished requests"


if __name__ == "__main__":
    main()

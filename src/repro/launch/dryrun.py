import os
import tempfile

# Pre-normalization HLO dumps: XLA:CPU's float-normalization pass legalizes
# bf16 collectives/ops to f32 (CPU has no bf16 reducers), inflating byte
# counts 2x vs the TPU target. We therefore parse collective bytes from the
# after_spmd-partitioning snapshot (true wire dtypes) rather than the
# post-optimization module. Verified: a bf16 psum shows as
# `f32 all-reduce(..) to_apply=%add.clone_promoted` post-opt but stays bf16
# in the after_spmd-partitioning dump.
_DUMP_DIR = tempfile.mkdtemp(prefix="repro_hlo_dump_")
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    f"--xla_dump_to={_DUMP_DIR} "
    "--xla_dump_hlo_pass_re=spmd-partitioning "
    "--xla_dump_hlo_module_re=.*(train_step|prefill_step|serve_step).*")

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes (16×16 single-pod, 2×16×16 multi-pod) and extract the
memory / cost / collective roofline inputs. All inputs are ShapeDtypeStructs —
nothing is allocated.

Methodology note (two-point extrapolation): XLA's ``cost_analysis()`` counts a
``while`` body ONCE, not ×trip-count, so a scanned layer stack under-reports
FLOPs/bytes/collectives. For the roofline we therefore lower two *analysis*
builds with block-scan unroll u=1 and u=2 (inner attention/SSM/loss loops
disabled so the layer scan is the only while loop) and extrapolate:

    total = m(u1) + (n_rep - 1) · (m(u2) - m(u1))

The *production* build (scanned, chunked, remat) supplies memory_analysis().

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_arch, get_shape, is_skipped, strategy
from repro.configs.registry import ARCHS
from repro.core.roofline import analyze_costs, parse_collectives
from repro.core.sharding import Partitioner
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.models.cache import init_cache
from repro.optim.optimizers import adamw
from repro.train.train_step import (batch_template, make_prefill_step,
                                    make_serve_step, make_train_step,
                                    serve_params_template,
                                    train_state_template)


def analysis_variant(cfg, shape, unroll: int):
    """Analysis build: only the layer-stack scan remains a while loop."""
    kw = dict(scan_unroll=unroll, attn_chunk=shape.seq_len, loss_chunk=0)
    if cfg.ssm is not None:
        import dataclasses
        kw["ssm"] = dataclasses.replace(cfg.ssm, chunk=shape.seq_len)
    if cfg.rglru is not None:
        import dataclasses
        kw["rglru"] = dataclasses.replace(cfg.rglru, chunk=shape.seq_len)
    return cfg.replace(**kw)


def input_specs(cfg, shape, mesh, strat):
    """ShapeDtypeStruct stand-ins + shardings for every model input of the
    (cfg, shape) cell. Returns (step_fn, args, in_shardings, out_shardings,
    donate). Output shardings mirror inputs so donated buffers alias."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mode = {"decode": "decode", "prefill": "prefill"}.get(shape.kind, "train")
    part = Partitioner(mesh, strat, cfg, shape, mode=mode)
    if shape.kind == "train":
        opt = adamw(1e-3)
        step = make_train_step(cfg, opt, strat, part)
        state = train_state_template(cfg, opt)
        batch = batch_template(cfg, shape)
        state_sh = {"params": part.params_sharding(state["params"]),
                    "opt": {k: part.params_sharding(v)
                            for k, v in state["opt"].items()},
                    "step": part.scalar_sharding()}
        in_sh = (state_sh, part.batch_sharding(batch))
        out_sh = (state_sh, {"loss": part.scalar_sharding(),
                             "grad_norm": part.scalar_sharding()})
        return step, (state, batch), in_sh, out_sh, (0,)
    if shape.kind == "prefill":
        params = serve_params_template(cfg)
        batch = batch_template(cfg, shape)
        cache = jax.eval_shape(lambda: init_cache(cfg, shape.global_batch,
                                                  shape.seq_len))
        step = make_prefill_step(cfg, part)
        cache_sh = part.cache_sharding(cache)
        in_sh = (part.params_sharding(params), part.batch_sharding(batch),
                 cache_sh)
        logits_sh = part.named(("batch", "vocab"),
                               (shape.global_batch, cfg.vocab_size))
        out_sh = (logits_sh, cache_sh)
        return step, (params, batch, cache), in_sh, out_sh, (2,)
    # decode
    params = serve_params_template(cfg)
    cache = jax.eval_shape(lambda: init_cache(cfg, shape.global_batch,
                                              shape.seq_len))
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    step = make_serve_step(cfg, part)
    cache_sh = part.cache_sharding(cache)
    in_sh = (part.params_sharding(params), cache_sh,
             part.batch_sharding({"t": tokens})["t"], part.scalar_sharding())
    logits_sh = part.named(("batch", None, "vocab"),
                           (shape.global_batch, 1, cfg.vocab_size))
    out_sh = (logits_sh, cache_sh)
    return step, (params, cache, tokens, pos), in_sh, out_sh, (1,)


def _clear_dump():
    for f in Path(_DUMP_DIR).glob("*"):
        try:
            f.unlink()
        except OSError:
            pass


def _read_spmd_dump() -> str | None:
    """The after_spmd-partitioning snapshot of the step module (true wire
    dtypes, before CPU float-normalization promotes bf16 to f32)."""
    cands = sorted(Path(_DUMP_DIR).glob("*after_spmd-partitioning*.txt"),
                   key=lambda p: p.stat().st_mtime)
    if not cands:
        return None
    return cands[-1].read_text()


def _compile(cfg, shape, mesh, strat):
    step, args, in_sh, out_sh, donate = input_specs(cfg, shape, mesh, strat)
    _clear_dump()
    with mesh:
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        return lowered.compile()


def _cost_triple(compiled):
    ca = compiled.cost_analysis()
    dump = _read_spmd_dump()
    if dump is not None:
        coll = parse_collectives(dump)
        coll["source"] = "after_spmd_partitioning(true-dtype)"
    else:  # fallback: post-opt module (bf16 collectives promoted to f32)
        coll = parse_collectives(compiled.as_text())
        coll["source"] = "post_optimization(f32-promoted)"
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            float(coll["total_bytes"]), coll)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             strategy_name: str = "ramora", verbose: bool = True,
             analysis: bool = True) -> dict:
    reason = is_skipped(arch, shape_name)
    if reason:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": reason}
    t0 = time.time()
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    strat = strategy(strategy_name, multi_pod=multi_pod)
    n_chips = mesh_chips(mesh)

    # 1) production build — the deployable artifact; memory truth
    compiled = _compile(cfg.replace(remat=strat.remat), shape, mesh, strat)
    mem = compiled.memory_analysis()
    t_prod = time.time() - t0

    result: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "strategy": strategy_name, "status": "ok", "n_chips": n_chips,
        "prod_compile_s": round(t_prod, 1),
        "memory": {
            "argument_gib_per_dev": mem.argument_size_in_bytes / 2**30,
            "output_gib_per_dev": mem.output_size_in_bytes / 2**30,
            "temp_gib_per_dev": mem.temp_size_in_bytes / 2**30,
            "alias_gib_per_dev": mem.alias_size_in_bytes / 2**30,
            "peak_gib_per_dev": (mem.argument_size_in_bytes
                                 + mem.output_size_in_bytes
                                 + mem.temp_size_in_bytes
                                 - mem.alias_size_in_bytes) / 2**30,
            "fits_16gib": (mem.argument_size_in_bytes + mem.output_size_in_bytes
                           + mem.temp_size_in_bytes
                           - mem.alias_size_in_bytes) < 16 * 2**30,
        },
    }
    if shape.kind == "decode":
        # XLA:CPU buffer assignment keeps xs/ys + update copies of the donated
        # KV cache (~2 extra copies); XLA:TPU updates donated caches in place
        # (the standard JAX serving pattern). Report the analytic sharded
        # cache size and the TPU-adjusted peak alongside the raw numbers.
        part = Partitioner(mesh, strat, cfg, shape, mode="decode")
        cache_t = jax.eval_shape(lambda: init_cache(cfg, shape.global_batch,
                                                    shape.seq_len))
        sh = part.cache_sharding(cache_t)
        per_dev = 0
        for leaf, s in zip(jax.tree.leaves(cache_t), jax.tree.leaves(
                sh, is_leaf=lambda x: hasattr(x, "spec"))):
            shard_elems = 1
            for dim, ax in zip(leaf.shape, tuple(s.spec) + (None,) * leaf.ndim):
                n = 1
                if ax is not None:
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    for a in axes:
                        n *= mesh.shape[a]
                shard_elems *= -(-dim // n)
            per_dev += shard_elems * leaf.dtype.itemsize
        peak = result["memory"]["peak_gib_per_dev"]
        adj = peak - 2 * per_dev / 2**30
        result["memory"]["kv_cache_gib_per_dev"] = per_dev / 2**30
        result["memory"]["peak_tpu_adjusted_gib_per_dev"] = adj
        result["memory"]["fits_16gib_tpu_adjusted"] = adj < 16.0

    # 2) roofline terms (single-pod only):
    #    FLOPs   <- analysis pair (inner loops disabled; chunk-independent)
    #    bytes & collectives <- production pair (flash-ideal HBM traffic and
    #    the deployable collective schedule)
    #    each extrapolated: total = m(u1) + (n_rep-1)·(m(u2)-m(u1))
    if analysis:
        _, _, n_rep, _ = cfg.layer_specs()

        # lax.scan(unroll=u) with length n lowers to a while body holding u
        # periods PLUS (n mod u) inline remainder periods. cost_analysis
        # counts the body once, so m(u) = fixed + P*(u + n mod u):
        #   u=1 -> fixed + P;  u=2 -> fixed + (2 + n%2)*P.
        # (calibrated: experiments/perf/calib_extrap.py shows m3-m2 == m2-m1
        # for odd n — both marginals are 2P, not P.)
        k2 = 2 + (n_rep % 2)

        def extrap(m1, m2):
            p = max(m2 - m1, 0.0) / (k2 - 1)
            return (m1 - p) + n_rep * p

        pf1, pb1, pcb1, coll1 = _cost_triple(compiled)
        a1 = _compile(analysis_variant(cfg, shape, 1), shape, mesh, strat)
        af1, _, _, _ = _cost_triple(a1)
        if n_rep > 1:
            prod2 = _compile(cfg.replace(remat=strat.remat, scan_unroll=2),
                             shape, mesh, strat)
            pf2, pb2, pcb2, _ = _cost_triple(prod2)
            a2 = _compile(analysis_variant(cfg, shape, 2), shape, mesh, strat)
            af2, _, _, _ = _cost_triple(a2)
            flops = extrap(af1, af2)
            nbytes = extrap(pb1, pb2)
            cbytes = extrap(pcb1, pcb2)
        else:
            flops, nbytes, cbytes = af1, pb1, pcb1
        # cost_analysis flops/bytes are per-partition on SPMD builds
        from repro.core.memfloor import (MeshSizes, hbm_bytes_floor,
                                         hbm_peak_floor)
        msz = (MeshSizes(mesh.shape["data"], mesh.shape["model"],
                         mesh.shape.get("pod", 1)))
        mode = {"decode": "decode", "prefill": "prefill"}.get(shape.kind,
                                                              "train")
        part = Partitioner(mesh, strat, cfg, shape, mode=mode)
        dp = part.logical_size("batch")
        tp = part.logical_size("tp")
        floor = hbm_bytes_floor(cfg, shape, msz, fsdp=strat.fsdp, dp=dp, tp=tp)
        result["memory_floor_components_gib"] = {
            k: v / 2**30 for k, v in floor.items()}
        result["parallel_degrees"] = {"dp": dp, "tp": tp}
        lc = cfg.loss_chunk or (512 if strat.chunked_loss else 0)
        peak_fl = hbm_peak_floor(cfg, shape, msz, fsdp=strat.fsdp,
                                 loss_chunk=lc, seq_shard=strat.seq_shard,
                                 dp=dp, tp=tp)
        result["memory"]["peak_floor_tpu_gib_per_dev"] = peak_fl["total"] / 2**30
        result["memory"]["peak_floor_components_gib"] = {
            k: round(v / 2**30, 3) for k, v in peak_fl.items()}
        result["memory"]["fits_16gib_floor"] = peak_fl["total"] < 16 * 2**30
        result.update(analyze_costs(
            flops_per_dev=flops, bytes_per_dev=nbytes,
            collective_bytes_per_dev=cbytes, collectives=coll1,
            arch=arch, shape=shape_name, n_chips=n_chips,
            memory_floor_bytes_per_dev=floor["total"]))
        result["analysis_compile_s"] = round(time.time() - t0 - t_prod, 1)

    if verbose:
        m = result["memory"]
        line = (f"[{result['mesh']}|{strategy_name}] {arch} × {shape_name}: "
                f"peak {m['peak_gib_per_dev']:.2f} GiB/dev")
        if analysis:
            r = result["roofline"]
            line += (f" | compute {r['compute_s']:.2e}s memory {r['memory_s']:.2e}s"
                     f" collective {r['collective_s']:.2e}s -> {r['bottleneck']}"
                     f" | frac {r['roofline_fraction']:.2f}"
                     f" useful {r['useful_flops_ratio']:.2f}")
        print(line + f" ({round(time.time() - t0)}s)", flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="both")
    ap.add_argument("--strategy", default="ramora",
                    choices=["occamy", "ramora", "ogopogo", "fsdp2d"])
    ap.add_argument("--no-analysis", action="store_true",
                    help="production compile only (multi-pod shard proof)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = ([(args.arch, args.shape)] if not args.all else
             [(a, s) for a in ARCHS for s in SHAPES])
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            # roofline analysis is single-pod only (per spec); multi-pod pass
            # proves the 'pod' axis shards.
            analysis = (not mp) and (not args.no_analysis)
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}__{args.strategy}"
            fp = outdir / f"{tag}.json"
            try:
                res = run_cell(arch, shape, multi_pod=mp,
                               strategy_name=args.strategy, analysis=analysis)
            except Exception as e:  # a failure here is a bug in the system
                failures += 1
                res = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "strategy": args.strategy, "status": "FAILED",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                print(f"FAILED {tag}: {type(e).__name__}: {e}", flush=True)
            fp.write_text(json.dumps(res, indent=1))
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()

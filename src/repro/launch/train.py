"""Training launcher.

Production: ``--arch gemma2-27b --shape train_4k --strategy ramora`` on a real
pod (the dry-run proves the mesh/sharding; see launch/dryrun.py).
CPU bring-up: ``--reduced`` shrinks the arch to its smoke-size family twin and
runs real steps on host devices, exercising the identical code path
(trainer, checkpoints, straggler watch, data pipeline).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --steps 50 --ckpt-dir /tmp/ckpt --mesh 1x1
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import get_arch, get_shape, reduced, strategy
from repro.configs.base import ShapeConfig
from repro.optim.optimizers import get_optimizer
from repro.optim.schedules import get_schedule
from repro.train.trainer import FaultInjector, Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--strategy", default="ramora",
                    choices=["occamy", "ramora", "ogopogo"])
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-size family twin of the arch")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=0)
    ap.add_argument("--seq-len", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "wsd", "constant"])
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "sgdm", "adafactor"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--mesh", default="1x1",
                    help="DxM data x model mesh for CPU runs, e.g. 2x2")
    ap.add_argument("--inject-fault-at", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    # minicpm trains with its WSD schedule per the assignment
    sched_name = "wsd" if args.arch == "minicpm-2b" else args.schedule
    shape = get_shape(args.shape)
    if args.global_batch or args.seq_len:
        shape = ShapeConfig(shape.name, shape.kind,
                            args.seq_len or shape.seq_len,
                            args.global_batch or shape.global_batch)
    if args.reduced and not (args.global_batch or args.seq_len):
        shape = ShapeConfig(shape.name, shape.kind, seq_len=128, global_batch=8)

    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = None
    if d * m > 1:
        mesh = jax.make_mesh((d, m), ("data", "model"))
    strat = strategy(args.strategy, multi_pod=False)

    sched = get_schedule(sched_name, args.lr, args.steps)
    opt = get_optimizer(args.optimizer, sched)
    tcfg = TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every, seed=args.seed)
    fault = (FaultInjector(at_step=args.inject_fault_at)
             if args.inject_fault_at >= 0 else None)
    trainer = Trainer(cfg, shape, strat, opt, tcfg, mesh=mesh, fault=fault)

    t0 = time.time()
    out = trainer.run_with_restarts()
    dt = time.time() - t0
    losses = out["losses"]
    print(json.dumps({
        "arch": cfg.name, "steps": out["stopped_at"], "wall_s": round(dt, 1),
        "loss_first": round(losses[0], 4) if losses else None,
        "loss_last": round(losses[-1], 4) if losses else None,
        "restarts": out["restarts"], "n_stragglers": out["n_stragglers"],
        "tokens_per_s": round(out["stopped_at"] * shape.global_batch
                              * shape.seq_len / dt, 1),
    }, indent=1))


if __name__ == "__main__":
    main()

"""Ring-buffered request-lifecycle tracer with Chrome-trace export.

The engine emits structured events at every lifecycle edge — submit,
queue-skip/aging, admission, per-chunk prefill, handoff, dispatch vs sync
under ``overlap_decode``, preempt/requeue/resume, spec propose/accept/
rollback, COW fork, finish. Events are keyed by request ``uid`` and (when
placed) ``slot``; phases of a request's life are *spans* (``begin``/``end``
pairs) and point occurrences are *instants*.

Pay-for-what-you-use: a disabled tracer is :data:`NULL_TRACER`, whose
methods are empty — call sites invoke it unconditionally instead of
branching on a flag, so the hot path carries no if-forest.

Export is the Chrome trace-event JSON format (``to_chrome``), loadable in
Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``: spans become
async ``b``/``e`` events matched on ``(cat, id, name)`` so preempt/resume
gaps render as separate slices on the request's track, instants become
``i`` events on the slot's thread track. :func:`validate_chrome_trace`
checks the structural contract CI relies on — balanced begin/end pairs and
a closed ``request`` span for every request id.
"""
from __future__ import annotations

import json
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable

__all__ = [
    "NULL_TRACER",
    "NullTracer",
    "TraceEvent",
    "Tracer",
    "validate_chrome_trace",
]

#: span names in nesting order (outermost first)
SPANS = ("request", "queue", "prefill", "decode")

#: instant event catalogue (see docs/observability.md for the schema)
INSTANTS = (
    "submit", "queue_skip", "aged", "admit", "reject", "prefill_chunk",
    "handoff", "handoff_wait", "dispatch", "sync", "preempt", "requeue",
    "spec_propose", "spec_commit", "spec_rollback", "cow", "fork",
    "finish", "truncate",
)


def _scalar(v: Any) -> Any:
    """Coerce numpy scalars (slot indices, summed counters) to JSON types."""
    return v.item() if hasattr(v, "item") else v


@dataclass(frozen=True)
class TraceEvent:
    name: str
    ph: str                      # "i" instant | "b" span begin | "e" span end
    ts: float                    # clock() seconds (perf_counter by default)
    uid: int | None = None
    slot: int | None = None
    args: tuple[tuple[str, Any], ...] = ()


class Tracer:
    """Bounded in-memory event recorder. ``buffer`` caps retained events;
    overflow drops the oldest and counts into ``dropped`` (exported as
    metadata so validators know the record is partial)."""

    enabled = True

    def __init__(self, buffer: int = 65536,
                 clock: Callable[[], float] = time.perf_counter):
        if buffer < 1:
            raise ValueError("trace buffer must hold >= 1 event")
        self.clock = clock
        self.dropped = 0
        self._ev: deque[TraceEvent] = deque(maxlen=int(buffer))
        # uid -> stack of open span names (LIFO close order)
        self._open: dict[int, list[str]] = {}

    # ---- recording -------------------------------------------------------
    def _push(self, ev: TraceEvent) -> None:
        if len(self._ev) == self._ev.maxlen:
            self.dropped += 1
        self._ev.append(ev)

    def event(self, name: str, uid: int | None = None,
              slot: int | None = None, **args: Any) -> None:
        self._push(TraceEvent(name, "i", self.clock(), uid, slot,
                              tuple(args.items())))

    def begin(self, span: str, uid: int, slot: int | None = None,
              **args: Any) -> None:
        self._open.setdefault(uid, []).append(span)
        self._push(TraceEvent(span, "b", self.clock(), uid, slot,
                              tuple(args.items())))

    def end(self, span: str, uid: int, slot: int | None = None,
            **args: Any) -> None:
        stack = self._open.get(uid)
        if stack and span in stack:
            stack.remove(span)
            if not stack:
                del self._open[uid]
        self._push(TraceEvent(span, "e", self.clock(), uid, slot,
                              tuple(args.items())))

    def close_open(self, uid: int, keep: tuple[str, ...] = (),
                   slot: int | None = None, **args: Any) -> None:
        """End every span still open for ``uid`` (innermost first), except
        names in ``keep`` — preemption closes phase spans but keeps the
        request span alive across the requeue."""
        stack = self._open.get(uid, [])
        for span in [s for s in reversed(stack) if s not in keep]:
            self.end(span, uid, slot=slot, **args)

    def open_spans(self, uid: int) -> tuple[str, ...]:
        return tuple(self._open.get(uid, ()))

    # ---- reading / export ------------------------------------------------
    def events(self) -> list[TraceEvent]:
        return list(self._ev)

    def to_chrome(self) -> dict:
        """Chrome trace-event JSON object format."""
        evs = self.events()
        t0 = min((e.ts for e in evs), default=0.0)
        out: list[dict] = [
            {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
             "args": {"name": "repro.serve"}},
        ]
        seen_tids: set[int] = set()
        for e in evs:
            tid = int(e.slot) if e.slot is not None else -1
            seen_tids.add(tid)
            args = {k: _scalar(v) for k, v in e.args}
            if e.uid is not None:
                args.setdefault("uid", int(e.uid))
            rec: dict[str, Any] = {
                "name": e.name,
                "ph": e.ph,
                "ts": round((e.ts - t0) * 1e6, 3),
                "pid": 0,
                "tid": tid,
                "args": args,
            }
            if e.ph == "i":
                rec["s"] = "p"
            else:  # async span events match on (cat, id, name)
                rec["cat"] = "lifecycle"
                rec["id"] = str(e.uid)
            out.append(rec)
        for tid in sorted(seen_tids):
            out.append({"name": "thread_name", "ph": "M", "pid": 0,
                        "tid": tid,
                        "args": {"name": ("queue/engine" if tid < 0
                                          else f"slot {tid}")}})
        return {
            "traceEvents": out,
            "displayTimeUnit": "ms",
            "otherData": {"dropped": self.dropped,
                          "clock": getattr(self.clock, "__name__",
                                           str(self.clock))},
        }

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1)

    def __len__(self) -> int:
        return len(self._ev)


class NullTracer:
    """No-op tracer with the full :class:`Tracer` surface. Engine code calls
    ``self.trace.event(...)`` unconditionally; disabled tracing costs one
    empty method call, not a branch per site."""

    enabled = False
    dropped = 0

    def event(self, name, uid=None, slot=None, **args):
        pass

    def begin(self, span, uid, slot=None, **args):
        pass

    def end(self, span, uid, slot=None, **args):
        pass

    def close_open(self, uid, keep=(), slot=None, **args):
        pass

    def open_spans(self, uid):
        return ()

    def events(self):
        return []

    def to_chrome(self):
        return {"traceEvents": [], "displayTimeUnit": "ms",
                "otherData": {"dropped": 0}}

    def export(self, path):
        pass

    def __len__(self):
        return 0

    def __bool__(self):
        return False


#: shared disabled tracer — the default hook on every engine
NULL_TRACER = NullTracer()


def validate_chrome_trace(doc: Any) -> dict:
    """Validate a Chrome-trace JSON object against the contract the engine
    guarantees; raises ``ValueError`` on violation, returns a summary.

    Checks: structural shape (object format, required keys per phase type),
    monotone non-negative ``ts``, balanced async begin/end per
    ``(cat, id, name)`` with begin-before-end, and a *closed* ``request``
    span for every request id that has any lifecycle event.
    """
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("not a Chrome trace object: missing traceEvents")
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("traceEvents must be a list")

    open_spans: dict[tuple[str, str, str], list[float]] = {}
    request_ids: set[str] = set()
    closed_requests: set[str] = set()
    n_spans = n_instants = 0
    for i, e in enumerate(evs):
        if not isinstance(e, dict) or "ph" not in e or "name" not in e:
            raise ValueError(f"event {i}: missing ph/name")
        ph = e["ph"]
        if ph == "M":
            continue
        for k in ("ts", "pid", "tid"):
            if k not in e:
                raise ValueError(f"event {i} ({e['name']}): missing {k!r}")
        if e["ts"] < 0:
            raise ValueError(f"event {i} ({e['name']}): negative ts")
        if ph == "i":
            n_instants += 1
            continue
        if ph not in ("b", "e"):
            raise ValueError(f"event {i}: unknown phase type {ph!r}")
        if "cat" not in e or "id" not in e:
            raise ValueError(
                f"event {i} ({e['name']}): async span missing cat/id")
        key = (e["cat"], str(e["id"]), e["name"])
        request_ids.add(str(e["id"]))
        if ph == "b":
            n_spans += 1
            open_spans.setdefault(key, []).append(e["ts"])
        else:
            stack = open_spans.get(key)
            if not stack:
                raise ValueError(
                    f"event {i}: orphan end for span {key} (no open begin)")
            begin_ts = stack.pop()
            if e["ts"] < begin_ts:
                raise ValueError(
                    f"event {i}: span {key} ends before it begins")
            if e["name"] == "request":
                closed_requests.add(str(e["id"]))

    orphans = {k: len(v) for k, v in open_spans.items() if v}
    if orphans:
        raise ValueError(f"orphan begin events (never ended): {orphans}")
    unclosed = request_ids - closed_requests
    if unclosed:
        raise ValueError(
            f"request ids without a closed 'request' span: {sorted(unclosed)}")
    return {
        "events": len(evs),
        "spans": n_spans,
        "instants": n_instants,
        "requests": len(closed_requests),
        "dropped": (doc.get("otherData") or {}).get("dropped", 0),
    }

"""Serving observability: metrics registry, lifecycle tracer, utilization.

See docs/observability.md for the metrics schema, trace event catalogue,
and the utilization methodology.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               Snapshot, StatsView)
from repro.obs.report import (decode_utilization, utilization_report,
                              windows_from_trace, write_metrics_json)
from repro.obs.trace import (NULL_TRACER, NullTracer, Tracer,
                             validate_chrome_trace)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_TRACER",
    "NullTracer",
    "Snapshot",
    "StatsView",
    "Tracer",
    "decode_utilization",
    "utilization_report",
    "validate_chrome_trace",
    "windows_from_trace",
    "write_metrics_json",
]

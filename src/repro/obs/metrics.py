"""Typed metrics registry for the serving stack.

Three instrument kinds — :class:`Counter` (monotonic), :class:`Gauge`
(set-to-latest), :class:`Histogram` (bucketed observations) — live in a
:class:`MetricsRegistry`. Instruments are get-or-create by name so several
components (engine, allocator, prefix index, scheduler) can share one
registry and converge on the same counter object (e.g. ``prefix_evictions``
is created by the engine and incremented by the index).

Reading happens through :meth:`MetricsRegistry.snapshot`: an immutable
:class:`Snapshot` supports ``snap[name]`` lookup, ``later.delta(earlier)``
(counters/histograms difference, gauges take the later value), and lossless
JSON round-trip (``to_json`` / ``Snapshot.from_json``). ``to_prometheus``
emits the text exposition format.

Backward compatibility with the historical ``ServeEngine.stats`` dict is
provided by :class:`StatsView`, a ``MutableMapping`` over the registry's
scalar instruments: ``stats["prefills"] += 1``, ``dict(engine.stats)``,
and per-key equality all keep working. Components whose legacy dicts used
short keys (``Scheduler.stats["skips"]``) get a view with *aliases* mapping
the legacy key to the registered metric name.
"""
from __future__ import annotations

import json
from collections.abc import MutableMapping
from dataclasses import dataclass, field
from typing import Any, Iterator, Mapping

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Snapshot",
    "StatsView",
]

# default histogram bucket upper bounds (seconds-ish scale); +inf is implicit
DEFAULT_BUCKETS = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


def _label_key(labels: Mapping[str, str]) -> tuple[tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _series_name(name: str, key: tuple[tuple[str, str], ...]) -> str:
    if not key:
        return name
    inner = ",".join(f"{k}={v}" for k, v in key)
    return f"{name}{{{inner}}}"


class _Instrument:
    kind = "?"

    def __init__(self, name: str, help: str = "", labelnames: tuple[str, ...] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)

    def _check_labels(self, labels: Mapping[str, str]) -> None:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.kind} {self.name!r} expects labels "
                f"{sorted(self.labelnames)}, got {sorted(labels)}")


class Counter(_Instrument):
    """Monotonically non-decreasing count, optionally per label set."""

    kind = "counter"

    def __init__(self, name, help="", labelnames=()):
        super().__init__(name, help, labelnames)
        self._values: dict[tuple[tuple[str, str], ...], float] = {}
        if not self.labelnames:
            self._values[()] = 0.0

    def inc(self, n: float = 1.0, **labels: str) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease")
        self._check_labels(labels)
        key = _label_key(labels)
        # float() strips numpy scalar types so exports stay JSON-clean
        self._values[key] = self._values.get(key, 0.0) + float(n)

    @property
    def value(self) -> float:
        if self.labelnames:
            raise ValueError(f"counter {self.name!r} is labeled; read series")
        return self._values[()]

    def _assign(self, v: float) -> None:
        # StatsView assignment path: monotonicity is still enforced
        if self.labelnames:
            raise ValueError(f"counter {self.name!r} is labeled")
        if v < self._values[()]:
            raise ValueError(
                f"counter {self.name!r} cannot be set backwards "
                f"({self._values[()]} -> {v})")
        self._values[()] = float(v)

    def series(self) -> dict[str, float]:
        return {_series_name(self.name, k): v for k, v in self._values.items()}


class Gauge(_Instrument):
    """Point-in-time value; ``set`` overwrites, ``inc`` adjusts."""

    kind = "gauge"

    def __init__(self, name, help="", labelnames=()):
        super().__init__(name, help, labelnames)
        self._values: dict[tuple[tuple[str, str], ...], float] = {}
        if not self.labelnames:
            self._values[()] = 0.0

    def set(self, v: float, **labels: str) -> None:
        self._check_labels(labels)
        self._values[_label_key(labels)] = float(v)

    def inc(self, n: float = 1.0, **labels: str) -> None:
        self._check_labels(labels)
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + float(n)

    @property
    def value(self) -> float:
        if self.labelnames:
            raise ValueError(f"gauge {self.name!r} is labeled; read series")
        return self._values[()]

    def _assign(self, v: float) -> None:
        self.set(v)

    def series(self) -> dict[str, float]:
        return {_series_name(self.name, k): v for k, v in self._values.items()}


class Histogram(_Instrument):
    """Cumulative-bucket histogram with count and sum, per label set."""

    kind = "histogram"

    def __init__(self, name, help="", labelnames=(), buckets=DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError(f"histogram {self.name!r} needs >= 1 bucket")
        self._series: dict[tuple[tuple[str, str], ...], dict[str, Any]] = {}
        if not self.labelnames:
            self._series[()] = self._blank()

    def _blank(self) -> dict[str, Any]:
        return {"count": 0, "sum": 0.0, "buckets": [0] * len(self.buckets)}

    def observe(self, v: float, **labels: str) -> None:
        self._check_labels(labels)
        key = _label_key(labels)
        s = self._series.setdefault(key, self._blank())
        s["count"] += 1
        s["sum"] += float(v)
        for i, le in enumerate(self.buckets):
            if v <= le:
                s["buckets"][i] += 1

    def series(self) -> dict[str, dict[str, Any]]:
        out = {}
        for key, s in self._series.items():
            out[_series_name(self.name, key)] = {
                "count": s["count"],
                "sum": s["sum"],
                "buckets": {str(le): n
                            for le, n in zip(self.buckets, s["buckets"])},
            }
        return out


@dataclass(frozen=True)
class Snapshot:
    """Immutable point-in-time read of a registry.

    ``counters``/``gauges`` map series name -> value; ``histograms`` map
    series name -> ``{"count", "sum", "buckets": {le: n}}``.
    """

    counters: Mapping[str, float] = field(default_factory=dict)
    gauges: Mapping[str, float] = field(default_factory=dict)
    histograms: Mapping[str, Mapping[str, Any]] = field(default_factory=dict)

    def __getitem__(self, name: str) -> Any:
        for table in (self.counters, self.gauges, self.histograms):
            if name in table:
                return table[name]
        raise KeyError(name)

    def __contains__(self, name: str) -> bool:
        return (name in self.counters or name in self.gauges
                or name in self.histograms)

    def get(self, name: str, default: Any = None) -> Any:
        try:
            return self[name]
        except KeyError:
            return default

    def delta(self, earlier: "Snapshot") -> "Snapshot":
        """Change since ``earlier``: counters and histogram count/sum/buckets
        subtract (series absent earlier count from zero); gauges take the
        later value — a gauge has no meaningful difference."""
        counters = {k: v - earlier.counters.get(k, 0.0)
                    for k, v in self.counters.items()}
        hists = {}
        for k, s in self.histograms.items():
            e = earlier.histograms.get(k, {"count": 0, "sum": 0.0,
                                           "buckets": {}})
            hists[k] = {
                "count": s["count"] - e["count"],
                "sum": s["sum"] - e["sum"],
                "buckets": {le: n - e["buckets"].get(le, 0)
                            for le, n in s["buckets"].items()},
            }
        return Snapshot(counters=counters, gauges=dict(self.gauges),
                        histograms=hists)

    def as_dict(self) -> dict:
        return {
            "schema": "repro-metrics-v1",
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {k: {"count": v["count"], "sum": v["sum"],
                               "buckets": dict(v["buckets"])}
                           for k, v in self.histograms.items()},
        }

    def to_json(self, **dump_kw) -> str:
        return json.dumps(self.as_dict(), sort_keys=True, **dump_kw)

    @classmethod
    def from_json(cls, text: str) -> "Snapshot":
        d = json.loads(text)
        if d.get("schema") != "repro-metrics-v1":
            raise ValueError(f"not a metrics snapshot: {d.get('schema')!r}")
        return cls(counters=d["counters"], gauges=d["gauges"],
                   histograms=d["histograms"])

    def __eq__(self, other: Any) -> bool:
        if not isinstance(other, Snapshot):
            return NotImplemented
        return (dict(self.counters) == dict(other.counters)
                and dict(self.gauges) == dict(other.gauges)
                and {k: dict(v, buckets=dict(v["buckets"]))
                     for k, v in self.histograms.items()}
                == {k: dict(v, buckets=dict(v["buckets"]))
                    for k, v in other.histograms.items()})


class MetricsRegistry:
    """Name -> instrument store with typed get-or-create accessors."""

    def __init__(self):
        self._instruments: dict[str, _Instrument] = {}

    def _get_or_create(self, cls, name, help, labelnames, **kw):
        inst = self._instruments.get(name)
        if inst is None:
            inst = cls(name, help=help, labelnames=labelnames, **kw)
            self._instruments[name] = inst
            return inst
        if not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as {inst.kind}, "
                f"requested {cls.kind}")
        if tuple(labelnames) != inst.labelnames:
            raise ValueError(
                f"metric {name!r} labelnames mismatch: "
                f"{inst.labelnames} vs {tuple(labelnames)}")
        return inst

    def counter(self, name: str, help: str = "",
                labels: tuple[str, ...] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: tuple[str, ...] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: tuple[str, ...] = (),
                  buckets=DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, labels,
                                   buckets=buckets)

    def get(self, name: str) -> _Instrument | None:
        return self._instruments.get(name)

    def instruments(self) -> list[_Instrument]:
        return list(self._instruments.values())

    # ---- reading ---------------------------------------------------------
    def snapshot(self) -> Snapshot:
        counters: dict[str, float] = {}
        gauges: dict[str, float] = {}
        hists: dict[str, Any] = {}
        for inst in self._instruments.values():
            if isinstance(inst, Counter):
                counters.update(inst.series())
            elif isinstance(inst, Gauge):
                gauges.update(inst.series())
            elif isinstance(inst, Histogram):
                hists.update(inst.series())
        return Snapshot(counters=counters, gauges=gauges, histograms=hists)

    def to_json(self, **dump_kw) -> str:
        return self.snapshot().to_json(**dump_kw)

    def to_prometheus(self, prefix: str = "repro_") -> str:
        """Prometheus text exposition format (v0.0.4)."""
        lines: list[str] = []
        for inst in self._instruments.values():
            base = f"{prefix}{inst.name}"
            suffix = "_total" if isinstance(inst, Counter) else ""
            if inst.help:
                lines.append(f"# HELP {base}{suffix} {inst.help}")
            lines.append(f"# TYPE {base}{suffix} {inst.kind}")
            if isinstance(inst, Histogram):
                for key, s in inst._series.items():
                    lbl = ",".join(f'{k}="{v}"' for k, v in key)
                    cum = 0
                    for le, n in zip(inst.buckets, s["buckets"]):
                        cum = n  # buckets are already cumulative
                        q = f'{lbl},le="{le:g}"' if lbl else f'le="{le:g}"'
                        lines.append(f"{base}_bucket{{{q}}} {cum}")
                    q = f'{lbl},le="+Inf"' if lbl else 'le="+Inf"'
                    lines.append(f"{base}_bucket{{{q}}} {s['count']}")
                    amid = f"{{{lbl}}}" if lbl else ""
                    lines.append(f"{base}_sum{amid} {s['sum']:g}")
                    lines.append(f"{base}_count{amid} {s['count']}")
                continue
            for key, v in inst._values.items():
                lbl = ",".join(f'{k}="{v2}"' for k, v2 in key)
                amid = f"{{{lbl}}}" if lbl else ""
                lines.append(f"{base}{suffix}{amid} {v:g}")
        return "\n".join(lines) + "\n"

    def view(self, aliases: Mapping[str, str] | None = None,
             names: tuple[str, ...] | None = None) -> "StatsView":
        return StatsView(self, aliases=aliases, names=names)


def _as_scalar(v: float):
    """Legacy stats consumers expect ints for counts; keep floats float."""
    return int(v) if float(v).is_integer() else v


class StatsView(MutableMapping):
    """Dict-compatible live view over a registry's scalar instruments.

    With ``aliases`` only, the view exposes exactly the alias keys (legacy
    short names -> registered metric names). Otherwise it exposes every
    unlabeled Counter/Gauge in the registry (plus any aliases). Assignment
    routes to ``Gauge.set`` or the monotonicity-checked counter setter, so
    ``stats[k] += 1`` behaves exactly like the historical dict.
    """

    def __init__(self, registry: MetricsRegistry,
                 aliases: Mapping[str, str] | None = None,
                 names: tuple[str, ...] | None = None):
        self._registry = registry
        self._aliases = dict(aliases or {})
        self._names = tuple(names) if names is not None else None
        # aliases-only views are closed over the alias keys; otherwise open
        self._open = aliases is None and names is None

    def _resolve(self, key: str) -> _Instrument:
        name = self._aliases.get(key, key)
        inst = self._registry.get(name)
        if inst is None or isinstance(inst, Histogram) or inst.labelnames:
            raise KeyError(key)
        if not self._open and key not in self._keys():
            raise KeyError(key)
        return inst

    def _keys(self) -> list[str]:
        if self._names is not None:
            keys = list(self._names) + [a for a in self._aliases
                                        if a not in self._names]
        elif self._aliases and not self._open:
            keys = list(self._aliases)
        else:
            keys = [n for n, inst in self._registry._instruments.items()
                    if not isinstance(inst, Histogram)
                    and not inst.labelnames]
            keys += [a for a in self._aliases if a not in keys]
        return keys

    def __getitem__(self, key: str):
        return _as_scalar(self._resolve(key).value)

    def __setitem__(self, key: str, value) -> None:
        self._resolve(key)._assign(float(value))

    def __delitem__(self, key: str) -> None:
        raise TypeError("metrics cannot be deleted through the stats view")

    def __iter__(self) -> Iterator[str]:
        return iter(self._keys())

    def __len__(self) -> int:
        return len(self._keys())

    def __contains__(self, key) -> bool:
        try:
            self._resolve(key)
            return True
        except KeyError:
            return False

    def __repr__(self) -> str:
        return f"StatsView({dict(self)!r})"

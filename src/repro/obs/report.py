"""Measured-utilization reporting: the software analogue of Occamy's
counter-derived utilization plots.

The engine's metrics registry accumulates *measured* decode windows —
``decode_window_s`` (histogram: wall seconds per engine step that dispatched
decode work), ``decode_window_tokens`` / ``decode_window_batch`` /
``decode_window_kv_rows`` (counters) — and this module joins them against
the analytic cost model in ``core/roofline.py`` / ``core/memfloor.py``:

* **MFU** — achieved model FLOP/s over the device pool's peak, with decode
  FLOPs/token = ``2 * (nonembed_active + embedding)`` params, exactly the
  convention ``roofline.model_flops`` uses for decode shapes.
* **HBM bandwidth utilization** — the per-step decode *floor* bytes from
  ``memfloor.hbm_bytes_floor`` (weights replicated in serve mode, KV cache
  sharded ``kv_shard``-way) replayed at the measured step rate, over
  ``CHIP.hbm_bw``. This is a lower bound on true traffic, so the reported
  fraction is "what the floor model says we must have moved".
* **D2D bandwidth utilization** — ``memfloor.d2d_bytes_serve_decode`` at the
  measured average batch, over ``CHIP.ici_link_bw`` (zero off-shard).

``utilization_report(engine)`` reads one engine; ``windows_from_trace``
re-derives a per-window series from a :class:`~repro.obs.trace.Tracer`'s
dispatch/sync instants when tracing was enabled.
"""
from __future__ import annotations

from typing import Any

__all__ = ["decode_utilization", "utilization_report", "windows_from_trace",
           "write_metrics_json"]


def _serve_decode_floor(cfg, *, batch: float, context: float,
                        kv_shard: int = 1) -> dict:
    """Per-device HBM floor bytes for ONE serve-mode decode step.

    Serve mode (``Partitioner(mode="serve")``) replicates weights and
    activations on every device and shards only the paged KV pools by KV
    head — so the floor joins the *replicated* weight/activation/logit
    terms with the *sharded* cache term, rather than taking either pure
    tensor-parallel view of ``hbm_bytes_floor``.
    """
    from repro.configs.base import ShapeConfig
    from repro.core.memfloor import MeshSizes, hbm_bytes_floor

    shape = ShapeConfig(name="obs-decode", kind="decode",
                        seq_len=max(int(round(context)), 1),
                        global_batch=max(batch, 1.0))
    full = hbm_bytes_floor(cfg, shape, MeshSizes(n_data=1, n_model=1),
                           dp=1, tp=1)
    if kv_shard <= 1:
        return full
    shard = hbm_bytes_floor(
        cfg, shape, MeshSizes(n_data=1, n_model=kv_shard),
        dp=1, tp=kv_shard)
    out = {"weights": full["weights"], "cache": shard["cache"],
           "activations": full["activations"], "logits": full["logits"]}
    out["total"] = sum(out.values())
    return out


def decode_utilization(cfg, *, tokens: float, steps: float, wall_s: float,
                       batch_sum: float, kv_row_sum: float,
                       kv_shard: int = 1) -> dict:
    """Join one measured decode window against the analytic model.

    ``tokens``: tokens committed in the window (spec-decode commits count);
    ``steps``: decode dispatches; ``wall_s``: measured wall seconds;
    ``batch_sum``: sum over dispatches of active decode slots;
    ``kv_row_sum``: sum over dispatches of context rows attended.
    """
    from repro.core.memfloor import d2d_bytes_serve_decode
    from repro.core.topology import CHIP, dtype_peak_flops

    n_dev = max(int(kv_shard), 1)
    if steps <= 0 or wall_s <= 0:
        return {"tokens": int(tokens), "steps": int(steps),
                "wall_s": wall_s, "tok_per_s": 0.0, "avg_batch": 0.0,
                "avg_context": 0.0, "flops_per_token": 0.0,
                "achieved_tflops": 0.0, "mfu": 0.0,
                "hbm_floor_bytes_per_step_dev": 0.0, "hbm_util": 0.0,
                "d2d_bytes_per_step_dev": 0.0, "d2d_util": 0.0,
                "devices": n_dev}

    avg_batch = batch_sum / steps
    avg_context = kv_row_sum / max(batch_sum, 1.0)

    pc = cfg.param_count()
    flops_per_token = 2.0 * (pc["nonembed_active"] + pc["embedding"])
    achieved = flops_per_token * tokens / wall_s
    peak = dtype_peak_flops(cfg.dtype) * n_dev

    floor = _serve_decode_floor(cfg, batch=avg_batch, context=avg_context,
                                kv_shard=n_dev)
    hbm_rate = floor["total"] * steps / wall_s          # per-device B/s
    d2d = d2d_bytes_serve_decode(cfg, max(int(round(avg_batch)), 1), n_dev)
    d2d_rate = d2d["total"] * steps / wall_s

    return {
        "tokens": int(tokens),
        "steps": int(steps),
        "wall_s": round(wall_s, 6),
        "tok_per_s": round(tokens / wall_s, 2),
        "avg_batch": round(avg_batch, 3),
        "avg_context": round(avg_context, 2),
        "flops_per_token": flops_per_token,
        "achieved_tflops": round(achieved / 1e12, 6),
        "mfu": round(achieved / peak, 6),
        "hbm_floor_bytes_per_step_dev": round(floor["total"], 1),
        "hbm_util": round(hbm_rate / CHIP.hbm_bw, 6),
        "d2d_bytes_per_step_dev": round(d2d["total"], 1),
        "d2d_util": round(d2d_rate / CHIP.ici_link_bw, 6),
        "devices": n_dev,
    }


def utilization_report(engine) -> dict:
    """Aggregate measured-window utilization for one engine run."""
    snap = engine.metrics.snapshot()
    win = snap.histograms.get("decode_window_s",
                              {"count": 0, "sum": 0.0, "buckets": {}})
    return decode_utilization(
        engine.cfg,
        tokens=snap.counters.get("decode_window_tokens", 0.0),
        steps=win["count"],
        wall_s=win["sum"],
        batch_sum=snap.counters.get("decode_window_batch", 0.0),
        kv_row_sum=snap.counters.get("decode_window_kv_rows", 0.0),
        kv_shard=getattr(engine, "_kv_shard", 1),
    )


def windows_from_trace(trace, cfg, *, kv_shard: int = 1,
                       window_steps: int = 32) -> list[dict]:
    """Per-window utilization series from a tracer's decode instants.

    Groups consecutive ``dispatch`` events (which carry ``n`` active slots
    and ``kv`` context rows) into windows of ``window_steps`` dispatches;
    tokens come from the ``sync`` / ``spec_commit`` instants that land
    inside the window's time range. Requires tracing to have been enabled
    for the run — returns ``[]`` on an empty or disabled trace.
    """
    evs = trace.events()
    dispatches = [e for e in evs if e.name == "dispatch"]
    if not dispatches:
        return []
    emits = [(e.ts, dict(e.args)) for e in evs
             if e.name in ("sync", "spec_commit")]
    out = []
    for w0 in range(0, len(dispatches), window_steps):
        group = dispatches[w0:w0 + window_steps]
        t_lo = group[0].ts
        t_hi = (dispatches[w0 + window_steps].ts
                if w0 + window_steps < len(dispatches)
                else max(e.ts for e in evs))
        args = [dict(e.args) for e in group]
        tokens = sum(a.get("tokens", a.get("accepted", 0))
                     for ts, a in emits if t_lo <= ts <= t_hi)
        row = decode_utilization(
            cfg,
            tokens=tokens,
            steps=len(group),
            wall_s=max(t_hi - t_lo, 1e-9),
            batch_sum=sum(a.get("n", 0) for a in args),
            kv_row_sum=sum(a.get("kv", 0) for a in args),
            kv_shard=kv_shard)
        row["window"] = w0 // window_steps
        out.append(row)
    return out


def write_metrics_json(path: str, *, suite: str, snapshot,
                       utilization: dict | None = None,
                       extra: dict | None = None) -> dict:
    """The one metrics-JSON schema every benchmark and the launcher emit."""
    import json

    payload: dict[str, Any] = {
        "schema": "repro-metrics-report-v1",
        "suite": suite,
        "snapshot": snapshot.as_dict(),
    }
    if utilization is not None:
        payload["utilization"] = utilization
    if extra:
        payload["extra"] = extra
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    return payload

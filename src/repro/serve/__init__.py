from repro.serve.engine import BlockAllocator, Request, Result, ServeEngine
from repro.serve.prefix import PrefixIndex, page_hashes

__all__ = ["BlockAllocator", "PrefixIndex", "Request", "Result",
           "ServeEngine", "page_hashes"]

from repro.serve.engine import BlockAllocator, Request, Result, ServeEngine
from repro.serve.prefix import PrefixIndex, page_hashes
from repro.serve.scheduler import SchedEntry, Scheduler

__all__ = ["BlockAllocator", "PrefixIndex", "Request", "Result",
           "SchedEntry", "Scheduler", "ServeEngine", "page_hashes"]

from repro.serve.engine import BlockAllocator, Request, Result, ServeEngine

__all__ = ["BlockAllocator", "Request", "Result", "ServeEngine"]

from repro.serve.engine import Request, Result, ServeEngine

__all__ = ["Request", "Result", "ServeEngine"]

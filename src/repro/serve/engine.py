"""Serving engine: prefill/decode with slot-based continuous batching.

The engine owns a fixed pool of ``max_slots`` sequence slots sharing one
batched KV/recurrent cache (batch dim = slot id). Requests are admitted into
free slots (prefill writes that slot's cache region), then a single jit'd
decode step advances *all* active slots with per-slot positions — finished
slots free immediately and new requests take their place without draining the
batch. This is the serving analogue of Ramora's ROB-less NI + multi-backend
DMA: many independent in-flight streams, no global reorder barrier.

Prefill is exact-length (jit cache per distinct prompt length). Length
bucketing is deliberately NOT used: right-padding corrupts ring-buffer
(sliding-window) caches and recurrent (SSM/RG-LRU) states, so padded prefill
is only sound for pure global-attention models — exactness is worth the
occasional recompile here.
"""
from __future__ import annotations

import contextlib
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import dispatch as kdispatch
from repro.models import decode_step, forward, logits_fn
from repro.models.cache import init_cache

PyTree = Any


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                      # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0                # 0 => greedy
    frames: np.ndarray | None = None        # enc-dec (audio) models
    extra_embeds: np.ndarray | None = None  # vlm models


@dataclass
class Result:
    uid: int
    tokens: list[int] = field(default_factory=list)
    finish_reason: str = ""
    prefill_s: float = 0.0
    decode_steps: int = 0


def _tree_write_slot(big: PyTree, small: PyTree, slot: int) -> PyTree:
    """Write a batch-1 cache pytree into slot ``slot`` of the pooled cache.
    Stacked scan blocks carry a leading n_rep dim (batch is axis 1)."""
    def f(path, b, s):
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        axis = 1 if "blocks" in keys else 0
        idx = [slice(None)] * b.ndim
        idx[axis] = slice(slot, slot + 1)
        return b.at[tuple(idx)].set(s.astype(b.dtype))
    return jax.tree_util.tree_map_with_path(f, big, small)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: PyTree, *, max_slots: int = 4,
                 max_len: int = 512, eos_id: int | None = None, seed: int = 0,
                 part=None, kernel_backend: str | None = None):
        self.cfg, self.params = cfg, params
        self.max_slots, self.max_len = max_slots, max_len
        self.eos_id = eos_id
        self.part = part
        # kernel selection for the engine's jitted graphs: explicit arg >
        # cfg.kernel_backend; block tuning comes from the strategy when
        # serving under a Partitioner. Fixed for the engine's lifetime (the
        # scope must be active whenever a prefill/decode graph traces).
        self.kernel_backend = (kernel_backend or cfg.resolved_kernel_backend
                               or None)
        strat = getattr(part, "strategy", None)
        self._kernel_blocks = (kdispatch.blocks_from_pairs(strat.kernel_blocks)
                               if strat is not None and strat.kernel_blocks
                               else None)
        self.rng = jax.random.PRNGKey(seed)
        self.cache = init_cache(cfg, max_slots, max_len)
        # slot bookkeeping (host side)
        self.slot_uid = np.full(max_slots, -1, np.int64)
        self.slot_pos = np.zeros(max_slots, np.int32)    # next write position
        self.slot_budget = np.zeros(max_slots, np.int32)
        self.slot_temp = np.zeros(max_slots, np.float32)
        self.active = np.zeros(max_slots, bool)
        self.queue: deque[Request] = deque()
        self.results: dict[int, Result] = {}
        self._prefill_cache: dict[tuple, Any] = {}
        self._decode_fn = jax.jit(self._decode_all)
        self.stats = {"prefills": 0, "decode_steps": 0, "prefill_recompiles": 0}

    # ------------------------------------------------------------------
    def _kernel_scope(self):
        """Backend/block-tuning scope for prefill and decode graphs. SPMD
        serving never opens a kernel scope: forward/decode_step would
        neutralize it anyway (no pallas_call inside pjit)."""
        if self.part is not None:
            return contextlib.nullcontext()
        if self.kernel_backend or self._kernel_blocks:
            return kdispatch.use_backend(self.kernel_backend,
                                         blocks=self._kernel_blocks)
        return contextlib.nullcontext()

    def _decode_all(self, params, cache, tokens, pos):
        """One decode step over the whole slot pool (per-slot positions)."""
        logits, cache = decode_step(params, self.cfg, cache, tokens, pos,
                                    part=self.part)
        return logits[:, 0], cache

    def _prefill_fn(self, length: int, has_frames: bool, has_extra: bool):
        key = (length, has_frames, has_extra)
        if key not in self._prefill_cache:
            self.stats["prefill_recompiles"] += 1

            def fn(params, tokens, frames, extra):
                cache_t = init_cache(self.cfg, 1, self.max_len)
                hidden, cache, _ = forward(params, self.cfg, tokens,
                                           frames=frames, extra_embeds=extra,
                                           cache=cache_t, part=self.part)
                logits = logits_fn(params, self.cfg, hidden[:, -1:, :],
                                   self.part)[..., :self.cfg.vocab_size]
                return logits[:, 0], cache

            self._prefill_cache[key] = jax.jit(fn)
        return self._prefill_cache[key]

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)
        self.results[req.uid] = Result(uid=req.uid)

    def _sample(self, logits: jnp.ndarray, temps: np.ndarray) -> np.ndarray:
        """Greedy for temp==0 rows, categorical otherwise."""
        greedy = np.asarray(jnp.argmax(logits, axis=-1))
        if (temps <= 0).all():
            return greedy
        self.rng, k = jax.random.split(self.rng)
        t = jnp.asarray(np.where(temps <= 0, 1.0, temps))[:, None]
        sampled = np.asarray(jax.random.categorical(k, logits / t, axis=-1))
        return np.where(temps <= 0, greedy, sampled)

    def _admit(self):
        """Fill free slots from the queue (prefill each admitted request)."""
        for slot in range(self.max_slots):
            if self.active[slot] or not self.queue:
                continue
            req = self.queue.popleft()
            t0 = time.perf_counter()
            prompt = np.asarray(req.prompt, np.int32)[None]  # (1, S)
            length = prompt.shape[1]
            assert length + req.max_new_tokens <= self.max_len, \
                f"request {req.uid} exceeds max_len {self.max_len}"
            fn = self._prefill_fn(length, req.frames is not None,
                                  req.extra_embeds is not None)
            frames = (jnp.asarray(req.frames)[None]
                      if req.frames is not None else None)
            extra = (jnp.asarray(req.extra_embeds)[None]
                     if req.extra_embeds is not None else None)
            with self._kernel_scope():
                logits, slot_cache = fn(self.params, jnp.asarray(prompt),
                                        frames, extra)
            self.cache = _tree_write_slot(self.cache, slot_cache, slot)
            first = int(self._sample(logits, np.asarray(
                [req.temperature]))[0])
            res = self.results[req.uid]
            res.tokens.append(first)
            res.prefill_s = time.perf_counter() - t0
            self.slot_uid[slot] = req.uid
            self.slot_pos[slot] = length  # position of `first` when decoded
            self.slot_budget[slot] = req.max_new_tokens - 1
            self.slot_temp[slot] = req.temperature
            self.active[slot] = True
            self.stats["prefills"] += 1
            if self.eos_id is not None and first == self.eos_id:
                self._finish(slot, "eos")
            elif self.slot_budget[slot] <= 0:
                self._finish(slot, "length")

    def _finish(self, slot: int, reason: str):
        res = self.results[self.slot_uid[slot]]
        res.finish_reason = reason
        self.active[slot] = False
        self.slot_uid[slot] = -1

    def step(self) -> int:
        """Admit + one decode step over active slots. Returns #active."""
        self._admit()
        if not self.active.any():
            return 0
        # last sampled token per slot feeds the next decode step
        tokens = np.zeros((self.max_slots, 1), np.int32)
        for slot in range(self.max_slots):
            if self.active[slot]:
                tokens[slot, 0] = self.results[self.slot_uid[slot]].tokens[-1]
        pos = jnp.asarray(self.slot_pos)
        with self._kernel_scope():
            logits, self.cache = self._decode_fn(self.params, self.cache,
                                                 jnp.asarray(tokens), pos)
        nxt = self._sample(logits, self.slot_temp)
        self.stats["decode_steps"] += 1
        for slot in range(self.max_slots):
            if not self.active[slot]:
                continue
            res = self.results[self.slot_uid[slot]]
            tok = int(nxt[slot])
            res.tokens.append(tok)
            res.decode_steps += 1
            self.slot_pos[slot] += 1
            self.slot_budget[slot] -= 1
            if self.eos_id is not None and tok == self.eos_id:
                self._finish(slot, "eos")
            elif self.slot_budget[slot] <= 0:
                self._finish(slot, "length")
        return int(self.active.sum())

    def run(self, requests: list[Request], *, max_steps: int = 100000
            ) -> list[Result]:
        """Drive all requests to completion (continuous batching loop)."""
        for r in requests:
            self.submit(r)
        steps = 0
        while (self.queue or self.active.any()) and steps < max_steps:
            self.step()
            steps += 1
        return [self.results[r.uid] for r in requests]

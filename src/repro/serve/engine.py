"""Serving engine: continuous batching over a paged (block-pool) KV cache.

The engine owns a fixed pool of ``max_slots`` sequence slots sharing one
batched KV/recurrent cache. Two cache layouts:

* **dense** — every slot statically reserves ``max_len`` KV rows.
* **paged** (``paged=True`` / ``cfg.paged_kv``) — full-attention KV lives in
  a global pool of ``page_size``-row blocks handed out by a
  :class:`BlockAllocator`; admission is gated on free *blocks* for the
  request's ``len(prompt) + max_new_tokens`` tokens, so KV memory tracks
  actual sequence lengths instead of ``max_slots × max_len``. This is the
  serving analogue of Occamy's banked-TCDM + ROB-less NI memory story: many
  independent in-flight streams over fixed-size blocks, no per-stream
  worst-case reservation. Blocks free the moment a request finishes.

With ``prefix_cache=True`` (``cfg.prefix_cache``), fully-written prompt
pages are published into a :class:`repro.serve.prefix.PrefixIndex` (page-
granular chain hashes); a later request whose prompt shares the prefix maps
those blocks read-only into its block table (refcount++), skips prefill for
the matched pages, and chunk-prefills only the tail from ``first_new_pos``.
Writes to a shared block privatize it first (copy-on-write: fresh block,
jitted page copy, table remap). Finished requests leave their indexed pages
resident as refcount-0 *cached* blocks, reclaimed LRU under pool pressure —
so a hot system prompt's KV survives between requests at zero steady-state
cost. All-full-attention configs only (ring/recurrent per-slot state cannot
be restored from the pool); incapable configs serve cold.

Admission order is owned by a :class:`repro.serve.scheduler.Scheduler`
policy layer: priority classes, per-request SLO deadlines (TTFT targets go
earliest-deadline-first once urgent), multi-tenant fair queuing over
``Request.user``, and — the head-of-line fix — *skip-with-aging*: a request
blocked on pool resources is skipped in favor of smaller ones that fit now,
until aging promotes it to a reservation nothing may overtake. With
``preemption=True`` a high-priority arrival that cannot get blocks evicts a
lower-priority victim: the victim's fully-written pages are published into
the prefix index (when enabled), its blocks released through the refcount
path, and the request requeued with its generated tokens folded into the
prompt — resumption chunk-prefills only the un-cached tail via
``first_new_pos``, so preemption costs a warm prefix hit, not a byte swap.

Prefill is **chunked**: prompts advance ``prefill_chunk`` tokens per engine
step through one jitted ``extend_step`` graph (ragged tails ride in the same
shape behind an ``n_valid`` scalar), interleaved with decode steps for the
already-running slots — one compiled prefill shape regardless of prompt
length, and no prefill head-of-line blocking of the decode pool. Enc-dec
(audio) and vlm requests, and SPMD serving (``part``), keep the legacy
whole-prompt prefill path (jit per distinct length).

Sampling is fused into the jitted step (per-slot temperatures + PRNG key as
inputs): each ``step()`` syncs only the sampled token ids to host, never the
``(max_slots, vocab)`` logits. Cache buffers are donated through every
jitted update, so admission/decode cost scales with the written region, not
the pool. With ``overlap=True`` the decode loop double-buffers: step N+1 is
dispatched on device (fed step N's sampled ids *as a device array*) before
step N's ids are synced to host, so host bookkeeping and admission overlap
device compute — token streams are identical, ids just reach callbacks one
step later.

Tokens stream out as they are sampled: every append stamps a
``perf_counter`` timestamp into ``Result.token_ts`` and fires the request's
``on_token`` callback; :meth:`ServeEngine.stream` wraps submit+step into a
per-request iterator.
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field, replace as dc_replace
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import dispatch as kdispatch
from repro.models import decode_step, extend_step, forward, logits_fn
from repro.models.cache import copy_block, default_n_blocks, init_cache, \
    kv_bytes, n_blocks_for_bytes, pages_per_slot
from repro.quant import is_quant_dtype, quantize_params
from repro.serve.prefix import PrefixIndex, page_hashes
from repro.serve.scheduler import Scheduler

PyTree = Any

#: Slot lifecycle: FREE -> PREFILL (chunked) -> DECODE -> FREE.
FREE, PREFILL, DECODE = 0, 1, 2


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                      # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0                # 0 => greedy
    frames: np.ndarray | None = None        # enc-dec (audio) models
    extra_embeds: np.ndarray | None = None  # vlm models
    # scheduling (repro.serve.scheduler)
    priority: int = 0                       # larger = more urgent
    user: str | None = None                 # tenant for fair queuing
    slo_ttft_ms: float | None = None        # time-to-first-token target
    slo_itl_ms: float | None = None         # mean inter-token target
    #: streaming callback, called as ``on_token(token, result)`` the moment
    #: each token reaches the host (with overlap, one step after sampling)
    on_token: Callable[[int, "Result"], None] | None = None


@dataclass
class Result:
    uid: int
    tokens: list[int] = field(default_factory=list)
    finish_reason: str = ""                 # eos | length | rejected | truncated
    detail: str = ""                        # rejection/truncation cause
    prefill_s: float = 0.0
    decode_steps: int = 0
    submit_s: float = 0.0                   # perf_counter at submit
    token_ts: list[float] = field(default_factory=list)  # one per token
    preempted: int = 0                      # times evicted and requeued
    slo_met: bool | None = None             # None = request had no SLO

    @property
    def ttft_s(self) -> float | None:
        """Submit-to-first-token latency (queueing + prefill)."""
        return (self.token_ts[0] - self.submit_s) if self.token_ts else None

    @property
    def itl_s(self) -> float | None:
        """Mean inter-token latency over the decoded tokens."""
        if len(self.token_ts) < 2:
            return None
        return (self.token_ts[-1] - self.token_ts[0]) / (len(self.token_ts) - 1)


class BlockAllocator:
    """Refcounted free-list allocator over the global KV block pool.

    Block 0 is the *null block*: never handed out, it absorbs the dropped
    writes of inactive slots and ragged prefill tails (their scatter indices
    route out of bounds / to the null entry instead of another stream's
    data — the block-pool equivalent of writing into a scratch bank).

    Every other block is in exactly one of three states:

    * **free** — on the free list, refcount 0;
    * **live** — refcount >= 1: owned by one slot, or *shared* read-only by
      several slots through the prefix cache (``incref`` per sharer; a write
      to a shared block must copy-on-write first);
    * **cached** — refcount 0 but pinned by the :class:`PrefixIndex`
      (``evictor``): retained after its last owner finished so future
      prefix hits can adopt it, evictable LRU under pool pressure.

    ``alloc`` is transactional: if the grant cannot be completed — even
    after asking the evictor to reclaim cached blocks — every block already
    popped is rolled back onto the free list before the error propagates,
    so a partial failure never leaks blocks.
    """

    def __init__(self, n_blocks: int, page_size: int):
        self.n_blocks = n_blocks
        self.page_size = page_size
        self._free = list(range(n_blocks - 1, 0, -1))
        self.ref = np.zeros(n_blocks, np.int32)
        self.evictor = None      # PrefixIndex (or None): reclaims cached

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        """Distinct blocks with refcount >= 1."""
        return int((self.ref > 0).sum())

    @property
    def n_evictable(self) -> int:
        return 0 if self.evictor is None else self.evictor.n_evictable(self)

    @property
    def n_available(self) -> int:
        """Blocks an ``alloc`` could obtain right now (free + evictable)."""
        return self.n_free + self.n_evictable

    @property
    def capacity(self) -> int:
        return self.n_blocks - 1

    def pages_for(self, n_tokens: int) -> int:
        return pages_per_slot(n_tokens, self.page_size)

    def alloc(self, n: int) -> list[int]:
        """Grant ``n`` fresh blocks at refcount 1, evicting cached blocks
        as needed; rolls the partial grant back cleanly on failure."""
        got: list[int] = []
        try:
            for _ in range(n):
                if not self._free and self.evictor is not None:
                    self.evictor.evict_one(self)
                if not self._free:
                    raise RuntimeError(
                        f"allocator exhausted: want {n}, free {self.n_free} "
                        f"(+{self.n_evictable} evictable)")
                blk = self._free.pop()
                if self.ref[blk] != 0:      # corrupted free list
                    self._free.append(blk)
                    raise RuntimeError(f"free-list block {blk} has "
                                       f"refcount {int(self.ref[blk])}")
                self.ref[blk] = 1
                got.append(blk)
        except Exception:
            for blk in reversed(got):
                self.ref[blk] = 0
                self._free.append(blk)
            raise
        return got

    def incref(self, block: int) -> None:
        """Adopt a cached block (0 -> 1) or add a sharer to a live one."""
        if not 0 < block < self.n_blocks:
            raise ValueError(f"invalid block id {block}")
        if (self.evictor is not None and self.ref[block] == 0
                and self.evictor.is_cached(block)):
            self.evictor.note_adopted(block)     # cached -> live
        self.ref[block] += 1

    def decref(self, block: int, *, retain: bool = False) -> int:
        """Drop one reference. At refcount 0 the block returns to the free
        list unless ``retain`` (the prefix index keeps it cached). Returns
        the new refcount; a double free raises instead of corrupting."""
        r = int(self.ref[block]) - 1
        if r < 0:
            raise RuntimeError(f"double free of block {block}")
        self.ref[block] = r
        if r == 0:
            if not retain:
                self._free.append(block)
            elif self.evictor is not None:
                self.evictor.note_cached(block)  # live -> cached
        return r

    def free_block(self, block: int) -> None:
        """Return an (evicted, refcount-0) block to the free list."""
        if self.ref[block] != 0:
            raise RuntimeError(f"freeing live block {block} "
                               f"(refcount {int(self.ref[block])})")
        self._free.append(block)

    def release(self, blocks: list[int]) -> None:
        """Drop one reference on each block; blocks pinned by the evictor
        (prefix index) are retained as cached instead of freed."""
        for blk in blocks:
            retain = (self.evictor is not None
                      and self.evictor.is_cached(blk))
            self.decref(blk, retain=retain)


def _sample(logits, temps, key):
    """Greedy rows where temp <= 0, temperature-categorical otherwise.
    Runs inside the jitted step: only sampled ids reach the host."""
    greedy = jnp.argmax(logits, axis=-1)
    t = jnp.where(temps <= 0, 1.0, temps)[:, None]
    sampled = jax.random.categorical(key, logits / t, axis=-1)
    return jnp.where(temps <= 0, greedy, sampled).astype(jnp.int32)


@dataclass
class _Pending:
    """One dispatched-but-unsynced decode step (overlap double-buffer)."""
    ids: Any                 # (max_slots,) int32 device array
    mask: np.ndarray         # slots this dispatch decoded
    uids: np.ndarray         # slot -> uid snapshot at dispatch time


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: PyTree, *, max_slots: int = 4,
                 max_len: int = 512, eos_id: int | None = None, seed: int = 0,
                 part=None, kernel_backend: str | None = None,
                 paged: bool | None = None, page_size: int | None = None,
                 prefill_chunk: int | None = None,
                 max_blocks: int | None = None,
                 kv_budget_bytes: int | None = None,
                 prefix_cache: bool | None = None,
                 prefix_lru: int | None = None,
                 sched: str | None = None,
                 sched_aging: int | None = None,
                 preemption: bool | None = None,
                 overlap: bool | None = None):
        self.cfg, self.params = cfg, params
        self.max_slots, self.max_len = max_slots, max_len
        self.eos_id = eos_id
        self.part = part
        self.paged = cfg.paged_kv if paged is None else paged
        self.page_size = page_size or cfg.page_size
        self.prefill_chunk = prefill_chunk or cfg.prefill_chunk
        # prefix caching shares fully-written prompt pages of the block pool
        # across requests (refcounted, copy-on-write). It requires every
        # cacheable layer state to live in the paged pools, so it is gated
        # on all-full-attention decoder configs: sliding-window rings and
        # recurrent carries are per-slot dense state a prefix hit cannot
        # restore. Incapable configs silently serve cold (prefix_hits == 0)
        # rather than erroring — the flag is a throughput hint, not a
        # semantics change.
        want_prefix = (cfg.prefix_cache if prefix_cache is None
                       else prefix_cache)
        self.prefix_capable = (self.paged and part is None
                               and cfg.encoder is None
                               and all(sp.mixer == "full"
                                       for sp in cfg.all_layers()))
        self.prefix_cache = bool(want_prefix) and self.prefix_capable
        self.prefix_lru = (cfg.prefix_lru if prefix_lru is None
                           else prefix_lru)
        if self.prefix_lru < 0:     # engine kwarg / --prefix-lru bypasses
            raise ValueError("prefix_lru must be >= 0")
        if self.paged and part is not None:
            raise ValueError("paged serving is local-only: SPMD serving "
                             "keeps the dense layout")
        # scheduling policy layer: admission order, SLOs, fairness, aging
        self.scheduler = Scheduler(
            sched or cfg.sched_policy,
            aging_skips=cfg.sched_aging if sched_aging is None
            else sched_aging)
        self.preemption = cfg.preemption if preemption is None else preemption
        if self.preemption and not self.paged:
            raise ValueError("preemption requires the paged (block-pool) "
                             "layout: dense slots hold no reclaimable blocks")
        self.overlap = cfg.overlap_decode if overlap is None else overlap
        # multi-precision serving (repro.quant): post-load weight
        # quantization keyed off cfg.weight_dtype — local-only (SPMD graphs
        # keep the dense master params), applied here so callers need no
        # separate transform step
        if cfg.weight_dtype:
            if part is not None:
                raise ValueError("weight quantization is local-only: SPMD "
                                 "serving keeps the dense master params")
            self.params = quantize_params(params, cfg)
        if is_quant_dtype(cfg.kv_dtype):
            if not self.paged:
                raise ValueError(
                    "kv_dtype requires the paged (block-pool) cache layout: "
                    "per-row scales live alongside the pools")
            if cfg.encoder is not None:
                raise ValueError(
                    "quantized KV does not support enc-dec models: the "
                    "whole-prompt prefill commit path writes dense rows")
        # kernel selection for the engine's jitted graphs: explicit arg >
        # cfg.kernel_backend; block tuning comes from the strategy when
        # serving under a Partitioner. Fixed for the engine's lifetime (the
        # scope must be active whenever a prefill/decode graph traces).
        self.kernel_backend = (kernel_backend or cfg.resolved_kernel_backend
                               or None)
        strat = getattr(part, "strategy", None)
        self._kernel_blocks = (kdispatch.blocks_from_pairs(strat.kernel_blocks)
                               if strat is not None and strat.kernel_blocks
                               else None)
        self.rng = jax.random.PRNGKey(seed)
        if self.paged:
            if kv_budget_bytes is not None:
                # size the pool by HBM budget through the cache's sizing
                # helper: the narrower the KV dtype, the more blocks the
                # same budget admits (dense-equivalent count is the cap)
                n_blocks = min(
                    n_blocks_for_bytes(cfg, kv_budget_bytes, self.page_size),
                    default_n_blocks(max_slots, max_len, self.page_size))
            else:
                n_blocks = (max_blocks or cfg.max_blocks
                            or default_n_blocks(max_slots, max_len,
                                                self.page_size))
            # pool leaves must be distinguishable from batch-sized leaves,
            # and a pool smaller than the slot count cannot serve anyway
            self.n_blocks = max(n_blocks, max_slots + 1)
            self.allocator = BlockAllocator(self.n_blocks, self.page_size)
            if self.prefix_cache:
                self.prefix_index = PrefixIndex(self.page_size,
                                                max_cached=self.prefix_lru)
                self.allocator.evictor = self.prefix_index
            else:
                self.prefix_index = None
            self.n_pages = pages_per_slot(max_len, self.page_size)
            self.block_tables = np.zeros((max_slots, self.n_pages), np.int32)
            self.cache = init_cache(cfg, max_slots, max_len,
                                    n_blocks=self.n_blocks,
                                    page_size=self.page_size)
            pool = kv_bytes(self.cache, pool_n_blocks=self.n_blocks)
            self._block_kv_bytes = pool // self.n_blocks
            # ring buffers / recurrent-adjacent dense KV still charge per slot
            self._slot_kv_bytes = (kv_bytes(self.cache) - pool) // max_slots
        else:
            self.allocator = None
            self.prefix_index = None
            self.n_blocks = 0
            self.block_tables = None
            self.cache = init_cache(cfg, max_slots, max_len)
            self._block_kv_bytes = 0
            self._slot_kv_bytes = kv_bytes(self.cache) // max_slots
        # slot bookkeeping (host side)
        self.phase = np.full(max_slots, FREE, np.int8)
        self.slot_uid = np.full(max_slots, -1, np.int64)
        #: next KV write position per slot — advanced at *dispatch* time, so
        #: with overlap it can run one step ahead of the synced token lists
        self.slot_pos = np.zeros(max_slots, np.int32)
        self.slot_budget = np.zeros(max_slots, np.int32)
        self.slot_temp = np.zeros(max_slots, np.float32)
        self.slot_blocks: list[list[int]] = [[] for _ in range(max_slots)]
        self._prefilling: dict[int, Request] = {}        # slot -> request
        self._admit_hashes: dict[int, list[int]] = {}    # uid -> page hashes
        self._prefill_off = np.zeros(max_slots, np.int32)
        #: absolute position of the first non-prefix-cached token per slot —
        #: chunked prefill starts here; everything below it was mapped
        #: read-only from shared blocks
        self._first_new = np.zeros(max_slots, np.int32)
        self._t0 = np.zeros(max_slots, np.float64)
        # per-slot scheduling state (preemption victims, requeue identity)
        self._slot_req: list[Request | None] = [None] * max_slots
        self._slot_legacy = np.zeros(max_slots, bool)
        self._slot_prio = np.zeros(max_slots, np.int32)
        self._slot_seq = np.zeros(max_slots, np.int64)   # admission recency
        self._slot_sched_seq = np.zeros(max_slots, np.int64)
        #: len(res.tokens) at admission — length finishes compare *emitted*
        #: tokens against the segment budget, because with overlap
        #: ``slot_budget`` is decremented at dispatch and runs one
        #: speculative step ahead of the synced token list
        self._slot_tok0 = np.zeros(max_slots, np.int64)
        self._admit_seq = 0
        self._pending: _Pending | None = None
        self.results: dict[int, Result] = {}
        self._prefill_cache: dict[tuple, Any] = {}
        self._decode_fn = jax.jit(self._decode_all, donate_argnums=(1,))
        self._commit_fn = jax.jit(self._commit_slot, donate_argnums=(0,))
        self._chunk_fn = None
        self._copy_fn = jax.jit(
            lambda cache, src, dst: copy_block(cache, src, dst,
                                               self.n_blocks),
            donate_argnums=(0,))
        self.stats = {"prefills": 0, "decode_steps": 0, "prefill_chunks": 0,
                      "prefill_recompiles": 0, "rejected": 0,
                      "kv_bytes_alloc": 0, "kv_bytes_cached": 0,
                      "prefix_hits": 0, "prefix_hit_tokens": 0,
                      "prefix_cow": 0, "prefix_evictions": 0,
                      "preemptions": 0, "sched_skips": 0,
                      "slo_met": 0, "slo_missed": 0}

    # ------------------------------------------------------------------
    @property
    def active(self) -> np.ndarray:
        """Slots currently owned by a request (prefilling or decoding)."""
        return self.phase != FREE

    @property
    def queue(self) -> list[Request]:
        """Waiting requests in arrival order (scheduler-owned)."""
        return [e.req for e in self.scheduler.entries()]

    def _kernel_scope(self):
        """Backend/block-tuning scope for prefill and decode graphs. SPMD
        serving never opens a kernel scope: forward/decode_step would
        neutralize it anyway (no pallas_call inside pjit)."""
        if self.part is not None:
            return contextlib.nullcontext()
        if self.kernel_backend or self._kernel_blocks:
            return kdispatch.use_backend(self.kernel_backend,
                                         blocks=self._kernel_blocks)
        return contextlib.nullcontext()

    def _tables(self):
        return jnp.asarray(self.block_tables) if self.paged else None

    # ---- jitted graphs ------------------------------------------------
    def _decode_all(self, params, cache, tokens, pos, active, tables, temps,
                    key):
        """One decode step over the whole slot pool + fused sampling."""
        logits, cache = decode_step(params, self.cfg, cache, tokens, pos,
                                    part=self.part, active=active,
                                    block_tables=tables)
        return _sample(logits[:, 0], temps, key), cache

    def _chunk_step(self, params, cache, tokens, pos, n_valid, slot, tables,
                    temp, key, first_new):
        """One chunked-prefill step for one slot + fused sampling (the
        sampled id only matters on the final chunk). ``first_new`` (traced
        scalar) is the absolute position prefill started at — positions
        below it come from prefix-shared blocks."""
        logits, cache = extend_step(params, self.cfg, cache, tokens, pos,
                                    n_valid, slot, block_tables=tables,
                                    first_new_pos=first_new)
        return _sample(logits[:, 0], temp[None], key), cache

    def _commit_slot(self, cache, slot_cache, slot, tables):
        """Write a batch-1 dense prefill cache into slot ``slot`` of the
        pooled cache (donated: cost scales with the written region). Paged
        pool leaves take the slot's rows through its block table; everything
        else (dense KV, ring buffers, recurrent states, cross caches) is a
        dynamic-slice update at the slot index."""
        page = self.page_size

        def f(path, b, s):
            keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
            axis = 1 if "blocks" in keys else 0
            if self.paged and b.shape[axis] == self.n_blocks:
                s_buf = s.shape[axis + 1]
                rows = jnp.arange(s_buf)
                trow = jax.lax.dynamic_slice(
                    tables, (slot, 0), (1, tables.shape[1]))[0]
                blk = trow[rows // page]
                r = rows % page
                if axis == 0:
                    return b.at[blk, r].set(s[0].astype(b.dtype), mode="drop")
                return b.at[:, blk, r].set(s[:, 0].astype(b.dtype),
                                           mode="drop")
            start = tuple(slot if i == axis else 0 for i in range(b.ndim))
            return jax.lax.dynamic_update_slice(b, s.astype(b.dtype), start)

        return jax.tree_util.tree_map_with_path(f, cache, slot_cache)

    def _ensure_chunk_fn(self):
        if self._chunk_fn is None:
            # one compiled shape serves every chunk of every prompt length
            self.stats["prefill_recompiles"] += 1
            self._chunk_fn = jax.jit(self._chunk_step, donate_argnums=(1,))
        return self._chunk_fn

    def _prefill_fn(self, length: int, has_frames: bool, has_extra: bool):
        """Legacy whole-prompt prefill (enc-dec / vlm / SPMD): jit per
        distinct prompt length — exactness over the recompile."""
        key = (length, has_frames, has_extra)
        if key not in self._prefill_cache:
            self.stats["prefill_recompiles"] += 1

            def fn(params, tokens, frames, extra):
                cache_t = init_cache(self.cfg, 1, self.max_len)
                hidden, cache, _ = forward(params, self.cfg, tokens,
                                           frames=frames, extra_embeds=extra,
                                           cache=cache_t, part=self.part)
                logits = logits_fn(params, self.cfg, hidden[:, -1:, :],
                                   self.part)[..., :self.cfg.vocab_size]
                return logits[:, 0], cache

            self._prefill_cache[key] = jax.jit(fn)
        return self._prefill_cache[key]

    # ---- streaming ----------------------------------------------------
    def submit(self, req: Request):
        self.results[req.uid] = Result(uid=req.uid,
                                       submit_s=time.perf_counter())
        self.scheduler.submit(req)

    def stream(self, req: Request, *, max_steps: int = 100000
               ) -> Iterator[int]:
        """Submit ``req`` and yield its tokens as they arrive, stepping the
        engine (and any other in-flight requests) between yields."""
        self.submit(req)
        res = self.results[req.uid]
        sent = steps = 0
        while True:
            while sent < len(res.tokens):
                yield res.tokens[sent]
                sent += 1
            if res.finish_reason:
                return
            if steps >= max_steps:
                self._truncate()
                continue
            self.step()
            steps += 1

    def _emit(self, slot: int, tok: int):
        """Append one sampled token to the slot's result: timestamped for
        TTFT/ITL accounting, streamed through the request's callback."""
        res = self.results[self.slot_uid[slot]]
        res.tokens.append(tok)
        res.token_ts.append(time.perf_counter())
        req = self._slot_req[slot]
        if req is not None and req.on_token is not None:
            req.on_token(tok, res)

    # ---- scheduling ----------------------------------------------------
    def _reject(self, req: Request, why: str):
        """Graceful per-request rejection: the engine loop keeps serving."""
        res = self.results[req.uid]
        res.finish_reason = "rejected"
        res.detail = why
        self._admit_hashes.pop(req.uid, None)
        self.stats["rejected"] += 1

    def _cow_pages(self, slot: int, lo: int, hi: int) -> None:
        """Copy-on-write guard before writing positions ``[lo, hi)`` of
        ``slot``: any touched page whose block is shared (refcount > 1) or
        pinned by the prefix index gets a private copy first (fresh block,
        jitted page copy, table remap). Admission already privatizes the one
        boundary page a prefix hit can write, so this keeps 'writers never
        touch shared blocks' true by construction rather than by scheduling
        luck."""
        if not self.paged or self.prefix_index is None or hi <= lo:
            return
        page = self.page_size
        for p in range(lo // page, (hi - 1) // page + 1):
            blk = int(self.block_tables[slot, p])
            if blk == 0:
                continue
            if (self.allocator.ref[blk] > 1
                    or self.prefix_index.is_cached(blk)):
                [dst] = self.allocator.alloc(1)
                self.cache = self._copy_fn(self.cache, np.int32(blk),
                                           np.int32(dst))
                self.allocator.release([blk])
                self.slot_blocks[slot][
                    self.slot_blocks[slot].index(blk)] = dst
                self.block_tables[slot, p] = dst
                self.stats["prefix_cow"] += 1

    # ---- preemption ----------------------------------------------------
    def _preempt_for(self, prio: int) -> bool:
        """Free resources for a priority-``prio`` arrival: evict one victim
        slot of strictly lower priority (lowest class first, then the most
        recently admitted — the least sunk work). Returns True when anything
        may have freed, so the caller re-checks fit before preempting more.

        A pending overlapped decode is flushed first: its in-flight sampled
        ids must land before a victim's generated tokens are folded into its
        resumption prompt (and the flush itself can finish slots, making
        the preemption unnecessary)."""
        if not self.preemption:
            return False
        if self._pending is not None:
            self._sync_pending()
            return True
        cands = [s for s in range(self.max_slots)
                 if self.phase[s] != FREE and not self._slot_legacy[s]
                 and self._slot_prio[s] < prio]
        if not cands:
            return False
        victim = max(cands, key=lambda s: (-int(self._slot_prio[s]),
                                           int(self._slot_seq[s])))
        self._preempt(victim)
        return True

    def _preempt(self, slot: int) -> None:
        """Evict ``slot``: publish its fully-written pages into the prefix
        index (so resumption is a warm hit, not a recompute), release its
        blocks through the refcounted path (indexed pages stay cached,
        fresh ones free — a mid-prefill victim rolls back exactly like a
        failed admission), and requeue the request with its generated
        tokens folded into the prompt at its original place in line."""
        uid = int(self.slot_uid[slot])
        res = self.results[uid]
        req = self._slot_req[slot]
        if self.phase[slot] == PREFILL:
            written = int(self._prefill_off[slot])
            new_prompt = np.asarray(req.prompt, np.int32)
            self._prefilling.pop(slot, None)
        else:
            # rows [0, slot_pos) are written; the last sampled token's KV is
            # not (it would be written by the next decode step), so the
            # resumption prompt = written tokens + that trailing token, and
            # its chunked prefill re-derives exactly the logits decode
            # would have produced next
            written = int(self.slot_pos[slot])
            gen = [t for t in res.tokens[len(res.tokens)
                                         - (written + 1
                                            - len(req.prompt)):]]
            new_prompt = np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(gen, np.int32)])
        new_budget = int(self.slot_budget[slot])
        if self.prefix_index is not None:
            n_full = written // self.page_size
            if n_full:
                # full pages of the written region (prompt AND generated
                # tokens) are valid chain entries: the resumption — or any
                # request sharing the extended prefix — adopts them
                seq_tokens = (new_prompt if self.phase[slot] != PREFILL
                              else np.asarray(req.prompt, np.int32))
                self.prefix_index.publish(seq_tokens,
                                          self.slot_blocks[slot][:n_full])
        self.allocator.release(self.slot_blocks[slot])
        if self.prefix_index is not None:
            self.prefix_index.trim(self.allocator)
        self.slot_blocks[slot] = []
        self.block_tables[slot, :] = 0
        self.phase[slot] = FREE
        self.slot_uid[slot] = -1
        self._slot_req[slot] = None
        res.preempted += 1
        self.stats["preemptions"] += 1
        self.scheduler.requeue(
            dc_replace(req, prompt=new_prompt, max_new_tokens=new_budget),
            seq=int(self._slot_sched_seq[slot]), submit_s=res.submit_s)

    # ---- admission -----------------------------------------------------
    def _free_slot(self) -> int | None:
        for s in range(self.max_slots):
            if self.phase[s] == FREE:
                return s
        return None

    def _admit(self):
        """Fill free slots in scheduler order. A request blocked on pool
        resources is *skipped* (smaller ones behind it admit now — the
        head-of-line fix) and aged: once promoted to a reservation, nothing
        overtakes it until it admits. Impossible requests reject instead of
        crashing the loop; with preemption enabled, a blocked high-priority
        request evicts lower-priority victims first."""
        guard = 0
        while self.scheduler and guard <= 4 * self.max_slots + 8:
            guard += 1
            if not self._admit_pass():
                return

    def _admit_pass(self) -> bool:
        """One pass over the scheduler order. Returns True when a
        preemption changed the resource picture and the pass should
        restart."""
        fcfs = self.scheduler.policy == "fcfs"
        for entry in self.scheduler.order():
            req = entry.req
            n_tokens = len(req.prompt) + req.max_new_tokens
            if n_tokens > self.max_len:
                self.scheduler.remove(entry)
                self._reject(req, f"exceeds max_len: prompt+budget "
                                  f"{n_tokens} tokens > {self.max_len}")
                continue
            legacy = (self.cfg.encoder is not None
                      or req.frames is not None
                      or req.extra_embeds is not None
                      or self.part is not None)
            if legacy and is_quant_dtype(self.cfg.kv_dtype):
                # the whole-prompt prefill commit writes dense rows —
                # incompatible with quantized pools
                self.scheduler.remove(entry)
                self._reject(req, "quantized KV serves chunked-prefill "
                                  "requests only (no frames/embeds)")
                continue
            if self.paged:
                total = self.allocator.pages_for(n_tokens)
                if total > self.allocator.capacity:
                    cap = self.allocator.capacity
                    self.scheduler.remove(entry)
                    self._reject(
                        req,
                        f"exceeds block pool: needs {total} blocks "
                        f"({total * self._block_kv_bytes} KV bytes) > "
                        f"capacity {cap} blocks "
                        f"({cap * self._block_kv_bytes} KV bytes)")
                    continue
            slot = self._free_slot()
            if slot is None:
                if self._preempt_for(int(req.priority)):
                    return True              # resources moved: re-plan
                return False                 # every slot busy: nobody admits
            if self.paged:
                if not self._admit_paged(entry, slot, n_tokens, legacy):
                    if fcfs or self.scheduler.reserved(entry):
                        # FCFS never overtakes; a reserved (aged) entry
                        # holds the pool until it fits
                        return False
                    continue
            else:
                self._first_new[slot] = 0
                self.stats["kv_bytes_alloc"] += self._slot_kv_bytes
            self._place(entry, slot, legacy)
        return False

    def _admit_paged(self, entry, slot: int, n_tokens: int,
                     legacy: bool) -> bool:
        """Block-pool admission for one request: prefix lookup, grant, COW.
        Returns False (after noting the skip) when blocks are short even
        after preemption."""
        req = entry.req
        total = self.allocator.pages_for(n_tokens)
        # prefix cache: map the longest indexed chain of this prompt's
        # pages read-only into the slot's block table (refcount++ per
        # page) and prefill only the tail
        matched: list[int] = []
        first_new = 0
        if self.prefix_cache and not legacy:
            # hash once per request: a request stalled on free blocks
            # retries every step and must not re-hash its whole prompt
            hs = self._admit_hashes.get(req.uid)
            if hs is None:
                hs = page_hashes(req.prompt, self.page_size)
                self._admit_hashes[req.uid] = hs
            matched = self.prefix_index.lookup(
                req.prompt, self.allocator, hashes=hs)
            # clamp below by 0: an empty prompt must not push the
            # prefill offset negative
            first_new = max(0, min(len(matched) * self.page_size,
                                   len(req.prompt) - 1))
        # a page-aligned full-prompt match still recomputes the final
        # token (its logits seed decode), so the last matched page gets
        # written mid-page -> privatize it now via copy-on-write
        # (counted into the grant, so the pool can never strand a
        # request mid-COW)
        cow = (bool(matched)
               and first_new < len(matched) * self.page_size)
        need = total - len(matched) + (1 if cow else 0)
        while (need > self.allocator.n_available
               and self._preempt_for(int(req.priority))):
            pass                      # each eviction is re-checked
        if need > self.allocator.n_available:
            # hand the prefix references back (refcount-0 indexed blocks
            # return to cached, not freed) and note the skip for aging
            self.allocator.release(matched)
            self.scheduler.note_skip(entry)
            return False
        try:
            fresh = self.allocator.alloc(need)
        except RuntimeError:
            # alloc rolled its partial grant back; hand the prefix
            # references back too — admission leaves no trace
            self.allocator.release(matched)
            self.scheduler.note_skip(entry)
            return False
        if cow:
            shared = matched[-1]
            matched[-1] = fresh.pop(0)
            self.cache = self._copy_fn(
                self.cache, np.int32(shared), np.int32(matched[-1]))
            self.allocator.release([shared])
            self.stats["prefix_cow"] += 1
        blocks = matched + fresh
        self.slot_blocks[slot] = blocks
        self.block_tables[slot, :] = 0
        self.block_tables[slot, :len(blocks)] = blocks
        self._first_new[slot] = first_new
        self.stats["kv_bytes_alloc"] += (
            need * self._block_kv_bytes + self._slot_kv_bytes)
        if matched:
            self.stats["prefix_hits"] += 1
            self.stats["prefix_hit_tokens"] += first_new
        return True

    def _place(self, entry, slot: int, legacy: bool) -> None:
        """Bind an admitted request to its slot and start prefill."""
        req = entry.req
        self.scheduler.note_admitted(entry,
                                     len(req.prompt) + req.max_new_tokens)
        self._admit_hashes.pop(req.uid, None)
        self._t0[slot] = time.perf_counter()
        self.slot_uid[slot] = req.uid
        self.slot_temp[slot] = req.temperature
        self.slot_budget[slot] = req.max_new_tokens
        self._slot_req[slot] = req
        self._slot_legacy[slot] = legacy
        self._slot_prio[slot] = req.priority
        self._slot_seq[slot] = self._admit_seq
        self._slot_sched_seq[slot] = entry.seq
        self._slot_tok0[slot] = len(self.results[req.uid].tokens)
        self._admit_seq += 1
        self.stats["prefills"] += 1
        if legacy:
            self._prefill_whole(slot, req)
        else:
            self.phase[slot] = PREFILL
            self._prefilling[slot] = req
            # chunked prefill starts at the first non-cached token:
            # everything below rode in read-only through the table
            self._prefill_off[slot] = self._first_new[slot]

    def _prefill_whole(self, slot: int, req: Request):
        prompt = np.asarray(req.prompt, np.int32)[None]  # (1, S)
        length = prompt.shape[1]
        fn = self._prefill_fn(length, req.frames is not None,
                              req.extra_embeds is not None)
        frames = (jnp.asarray(req.frames)[None]
                  if req.frames is not None else None)
        extra = (jnp.asarray(req.extra_embeds)[None]
                 if req.extra_embeds is not None else None)
        with self._kernel_scope():
            logits, slot_cache = fn(self.params, jnp.asarray(prompt),
                                    frames, extra)
        self.cache = self._commit_fn(self.cache, slot_cache, np.int32(slot),
                                     self._tables())
        self.rng, k = jax.random.split(self.rng)
        first = int(_sample(logits, jnp.asarray([req.temperature],
                                                jnp.float32), k)[0])
        self.phase[slot] = DECODE
        self._finish_prefill(slot, first, length)

    def _prefill_chunks(self):
        """Advance every mid-prefill slot by one ``prefill_chunk``-token
        chunk (ragged tails pad to the same compiled shape behind
        ``n_valid``); decode interleaves between chunks, so a long prompt
        never stalls the running slots."""
        for slot in sorted(self._prefilling):
            req = self._prefilling[slot]
            prompt = np.asarray(req.prompt, np.int32)
            off = int(self._prefill_off[slot])
            t = min(self.prefill_chunk, len(prompt) - off)
            buf = np.zeros((1, self.prefill_chunk), np.int32)
            buf[0, :t] = prompt[off:off + t]
            self.rng, k = jax.random.split(self.rng)
            fn = self._ensure_chunk_fn()
            self._cow_pages(slot, off, off + t)
            with self._kernel_scope():
                tok, self.cache = fn(self.params, self.cache,
                                     jnp.asarray(buf), np.int32(off),
                                     np.int32(t), np.int32(slot),
                                     self._tables(),
                                     np.float32(req.temperature), k,
                                     np.int32(self._first_new[slot]))
            self.stats["prefill_chunks"] += 1
            off += t
            self._prefill_off[slot] = off
            if off >= len(prompt):
                del self._prefilling[slot]
                if self.prefix_index is not None:
                    # every full prompt page is now written: publish the
                    # slot's pages so later identical prefixes can share
                    # them (matched pages re-register as a no-op; cold
                    # concurrent duplicates stay un-indexed and free
                    # normally at finish)
                    n_full = len(prompt) // self.page_size
                    if n_full:
                        self.prefix_index.publish(
                            prompt, self.slot_blocks[slot][:n_full])
                self.phase[slot] = DECODE
                self._finish_prefill(slot, int(tok[0]), len(prompt))

    def _emitted(self, slot: int) -> int:
        """Tokens emitted in this admission segment (synced to host)."""
        return (len(self.results[self.slot_uid[slot]].tokens)
                - int(self._slot_tok0[slot]))

    def _finish_prefill(self, slot: int, first: int, length: int):
        res = self.results[self.slot_uid[slot]]
        self._emit(slot, first)
        if res.prefill_s == 0.0:    # resumption keeps the original TTFT
            res.prefill_s = time.perf_counter() - self._t0[slot]
        self.slot_pos[slot] = length  # position of `first` when decoded
        self.slot_budget[slot] -= 1
        if self.eos_id is not None and first == self.eos_id:
            self._finish(slot, "eos")
        elif self._emitted(slot) >= self._slot_req[slot].max_new_tokens:
            self._finish(slot, "length")

    def _finish(self, slot: int, reason: str):
        res = self.results[self.slot_uid[slot]]
        res.finish_reason = reason
        req = self._slot_req[slot]
        if (req is not None and reason in ("eos", "length")
                and (req.slo_ttft_ms is not None
                     or req.slo_itl_ms is not None)):
            ok = True
            if req.slo_ttft_ms is not None:
                ok &= (res.ttft_s is not None
                       and res.ttft_s * 1e3 <= req.slo_ttft_ms)
            if req.slo_itl_ms is not None and res.itl_s is not None:
                ok &= res.itl_s * 1e3 <= req.slo_itl_ms
            res.slo_met = bool(ok)
            self.stats["slo_met" if ok else "slo_missed"] += 1
        self.phase[slot] = FREE
        self.slot_uid[slot] = -1
        self._slot_req[slot] = None
        self._prefilling.pop(slot, None)
        if self.paged and self.slot_blocks[slot]:
            # drop this slot's references immediately: unshared blocks are
            # admittable this very step, and fully-written prompt pages
            # that made it into the prefix index stay resident as cached
            # (refcount-0, LRU-evictable) blocks instead of freeing
            self.allocator.release(self.slot_blocks[slot])
            if self.prefix_index is not None:
                self.prefix_index.trim(self.allocator)
            self.slot_blocks[slot] = []
            self.block_tables[slot, :] = 0

    # ---- decode (double-buffered) --------------------------------------
    def _decode(self):
        """Dispatch one decode step, then sync. Without overlap the sync is
        immediate (legacy behavior). With overlap the *previous* step's ids
        sync after this step's dispatch is already on the device — host
        bookkeeping and the next admission run while the device computes,
        at the cost of ids reaching callbacks one step late."""
        prev = self._pending
        self._pending = self._dispatch_decode(prev)
        if prev is not None:
            self._sync(prev)
        if not self.overlap and self._pending is not None:
            p, self._pending = self._pending, None
            self._sync(p)

    def _dispatch_decode(self, prev: _Pending | None) -> _Pending | None:
        """Enqueue one decode step on device. Continuing slots take their
        token feed from ``prev``'s device ids (never synced to host);
        slots that just finished prefill take their host-known first token.
        Positions and budgets advance at dispatch, so the mask and the COW
        guard stay exact even while ids are in flight."""
        dec = (self.phase == DECODE) & (self.slot_budget > 0)
        if not dec.any():
            return None
        tokens = np.zeros((self.max_slots, 1), np.int32)
        for slot in np.nonzero(dec)[0]:
            res = self.results[self.slot_uid[slot]]
            if res.tokens:
                tokens[slot, 0] = res.tokens[-1]
            # a decode write to a prefix-shared page privatizes it first
            self._cow_pages(slot, int(self.slot_pos[slot]),
                            int(self.slot_pos[slot]) + 1)
        feed = jnp.asarray(tokens)
        if prev is not None:
            # double-buffer: the last sampled ids are still on device
            feed = jnp.where(jnp.asarray(prev.mask)[:, None],
                             prev.ids[:, None], feed)
        self.rng, k = jax.random.split(self.rng)
        with self._kernel_scope():
            ids, self.cache = self._decode_fn(
                self.params, self.cache, feed,
                jnp.asarray(self.slot_pos), jnp.asarray(dec), self._tables(),
                jnp.asarray(self.slot_temp), k)
        self.stats["decode_steps"] += 1
        self.slot_pos[dec] += 1
        self.slot_budget[dec] -= 1
        return _Pending(ids=ids, mask=dec, uids=self.slot_uid.copy())

    def _sync(self, p: _Pending):
        """Bring one dispatched decode step's sampled ids to host and run
        the bookkeeping: stream/append tokens, finish on eos or exhausted
        budget. Ids for requests that finished while the step was in
        flight (an eos discovered one sync earlier) are discarded — their
        slot was dispatched speculatively."""
        ids = np.asarray(p.ids)
        for slot in np.nonzero(p.mask)[0]:
            uid = int(p.uids[slot])
            res = self.results.get(uid)
            if (res is None or res.finish_reason
                    or self.slot_uid[slot] != uid):
                continue                    # speculative overflow step
            tok = int(ids[slot])
            self._emit(slot, tok)
            res.decode_steps += 1
            if self.eos_id is not None and tok == self.eos_id:
                self._finish(slot, "eos")
            elif self._emitted(slot) >= self._slot_req[slot].max_new_tokens:
                # emitted-count check, NOT slot_budget: with overlap the
                # budget already paid for the next in-flight dispatch
                self._finish(slot, "length")

    def _sync_pending(self):
        """Flush the overlapped decode step, if any (idempotent)."""
        p, self._pending = self._pending, None
        if p is not None:
            self._sync(p)

    # ---- engine loop ---------------------------------------------------
    def step(self) -> int:
        """Admit, advance prefill chunks, one decode step. Returns #busy."""
        self._admit()
        self._prefill_chunks()
        self._decode()
        if self.prefix_index is not None:
            self.stats["prefix_evictions"] = \
                self.prefix_index.stats["evictions"]
            # cached-block accounting: KV bytes held by refcount-0 pages
            # retained for future prefix hits (reclaimable, so they are
            # reported separately from kv_bytes_alloc)
            self.stats["kv_bytes_cached"] = (
                self.prefix_index.n_evictable(self.allocator)
                * self._block_kv_bytes)
        self.stats["sched_skips"] = self.scheduler.stats["skips"]
        return int((self.phase != FREE).sum())

    def _busy(self) -> bool:
        return (bool(self.scheduler) or bool((self.phase != FREE).any())
                or self._pending is not None)

    def _truncate(self):
        """Drain a run that hit ``max_steps``: flush the overlapped step so
        no sampled token is lost, finish every in-flight slot as
        ``truncated`` (blocks released — leak-free), and mark still-queued
        requests the same way. Partial tokens stay on the Result."""
        self._sync_pending()
        for slot in range(self.max_slots):
            if self.phase[slot] == FREE:
                continue
            res = self.results[self.slot_uid[slot]]
            res.detail = ("prefill interrupted at max_steps"
                          if self.phase[slot] == PREFILL
                          else "decode interrupted at max_steps")
            self._finish(slot, "truncated")
        for entry in self.scheduler.drain():
            res = self.results.get(entry.req.uid)
            self._admit_hashes.pop(entry.req.uid, None)
            if res is not None and not res.finish_reason:
                res.finish_reason = "truncated"
                res.detail = "still queued at max_steps"

    def run(self, requests: list[Request], *, max_steps: int = 100000
            ) -> list[Result]:
        """Drive all requests to completion (continuous batching loop).
        Hitting ``max_steps`` truncates cleanly: in-flight slots release
        their blocks and every unfinished request gets
        ``finish_reason="truncated"`` instead of a half-populated Result."""
        for r in requests:
            self.submit(r)
        steps = 0
        while self._busy() and steps < max_steps:
            self.step()
            steps += 1
        if self._busy():
            self._truncate()
        return [self.results[r.uid] for r in requests]

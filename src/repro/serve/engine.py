"""Serving engine: continuous batching over a paged (block-pool) KV cache.

The engine owns a fixed pool of ``max_slots`` sequence slots sharing one
batched KV/recurrent cache. Two cache layouts:

* **dense** — every slot statically reserves ``max_len`` KV rows.
* **paged** (``paged=True`` / ``cfg.paged_kv``) — full-attention KV lives in
  a global pool of ``page_size``-row blocks handed out by a
  :class:`BlockAllocator`; admission is gated on free *blocks* for the
  request's ``len(prompt) + max_new_tokens`` tokens, so KV memory tracks
  actual sequence lengths instead of ``max_slots × max_len``. This is the
  serving analogue of Occamy's banked-TCDM + ROB-less NI memory story: many
  independent in-flight streams over fixed-size blocks, no per-stream
  worst-case reservation. Blocks free the moment a request finishes.

With ``prefix_cache=True`` (``cfg.prefix_cache``), fully-written prompt
pages are published into a :class:`repro.serve.prefix.PrefixIndex` (page-
granular chain hashes); a later request whose prompt shares the prefix maps
those blocks read-only into its block table (refcount++), skips prefill for
the matched pages, and chunk-prefills only the tail from ``first_new_pos``.
Writes to a shared block privatize it first (copy-on-write: fresh block,
jitted page copy, table remap). Finished requests leave their indexed pages
resident as refcount-0 *cached* blocks, reclaimed LRU under pool pressure —
so a hot system prompt's KV survives between requests at zero steady-state
cost. All-full-attention configs only (ring/recurrent per-slot state cannot
be restored from the pool); incapable configs serve cold.

Admission order is owned by a :class:`repro.serve.scheduler.Scheduler`
policy layer: priority classes, per-request SLO deadlines (TTFT targets go
earliest-deadline-first once urgent), multi-tenant fair queuing over
``Request.user``, and — the head-of-line fix — *skip-with-aging*: a request
blocked on pool resources is skipped in favor of smaller ones that fit now,
until aging promotes it to a reservation nothing may overtake. With
``preemption=True`` a high-priority arrival that cannot get blocks evicts a
lower-priority victim: the victim's fully-written pages are published into
the prefix index (when enabled), its blocks released through the refcount
path, and the request requeued with its generated tokens folded into the
prompt — resumption chunk-prefills only the un-cached tail via
``first_new_pos``, so preemption costs a warm prefix hit, not a byte swap.

Prefill is **chunked**: prompts advance ``prefill_chunk`` tokens per engine
step through one jitted ``extend_step`` graph (ragged tails ride in the same
shape behind an ``n_valid`` scalar), interleaved with decode steps for the
already-running slots — one compiled prefill shape regardless of prompt
length, and no prefill head-of-line blocking of the decode pool. Enc-dec
(audio) and vlm requests, and SPMD serving (``part``), keep the legacy
whole-prompt prefill path (jit per distinct length).

Sampling is fused into the jitted step (per-slot temperatures + PRNG key as
inputs): each ``step()`` syncs only the sampled token ids to host, never the
``(max_slots, vocab)`` logits. Cache buffers are donated through every
jitted update, so admission/decode cost scales with the written region, not
the pool. With ``overlap=True`` the decode loop double-buffers: step N+1 is
dispatched on device (fed step N's sampled ids *as a device array*) before
step N's ids are synced to host, so host bookkeeping and admission overlap
device compute — token streams are identical, ids just reach callbacks one
step later.

Tokens stream out as they are sampled: every append stamps a
``perf_counter`` timestamp into ``Result.token_ts`` and fires the request's
``on_token`` callback; :meth:`ServeEngine.stream` wraps submit+step into a
per-request iterator.
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field, replace as dc_replace
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import dispatch as kdispatch
from repro.models import decode_step, extend_step, forward, logits_fn, \
    verify_step
from repro.models.cache import copy_block, default_n_blocks, init_cache, \
    kv_bytes, n_blocks_for_bytes, pages_per_slot
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER
from repro.quant import is_quant_dtype, quantize_params
from repro.serve.prefix import PrefixIndex, page_hashes
from repro.serve.scheduler import Scheduler
from repro.spec import DraftWorker, sample_tokens, speculative_accept
from repro.spec.sampling import (P_ACCEPT as _P_ACCEPT,
                                 P_FORK as _P_FORK,
                                 P_SAMPLE as _P_SAMPLE,
                                 fold_keys as _fold_keys)

PyTree = Any

#: Slot lifecycle: FREE -> PREFILL (chunked) -> DECODE -> FREE.
FREE, PREFILL, DECODE = 0, 1, 2


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                      # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0                # 0 => greedy
    top_k: int = 0                          # 0 => no top-k filter
    top_p: float = 1.0                      # >= 1 => no nucleus filter
    #: parallel sampling: fork the prefilled slot into n sequences that
    #: share all common KV pages copy-on-write (paged all-full configs);
    #: children land on ``Result.children``
    n: int = 1
    #: per-request PRNG seed: the sampling stream depends only on
    #: (seed, step) — not on pool co-residents or admission order
    seed: int | None = None
    frames: np.ndarray | None = None        # enc-dec (audio) models
    extra_embeds: np.ndarray | None = None  # vlm models
    # scheduling (repro.serve.scheduler)
    priority: int = 0                       # larger = more urgent
    user: str | None = None                 # tenant for fair queuing
    slo_ttft_ms: float | None = None        # time-to-first-token target
    slo_itl_ms: float | None = None         # mean inter-token target
    #: streaming callback, called as ``on_token(token, result)`` the moment
    #: each token reaches the host (with overlap, one step after sampling)
    on_token: Callable[[int, "Result"], None] | None = None


@dataclass
class Result:
    uid: int
    tokens: list[int] = field(default_factory=list)
    finish_reason: str = ""                 # eos | length | rejected | truncated
    detail: str = ""                        # rejection/truncation cause
    prefill_s: float = 0.0
    decode_steps: int = 0
    submit_s: float = 0.0                   # perf_counter at submit
    token_ts: list[float] = field(default_factory=list)  # one per token
    preempted: int = 0                      # times evicted and requeued
    slo_met: bool | None = None             # None = request had no SLO
    #: parallel sampling (``Request.n > 1``): one Result per forked child,
    #: in fork order — the parent's own tokens stay on this Result
    children: list["Result"] = field(default_factory=list)

    @property
    def ttft_s(self) -> float | None:
        """Submit-to-first-token latency (queueing + prefill)."""
        return (self.token_ts[0] - self.submit_s) if self.token_ts else None

    @property
    def itl_s(self) -> float | None:
        """Mean inter-token latency over the decoded tokens."""
        if len(self.token_ts) < 2:
            return None
        return (self.token_ts[-1] - self.token_ts[0]) / (len(self.token_ts) - 1)


class BlockAllocator:
    """Refcounted free-list allocator over the global KV block pool.

    Block 0 is the *null block*: never handed out, it absorbs the dropped
    writes of inactive slots and ragged prefill tails (their scatter indices
    route out of bounds / to the null entry instead of another stream's
    data — the block-pool equivalent of writing into a scratch bank).

    Every other block is in exactly one of three states:

    * **free** — on the free list, refcount 0;
    * **live** — refcount >= 1: owned by one slot, or *shared* read-only by
      several slots through the prefix cache (``incref`` per sharer; a write
      to a shared block must copy-on-write first);
    * **cached** — refcount 0 but pinned by the :class:`PrefixIndex`
      (``evictor``): retained after its last owner finished so future
      prefix hits can adopt it, evictable LRU under pool pressure.

    ``alloc`` is transactional: if the grant cannot be completed — even
    after asking the evictor to reclaim cached blocks — every block already
    popped is rolled back onto the free list before the error propagates,
    so a partial failure never leaks blocks.
    """

    def __init__(self, n_blocks: int, page_size: int, n_shards: int = 1,
                 metrics=None):
        from repro.obs.metrics import MetricsRegistry
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        # conservation invariant (tests/test_allocator_props.py):
        # blocks_granted - blocks_released == n_live + cached
        self._c_granted = self.metrics.counter(
            "blocks_granted", "blocks removed from the free list by alloc()")
        self._c_released = self.metrics.counter(
            "blocks_released", "blocks returned to the free list")
        self._c_adopted = self.metrics.counter(
            "blocks_adopted", "cached blocks revived to live by incref()")
        self.n_blocks = n_blocks
        self.page_size = page_size
        #: mesh shards the pool tensors are split over (serve-mode KV-head
        #: sharding). Block ids are *global*: every shard holds rows
        #: ``1/n_shards`` of each block, so one grant is implicitly a
        #: transaction of ``n_shards`` per-shard sub-grants that commit and
        #: roll back atomically — the single free list IS the cross-shard
        #: transaction log, and budgets are per-shard by construction
        #: (every device pays ``block_bytes / n_shards`` per granted block).
        self.n_shards = max(1, n_shards)
        self._free = list(range(n_blocks - 1, 0, -1))
        self.ref = np.zeros(n_blocks, np.int32)
        self.evictor = None      # PrefixIndex (or None): reclaims cached

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        """Distinct blocks with refcount >= 1."""
        return int((self.ref > 0).sum())

    @property
    def n_evictable(self) -> int:
        return 0 if self.evictor is None else self.evictor.n_evictable(self)

    @property
    def n_available(self) -> int:
        """Blocks an ``alloc`` could obtain right now (free + evictable)."""
        return self.n_free + self.n_evictable

    @property
    def capacity(self) -> int:
        return self.n_blocks - 1

    def pages_for(self, n_tokens: int) -> int:
        return pages_per_slot(n_tokens, self.page_size)

    def alloc(self, n: int) -> list[int]:
        """Grant ``n`` fresh blocks at refcount 1, evicting cached blocks
        as needed; rolls the partial grant back cleanly on failure."""
        got: list[int] = []
        try:
            for _ in range(n):
                if not self._free and self.evictor is not None:
                    self.evictor.evict_one(self)
                if not self._free:
                    raise RuntimeError(
                        f"allocator exhausted: want {n}, free {self.n_free} "
                        f"(+{self.n_evictable} evictable)")
                blk = self._free.pop()
                if self.ref[blk] != 0:      # corrupted free list
                    self._free.append(blk)
                    raise RuntimeError(f"free-list block {blk} has "
                                       f"refcount {int(self.ref[blk])}")
                self.ref[blk] = 1
                got.append(blk)
        except Exception:
            for blk in reversed(got):
                self.ref[blk] = 0
                self._free.append(blk)
            raise
        self._c_granted.inc(len(got))
        return got

    def incref(self, block: int) -> None:
        """Adopt a cached block (0 -> 1) or add a sharer to a live one."""
        if not 0 < block < self.n_blocks:
            raise ValueError(f"invalid block id {block}")
        if (self.evictor is not None and self.ref[block] == 0
                and self.evictor.is_cached(block)):
            self.evictor.note_adopted(block)     # cached -> live
            self._c_adopted.inc()
        self.ref[block] += 1

    def decref(self, block: int, *, retain: bool = False) -> int:
        """Drop one reference. At refcount 0 the block returns to the free
        list unless ``retain`` (the prefix index keeps it cached). Returns
        the new refcount; a double free raises instead of corrupting."""
        r = int(self.ref[block]) - 1
        if r < 0:
            raise RuntimeError(f"double free of block {block}")
        self.ref[block] = r
        if r == 0:
            if not retain:
                self._free.append(block)
                self._c_released.inc()
            elif self.evictor is not None:
                self.evictor.note_cached(block)  # live -> cached
        return r

    def free_block(self, block: int) -> None:
        """Return an (evicted, refcount-0) block to the free list."""
        if self.ref[block] != 0:
            raise RuntimeError(f"freeing live block {block} "
                               f"(refcount {int(self.ref[block])})")
        self._free.append(block)
        self._c_released.inc()

    def release(self, blocks: list[int]) -> None:
        """Drop one reference on each block; blocks pinned by the evictor
        (prefix index) are retained as cached instead of freed."""
        for blk in blocks:
            retain = (self.evictor is not None
                      and self.evictor.is_cached(blk))
            self.decref(blk, retain=retain)


def _sample(logits, temps, top_k, top_p, keys):
    """Fused on-device sampler: greedy rows where temp <= 0, top-k/top-p
    filtered temperature sampling otherwise, one PRNG key per row. Runs
    inside the jitted step: only sampled ids reach the host."""
    return sample_tokens(logits, temps, top_k, top_p, keys)


@dataclass
class _Pending:
    """One dispatched-but-unsynced decode step (overlap double-buffer)."""
    ids: Any                 # (max_slots,) int32 device array
    mask: np.ndarray         # slots this dispatch decoded
    uids: np.ndarray         # slot -> uid snapshot at dispatch time


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: PyTree, *, max_slots: int = 4,
                 max_len: int = 512, eos_id: int | None = None, seed: int = 0,
                 part=None, kernel_backend: str | None = None,
                 paged: bool | None = None, page_size: int | None = None,
                 prefill_chunk: int | None = None,
                 max_blocks: int | None = None,
                 kv_budget_bytes: int | None = None,
                 prefix_cache: bool | None = None,
                 prefix_lru: int | None = None,
                 sched: str | None = None,
                 sched_aging: int | None = None,
                 preemption: bool | None = None,
                 overlap: bool | None = None,
                 draft_model: "ModelConfig | str | None" = None,
                 draft_params: PyTree | None = None,
                 spec_k: int | None = None,
                 split_pools: bool | None = None,
                 prefill_slots: int | None = None,
                 metrics: "MetricsRegistry | None" = None,
                 tracer=None):
        self.cfg, self.params = cfg, params
        self.max_slots, self.max_len = max_slots, max_len
        self.eos_id = eos_id
        self.part = part
        # observability: one shared metrics registry (allocator, prefix
        # index, and scheduler register into it) + a lifecycle tracer.
        # The default NULL_TRACER is a no-op hook — call sites emit
        # unconditionally, disabled tracing costs one empty method call.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.trace = tracer if tracer is not None else NULL_TRACER
        self.paged = cfg.paged_kv if paged is None else paged
        self.page_size = page_size or cfg.page_size
        self.prefill_chunk = prefill_chunk or cfg.prefill_chunk
        # prefix caching shares fully-written prompt pages of the block pool
        # across requests (refcounted, copy-on-write). It requires every
        # cacheable layer state to live in the paged pools, so it is gated
        # on all-full-attention decoder configs: sliding-window rings and
        # recurrent carries are per-slot dense state a prefix hit cannot
        # restore. Incapable configs silently serve cold (prefix_hits == 0)
        # rather than erroring — the flag is a throughput hint, not a
        # semantics change.
        want_prefix = (cfg.prefix_cache if prefix_cache is None
                       else prefix_cache)
        self.prefix_capable = (self.paged and cfg.encoder is None
                               and all(sp.mixer == "full"
                                       for sp in cfg.all_layers()))
        self.prefix_cache = bool(want_prefix) and self.prefix_capable
        self.prefix_lru = (cfg.prefix_lru if prefix_lru is None
                           else prefix_lru)
        if self.prefix_lru < 0:     # engine kwarg / --prefix-lru bypasses
            raise ValueError("prefix_lru must be >= 0")
        # SPMD serving: a serve-mode Partitioner shards the paged KV pools
        # (and their per-row quant scales) over the model axis by KV head;
        # everything per-slot — block tables, lengths, sampling state —
        # stays replicated host metadata. ``kv_shard`` > 1 is the capacity
        # dividend: each device holds 1/kv_shard of every block.
        self._kv_shard = 1
        if self.paged and part is not None:
            if getattr(part, "mode", None) != "serve":
                raise ValueError(
                    "paged SPMD serving needs a serve-mode Partitioner "
                    "(Partitioner(..., mode='serve')): training-mode rules "
                    "shard batch/seq dims the block pool does not have")
            self._kv_shard = int(getattr(part, "kv_shard", 1))
        # scheduling policy layer: admission order, SLOs, fairness, aging
        self.scheduler = Scheduler(
            sched or cfg.sched_policy,
            aging_skips=cfg.sched_aging if sched_aging is None
            else sched_aging, metrics=self.metrics)
        self.preemption = cfg.preemption if preemption is None else preemption
        if self.preemption and not self.paged:
            raise ValueError("preemption requires the paged (block-pool) "
                             "layout: dense slots hold no reclaimable blocks")
        self.overlap = cfg.overlap_decode if overlap is None else overlap
        # multi-precision serving (repro.quant): post-load weight
        # quantization keyed off cfg.weight_dtype, applied here so callers
        # need no separate transform step. Under a serve-mode Partitioner
        # the quantized params are simply replicated (serve rules shard only
        # the KV pools), so the combination is fine.
        if cfg.weight_dtype:
            self.params = quantize_params(params, cfg)
        if is_quant_dtype(cfg.kv_dtype):
            if not self.paged:
                raise ValueError(
                    "kv_dtype requires the paged (block-pool) cache layout: "
                    "per-row scales live alongside the pools")
            if cfg.encoder is not None:
                raise ValueError(
                    "quantized KV does not support enc-dec models: the "
                    "whole-prompt prefill commit path writes dense rows")
        # kernel selection for the engine's jitted graphs: explicit arg >
        # cfg.kernel_backend; block tuning comes from the strategy when
        # serving under a Partitioner. Fixed for the engine's lifetime (the
        # scope must be active whenever a prefill/decode graph traces).
        self.kernel_backend = (kernel_backend or cfg.resolved_kernel_backend
                               or None)
        strat = getattr(part, "strategy", None)
        self._kernel_blocks = (kdispatch.blocks_from_pairs(strat.kernel_blocks)
                               if strat is not None and strat.kernel_blocks
                               else None)
        #: engine-level base key: per-request streams are derived from it
        #: by folding the request uid (or replaced by ``Request.seed``)
        self._base_key = np.asarray(jax.random.PRNGKey(seed), np.uint32)
        # speculative decoding: a small draft model proposes spec_k tokens
        # per turn; the verifier scores all of them plus one bonus position
        # in a single batched verify_step pass (see repro.spec)
        dm = draft_model if draft_model is not None else (
            cfg.draft_model or None)
        if isinstance(dm, str):
            from repro.configs import get_arch
            dm = get_arch(dm)
        self.spec_k = int(cfg.spec_k if spec_k is None else spec_k)
        self._draft_cfg = dm
        self.draft = None
        if dm is not None:
            if not self.prefix_capable:
                raise ValueError(
                    "speculative decoding requires the paged local "
                    "all-full-attention path: verify_step rolls uncommitted "
                    "rows back through the block allocator")
            if self.overlap:
                raise ValueError(
                    "speculative decoding and overlap_decode are exclusive: "
                    "the spec turn already overlaps draft and verifier work")
            if dm.vocab_size != cfg.vocab_size:
                raise ValueError(
                    "draft model must share the verifier's vocabulary: "
                    f"{dm.vocab_size} != {cfg.vocab_size}")
            if self.spec_k < 1:
                self.spec_k = 4
        if self.paged:
            if kv_budget_bytes is not None:
                # size the pool by HBM budget through the cache's sizing
                # helper: the narrower the KV dtype, the more blocks the
                # same budget admits (dense-equivalent count is the cap)
                n_blocks = min(
                    n_blocks_for_bytes(cfg, kv_budget_bytes, self.page_size,
                                       kv_shard=self._kv_shard),
                    default_n_blocks(max_slots, max_len, self.page_size))
            else:
                n_blocks = (max_blocks or cfg.max_blocks
                            or default_n_blocks(max_slots, max_len,
                                                self.page_size))
            # pool leaves must be distinguishable from batch-sized leaves,
            # and a pool smaller than the slot count cannot serve anyway
            self.n_blocks = max(n_blocks, max_slots + 1)
            self.allocator = BlockAllocator(self.n_blocks, self.page_size,
                                            n_shards=self._kv_shard,
                                            metrics=self.metrics)
            if self.prefix_cache:
                self.prefix_index = PrefixIndex(self.page_size,
                                                max_cached=self.prefix_lru,
                                                metrics=self.metrics)
                self.allocator.evictor = self.prefix_index
            else:
                self.prefix_index = None
            self.n_pages = pages_per_slot(max_len, self.page_size)
            self.block_tables = np.zeros((max_slots, self.n_pages), np.int32)
            self.cache = init_cache(cfg, max_slots, max_len,
                                    n_blocks=self.n_blocks,
                                    page_size=self.page_size)
            self._cache_shardings = None
            if part is not None:
                # place pool leaves sharded by KV head over the model axis,
                # everything else replicated, and pin the layout so donation
                # round-trips through the jitted updates keep it stable
                self._cache_shardings = part.serve_cache_sharding(
                    self.cache, self.n_blocks)
                self.cache = jax.device_put(self.cache, self._cache_shardings)
                self.params = jax.device_put(
                    self.params, part.params_sharding(self.params))
            pool = kv_bytes(self.cache, pool_n_blocks=self.n_blocks)
            self._block_kv_bytes = pool // self.n_blocks
            # ring buffers / recurrent-adjacent dense KV still charge per slot
            self._slot_kv_bytes = (kv_bytes(self.cache) - pool) // max_slots
        else:
            self.allocator = None
            self.prefix_index = None
            self.n_blocks = 0
            self.block_tables = None
            self.cache = init_cache(cfg, max_slots, max_len)
            self._cache_shardings = None
            self._block_kv_bytes = 0
            self._slot_kv_bytes = kv_bytes(self.cache) // max_slots
        # disaggregated prefill/decode pools: the first ``prefill_slots``
        # slots chunk-prefill only; completed prompts hand their KV off to a
        # decode-pool slot purely by republishing pages through the block
        # table (a host-side int32 row copy — zero tensor traffic).
        self.split_pools = (cfg.split_pools if split_pools is None
                            else split_pools)
        n_pre = (cfg.prefill_slots if prefill_slots is None
                 else prefill_slots)
        if self.split_pools:
            if not self.paged:
                raise ValueError("split_pools requires the paged layout: "
                                 "the handoff republishes pool pages")
            if n_pre <= 0:
                n_pre = max(1, max_slots // 4)
            if not 0 < n_pre < max_slots:
                raise ValueError(
                    f"prefill_slots must leave both pools non-empty: "
                    f"{n_pre} of {max_slots} slots")
        self.prefill_slots = n_pre if self.split_pools else 0
        #: slot -> pool id (1 = prefill pool, 0 = decode pool / unified)
        self._slot_pool = np.zeros(max_slots, np.int8)
        if self.split_pools:
            self._slot_pool[:self.prefill_slots] = 1
        #: prefill-pool slots whose prompt is fully written, awaiting a
        #: decode-pool slot for the block-table handoff
        self._handoff_ready: set[int] = set()
        # slot bookkeeping (host side)
        self.phase = np.full(max_slots, FREE, np.int8)
        self.slot_uid = np.full(max_slots, -1, np.int64)
        #: next KV write position per slot — advanced at *dispatch* time, so
        #: with overlap it can run one step ahead of the synced token lists
        self.slot_pos = np.zeros(max_slots, np.int32)
        self.slot_budget = np.zeros(max_slots, np.int32)
        self.slot_temp = np.zeros(max_slots, np.float32)
        self.slot_blocks: list[list[int]] = [[] for _ in range(max_slots)]
        self._prefilling: dict[int, Request] = {}        # slot -> request
        self._admit_hashes: dict[int, list[int]] = {}    # uid -> page hashes
        self._prefill_off = np.zeros(max_slots, np.int32)
        #: absolute position of the first non-prefix-cached token per slot —
        #: chunked prefill starts here; everything below it was mapped
        #: read-only from shared blocks
        self._first_new = np.zeros(max_slots, np.int32)
        self._t0 = np.zeros(max_slots, np.float64)
        # per-slot scheduling state (preemption victims, requeue identity)
        self._slot_req: list[Request | None] = [None] * max_slots
        self._slot_legacy = np.zeros(max_slots, bool)
        self._slot_prio = np.zeros(max_slots, np.int32)
        self._slot_seq = np.zeros(max_slots, np.int64)   # admission recency
        self._slot_sched_seq = np.zeros(max_slots, np.int64)
        #: len(res.tokens) at admission — length finishes compare *emitted*
        #: tokens against the segment budget, because with overlap
        #: ``slot_budget`` is decremented at dispatch and runs one
        #: speculative step ahead of the synced token list
        self._slot_tok0 = np.zeros(max_slots, np.int64)
        # per-request sampling state: top-k/top-p knobs, PRNG base key and
        # dispatch counter (the in-jit key is fold(base, ctr, purpose))
        self.slot_topk = np.zeros(max_slots, np.int32)
        self.slot_topp = np.ones(max_slots, np.float32)
        self._slot_key = np.zeros((max_slots, 2), np.uint32)
        self._slot_ctr = np.zeros(max_slots, np.int64)
        #: token fed on a slot's first decode when nothing was emitted yet
        #: (fork children re-decode the prompt's last row to diverge)
        self._slot_feed = np.zeros(max_slots, np.int32)
        #: fork-family membership: parents with reserved children and the
        #: children themselves are never preemption victims (their shared
        #: refcounts would outlive the eviction)
        self._slot_fork = np.zeros(max_slots, bool)
        self._slot_children: dict[int, list[int]] = {}
        #: pages granted at admission — speculative extras roll back to this
        self._slot_base_pages = np.zeros(max_slots, np.int64)
        self._slot_first = np.zeros(max_slots, np.int32)
        self._next_child_uid = -2
        self._admit_seq = 0
        self._pending: _Pending | None = None
        self.results: dict[int, Result] = {}
        self._prefill_cache: dict[tuple, Any] = {}
        self._decode_fn = jax.jit(self._decode_all, donate_argnums=(1,))
        self._commit_fn = jax.jit(self._commit_slot, donate_argnums=(0,))
        self._chunk_fn = None
        self._copy_fn = jax.jit(
            lambda cache, src, dst: self._pin(
                copy_block(cache, src, dst, self.n_blocks)),
            donate_argnums=(0,))
        # the historical ``stats`` dict, rebuilt on the metrics registry:
        # every legacy key is a registered Counter/Gauge and ``self.stats``
        # is a dict-compatible live view over the registry (so
        # ``stats[k] += 1``, ``dict(engine.stats)``, and per-key reads all
        # behave exactly as before). Counters the scheduler / prefix index
        # own (``sched_skips``, ``prefix_evictions``) are the *same*
        # instrument objects — no per-step mirroring.
        for name, help_ in (
                ("prefills", "prompts placed into a slot"),
                ("decode_steps", "decode dispatches (batched steps)"),
                ("prefill_chunks", "chunked-prefill extend_step dispatches"),
                ("prefill_recompiles", "distinct compiled prefill shapes"),
                ("rejected", "requests rejected at submit/admission"),
                ("kv_bytes_alloc", "KV bytes allocated (global, lifetime)"),
                ("prefix_hits", "admissions that matched cached prefix pages"),
                ("prefix_hit_tokens", "prompt tokens skipped via prefix hits"),
                ("prefix_cow", "copy-on-write block privatizations"),
                ("prefix_evictions",
                 "cached blocks reclaimed to the free list"),
                ("preemptions", "slots evicted for higher-priority arrivals"),
                ("sched_skips",
                 "admission passes that overtook a blocked entry"),
                ("slo_met", "finished requests inside their SLO targets"),
                ("slo_missed", "finished requests outside their SLO targets"),
                ("spec_turns", "speculative draft+verify turns"),
                ("spec_proposed", "draft tokens proposed"),
                ("spec_accepted", "draft tokens accepted (incl. bonus)"),
                ("spec_extra_blocks", "blocks granted for spec overflow"),
                ("forks", "parallel-sampling fork fan-outs"),
                ("fork_shared_blocks", "prompt blocks shared COW at fork"),
                ("fork_fresh_blocks", "fresh blocks granted to fork children"),
                ("handoffs", "prefill->decode pool block-table handoffs"),
                ("handoff_wait_steps",
                 "steps a finished prefill waited for a decode slot"),
                ("decode_gap_steps",
                 "steps with queued work but no decode dispatched"),
                ("decode_window_tokens",
                 "tokens committed inside measured decode windows"),
                ("decode_window_batch",
                 "sum over decode dispatches of active slots"),
                ("decode_window_kv_rows",
                 "sum over decode dispatches of context rows attended"),
        ):
            self.metrics.counter(name, help_)
        for name, help_ in (
                ("kv_bytes_cached", "refcount-0 bytes retained by the index"),
                ("kv_bytes_alloc_dev", "per-device share of kv_bytes_alloc"),
                ("max_concurrency", "peak concurrently-active slots"),
        ):
            self.metrics.gauge(name, help_)
        self._h_decode_window = self.metrics.histogram(
            "decode_window_s",
            "measured wall seconds per engine step that dispatched decode "
            "work (joined against roofline/memfloor by repro.obs.report)")
        self._h_spec_accept = self.metrics.histogram(
            "spec_accept_len", "accepted tokens per speculative turn",
            buckets=tuple(float(b) for b in range(0, 17)))
        self._c_win_tokens = self.metrics.counter("decode_window_tokens")
        self._c_win_batch = self.metrics.counter("decode_window_batch")
        self._c_win_kv = self.metrics.counter("decode_window_kv_rows")
        self._c_finished = self.metrics.counter(
            "finished", "requests finished, by reason", labels=("reason",))
        self.stats = self.metrics.view()
        if self._draft_cfg is not None:
            self.draft = DraftWorker(
                self._draft_cfg, draft_params, max_slots=max_slots,
                max_len=max_len, k=self.spec_k,
                prefill_chunk=self.prefill_chunk, seed=seed + 1)
            self._spec_fn = jax.jit(self._spec_verify, donate_argnums=(1,))

    # ------------------------------------------------------------------
    @property
    def active(self) -> np.ndarray:
        """Slots currently owned by a request (prefilling or decoding)."""
        return self.phase != FREE

    @property
    def queue(self) -> list[Request]:
        """Waiting requests in arrival order (scheduler-owned)."""
        return [e.req for e in self.scheduler.entries()]

    def _kernel_scope(self):
        """Backend/block-tuning scope for prefill and decode graphs. SPMD
        serving never opens a kernel scope: forward/decode_step would
        neutralize it anyway (no pallas_call inside pjit)."""
        if self.part is not None:
            return contextlib.nullcontext()
        if self.kernel_backend or self._kernel_blocks:
            return kdispatch.use_backend(self.kernel_backend,
                                         blocks=self._kernel_blocks)
        return contextlib.nullcontext()

    def _tables(self):
        return jnp.asarray(self.block_tables) if self.paged else None

    def _pin(self, cache):
        """Pin a jitted graph's output cache to the serve shardings so the
        donation round-trip keeps a stable (sharded-pool) layout across
        engine steps instead of letting propagation reshard per graph."""
        if self._cache_shardings is None:
            return cache
        return self.part.serve_cache_constraint(cache, self._cache_shardings)

    # ---- jitted graphs ------------------------------------------------
    def _decode_all(self, params, cache, tokens, pos, active, tables, temps,
                    topk, topp, keys, ctrs):
        """One decode step over the whole slot pool + fused sampling."""
        logits, cache = decode_step(params, self.cfg, cache, tokens, pos,
                                    part=self.part, active=active,
                                    block_tables=tables)
        kk = _fold_keys(keys, ctrs, _P_SAMPLE)
        return _sample(logits[:, 0], temps, topk, topp, kk), self._pin(cache)

    def _chunk_step(self, params, cache, tokens, pos, n_valid, slot, tables,
                    temp, topk, topp, key, ctr, first_new):
        """One chunked-prefill step for one slot + fused sampling (the
        sampled id only matters on the final chunk). ``first_new`` (traced
        scalar) is the absolute position prefill started at — positions
        below it come from prefix-shared blocks."""
        logits, cache = extend_step(params, self.cfg, cache, tokens, pos,
                                    n_valid, slot, block_tables=tables,
                                    first_new_pos=first_new, part=self.part)
        kk = _fold_keys(key[None], ctr[None], _P_SAMPLE)
        return _sample(logits[:, 0], temp[None], topk[None], topp[None],
                       kk), self._pin(cache)

    def _spec_verify(self, params, cache, feed, draft_toks, draft_probs,
                     pos, n_valid, active, tables, temps, topk, topp, keys,
                     ctrs):
        """One speculative verify turn, fully in-jit: score the last
        committed token plus the k draft proposals in a single batched
        ``verify_step`` pass, then run the distribution-preserving
        acceptance rule. Returns (out_tokens (B, k+1), n_accept (B), cache);
        only the committed prefix of ``out_tokens`` reaches the results."""
        toks = jnp.concatenate([feed, draft_toks], axis=1)
        logits, cache = verify_step(params, self.cfg, cache, toks, pos,
                                    n_valid, active=active,
                                    block_tables=tables, part=self.part)
        kk = _fold_keys(keys, ctrs, _P_ACCEPT)
        out, n_acc = speculative_accept(logits, draft_toks, draft_probs,
                                        temps, topk, topp, kk,
                                        n_draft=n_valid - 1)
        return out, n_acc, self._pin(cache)

    def _commit_slot(self, cache, slot_cache, slot, tables):
        """Write a batch-1 dense prefill cache into slot ``slot`` of the
        pooled cache (donated: cost scales with the written region). Paged
        pool leaves take the slot's rows through its block table; everything
        else (dense KV, ring buffers, recurrent states, cross caches) is a
        dynamic-slice update at the slot index."""
        page = self.page_size

        def f(path, b, s):
            keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
            axis = 1 if "blocks" in keys else 0
            if self.paged and b.shape[axis] == self.n_blocks:
                s_buf = s.shape[axis + 1]
                rows = jnp.arange(s_buf)
                trow = jax.lax.dynamic_slice(
                    tables, (slot, 0), (1, tables.shape[1]))[0]
                blk = trow[rows // page]
                r = rows % page
                if axis == 0:
                    return b.at[blk, r].set(s[0].astype(b.dtype), mode="drop")
                return b.at[:, blk, r].set(s[:, 0].astype(b.dtype),
                                           mode="drop")
            start = tuple(slot if i == axis else 0 for i in range(b.ndim))
            return jax.lax.dynamic_update_slice(b, s.astype(b.dtype), start)

        return jax.tree_util.tree_map_with_path(f, cache, slot_cache)

    def _ensure_chunk_fn(self):
        if self._chunk_fn is None:
            # one compiled shape serves every chunk of every prompt length
            self.stats["prefill_recompiles"] += 1
            self._chunk_fn = jax.jit(self._chunk_step, donate_argnums=(1,))
        return self._chunk_fn

    def _prefill_fn(self, length: int, has_frames: bool, has_extra: bool):
        """Legacy whole-prompt prefill (enc-dec / vlm / SPMD): jit per
        distinct prompt length — exactness over the recompile."""
        key = (length, has_frames, has_extra)
        if key not in self._prefill_cache:
            self.stats["prefill_recompiles"] += 1

            def fn(params, tokens, frames, extra):
                cache_t = init_cache(self.cfg, 1, self.max_len)
                hidden, cache, _ = forward(params, self.cfg, tokens,
                                           frames=frames, extra_embeds=extra,
                                           cache=cache_t, part=self.part)
                logits = logits_fn(params, self.cfg, hidden[:, -1:, :],
                                   self.part)[..., :self.cfg.vocab_size]
                return logits[:, 0], cache

            self._prefill_cache[key] = jax.jit(fn)
        return self._prefill_cache[key]

    # ---- streaming ----------------------------------------------------
    def submit(self, req: Request):
        self.results[req.uid] = Result(uid=req.uid,
                                       submit_s=time.perf_counter())
        self.scheduler.submit(req)
        self.trace.begin("request", req.uid,
                         prompt_tokens=len(req.prompt),
                         max_new=req.max_new_tokens)
        self.trace.begin("queue", req.uid)
        self.trace.event("submit", req.uid)

    def stream(self, req: Request, *, max_steps: int = 100000
               ) -> Iterator[int]:
        """Submit ``req`` and yield its tokens as they arrive, stepping the
        engine (and any other in-flight requests) between yields."""
        self.submit(req)
        res = self.results[req.uid]
        sent = steps = 0
        while True:
            while sent < len(res.tokens):
                yield res.tokens[sent]
                sent += 1
            if res.finish_reason:
                return
            if steps >= max_steps:
                self._truncate()
                continue
            self.step()
            steps += 1

    def _emit(self, slot: int, tok: int):
        """Append one sampled token to the slot's result: timestamped for
        TTFT/ITL accounting, streamed through the request's callback."""
        res = self.results[self.slot_uid[slot]]
        res.tokens.append(tok)
        res.token_ts.append(time.perf_counter())
        req = self._slot_req[slot]
        if req is not None and req.on_token is not None:
            req.on_token(tok, res)

    # ---- scheduling ----------------------------------------------------
    def _reject(self, req: Request, why: str):
        """Graceful per-request rejection: the engine loop keeps serving."""
        res = self.results[req.uid]
        res.finish_reason = "rejected"
        res.detail = why
        self._admit_hashes.pop(req.uid, None)
        self.stats["rejected"] += 1
        self._c_finished.inc(reason="rejected")
        self.trace.event("reject", req.uid, why=why[:120])
        self.trace.close_open(req.uid, reason="rejected")

    def _cow_pages(self, slot: int, lo: int, hi: int) -> None:
        """Copy-on-write guard before writing positions ``[lo, hi)`` of
        ``slot``: any touched page whose block is shared (refcount > 1) or
        pinned by the prefix index gets a private copy first (fresh block,
        jitted page copy, table remap). Admission already privatizes the one
        boundary page a prefix hit can write, so this keeps 'writers never
        touch shared blocks' true by construction rather than by scheduling
        luck. Fork children lean on the same guard: their shared prompt
        pages carry refcount > 1 whether or not a prefix index exists."""
        if not self.paged or hi <= lo:
            return
        page = self.page_size
        for p in range(lo // page, (hi - 1) // page + 1):
            blk = int(self.block_tables[slot, p])
            if blk == 0:
                continue
            if (self.allocator.ref[blk] > 1
                    or (self.prefix_index is not None
                        and self.prefix_index.is_cached(blk))):
                [dst] = self.allocator.alloc(1)
                self.cache = self._copy_fn(self.cache, np.int32(blk),
                                           np.int32(dst))
                self.allocator.release([blk])
                self.slot_blocks[slot][
                    self.slot_blocks[slot].index(blk)] = dst
                self.block_tables[slot, p] = dst
                self.stats["prefix_cow"] += 1
                self.trace.event("cow", int(self.slot_uid[slot]), slot=slot,
                                 page=p)

    # ---- preemption ----------------------------------------------------
    def _preempt_for(self, prio: int, pool: int | None = None) -> bool:
        """Free resources for a priority-``prio`` arrival: evict one victim
        slot of strictly lower priority (lowest class first, then the most
        recently admitted — the least sunk work). Returns True when anything
        may have freed, so the caller re-checks fit before preempting more.
        ``pool`` restricts victims to one side of a split-pool engine (a
        blocked handoff may only evict decode-pool slots).

        A pending overlapped decode is flushed first: its in-flight sampled
        ids must land before a victim's generated tokens are folded into its
        resumption prompt (and the flush itself can finish slots, making
        the preemption unnecessary)."""
        if not self.preemption:
            return False
        if self._pending is not None:
            self._sync_pending()
            return True
        cands = [s for s in range(self.max_slots)
                 if self.phase[s] != FREE and not self._slot_legacy[s]
                 and not self._slot_fork[s]
                 and self._slot_prio[s] < prio
                 and (pool is None or self._slot_pool[s] == pool)]
        if not cands:
            return False
        victim = max(cands, key=lambda s: (-int(self._slot_prio[s]),
                                           int(self._slot_seq[s])))
        self._preempt(victim)
        return True

    def _preempt(self, slot: int) -> None:
        """Evict ``slot``: publish its fully-written pages into the prefix
        index (so resumption is a warm hit, not a recompute), release its
        blocks through the refcounted path (indexed pages stay cached,
        fresh ones free — a mid-prefill victim rolls back exactly like a
        failed admission), and requeue the request with its generated
        tokens folded into the prompt at its original place in line."""
        uid = int(self.slot_uid[slot])
        res = self.results[uid]
        req = self._slot_req[slot]
        if self.phase[slot] == PREFILL:
            written = int(self._prefill_off[slot])
            new_prompt = np.asarray(req.prompt, np.int32)
            self._prefilling.pop(slot, None)
        else:
            # rows [0, slot_pos) are written; the last sampled token's KV is
            # not (it would be written by the next decode step), so the
            # resumption prompt = written tokens + that trailing token, and
            # its chunked prefill re-derives exactly the logits decode
            # would have produced next
            written = int(self.slot_pos[slot])
            gen = [t for t in res.tokens[len(res.tokens)
                                         - (written + 1
                                            - len(req.prompt)):]]
            new_prompt = np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(gen, np.int32)])
        new_budget = int(self.slot_budget[slot])
        if self.prefix_index is not None:
            n_full = written // self.page_size
            if n_full:
                # full pages of the written region (prompt AND generated
                # tokens) are valid chain entries: the resumption — or any
                # request sharing the extended prefix — adopts them
                seq_tokens = (new_prompt if self.phase[slot] != PREFILL
                              else np.asarray(req.prompt, np.int32))
                self.prefix_index.publish(seq_tokens,
                                          self.slot_blocks[slot][:n_full])
        self.allocator.release(self.slot_blocks[slot])
        if self.prefix_index is not None:
            self.prefix_index.trim(self.allocator)
        self.slot_blocks[slot] = []
        self.block_tables[slot, :] = 0
        self.phase[slot] = FREE
        self.slot_uid[slot] = -1
        self._slot_req[slot] = None
        self._handoff_ready.discard(slot)
        if self.draft is not None:
            self.draft.drop(slot)
        res.preempted += 1
        self.stats["preemptions"] += 1
        self.scheduler.requeue(
            dc_replace(req, prompt=new_prompt, max_new_tokens=new_budget),
            seq=int(self._slot_sched_seq[slot]), submit_s=res.submit_s)
        self.trace.event("preempt", uid, slot=slot, written=written)
        # phase spans close; the request span stays open across the requeue
        self.trace.close_open(uid, keep=("request",), slot=slot,
                              reason="preempted")
        self.trace.begin("queue", uid)
        self.trace.event("requeue", uid)

    # ---- admission -----------------------------------------------------
    def _free_slot(self, pool: int | None = None) -> int | None:
        for s in range(self.max_slots):
            if self.phase[s] != FREE:
                continue
            if pool is not None and self._slot_pool[s] != pool:
                continue
            return s
        return None

    def _note_skip(self, entry) -> None:
        """Record an admission pass-over: scheduler aging + trace events."""
        was = self.scheduler.reserved(entry)
        self.scheduler.note_skip(entry)
        self.trace.event("queue_skip", entry.req.uid, skips=entry.skips)
        if not was and self.scheduler.reserved(entry):
            self.trace.event("aged", entry.req.uid)

    def _admit(self):
        """Fill free slots in scheduler order. A request blocked on pool
        resources is *skipped* (smaller ones behind it admit now — the
        head-of-line fix) and aged: once promoted to a reservation, nothing
        overtakes it until it admits. Impossible requests reject instead of
        crashing the loop; with preemption enabled, a blocked high-priority
        request evicts lower-priority victims first."""
        guard = 0
        while self.scheduler and guard <= 4 * self.max_slots + 8:
            guard += 1
            if not self._admit_pass():
                return

    def _admit_pass(self) -> bool:
        """One pass over the scheduler order. Returns True when a
        preemption changed the resource picture and the pass should
        restart."""
        fcfs = self.scheduler.policy == "fcfs"
        for entry in self.scheduler.order():
            req = entry.req
            n_tokens = len(req.prompt) + req.max_new_tokens
            if n_tokens > self.max_len:
                self.scheduler.remove(entry)
                self._reject(req, f"exceeds max_len: prompt+budget "
                                  f"{n_tokens} tokens > {self.max_len}")
                continue
            if (self.part is not None and self.paged
                    and (self.cfg.encoder is not None
                         or req.frames is not None
                         or req.extra_embeds is not None)):
                # enc-dec / vlm inputs need the dense whole-prompt prefill
                # path, which commits batch-1 rows the sharded pools cannot
                # take — reject gracefully instead of crashing the loop
                ndev = int(getattr(self.part.mesh, "size", 1))
                self.scheduler.remove(entry)
                self._reject(
                    req,
                    f"unsupported on sharded KV pools: enc-dec/vlm "
                    f"requests use the dense whole-prompt prefill path, "
                    f"which does not run over the {ndev}-device serve mesh")
                continue
            legacy = (self.cfg.encoder is not None
                      or req.frames is not None
                      or req.extra_embeds is not None
                      or (self.part is not None and not self.paged))
            if legacy and is_quant_dtype(self.cfg.kv_dtype):
                # the whole-prompt prefill commit writes dense rows —
                # incompatible with quantized pools
                self.scheduler.remove(entry)
                self._reject(req, "quantized KV serves chunked-prefill "
                                  "requests only (no frames/embeds)")
                continue
            n_par = max(1, int(req.n))
            if n_par > 1 and (legacy or not self.prefix_capable):
                self.scheduler.remove(entry)
                self._reject(req, "parallel sampling (n > 1) requires the "
                                  "paged local all-full-attention path")
                continue
            if n_par > self.max_slots:
                self.scheduler.remove(entry)
                self._reject(req, f"n {n_par} exceeds max_slots "
                                  f"{self.max_slots}")
                continue
            if self.paged:
                total = self.allocator.pages_for(n_tokens)
                if total > self.allocator.capacity:
                    cap = self.allocator.capacity
                    self.scheduler.remove(entry)
                    self._reject(
                        req,
                        f"exceeds block pool: needs {total} blocks "
                        f"({total * self._block_kv_bytes} KV bytes) > "
                        f"capacity {cap} blocks "
                        f"({cap * self._block_kv_bytes} KV bytes)")
                    continue
            # split pools: chunked prefills start in the prefill pool
            # (pool 1) and hand off; legacy whole-prompt requests go
            # straight to a decode-pool slot (their prefill is synchronous)
            want_pool = (None if not self.split_pools
                         else (0 if legacy else 1))
            slot = self._free_slot(want_pool)
            if slot is None:
                if self._preempt_for(int(req.priority), pool=want_pool):
                    return True              # resources moved: re-plan
                return False                 # every slot busy: nobody admits
            if n_par > 1:
                if self.split_pools:
                    # children and the parent's eventual handoff all land
                    # in the decode pool
                    short = sum(1 for s in range(self.max_slots)
                                if self.phase[s] == FREE
                                and self._slot_pool[s] == 0) < n_par
                else:
                    short = int((self.phase == FREE).sum()) < n_par
                if short:
                    # the whole fan-out needs slots up front (children are
                    # reserved at admission); no preemption to make room —
                    # fan-outs wait rather than evict
                    self._note_skip(entry)
                    if fcfs or self.scheduler.reserved(entry):
                        return False
                    continue
            if self.paged:
                if not self._admit_paged(entry, slot, n_tokens, legacy):
                    if fcfs or self.scheduler.reserved(entry):
                        # FCFS never overtakes; a reserved (aged) entry
                        # holds the pool until it fits
                        return False
                    continue
            else:
                self._first_new[slot] = 0
                self.stats["kv_bytes_alloc"] += self._slot_kv_bytes
            self._place(entry, slot, legacy)
        return False

    def _admit_paged(self, entry, slot: int, n_tokens: int,
                     legacy: bool) -> bool:
        """Block-pool admission for one request: prefix lookup, grant, COW.
        Returns False (after noting the skip) when blocks are short even
        after preemption."""
        req = entry.req
        total = self.allocator.pages_for(n_tokens)
        # prefix cache: map the longest indexed chain of this prompt's
        # pages read-only into the slot's block table (refcount++ per
        # page) and prefill only the tail
        matched: list[int] = []
        first_new = 0
        if self.prefix_cache and not legacy:
            # hash once per request: a request stalled on free blocks
            # retries every step and must not re-hash its whole prompt
            hs = self._admit_hashes.get(req.uid)
            if hs is None:
                hs = page_hashes(req.prompt, self.page_size)
                self._admit_hashes[req.uid] = hs
            matched = self.prefix_index.lookup(
                req.prompt, self.allocator, hashes=hs)
            # clamp below by 0: an empty prompt must not push the
            # prefill offset negative
            first_new = max(0, min(len(matched) * self.page_size,
                                   len(req.prompt) - 1))
        # a page-aligned full-prompt match still recomputes the final
        # token (its logits seed decode), so the last matched page gets
        # written mid-page -> privatize it now via copy-on-write
        # (counted into the grant, so the pool can never strand a
        # request mid-COW)
        cow = (bool(matched)
               and first_new < len(matched) * self.page_size)
        need = total - len(matched) + (1 if cow else 0)
        while (need > self.allocator.n_available
               and self._preempt_for(int(req.priority))):
            pass                      # each eviction is re-checked
        if need > self.allocator.n_available:
            # hand the prefix references back (refcount-0 indexed blocks
            # return to cached, not freed) and note the skip for aging
            self.allocator.release(matched)
            self._note_skip(entry)
            return False
        try:
            fresh = self.allocator.alloc(need)
        except RuntimeError:
            # alloc rolled its partial grant back; hand the prefix
            # references back too — admission leaves no trace
            self.allocator.release(matched)
            self._note_skip(entry)
            return False
        if cow:
            shared = matched[-1]
            matched[-1] = fresh.pop(0)
            self.cache = self._copy_fn(
                self.cache, np.int32(shared), np.int32(matched[-1]))
            self.allocator.release([shared])
            self.stats["prefix_cow"] += 1
            self.trace.event("cow", req.uid, slot=slot,
                             page=len(matched) - 1)
        blocks = matched + fresh
        self.slot_blocks[slot] = blocks
        self.block_tables[slot, :] = 0
        self.block_tables[slot, :len(blocks)] = blocks
        self._first_new[slot] = first_new
        self.stats["kv_bytes_alloc"] += (
            need * self._block_kv_bytes + self._slot_kv_bytes)
        if matched:
            self.stats["prefix_hits"] += 1
            self.stats["prefix_hit_tokens"] += first_new
        return True

    def _request_key(self, req: Request) -> np.ndarray:
        """Per-request PRNG base key: ``Request.seed`` when given (exact
        replay across runs), else derived from the engine seed and the uid
        — either way independent of admission order and co-residents."""
        if req.seed is not None:
            return np.asarray(jax.random.PRNGKey(int(req.seed)), np.uint32)
        return np.asarray(
            jax.random.fold_in(jnp.asarray(self._base_key),
                               np.uint32(req.uid & 0xFFFFFFFF)), np.uint32)

    def _place(self, entry, slot: int, legacy: bool) -> None:
        """Bind an admitted request to its slot and start prefill."""
        req = entry.req
        # fan-outs charge their full decode cost: n sequences each draw up
        # to max_new_tokens against the user's service accumulator
        self.scheduler.note_admitted(
            entry, len(req.prompt) + max(1, int(req.n)) * req.max_new_tokens)
        self._admit_hashes.pop(req.uid, None)
        self._t0[slot] = time.perf_counter()
        self.slot_uid[slot] = req.uid
        self.slot_temp[slot] = req.temperature
        self.slot_budget[slot] = req.max_new_tokens
        self._slot_req[slot] = req
        self._slot_legacy[slot] = legacy
        self._slot_prio[slot] = req.priority
        self._slot_seq[slot] = self._admit_seq
        self._slot_sched_seq[slot] = entry.seq
        self._slot_tok0[slot] = len(self.results[req.uid].tokens)
        self._admit_seq += 1
        self.stats["prefills"] += 1
        self.trace.end("queue", req.uid, slot=slot)
        self.trace.begin("prefill", req.uid, slot=slot)
        res = self.results[req.uid]
        self.trace.event("admit", req.uid, slot=slot,
                         first_new=int(self._first_new[slot]),
                         pages=len(self.slot_blocks[slot]),
                         resumed=res.preempted > 0)
        self.slot_topk[slot] = max(0, int(req.top_k))
        self.slot_topp[slot] = float(req.top_p)
        self._slot_key[slot] = self._request_key(req)
        self._slot_ctr[slot] = len(self.results[req.uid].tokens)
        self._slot_feed[slot] = int(req.prompt[-1]) if len(req.prompt) else 0
        self._slot_base_pages[slot] = (len(self.slot_blocks[slot])
                                       if self.paged else 0)
        if self.draft is not None and not legacy:
            self.draft.begin(slot)
        if legacy:
            self._prefill_whole(slot, req)
        else:
            self.phase[slot] = PREFILL
            self._prefilling[slot] = req
            # chunked prefill starts at the first non-cached token:
            # everything below rode in read-only through the table
            self._prefill_off[slot] = self._first_new[slot]
            if int(req.n) > 1:
                # the parent's phase is set: reservation sees it as busy
                self._reserve_children(slot, entry)

    def _reserve_children(self, slot: int, entry) -> None:
        """Reserve one free slot per extra sample of a ``Request(n > 1)``.
        Reserved slots sit inert (phase PREFILL, zero budget, not in
        ``_prefilling``) until the parent's prefill completes and
        ``_fork_children`` maps the shared pages; the whole family is
        preemption-exempt so the shared refcounts cannot outlive a victim."""
        req = entry.req
        res = self.results[req.uid]
        kids: list[int] = []
        pool = 0 if self.split_pools else None
        for i in range(int(req.n) - 1):
            # guaranteed by the admission count (decode pool when split)
            cs = self._free_slot(pool)
            cuid = self._next_child_uid
            self._next_child_uid -= 1
            cres = Result(uid=cuid, submit_s=res.submit_s)
            res.children.append(cres)
            self.results[cuid] = cres
            self.trace.begin("request", cuid, slot=cs, parent=req.uid)
            self.phase[cs] = PREFILL
            self.slot_uid[cs] = cuid
            self.slot_temp[cs] = req.temperature
            self.slot_budget[cs] = 0
            self.slot_topk[cs] = max(0, int(req.top_k))
            self.slot_topp[cs] = float(req.top_p)
            self._slot_req[cs] = dc_replace(req, uid=cuid, n=1)
            self._slot_legacy[cs] = False
            self._slot_prio[cs] = req.priority
            self._slot_seq[cs] = self._admit_seq
            self._slot_sched_seq[cs] = entry.seq
            self._slot_tok0[cs] = 0
            self._slot_fork[cs] = True
            # child streams branch off the parent key through a fork tag:
            # child i is reproducible given (request seed, i)
            self._slot_key[cs] = np.asarray(jax.random.fold_in(
                jax.random.fold_in(jnp.asarray(self._slot_key[slot]),
                                   np.uint32(_P_FORK)),
                np.uint32(i + 1)), np.uint32)
            self._slot_ctr[cs] = 0
            kids.append(cs)
        self._slot_fork[slot] = True
        self._slot_children[slot] = kids

    def _fork_children(self, parent: int, req: Request) -> None:
        """COW-fork a prefilled parent into its reserved children. Shared
        prompt pages map read-only into each child's table (refcount++);
        the boundary page holding the prompt's last row is privatized per
        child, because the child re-decodes that row to sample its own
        first token; fresh pages back each child's future tail. A child
        whose fresh grant cannot be allocated rejects gracefully — the
        parent and remaining children keep going."""
        P = len(req.prompt)
        kids = self._slot_children.pop(parent, [])
        pblocks = self.slot_blocks[parent]
        w0 = (P - 1) // self.page_size      # page the child rewrites
        total = self.allocator.pages_for(P + req.max_new_tokens)
        for cs in kids:
            child_req = self._slot_req[cs]
            try:
                fresh = self.allocator.alloc(total - w0)
            except RuntimeError:
                self._reject(child_req, "fork: block pool exhausted")
                self.phase[cs] = FREE
                self.slot_uid[cs] = -1
                self._slot_req[cs] = None
                self._slot_fork[cs] = False
                continue
            for blk in pblocks[:w0]:
                self.allocator.incref(blk)
            # private copy of the boundary page: it holds committed rows
            # below P-1 that the family shares but this child must own
            self.cache = self._copy_fn(self.cache, np.int32(pblocks[w0]),
                                       np.int32(fresh[0]))
            blocks = list(pblocks[:w0]) + fresh
            self.slot_blocks[cs] = blocks
            self.block_tables[cs, :] = 0
            self.block_tables[cs, :len(blocks)] = blocks
            self.phase[cs] = DECODE
            self.slot_pos[cs] = P - 1
            self.slot_budget[cs] = req.max_new_tokens
            self._slot_feed[cs] = int(req.prompt[-1])
            self._slot_base_pages[cs] = len(blocks)
            self._prefill_off[cs] = 0
            self._first_new[cs] = 0
            self._t0[cs] = self._t0[parent]
            if self.draft is not None and self.draft.off[parent] >= 0:
                self.draft.fork_slot(parent, cs)
            self.stats["forks"] += 1
            self.stats["fork_shared_blocks"] += w0
            self.stats["fork_fresh_blocks"] += len(fresh)
            self.stats["kv_bytes_alloc"] += len(fresh) * self._block_kv_bytes
            self.trace.event("fork", int(self.slot_uid[cs]), slot=cs,
                             parent=req.uid, shared=w0, fresh=len(fresh))
            self.trace.begin("decode", int(self.slot_uid[cs]), slot=cs)

    def _prefill_whole(self, slot: int, req: Request):
        prompt = np.asarray(req.prompt, np.int32)[None]  # (1, S)
        length = prompt.shape[1]
        fn = self._prefill_fn(length, req.frames is not None,
                              req.extra_embeds is not None)
        frames = (jnp.asarray(req.frames)[None]
                  if req.frames is not None else None)
        extra = (jnp.asarray(req.extra_embeds)[None]
                 if req.extra_embeds is not None else None)
        with self._kernel_scope():
            logits, slot_cache = fn(self.params, jnp.asarray(prompt),
                                    frames, extra)
        self.cache = self._commit_fn(self.cache, slot_cache, np.int32(slot),
                                     self._tables())
        kk = _fold_keys(
            jnp.asarray(self._slot_key[slot][None]),
            jnp.asarray([self._slot_ctr[slot] & 0x7FFFFFFF], jnp.uint32),
            _P_SAMPLE)
        first = int(_sample(logits,
                            jnp.asarray([req.temperature], jnp.float32),
                            jnp.asarray(self.slot_topk[slot][None]),
                            jnp.asarray(self.slot_topp[slot][None]),
                            kk)[0])
        self._slot_ctr[slot] += 1
        self.phase[slot] = DECODE
        self._finish_prefill(slot, first, length)

    def _prefill_chunks(self):
        """Advance every mid-prefill slot by one ``prefill_chunk``-token
        chunk (ragged tails pad to the same compiled shape behind
        ``n_valid``); decode interleaves between chunks, so a long prompt
        never stalls the running slots."""
        for slot in sorted(self._prefilling):
            req = self._prefilling[slot]
            prompt = np.asarray(req.prompt, np.int32)
            if (self.draft is not None and self.draft.off[slot] >= 0
                    and not self.draft.ready(slot, len(prompt))):
                # the draft prefills its own dense cache in lockstep —
                # always from 0: prefix hits are a verifier-pool concept
                self.draft.prefill_chunk(slot, prompt)
            off = int(self._prefill_off[slot])
            if off < len(prompt):
                t = min(self.prefill_chunk, len(prompt) - off)
                buf = np.zeros((1, self.prefill_chunk), np.int32)
                buf[0, :t] = prompt[off:off + t]
                fn = self._ensure_chunk_fn()
                self._cow_pages(slot, off, off + t)
                with self._kernel_scope():
                    tok, self.cache = fn(
                        self.params, self.cache, jnp.asarray(buf),
                        np.int32(off), np.int32(t), np.int32(slot),
                        self._tables(), np.float32(req.temperature),
                        np.int32(self.slot_topk[slot]),
                        np.float32(self.slot_topp[slot]),
                        jnp.asarray(self._slot_key[slot]),
                        np.uint32(self._slot_ctr[slot] & 0x7FFFFFFF),
                        np.int32(self._first_new[slot]))
                self._slot_ctr[slot] += 1
                self.stats["prefill_chunks"] += 1
                self.trace.event("prefill_chunk", int(self.slot_uid[slot]),
                                 slot=slot, off=off, n=t)
                off += t
                self._prefill_off[slot] = off
                if off >= len(prompt):
                    self._slot_first[slot] = int(tok[0])
            if off < len(prompt):
                continue
            if (self.draft is not None and self.draft.off[slot] >= 0
                    and not self.draft.ready(slot, len(prompt))):
                continue        # verifier done; draft still catching up
            del self._prefilling[slot]
            if self.prefix_index is not None:
                # every full prompt page is now written: publish the
                # slot's pages so later identical prefixes can share
                # them (matched pages re-register as a no-op; cold
                # concurrent duplicates stay un-indexed and free
                # normally at finish)
                n_full = len(prompt) // self.page_size
                if n_full:
                    self.prefix_index.publish(
                        prompt, self.slot_blocks[slot][:n_full])
            if self.split_pools and self._slot_pool[slot] == 1:
                # disaggregated handoff: children fork off the shared pages
                # now (they already hold decode-pool slots), then the
                # parent's prompt KV moves pools purely by republishing its
                # pages through the block table
                if self._slot_children.get(slot):
                    self._fork_children(slot, req)
                self._handoff_ready.add(slot)
                self._try_handoffs()
                continue
            self.phase[slot] = DECODE
            if self._slot_children.get(slot):
                # fork before the parent can finish: children must map the
                # prompt pages while they are all still resident
                self._fork_children(slot, req)
            self._finish_prefill(slot, int(self._slot_first[slot]),
                                 len(prompt))

    # ---- disaggregated prefill/decode pools ----------------------------
    def _move_slot(self, src: int, dst: int) -> None:
        """Relocate a request between slots. The KV handoff is the block-
        table row copy: pages stay exactly where they are in the (possibly
        mesh-sharded) pool, the destination slot simply republishes them —
        zero tensor traffic on any mesh. Refcounts are untouched: the
        blocks change owner, not reference count."""
        self.block_tables[dst, :] = self.block_tables[src, :]
        self.block_tables[src, :] = 0
        self.slot_blocks[dst] = self.slot_blocks[src]
        self.slot_blocks[src] = []
        for arr in (self.phase, self.slot_uid, self.slot_pos,
                    self.slot_budget, self.slot_temp, self.slot_topk,
                    self.slot_topp, self._slot_ctr, self._slot_feed,
                    self._prefill_off, self._first_new, self._t0,
                    self._slot_legacy, self._slot_prio, self._slot_seq,
                    self._slot_sched_seq, self._slot_tok0, self._slot_fork,
                    self._slot_base_pages, self._slot_first):
            arr[dst] = arr[src]
        self._slot_key[dst] = self._slot_key[src]
        self._slot_req[dst] = self._slot_req[src]
        self._slot_req[src] = None
        if src in self._slot_children:
            self._slot_children[dst] = self._slot_children.pop(src)
        if self.draft is not None and self.draft.off[src] >= 0:
            # the draft's dense cache row moves with the request
            self.draft.fork_slot(src, dst)
            self.draft.drop(src)
        self.phase[src] = FREE
        self.slot_uid[src] = -1
        self._slot_fork[src] = False

    def _try_handoffs(self) -> None:
        """Move each prefill-pool slot whose prompt KV is fully written
        into a decode-pool slot (evicting a strictly-lower-priority decode
        slot when preemption allows). A blocked handoff counts wait steps
        instead of stalling the engine — the prefill slot stays parked
        until a decode slot frees."""
        for src in sorted(self._handoff_ready):
            dst = self._free_slot(pool=0)
            if dst is None and self._preempt_for(
                    int(self._slot_prio[src]), pool=0):
                dst = self._free_slot(pool=0)
            if dst is None:
                self.stats["handoff_wait_steps"] += 1
                self.trace.event("handoff_wait", int(self.slot_uid[src]),
                                 slot=src)
                continue
            self._handoff_ready.discard(src)
            req = self._slot_req[src]
            self._move_slot(src, dst)
            self.phase[dst] = DECODE
            self.stats["handoffs"] += 1
            self.trace.event("handoff", int(self.slot_uid[dst]), slot=dst,
                             src=src)
            self._finish_prefill(dst, int(self._slot_first[dst]),
                                 len(req.prompt))

    def _emitted(self, slot: int) -> int:
        """Tokens emitted in this admission segment (synced to host)."""
        return (len(self.results[self.slot_uid[slot]].tokens)
                - int(self._slot_tok0[slot]))

    def _finish_prefill(self, slot: int, first: int, length: int):
        uid = int(self.slot_uid[slot])
        res = self.results[uid]
        self.trace.end("prefill", uid, slot=slot, length=length)
        self.trace.begin("decode", uid, slot=slot)
        self._emit(slot, first)
        if res.prefill_s == 0.0:    # resumption keeps the original TTFT
            res.prefill_s = time.perf_counter() - self._t0[slot]
        self.slot_pos[slot] = length  # position of `first` when decoded
        self.slot_budget[slot] -= 1
        if self.eos_id is not None and first == self.eos_id:
            self._finish(slot, "eos")
        elif self._emitted(slot) >= self._slot_req[slot].max_new_tokens:
            self._finish(slot, "length")

    def _finish(self, slot: int, reason: str):
        uid = int(self.slot_uid[slot])
        res = self.results[uid]
        res.finish_reason = reason
        self._c_finished.inc(reason=reason)
        self.trace.event("finish", uid, slot=slot, reason=reason,
                         tokens=len(res.tokens))
        self.trace.close_open(uid, slot=slot, reason=reason)
        req = self._slot_req[slot]
        if (req is not None and reason in ("eos", "length")
                and (req.slo_ttft_ms is not None
                     or req.slo_itl_ms is not None)):
            ok = True
            if req.slo_ttft_ms is not None:
                ok &= (res.ttft_s is not None
                       and res.ttft_s * 1e3 <= req.slo_ttft_ms)
            if req.slo_itl_ms is not None and res.itl_s is not None:
                ok &= res.itl_s * 1e3 <= req.slo_itl_ms
            res.slo_met = bool(ok)
            self.stats["slo_met" if ok else "slo_missed"] += 1
        self.phase[slot] = FREE
        self.slot_uid[slot] = -1
        self._slot_req[slot] = None
        self._prefilling.pop(slot, None)
        self._handoff_ready.discard(slot)
        if self.draft is not None:
            self.draft.drop(slot)
        self._slot_fork[slot] = False
        # reserved-but-never-forked children (parent truncated mid-prefill)
        # hold no blocks and finish independently through the drain loop
        self._slot_children.pop(slot, None)
        if self.paged and self.slot_blocks[slot]:
            # drop this slot's references immediately: unshared blocks are
            # admittable this very step, and fully-written prompt pages
            # that made it into the prefix index stay resident as cached
            # (refcount-0, LRU-evictable) blocks instead of freeing
            self.allocator.release(self.slot_blocks[slot])
            if self.prefix_index is not None:
                self.prefix_index.trim(self.allocator)
            self.slot_blocks[slot] = []
            self.block_tables[slot, :] = 0

    # ---- speculative decoding ------------------------------------------
    def _committed_tok(self, slot: int, p: int) -> int:
        """Token at absolute position ``p`` of the slot's committed stream:
        the prompt, then this segment's emitted tokens (a resumption folds
        earlier generations into the prompt, so the formula holds across
        preemptions; fork children start with an empty segment)."""
        req = self._slot_req[slot]
        if p < len(req.prompt):
            return int(req.prompt[p])
        res = self.results[self.slot_uid[slot]]
        return int(res.tokens[int(self._slot_tok0[slot])
                              + (p - len(req.prompt))])

    def _rollback_spec(self, slot: int) -> None:
        """Roll the slot's speculative pages back through the allocator:
        release every page beyond what the committed stream needs (never
        below the admission grant — those pages are the request's own)."""
        keep = max(int(self._slot_base_pages[slot]),
                   self.allocator.pages_for(int(self.slot_pos[slot]) + 1))
        while len(self.slot_blocks[slot]) > keep:
            blk = self.slot_blocks[slot].pop()
            self.block_tables[slot, len(self.slot_blocks[slot])] = 0
            self.allocator.release([blk])

    def _spec_turn(self) -> np.ndarray | None:
        """One speculative draft-verify turn over every eligible DECODE
        slot. The draft proposes up to ``spec_k`` tokens per slot from its
        dense cache; the verifier scores the last committed token plus all
        proposals in one batched ``verify_step``; the acceptance rule
        commits a distribution-preserving prefix (plus one bonus/residual
        token); uncommitted verifier rows roll back through the block
        allocator. Returns the mask of slots handled here so the plain
        decode path skips them, or None when no slot was eligible."""
        k = self.spec_k
        mask = np.zeros(self.max_slots, bool)
        k_eff = np.zeros(self.max_slots, np.int32)
        feed0 = np.zeros((self.max_slots, 1), np.int32)
        feed1 = np.zeros((self.max_slots, 1), np.int32)
        for slot in range(self.max_slots):
            if (self.phase[slot] != DECODE or self.slot_budget[slot] <= 0
                    or self._slot_legacy[slot]
                    or self.draft.off[slot] < 0):
                continue
            req = self._slot_req[slot]
            pos0 = int(self.slot_pos[slot])
            if pos0 < 1 or not self.draft.ready(slot, len(req.prompt)):
                continue    # fall back to plain decode this turn
            ke = min(k, self.max_len - 1 - pos0)
            if ke < 1:
                continue
            # speculative pages: rows [pos0, pos0+ke] must be backed; the
            # extras beyond the admission grant are transient (rolled back
            # after the commit). On pool pressure, clamp ke to what the
            # current grant backs instead of stalling the slot.
            need = self.allocator.pages_for(pos0 + ke + 1)
            extra = need - len(self.slot_blocks[slot])
            if extra > 0:
                try:
                    got = self.allocator.alloc(extra)
                except RuntimeError:
                    got = []
                if got:
                    base = len(self.slot_blocks[slot])
                    self.slot_blocks[slot].extend(got)
                    self.block_tables[slot, base:base + len(got)] = got
                    self.stats["spec_extra_blocks"] += len(got)
                else:
                    ke = (len(self.slot_blocks[slot]) * self.page_size
                          - 1 - pos0)
                    if ke < 1:
                        continue
            mask[slot] = True
            k_eff[slot] = ke
            feed0[slot, 0] = self._committed_tok(slot, pos0 - 1)
            feed1[slot, 0] = self._committed_tok(slot, pos0)
            # verify writes rows [pos0, pos0+ke]: privatize shared pages
            self._cow_pages(slot, pos0, pos0 + ke + 1)
        if not mask.any():
            return None
        active = jnp.asarray(mask)
        temps = jnp.asarray(self.slot_temp)
        topk = jnp.asarray(self.slot_topk)
        topp = jnp.asarray(self.slot_topp)
        keys = jnp.asarray(self._slot_key)
        ctrs = jnp.asarray((self._slot_ctr & 0x7FFFFFFF).astype(np.uint32))
        pos = jnp.asarray(self.slot_pos)
        n_valid = jnp.asarray(np.where(mask, k_eff + 1, 1).astype(np.int32))
        with self._kernel_scope():
            dtoks, dprobs = self.draft.propose(
                jnp.asarray(feed0), jnp.asarray(feed1), pos, active, temps,
                topk, topp, keys, ctrs)
            if self.part is not None:
                # the draft runs single-device; re-materialize its outputs
                # host-side so the mesh-sharded verify graph can place them
                dtoks, dprobs = np.asarray(dtoks), np.asarray(dprobs)
            out, n_acc, self.cache = self._spec_fn(
                self.params, self.cache, jnp.asarray(feed1), dtoks, dprobs,
                pos, n_valid, active, self._tables(), temps, topk, topp,
                keys, ctrs)
        out = np.asarray(out)
        n_acc = np.asarray(n_acc)
        self.stats["spec_turns"] += 1
        nd = int(mask.sum())
        self._c_win_batch.inc(nd)
        self._c_win_kv.inc(int(self.slot_pos[mask].sum()) + nd)
        self.trace.event("spec_propose", n=nd,
                         kv=int(self.slot_pos[mask].sum()) + nd)
        for slot in np.nonzero(mask)[0]:
            self._slot_ctr[slot] += 1
            req = self._slot_req[slot]
            res = self.results[self.slot_uid[slot]]
            ke = int(k_eff[slot])
            na = min(int(n_acc[slot]), ke)
            self.stats["spec_proposed"] += ke
            self.stats["spec_accepted"] += na
            if req.user is not None:
                # draft-token budget accounting: proposing ke tokens costs
                # the user ke tokens of service whether or not they commit
                self.scheduler.charge(req.user, ke)
            finish = None
            committed = 0
            for j in range(min(na + 1, int(self.slot_budget[slot]))):
                tok = int(out[slot, j])
                self._emit(slot, tok)
                committed += 1
                if self.eos_id is not None and tok == self.eos_id:
                    finish = "eos"
                    break
                if self._emitted(slot) >= req.max_new_tokens:
                    finish = "length"
                    break
            res.decode_steps += 1
            self.stats["decode_steps"] += 1
            self.slot_pos[slot] += committed
            self.slot_budget[slot] -= committed
            self._c_win_tokens.inc(committed)
            self._h_spec_accept.observe(na)
            self.trace.event("spec_commit", int(self.slot_uid[slot]),
                             slot=int(slot), proposed=ke, accepted=na,
                             tokens=committed)
            n_extra = len(self.slot_blocks[slot])
            self._rollback_spec(slot)
            n_rolled = n_extra - len(self.slot_blocks[slot])
            if n_rolled:
                self.trace.event("spec_rollback", int(self.slot_uid[slot]),
                                 slot=int(slot), pages=n_rolled)
            if finish is not None:
                self._finish(slot, finish)
        return mask

    # ---- decode (double-buffered) --------------------------------------
    def _decode(self):
        """Dispatch one decode step, then sync. Without overlap the sync is
        immediate (legacy behavior). With overlap the *previous* step's ids
        sync after this step's dispatch is already on the device — host
        bookkeeping and the next admission run while the device computes,
        at the cost of ids reaching callbacks one step late."""
        t0 = time.perf_counter()
        skip = self._spec_turn() if self.draft is not None else None
        prev = self._pending
        self._pending = self._dispatch_decode(prev, skip=skip)
        did = (self._pending is not None
               or (skip is not None and bool(skip.any())))
        if not did and (bool(self.scheduler) or bool(self._handoff_ready)):
            # requests are queued or parked awaiting handoff but no decode
            # was issued: the decode side sat idle this step. In a unified
            # engine this gap grows with prompt length (prefill occupies
            # the slots); split pools keep it flat — the gate the
            # throughput benchmark checks.
            self.stats["decode_gap_steps"] += 1
        if prev is not None:
            self._sync(prev)
        if not self.overlap and self._pending is not None:
            p, self._pending = self._pending, None
            self._sync(p)
        if did:
            # measured decode window: sync-visible wall seconds for one
            # step that dispatched decode work (repro.obs.report joins
            # these against the roofline/memfloor model)
            self._h_decode_window.observe(time.perf_counter() - t0)

    def _dispatch_decode(self, prev: _Pending | None,
                         skip: np.ndarray | None = None
                         ) -> _Pending | None:
        """Enqueue one decode step on device. Continuing slots take their
        token feed from ``prev``'s device ids (never synced to host);
        slots that just finished prefill take their host-known first token.
        Positions and budgets advance at dispatch, so the mask and the COW
        guard stay exact even while ids are in flight. ``skip`` masks out
        slots a speculative turn already advanced this step."""
        dec = (self.phase == DECODE) & (self.slot_budget > 0)
        if skip is not None:
            dec &= ~skip
        if not dec.any():
            return None
        tokens = np.zeros((self.max_slots, 1), np.int32)
        for slot in np.nonzero(dec)[0]:
            if self._emitted(slot) > 0:
                res = self.results[self.slot_uid[slot]]
                tokens[slot, 0] = res.tokens[-1]
            else:
                # nothing emitted yet this segment: a fork child re-decodes
                # the prompt's last token to sample its own first one
                tokens[slot, 0] = self._slot_feed[slot]
            # a decode write to a shared page privatizes it first
            self._cow_pages(slot, int(self.slot_pos[slot]),
                            int(self.slot_pos[slot]) + 1)
        feed = jnp.asarray(tokens)
        if prev is not None:
            # double-buffer: the last sampled ids are still on device
            feed = jnp.where(jnp.asarray(prev.mask)[:, None],
                             prev.ids[:, None], feed)
        with self._kernel_scope():
            ids, self.cache = self._decode_fn(
                self.params, self.cache, feed,
                jnp.asarray(self.slot_pos), jnp.asarray(dec), self._tables(),
                jnp.asarray(self.slot_temp),
                jnp.asarray(self.slot_topk), jnp.asarray(self.slot_topp),
                jnp.asarray(self._slot_key),
                jnp.asarray((self._slot_ctr & 0x7FFFFFFF).astype(np.uint32)))
        self._slot_ctr[dec] += 1
        self.stats["decode_steps"] += 1
        # window accounting at dispatch, before pos advances: rows attended
        # this step = prior context + the token being written per slot
        nd = int(dec.sum())
        rows = int(self.slot_pos[dec].sum()) + nd
        self._c_win_batch.inc(nd)
        self._c_win_kv.inc(rows)
        self.trace.event("dispatch", n=nd, kv=rows)
        self.slot_pos[dec] += 1
        self.slot_budget[dec] -= 1
        return _Pending(ids=ids, mask=dec, uids=self.slot_uid.copy())

    def _sync(self, p: _Pending):
        """Bring one dispatched decode step's sampled ids to host and run
        the bookkeeping: stream/append tokens, finish on eos or exhausted
        budget. Ids for requests that finished while the step was in
        flight (an eos discovered one sync earlier) are discarded — their
        slot was dispatched speculatively."""
        ids = np.asarray(p.ids)
        n_emitted = 0
        for slot in np.nonzero(p.mask)[0]:
            uid = int(p.uids[slot])
            res = self.results.get(uid)
            if (res is None or res.finish_reason
                    or self.slot_uid[slot] != uid):
                continue                    # speculative overflow step
            tok = int(ids[slot])
            self._emit(slot, tok)
            n_emitted += 1
            res.decode_steps += 1
            if self.eos_id is not None and tok == self.eos_id:
                self._finish(slot, "eos")
            elif self._emitted(slot) >= self._slot_req[slot].max_new_tokens:
                # emitted-count check, NOT slot_budget: with overlap the
                # budget already paid for the next in-flight dispatch
                self._finish(slot, "length")
        # tokens become measured throughput only once sync-visible
        self._c_win_tokens.inc(n_emitted)
        self.trace.event("sync", n=int(p.mask.sum()), tokens=n_emitted)

    def _sync_pending(self):
        """Flush the overlapped decode step, if any (idempotent)."""
        p, self._pending = self._pending, None
        if p is not None:
            self._sync(p)

    # ---- engine loop ---------------------------------------------------
    def step(self) -> int:
        """Admit, retry parked handoffs, advance prefill chunks, one decode
        step. Returns #busy."""
        self._admit()
        if self._handoff_ready:
            self._try_handoffs()
        self._prefill_chunks()
        self._decode()
        # (sched_skips / prefix_evictions need no mirroring: the scheduler
        # and prefix index increment the same registry counters directly)
        if self.prefix_index is not None:
            # cached-block accounting: KV bytes held by refcount-0 pages
            # retained for future prefix hits (reclaimable, so they are
            # reported separately from kv_bytes_alloc)
            self.stats["kv_bytes_cached"] = (
                self.prefix_index.n_evictable(self.allocator)
                * self._block_kv_bytes)
        n_busy = int((self.phase != FREE).sum())
        self.stats["max_concurrency"] = max(self.stats["max_concurrency"],
                                            n_busy)
        # per-device KV footprint: pool bytes divide across kv_shard
        # devices (dense per-slot leaves are replicated, but all-full
        # paged configs have none)
        self.stats["kv_bytes_alloc_dev"] = (
            self.stats["kv_bytes_alloc"] // max(self._kv_shard, 1))
        return n_busy

    def _busy(self) -> bool:
        return (bool(self.scheduler) or bool((self.phase != FREE).any())
                or self._pending is not None)

    def _truncate(self):
        """Drain a run that hit ``max_steps``: flush the overlapped step so
        no sampled token is lost, finish every in-flight slot as
        ``truncated`` (blocks released — leak-free), and mark still-queued
        requests the same way. Partial tokens stay on the Result."""
        self._sync_pending()
        for slot in range(self.max_slots):
            if self.phase[slot] == FREE:
                continue
            res = self.results[self.slot_uid[slot]]
            res.detail = ("prefill interrupted at max_steps"
                          if self.phase[slot] == PREFILL
                          else "decode interrupted at max_steps")
            self._finish(slot, "truncated")
        for entry in self.scheduler.drain():
            res = self.results.get(entry.req.uid)
            self._admit_hashes.pop(entry.req.uid, None)
            if res is not None and not res.finish_reason:
                res.finish_reason = "truncated"
                res.detail = "still queued at max_steps"
                self._c_finished.inc(reason="truncated")
                self.trace.event("truncate", entry.req.uid)
                self.trace.close_open(entry.req.uid, reason="truncated")

    def run(self, requests: list[Request], *, max_steps: int = 100000
            ) -> list[Result]:
        """Drive all requests to completion (continuous batching loop).
        Hitting ``max_steps`` truncates cleanly: in-flight slots release
        their blocks and every unfinished request gets
        ``finish_reason="truncated"`` instead of a half-populated Result."""
        for r in requests:
            self.submit(r)
        steps = 0
        while self._busy() and steps < max_steps:
            self.step()
            steps += 1
        if self._busy():
            self._truncate()
        return [self.results[r.uid] for r in requests]

"""Admission scheduling policy for the serving engine.

The engine loop used to be synchronous FCFS with head-of-line admission
backpressure: one queued request that did not fit the block pool idled free
slots and free blocks behind it. This module is the policy layer that
replaces that deque — it owns the *waiting* requests and answers one
question each engine step: in what order should admission try them?

Policies
--------

``fcfs``
    Arrival order, no overtaking — the legacy behavior, kept as the
    baseline for the latency benchmark and for bug-for-bug comparisons.

``priority`` (default)
    A total order over waiting requests built from four signals, compared
    lexicographically:

    1. **reservation** (anti-starvation): a request that has been skipped
       ``aging_skips`` times while blocked on pool resources is *reserved* —
       it sorts to the absolute front and the engine stops overtaking it,
       so draining traffic is guaranteed to admit it eventually. Aging is
       the promotion mechanism: without it, skip-with-overtaking could
       starve a large request forever behind a stream of small ones.
    2. **priority class**: larger ``Request.priority`` is more urgent.
    3. **SLO urgency (EDF)**: a request with a time-to-first-token target
       (``slo_ttft_ms``) becomes *urgent* once less than half its target
       remains until the deadline; urgent requests order earliest-deadline-
       first within their priority class.
    4. **multi-tenant fair queuing**: among the rest, the tenant
       (``Request.user``) with the least admitted service (tokens) goes
       first — a well-behaved interactive user is not queued behind a bulk
       tenant's backlog at equal priority. Ties fall back to arrival order.

The scheduler never touches slots, blocks, or device state; the engine asks
for :meth:`order`, tries each entry, and reports back via
:meth:`note_admitted` / :meth:`note_skip`. Preempted requests re-enter
through :meth:`requeue` keeping their original arrival sequence number (and
submit timestamp), so a victim does not lose its place in line.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

#: scheduling policies understood by the engine / launcher.
POLICIES = ("fcfs", "priority")

#: fraction of the TTFT target that may remain before a request is treated
#: as deadline-urgent (EDF within its priority class).
URGENT_FRAC = 0.5


@dataclass(eq=False)            # identity semantics: entries are removed by
class SchedEntry:               # object, and Request holds ndarray fields
    """One waiting request plus its scheduling bookkeeping."""
    req: Any                    # repro.serve.engine.Request (duck-typed)
    seq: int                    # arrival order; preserved across preemption
    submit_s: float             # submission timestamp (perf_counter domain)
    skips: int = 0              # admission passes that overtook this entry

    @property
    def uid(self) -> int:
        return self.req.uid


@dataclass
class Scheduler:
    policy: str = "priority"
    #: skipped admission passes before a blocked entry reserves the pool
    #: (0 = never reserve, i.e. unbounded overtaking).
    aging_skips: int = 64
    #: injectable clock for deterministic tests.
    now: Callable[[], float] = time.perf_counter
    #: shared MetricsRegistry (the engine passes its own); None = private.
    metrics: Any = None

    def __post_init__(self):
        from repro.obs.metrics import MetricsRegistry
        if self.policy not in POLICIES:
            raise ValueError(f"sched policy {self.policy!r}; "
                             f"expected one of {POLICIES}")
        if self.aging_skips < 0:
            raise ValueError("aging_skips must be >= 0")
        self._entries: list[SchedEntry] = []
        self._seq = 0
        self._service: dict[Any, int] = {}      # user -> admitted tokens
        if self.metrics is None:
            self.metrics = MetricsRegistry()
        self._c_skips = self.metrics.counter(
            "sched_skips", "admission passes that overtook a blocked entry")
        self._c_aged = self.metrics.counter(
            "sched_aged", "entries promoted to reserved by skip aging")
        self._c_requeues = self.metrics.counter(
            "sched_requeues", "preempted requests re-entering the queue")
        # legacy dict interface: short keys alias the registered names
        self.stats = self.metrics.view(aliases={
            "skips": "sched_skips",
            "aged": "sched_aged",
            "requeues": "sched_requeues",
        })

    # ---- queue management -------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def __bool__(self) -> bool:
        return bool(self._entries)

    def entries(self) -> list[SchedEntry]:
        """Waiting entries in arrival order (not scheduling order)."""
        return sorted(self._entries, key=lambda e: e.seq)

    def submit(self, req) -> SchedEntry:
        e = SchedEntry(req, self._seq, self.now())
        self._seq += 1
        self._entries.append(e)
        return e

    def requeue(self, req, *, seq: int, submit_s: float) -> SchedEntry:
        """Re-enter a preempted request at its original place in line."""
        e = SchedEntry(req, seq, submit_s)
        self._entries.append(e)
        self._c_requeues.inc()
        return e

    def remove(self, entry: SchedEntry) -> None:
        self._entries.remove(entry)

    def drain(self) -> list[SchedEntry]:
        """Remove and return every waiting entry (run truncation)."""
        out, self._entries = self.entries(), []
        return out

    # ---- policy -----------------------------------------------------------
    def reserved(self, entry: SchedEntry) -> bool:
        """True once aging has promoted a skipped entry to the front: the
        engine stops overtaking it until it admits."""
        return bool(self.aging_skips) and entry.skips >= self.aging_skips

    def deadline_s(self, entry: SchedEntry) -> float:
        ttft = getattr(entry.req, "slo_ttft_ms", None)
        if ttft is None:
            return float("inf")
        return entry.submit_s + ttft / 1e3

    def urgent(self, entry: SchedEntry, now: float) -> bool:
        ttft = getattr(entry.req, "slo_ttft_ms", None)
        if ttft is None:
            return False
        return self.deadline_s(entry) - now <= URGENT_FRAC * ttft / 1e3

    def _key(self, entry: SchedEntry, now: float):
        if self.policy == "fcfs":
            return (entry.seq,)
        urgent = self.urgent(entry, now)
        return (0 if self.reserved(entry) else 1,
                -int(getattr(entry.req, "priority", 0)),
                0 if urgent else 1,
                self.deadline_s(entry) if urgent else float("inf"),
                self._service.get(getattr(entry.req, "user", None), 0),
                entry.seq)

    def order(self) -> list[SchedEntry]:
        """Snapshot of the waiting entries in admission-attempt order."""
        now = self.now()
        return sorted(self._entries, key=lambda e: self._key(e, now))

    # ---- engine feedback --------------------------------------------------
    def note_skip(self, entry: SchedEntry) -> None:
        """The engine passed over ``entry`` (blocked on pool resources)."""
        was = self.reserved(entry)
        entry.skips += 1
        self._c_skips.inc()
        if not was and self.reserved(entry):
            self._c_aged.inc()

    def note_admitted(self, entry: SchedEntry, n_tokens: int) -> None:
        """``entry`` was admitted: drop it and charge its tenant's service
        (prompt + generation budget tokens) for fair queuing."""
        self.remove(entry)
        self.charge(getattr(entry.req, "user", None), n_tokens)

    def charge(self, user, n_tokens: int) -> None:
        """Charge ``user`` extra service tokens outside admission — e.g.
        the draft-model tokens a speculative turn proposes on a request's
        behalf, which consume device time whether or not they commit."""
        self._service[user] = self._service.get(user, 0) + int(n_tokens)

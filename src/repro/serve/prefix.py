"""Radix prefix index for paged-KV prefix caching.

Real serving traffic is dominated by repeated prompt prefixes (shared system
prompts, multi-turn histories). This module maps *page-granular chain
hashes* of prompt tokens to KV pool blocks so a new request can share the
blocks a finished (or still-running) request already filled, instead of
recomputing and re-storing identical KV rows — the serving analogue of the
Occamy roadmap's amortize-the-shared-structure theme.

The index is radix-shaped without storing a tree: page ``i``'s hash chains
over page ``i-1``'s hash plus page ``i``'s tokens, so walking pages
left-to-right until the first miss *is* the radix descent, and two prompts
share an entry exactly when they share the whole token prefix up to that
page boundary.

Block lifetime is coordinated with :class:`repro.serve.engine.BlockAllocator`
refcounts:

* a **live** indexed block (refcount >= 1) is pinned — eviction never
  touches it;
* a **cached** indexed block (refcount 0) stays resident after its last
  owner finished, and is evictable LRU (lookup hits refresh recency) when
  the allocator runs out of free blocks or the ``max_cached`` cap
  (``--prefix-lru``) is exceeded;
* an indexed block is never on the free list.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np

#: hash-chain seed; any fixed int works (the index is engine-local).
_SEED = 0x9E3779B9


def page_hashes(tokens, page_size: int) -> list[int]:
    """Chain hashes of the *full* ``page_size``-token pages of ``tokens``.

    ``h[i] = hash((h[i-1], tokens[i*page : (i+1)*page]))`` — equal hashes
    imply equal whole-prefix token chains (up to Python-hash collisions,
    which page-chaining makes astronomically unlikely within one process).
    A trailing partial page is never hashed: only fully-written pages are
    shareable.
    """
    toks = np.asarray(tokens)
    h = _SEED ^ page_size
    out = []
    for i in range(len(toks) // page_size):
        page = tuple(int(t) for t in toks[i * page_size:(i + 1) * page_size])
        h = hash((h,) + page)
        out.append(h)
    return out


class PrefixIndex:
    """LRU radix index: page chain hash -> pool block id.

    ``max_cached`` bounds how many refcount-0 blocks the index may retain
    (0 = unbounded, i.e. bounded only by pool pressure via
    :meth:`evict_one`). The index never owns block storage — it only pins
    ids; all refcounting goes through the allocator passed into each call.
    """

    def __init__(self, page_size: int, max_cached: int = 0, metrics=None):
        from repro.obs.metrics import MetricsRegistry
        self.page_size = page_size
        self.max_cached = max_cached
        self._h2b: OrderedDict[int, int] = OrderedDict()  # MRU at the end
        self._b2h: dict[int, int] = {}
        self._parent: dict[int, int | None] = {}   # chain links (radix edges)
        self._nchild: dict[int, int] = {}
        self._n_cached = 0                         # refcount-0 indexed blocks
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._c_hits = self.metrics.counter(
            "prefix_index_hits", "lookups matching >= 1 indexed page")
        self._c_hit_tokens = self.metrics.counter(
            "prefix_index_hit_tokens", "prompt tokens served from the index")
        self._c_misses = self.metrics.counter(
            "prefix_index_misses", "lookups matching nothing")
        self._c_published = self.metrics.counter(
            "prefix_index_published", "new hash->block entries registered")
        self._c_evictions = self.metrics.counter(
            "prefix_evictions", "cached blocks reclaimed to the free list")
        # legacy dict interface: short keys alias the registered names
        self.stats = self.metrics.view(aliases={
            "hits": "prefix_index_hits",
            "hit_tokens": "prefix_index_hit_tokens",
            "misses": "prefix_index_misses",
            "published": "prefix_index_published",
            "evictions": "prefix_evictions",
        })

    def __len__(self) -> int:
        return len(self._h2b)

    def is_cached(self, block: int) -> bool:
        """True if ``block`` is pinned by the index (live or refcount-0)."""
        return block in self._b2h

    @property
    def blocks(self) -> set[int]:
        return set(self._b2h)

    # ------------------------------------------------------------------
    def lookup(self, tokens, alloc, *, hashes=None) -> list[int]:
        """Longest indexed chain of full prompt pages, in page order.

        Every matched block is incref'd through ``alloc`` (adopting
        refcount-0 cached blocks back to live) and LRU-refreshed. The
        caller owns the returned references — on admission failure it must
        hand them back via the engine's decref path. ``hashes`` short-
        circuits the token hashing (the engine precomputes them once per
        request, so a head-of-queue request stalled on free blocks does not
        re-hash its whole prompt every engine step).
        """
        blocks = []
        for h in (page_hashes(tokens, self.page_size) if hashes is None
                  else hashes):
            blk = self._h2b.get(h)
            if blk is None:
                break
            self._h2b.move_to_end(h)
            alloc.incref(blk)
            blocks.append(blk)
        if blocks:
            self._c_hits.inc()
            self._c_hit_tokens.inc(len(blocks) * self.page_size)
        else:
            self._c_misses.inc()
        return blocks

    def publish(self, tokens, blocks) -> int:
        """Register a request's fully-written prompt pages (hash -> block).

        ``blocks`` are the slot's pool blocks for the prompt's full pages,
        in page order. Pages whose hash is already indexed are skipped —
        blocks a request *matched* from the index re-register under their
        existing entry, and concurrent cold duplicates stay un-indexed (they
        free normally at finish). Returns the number of new entries.

        Mesh note: block ids are *global* under SPMD serving — every shard
        of a KV-head-sharded pool holds its slice of the same block row, so
        one index entry is valid on every device and a prefix hit (or a
        prefill->decode pool handoff) never moves tensor bytes, it only
        republishes ids through block tables.
        """
        n = 0
        prev = None
        for h, blk in zip(page_hashes(tokens, self.page_size), blocks):
            if h in self._h2b or blk in self._b2h:
                prev = h if h in self._h2b else None
                continue
            self._h2b[h] = blk
            self._b2h[blk] = h
            parent = prev if prev in self._h2b else None
            self._parent[h] = parent
            if parent is not None:
                self._nchild[parent] = self._nchild.get(parent, 0) + 1
            prev = h
            n += 1
        self._c_published.inc(n)
        return n

    # ------------------------------------------------------------------
    # cached-block accounting: the allocator notifies on every live<->cached
    # transition, so n_evictable is O(1) instead of an O(index) scan per
    # engine step
    def note_cached(self, block: int) -> None:
        """An indexed block's refcount just hit 0 (retained, not freed)."""
        if block not in self._b2h:
            raise RuntimeError(f"retain of unindexed block {block} "
                               "would leak it")
        self._n_cached += 1

    def note_adopted(self, block: int) -> None:
        """A refcount-0 cached block just went live again (prefix hit)."""
        self._n_cached -= 1

    def n_evictable(self, alloc) -> int:
        """Refcount-0 cached blocks the index could hand back to the pool."""
        return self._n_cached

    def evict_one(self, alloc) -> bool:
        """Drop one least-recently-used refcount-0 cached block back to the
        allocator's free list. Live (refcount > 0) entries are never
        evicted. Returns False when nothing is evictable.

        Victims are chosen *childless-first* (radix leaves): evicting a
        chain's head before its tail would leave the suffix entries
        unreachable — lookup walks from page 0, so a missing head makes
        every descendant dead weight still occupying pool blocks. Only when
        every refcount-0 entry has children does the LRU head get evicted
        anyway (reclaiming a block beats stranding admission)."""
        victim = fallback = None
        for h, blk in self._h2b.items():          # oldest first
            if alloc.ref[blk] != 0:
                continue
            if not self._nchild.get(h, 0):
                victim = (h, blk)
                break
            if fallback is None:
                fallback = (h, blk)
        victim = victim or fallback
        if victim is None:
            return False
        h, blk = victim
        del self._h2b[h]
        del self._b2h[blk]
        self._nchild.pop(h, None)
        parent = self._parent.pop(h, None)
        if parent is not None and parent in self._nchild:
            self._nchild[parent] -= 1
        self._n_cached -= 1
        alloc.free_block(blk)
        self._c_evictions.inc()
        return True

    def trim(self, alloc) -> None:
        """Enforce the ``max_cached`` cap on refcount-0 retained blocks."""
        if not self.max_cached:
            return
        while self._n_cached > self.max_cached and self.evict_one(alloc):
            pass

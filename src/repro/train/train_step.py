"""Train / prefill / decode step factories.

``make_train_step`` builds the jit-able step: loss → grads → clip → optimizer,
with optional microbatch gradient accumulation (``lax.scan``) that overlaps
each microbatch's backward collectives with the next microbatch's compute —
the XLA-native analogue of Ogopogo hiding collective latency inside the NoC.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, StrategyConfig
from repro.models import decode_step as model_decode_step
from repro.models import forward, lm_loss, logits_fn
from repro.optim.optimizers import (Optimizer, apply_updates,
                                    clip_by_global_norm)

PyTree = Any


def batch_template(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Shapes of one training/prefill batch (ints are tokens; frontends get
    precomputed embeddings per the assignment)."""
    B, S = shape.global_batch, shape.seq_len
    d = jnp.dtype(cfg.dtype)
    tpl: dict = {}
    if cfg.frontend == "vision":
        s_txt = S - cfg.n_frontend_tokens
        tpl["tokens"] = jax.ShapeDtypeStruct((B, s_txt), jnp.int32)
        tpl["extra_embeds"] = jax.ShapeDtypeStruct((B, cfg.n_frontend_tokens, cfg.d_model), d)
        tpl["targets"] = jax.ShapeDtypeStruct((B, s_txt), jnp.int32)
    elif cfg.frontend == "audio":
        tpl["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        tpl["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder.n_frames, cfg.d_model), d)
        tpl["targets"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:
        tpl["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        tpl["targets"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return tpl


def make_loss_fn(cfg: ModelConfig, strategy: StrategyConfig, part=None):
    loss_chunk = cfg.loss_chunk
    if strategy.chunked_loss and not loss_chunk:
        loss_chunk = 512

    def loss_fn(params, batch):
        return lm_loss(params, cfg, batch["tokens"], batch["targets"],
                       extra_embeds=batch.get("extra_embeds"),
                       frames=batch.get("frames"), part=part,
                       loss_chunk=loss_chunk)
    return loss_fn


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    strategy: StrategyConfig, part=None, *,
                    clip_norm: float = 1.0):
    loss_fn = make_loss_fn(cfg, strategy, part)
    n_mb = max(strategy.overlap_microbatches, 1)

    def train_step(state, batch):
        params = state["params"]

        if n_mb == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                return x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def body(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return jax.tree.map(jnp.add, acc, (l, g)), None

            zero = (jnp.zeros(()),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss, grads), _ = jax.lax.scan(body, zero, mbs)
            loss = loss / n_mb
            grads = jax.tree.map(lambda g: g / n_mb, grads)

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = optimizer.update(grads, state["opt"], params,
                                              state["step"])
        params = apply_updates(params, updates)
        new_state = {"params": params, "opt": opt_state,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, "grad_norm": gnorm}
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, part=None):
    """Prefill: run the full prompt, fill the decode cache, return the final
    position's logits only (never materializes (B, S, V))."""
    def prefill_step(params, batch, cache):
        hidden, cache, _ = forward(params, cfg, batch["tokens"],
                                   extra_embeds=batch.get("extra_embeds"),
                                   frames=batch.get("frames"),
                                   cache=cache, part=part)
        last = hidden[:, -1:, :]
        logits = logits_fn(params, cfg, last, part)
        return logits[:, 0], cache
    return prefill_step


def make_serve_step(cfg: ModelConfig, part=None, *, sample: bool = False):
    """One decode step: token in, logits/next-token out, cache updated."""
    def serve_step(params, cache, tokens, pos, rng=None):
        logits, cache = model_decode_step(params, cfg, cache, tokens, pos,
                                          part=part)
        if sample:
            nxt = jax.random.categorical(rng, logits[:, 0] / 0.8, axis=-1)
            return nxt[:, None], cache
        return logits, cache
    return serve_step


def train_state_template(cfg: ModelConfig, optimizer: Optimizer):
    """ShapeDtypeStruct pytree of the full train state (no allocation)."""
    from repro.models import init as model_init

    params_shape = jax.eval_shape(lambda: model_init(jax.random.PRNGKey(0), cfg))
    opt_shape = jax.eval_shape(optimizer.init, params_shape)
    return {"params": params_shape, "opt": opt_shape,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def serve_params_template(cfg: ModelConfig):
    """Serving params: compute-dtype (bf16) copies of the weights."""
    from repro.models import init as model_init

    params_shape = jax.eval_shape(lambda: model_init(jax.random.PRNGKey(0), cfg))
    dt = jnp.dtype(cfg.dtype)

    def cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating) and x.dtype != jnp.float32:
            return x
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(x.shape, dt)
        return x
    return jax.tree.map(cast, params_shape)

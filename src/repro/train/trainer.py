"""Production trainer: checkpoint/restart, preemption, straggler watch,
fault injection, elastic resume.

The fault-tolerance story is the software analogue of the paper's D2D channel
allocator (calibrate, detect faults, disable, continue):

- **checkpoint/restart** — async atomic checkpoints every N steps; on start
  the trainer restores the latest one (params, optimizer, step, data state).
- **preemption** — SIGTERM/SIGINT triggers a final blocking checkpoint and a
  clean exit (exit code 0: the scheduler reschedules us).
- **node failure** — ``FaultInjector`` raises a simulated device failure at a
  configured step/probability; ``run_with_restarts`` catches it, restores the
  last checkpoint, and continues — the restart path is *exercised*, not
  hypothetical.
- **straggler mitigation** — per-step wall time is compared to k× the rolling
  median; slow steps are counted and reported through ``on_straggler`` (on a
  fleet this hook re-dispatches the slow worker's shard).
"""
from __future__ import annotations

import signal
import time
from collections import deque
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np

from repro.ckpt import AsyncCheckpointer, restore_checkpoint
from repro.configs.base import ModelConfig, ShapeConfig, StrategyConfig
from repro.core.sharding import Partitioner
from repro.data import Prefetcher, SyntheticLM, device_put_batch
from repro.models import init as model_init
from repro.optim.optimizers import Optimizer
from repro.train.train_step import make_train_step

PyTree = Any


class SimulatedDeviceFailure(RuntimeError):
    """Stands in for a TPU worker dropping out mid-step."""


@dataclass
class FaultInjector:
    """Raise a SimulatedDeviceFailure at ``at_step`` (once) and/or with
    probability ``prob`` per step (seeded — deterministic tests)."""
    at_step: int = -1
    prob: float = 0.0
    seed: int = 0
    _fired: bool = field(default=False, repr=False)

    def check(self, step: int):
        if step == self.at_step and not self._fired:
            self._fired = True
            raise SimulatedDeviceFailure(f"injected failure at step {step}")
        if self.prob > 0.0:
            r = np.random.default_rng((self.seed << 16) ^ step).random()
            if r < self.prob:
                raise SimulatedDeviceFailure(f"injected failure at step {step}")


@dataclass
class StragglerWatch:
    """Rolling-median step-time deadline (k × median over a window)."""
    k: float = 3.0
    window: int = 32
    min_samples: int = 5
    times: deque = field(default_factory=lambda: deque(maxlen=32))
    n_stragglers: int = 0

    def observe(self, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= self.min_samples:
            med = float(np.median(self.times))
            if dt > self.k * med:
                self.n_stragglers += 1
                is_straggler = True
        self.times.append(dt)
        return is_straggler


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep_last: int = 3
    log_every: int = 10
    straggler_k: float = 3.0
    seed: int = 0
    max_restarts: int = 3


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 strategy: StrategyConfig, optimizer: Optimizer,
                 tcfg: TrainerConfig, *, mesh=None,
                 dataset: SyntheticLM | None = None,
                 fault: FaultInjector | None = None,
                 on_straggler: Callable[[int, float], None] | None = None):
        self.cfg, self.shape, self.strategy = cfg, shape, strategy
        self.optimizer, self.tcfg = optimizer, tcfg
        self.mesh = mesh
        self.fault = fault
        self.on_straggler = on_straggler
        self.dataset = dataset or SyntheticLM(
            cfg.vocab_size, shape.seq_len, shape.global_batch, seed=tcfg.seed)
        self.part = (Partitioner(mesh, strategy, cfg, shape, mode="train")
                     if mesh is not None else None)
        self.ckpt = AsyncCheckpointer(tcfg.ckpt_dir, keep_last=tcfg.keep_last)
        self.straggler = StragglerWatch(k=tcfg.straggler_k)
        self.history: list[dict] = []
        self._stop_requested = False
        self._step_fn = None

    # ------------------------------------------------------------------
    def _build_step(self):
        step = make_train_step(self.cfg, self.optimizer, self.strategy,
                               self.part)
        if self.mesh is not None:
            state_t = self._state_template()
            st_sh = self._state_sharding(state_t)
            batch_sh = self.part.batch_sharding(
                {"tokens": np.zeros((1, 1), np.int32),
                 "targets": np.zeros((1, 1), np.int32)})
            out_sh = (st_sh, {"loss": self.part.scalar_sharding(),
                              "grad_norm": self.part.scalar_sharding()})
            self._batch_sh = batch_sh
            return jax.jit(step, in_shardings=(st_sh, batch_sh),
                           out_shardings=out_sh, donate_argnums=(0,))
        self._batch_sh = None
        return jax.jit(step, donate_argnums=(0,))

    def _state_template(self):
        from repro.train.train_step import train_state_template
        return train_state_template(self.cfg, self.optimizer)

    def _state_sharding(self, state_t):
        assert self.part is not None
        return {"params": self.part.params_sharding(state_t["params"]),
                "opt": {k: self.part.params_sharding(v)
                        for k, v in state_t["opt"].items()},
                "step": self.part.scalar_sharding()}

    def init_state(self) -> PyTree:
        params = model_init(jax.random.PRNGKey(self.tcfg.seed), self.cfg)
        opt = self.optimizer.init(params)
        state = {"params": params, "opt": opt,
                 "step": jax.numpy.zeros((), jax.numpy.int32)}
        if self.mesh is not None:
            sh = self._state_sharding(jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
            state = jax.tree.map(jax.device_put, state, sh)
        return state

    # ------------------------------------------------------------------
    def restore_or_init(self) -> tuple[PyTree, int]:
        latest = self.ckpt.latest_step()
        if latest is None:
            return self.init_state(), 0
        template = self._state_template()
        shardings = (self._state_sharding(template)
                     if self.mesh is not None else None)
        state, meta = restore_checkpoint(self.tcfg.ckpt_dir, template,
                                         step=latest, shardings=shardings)
        data_step = int(meta.get("data_step", latest))
        return state, data_step

    def save(self, step: int, state: PyTree, blocking: bool = False):
        self.ckpt.save(step, state,
                       metadata={"data_step": int(step),
                                 "data_state": self.dataset.state(step),
                                 "arch": self.cfg.name,
                                 "mesh": (dict(self.mesh.shape)
                                          if self.mesh is not None else None)},
                       blocking=blocking)

    # ------------------------------------------------------------------
    def train(self, *, install_signal_handlers: bool = False) -> dict:
        """One trainer incarnation: restore → loop → final checkpoint."""
        if self._step_fn is None:
            self._step_fn = self._build_step()
        state, start = self.restore_or_init()
        if install_signal_handlers:
            def _handler(signum, frame):
                self._stop_requested = True
            signal.signal(signal.SIGTERM, _handler)
            signal.signal(signal.SIGINT, _handler)

        pf = Prefetcher(self.dataset, start=start, depth=2)
        losses = []
        try:
            for step in range(start, self.tcfg.steps):
                t0 = time.perf_counter()
                got_step, host_batch = pf.get()
                assert got_step == step, (got_step, step)
                if self._batch_sh is not None:
                    batch = device_put_batch(host_batch, self._batch_sh)
                else:
                    batch = host_batch
                # fault injection happens "inside" the step boundary, like a
                # worker dying mid-collective
                if self.fault is not None:
                    self.fault.check(step)
                state, metrics = self._step_fn(state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                if self.straggler.observe(dt) and self.on_straggler:
                    self.on_straggler(step, dt)
                losses.append(loss)
                self.history.append({"step": step, "loss": loss, "dt": dt})
                if (step + 1) % self.tcfg.ckpt_every == 0:
                    self.save(step + 1, state)
                if self._stop_requested:
                    self.save(step + 1, state, blocking=True)
                    return {"state": state, "stopped_at": step + 1,
                            "losses": losses, "preempted": True,
                            "n_stragglers": self.straggler.n_stragglers}
        finally:
            pf.close()
        self.save(self.tcfg.steps, state, blocking=True)
        self.ckpt.wait()
        return {"state": state, "stopped_at": self.tcfg.steps,
                "losses": losses, "preempted": False,
                "n_stragglers": self.straggler.n_stragglers}

    def run_with_restarts(self) -> dict:
        """Supervisor loop: restart from the latest checkpoint on simulated
        device failures, up to ``max_restarts`` times."""
        restarts = 0
        while True:
            try:
                out = self.train()
                out["restarts"] = restarts
                return out
            except SimulatedDeviceFailure:
                restarts += 1
                self.ckpt.wait()
                if restarts > self.tcfg.max_restarts:
                    raise

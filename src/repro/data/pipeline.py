"""Deterministic, checkpointable, host-sharded synthetic LM data pipeline.

Production shape: every batch is a pure function of ``(seed, step)``, so

- the iterator state is two integers (trivially checkpointable — the paper's
  C6 restart story needs the *data* position too, not just params),
- every data-parallel host can generate exactly its shard without
  coordination (``host_slice``), and
- an elastic restart onto a different host count replays the same global
  stream (the global batch is seeded per step, then sliced per host).

The synthetic stream is not iid noise: tokens follow a hidden per-document
Markov chain (banded transition structure + a few "motif" loops), so a real
model trained on it shows a real, monotonically decreasing loss — tests and
examples assert learning actually happens.

A ``MixtureDataset`` weights several sources (different chain temperatures /
vocab bands), mirroring production multi-corpus mixing; mixing is also a pure
function of step.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field
from typing import Any, Iterator

import numpy as np

PyTree = Any


# --------------------------------------------------------------------------
# token sources
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class MarkovSpec:
    """A banded Markov chain over the vocab with motif loops."""
    vocab_size: int
    bandwidth: int = 16          # next token within +-bandwidth of current
    n_motifs: int = 8            # short deterministic loops the model can learn
    motif_len: int = 12
    temperature: float = 1.0
    doc_len: int = 512           # average document length (resets the chain)


def _motif_table(spec: MarkovSpec, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed ^ 0x5EED)
    return rng.integers(0, spec.vocab_size,
                        size=(spec.n_motifs, spec.motif_len), dtype=np.int64)


def _gen_markov(spec: MarkovSpec, rng: np.random.Generator, batch: int,
                seq: int, motifs: np.ndarray) -> np.ndarray:
    """Vectorized chain: each row mixes banded random-walk steps with motif
    replay. Returns (batch, seq) int32 in [0, vocab)."""
    V = spec.vocab_size
    out = np.empty((batch, seq), dtype=np.int64)
    cur = rng.integers(0, V, size=batch)
    in_motif = np.zeros(batch, dtype=np.int64)      # 0 = free-running
    motif_id = np.zeros(batch, dtype=np.int64)
    motif_pos = np.zeros(batch, dtype=np.int64)
    for t in range(seq):
        # document reset
        reset = rng.random(batch) < (1.0 / spec.doc_len)
        cur = np.where(reset, rng.integers(0, V, size=batch), cur)
        in_motif = np.where(reset, 0, in_motif)
        # motif entry
        enter = (in_motif == 0) & (rng.random(batch) < 0.05)
        motif_id = np.where(enter, rng.integers(0, spec.n_motifs, size=batch),
                            motif_id)
        motif_pos = np.where(enter, 0, motif_pos)
        in_motif = np.where(enter, 1, in_motif)
        # banded random walk step
        step = rng.integers(-spec.bandwidth, spec.bandwidth + 1, size=batch)
        walk = np.mod(cur + step * max(spec.temperature, 1e-3), V).astype(np.int64)
        replay = motifs[motif_id, np.minimum(motif_pos, spec.motif_len - 1)]
        cur = np.where(in_motif == 1, replay, walk)
        motif_pos = in_motif * (motif_pos + 1)
        in_motif = np.where(motif_pos >= spec.motif_len, 0, in_motif)
        out[:, t] = cur
    return out.astype(np.int32)


# --------------------------------------------------------------------------
# datasets
# --------------------------------------------------------------------------
@dataclass
class SyntheticLM:
    """Deterministic synthetic LM stream: batch(step) is a pure function."""
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    spec: MarkovSpec | None = None

    def __post_init__(self):
        if self.spec is None:
            self.spec = MarkovSpec(vocab_size=self.vocab_size)
        self._motifs = _motif_table(self.spec, self.seed)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """The global batch for ``step``: {tokens, targets} (B, S) int32."""
        rng = np.random.default_rng((self.seed << 20) ^ step)
        toks = _gen_markov(self.spec, rng, self.global_batch, self.seq_len + 1,
                           self._motifs)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def host_slice(self, batch: dict, host_id: int, n_hosts: int) -> dict:
        """This host's contiguous shard of the global batch."""
        per = self.global_batch // n_hosts
        lo = host_id * per
        return {k: v[lo:lo + per] for k, v in batch.items()}

    # iterator protocol with explicit, checkpointable state -----------------
    def state(self, step: int) -> dict:
        return {"seed": self.seed, "step": int(step)}

    @staticmethod
    def from_state(state: dict, *, vocab_size: int, seq_len: int,
                   global_batch: int) -> tuple["SyntheticLM", int]:
        ds = SyntheticLM(vocab_size, seq_len, global_batch,
                         seed=int(state["seed"]))
        return ds, int(state["step"])


@dataclass
class MixtureDataset:
    """Weighted mixture of sources; assignment of rows to sources is a pure
    function of step (deterministic multi-corpus mixing)."""
    sources: list[SyntheticLM]
    weights: list[float]
    seed: int = 0

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        b = self.sources[0].global_batch
        rng = np.random.default_rng((self.seed << 21) ^ step)
        w = np.asarray(self.weights, dtype=np.float64)
        w = w / w.sum()
        assign = rng.choice(len(self.sources), size=b, p=w)
        batches = [s.batch_at(step) for s in self.sources]
        out = {}
        for key in batches[0]:
            stacked = np.stack([batches[i][key][r] for r, i in enumerate(assign)])
            out[key] = stacked
        return out

    def state(self, step: int) -> dict:
        return {"seed": self.seed, "step": int(step),
                "weights": list(self.weights)}


# --------------------------------------------------------------------------
# prefetch
# --------------------------------------------------------------------------
class Prefetcher:
    """Background-thread prefetch (depth-N queue) over ``dataset.batch_at``.

    The producer generates batches for steps ``start, start+1, ...``; consumer
    calls ``get()`` once per step. ``close()`` joins the thread. On restart,
    construct with ``start`` = restored step — determinism makes prefetch
    state-free.
    """

    def __init__(self, dataset, start: int = 0, depth: int = 2):
        self.dataset = dataset
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.dataset.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


# --------------------------------------------------------------------------
# device placement
# --------------------------------------------------------------------------
def device_put_batch(batch: dict, sharding_tree) -> dict:
    """Place a host batch onto the mesh with the partitioner's batch sharding
    (on multihost fleets each host feeds its slice; here: single process)."""
    import jax
    return jax.tree.map(lambda x, s: jax.device_put(x, s), batch,
                        sharding_tree)

from repro.data.pipeline import (MarkovSpec, MixtureDataset, Prefetcher,
                                 SyntheticLM, device_put_batch)

__all__ = ["MarkovSpec", "MixtureDataset", "Prefetcher", "SyntheticLM",
           "device_put_batch"]

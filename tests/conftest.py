"""Shared fixtures. NOTE: no XLA_FLAGS here — unit tests see 1 CPU device;
multi-device tests spawn subprocesses (tests/_subproc.py) so the dry-run's
512-device trick never leaks into smoke tests or benches."""
from __future__ import annotations

import numpy as np
import pytest

from repro.configs import ARCHS, get_arch, reduced


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def tiny(arch: str, **kw):
    """Session-cached reduced config for an assigned arch."""
    cfg = reduced(get_arch(arch))
    return cfg.replace(**kw) if kw else cfg


@pytest.fixture(params=sorted(ARCHS))
def arch_name(request):
    return request.param

"""Hypothesis shim: the real library when installed, a skip-only fallback
otherwise (minimal containers ship without a hypothesis wheel; property tests
skip rather than killing collection for the whole suite).

With the real library, two settings profiles are registered:

* ``ci`` — fixed-seed/deterministic (``derandomize=True``), fewer examples:
  the profile the CI ``pytest -m property`` step runs, so a red property
  job is reproducible rather than a roll of the dice;
* ``dev`` — more examples, randomized: what local runs get.

Select explicitly with ``HYPOTHESIS_PROFILE=ci|dev``; otherwise ``ci`` is
auto-picked when the ``CI`` env var is set. Tests that pass their own
``@settings(...)`` keep those values (profiles only fill the defaults).
"""
from __future__ import annotations

import os

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
    settings.register_profile(
        "ci", max_examples=25, derandomize=True, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile("dev", max_examples=75, deadline=None)
    settings.load_profile(os.environ.get(
        "HYPOTHESIS_PROFILE", "ci" if os.environ.get("CI") else "dev"))
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def settings(*_a, **_k):
        return lambda f: f

    def given(*_a, **_k):
        def deco(f):
            def _skipped():
                pytest.skip("hypothesis not installed")
            _skipped.__name__ = f.__name__
            _skipped.__doc__ = f.__doc__
            return _skipped
        return deco

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

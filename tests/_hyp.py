"""Hypothesis shim: the real library when installed, a skip-only fallback
otherwise (minimal containers ship without a hypothesis wheel; property tests
skip rather than killing collection for the whole suite)."""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    def settings(*_a, **_k):
        return lambda f: f

    def given(*_a, **_k):
        def deco(f):
            def _skipped():
                pytest.skip("hypothesis not installed")
            _skipped.__name__ = f.__name__
            _skipped.__doc__ = f.__doc__
            return _skipped
        return deco

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

__all__ = ["given", "settings", "st"]

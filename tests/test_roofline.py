"""Roofline machinery: HLO collective parsing, cost analysis, model FLOPs."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.roofline import (_shape_bytes, analyze_costs, model_flops,
                                 parse_collectives)
from repro.core.topology import CHIP, dtype_peak_flops, roofline_time

HLO = """
ENTRY main {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ag = f32[64,128]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = bf16[1024]{0} all-reduce(%x), replica_groups={{0,1},{2,3}}, to_apply=%add
  %rs = f32[4,32]{1,0} reduce-scatter(%y), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = u8[100]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = f32[8,8]{1,0} all-to-all(%w), replica_groups={{0,1,2,3}}
  %ars = f32[64]{0} all-reduce-start(%q), replica_groups={}
  %ard = f32[64]{0} all-reduce-done(%ars)
  ROOT %t = tuple(%ag)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[16,128]") == 16 * 128 * 4
    assert _shape_bytes("bf16[10]") == 20
    assert _shape_bytes("u8[7]") == 7
    assert _shape_bytes("f32[]") == 4  # scalar
    assert _shape_bytes("(f32[4], s8[8])") == 24  # tuples summed


def test_parse_collectives_kinds_and_bytes():
    out = parse_collectives(HLO)
    bk = out["bytes_by_kind"]
    assert bk["all-gather"] == 64 * 128 * 4
    assert bk["all-reduce"] == 1024 * 2 + 64 * 4  # ar + ar-start (done skipped)
    assert bk["reduce-scatter"] == 4 * 32 * 4
    assert bk["collective-permute"] == 100
    assert bk["all-to-all"] == 8 * 8 * 4
    assert out["count_by_kind"]["all-reduce"] == 2
    assert out["total_bytes"] == sum(bk.values())


def test_async_done_not_double_counted():
    out = parse_collectives(HLO)
    # only the -start of the async pair contributes
    assert out["count_by_kind"]["all-reduce"] == 2


def test_analyze_costs_bottleneck():
    r = analyze_costs(flops_per_dev=197e12, bytes_per_dev=1e9,
                      collective_bytes_per_dev=1e9,
                      collectives={}, arch="qwen3-0.6b", shape="train_4k",
                      n_chips=256)
    roof = r["roofline"]
    # 1s compute vs ~1.2ms memory vs 20ms collective
    assert roof["bottleneck"] == "compute"
    np.testing.assert_allclose(roof["compute_s"], 1.0, rtol=1e-6)
    np.testing.assert_allclose(roof["memory_s"], 1e9 / 819e9, rtol=1e-6)
    np.testing.assert_allclose(roof["collective_s"], 1e9 / 50e9, rtol=1e-6)
    assert roof["roofline_fraction"] == pytest.approx(1.0)


def test_d2d_serve_decode_term():
    """KV-head-sharded decode: the d2d floor is the attention-output
    all-gather plus sampled ids, scaled by (N-1)/N; 1-way shards are free;
    analyze_costs only grows a fourth term when the bytes are passed."""
    from repro.configs import get_arch, reduced
    from repro.core.memfloor import d2d_bytes_serve_decode

    cfg = reduced(get_arch("qwen3-0.6b"))
    assert d2d_bytes_serve_decode(cfg, 8, 1)["total"] == 0.0

    d4 = d2d_bytes_serve_decode(cfg, 8, 4)
    n_attn = sum(1 for sp in cfg.all_layers()
                 if sp.mixer in ("full", "local"))
    want = 8 * cfg.n_heads * cfg.resolved_head_dim * 2 * n_attn * 0.75
    assert d4["attn_out_allgather"] == pytest.approx(want)
    assert d4["sampled_ids"] == pytest.approx(8 * 4 * 0.75)
    assert d4["total"] == pytest.approx(want + 8 * 4 * 0.75)
    # more shards move more bytes per device ((N-1)/N grows), never fewer
    d8 = d2d_bytes_serve_decode(cfg, 8, 8)
    assert d8["total"] > d4["total"]

    base = dict(flops_per_dev=1e12, bytes_per_dev=1e9,
                collective_bytes_per_dev=0.0, collectives={},
                arch="qwen3-0.6b", shape="decode_32k", n_chips=4)
    r = analyze_costs(**base)
    assert "d2d_s" not in r["roofline"]
    r2 = analyze_costs(**base, d2d_bytes_per_dev=d4["total"])
    assert r2["roofline"]["d2d_s"] == pytest.approx(
        d4["total"] / CHIP.ici_link_bw)
    # a d2d-dominated step flips the bottleneck
    r3 = analyze_costs(**base, d2d_bytes_per_dev=1e12)
    assert r3["roofline"]["bottleneck"] == "d2d"


def test_model_flops_formulas():
    """6·N·D for training; gemma2 train_4k ≈ 6 × 27.2e9 × 1.05e6 tokens."""
    mf = model_flops("gemma2-27b", "train_4k")
    tokens = 256 * 4096
    assert 0.8 * 6 * 27e9 * tokens < mf < 1.3 * 6 * 29e9 * tokens
    # decode: one token per sequence
    mf_dec = model_flops("gemma2-27b", "decode_32k")
    assert mf_dec == pytest.approx(mf / tokens * 128 / 3.0, rel=0.01)


def test_moe_uses_active_params():
    """deepseek-moe 16B total / ~3B active: train flops reflect active only."""
    from repro.configs import get_arch
    pc = get_arch("deepseek-moe-16b").param_count()
    assert pc["total"] / pc["active"] > 4.0
    mf = model_flops("deepseek-moe-16b", "train_4k")
    assert mf < 6 * 0.35 * pc["total"] * 256 * 4096


def test_dtype_peaks():
    assert dtype_peak_flops("bfloat16") == CHIP.peak_bf16_flops
    assert dtype_peak_flops("float32") == pytest.approx(98.5e12)
    assert dtype_peak_flops("float8_e4m3fn") == 2 * CHIP.peak_bf16_flops


def test_roofline_time_formulas():
    t = roofline_time(flops=197e12 * 256, bytes_hbm=819e9 * 256,
                      bytes_collective=50e9 * 256, n_chips=256)
    for v in t.values():
        np.testing.assert_allclose(v, 1.0, rtol=1e-6)


def test_dryrun_artifacts_consistent():
    """If the sweep has produced artifacts, sanity-check them."""
    import json
    from pathlib import Path
    d = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    files = sorted(d.glob("*__16x16__*.json")) if d.exists() else []
    if not files:
        pytest.skip("no dry-run artifacts yet")
    for f in files:
        r = json.loads(f.read_text())
        if r.get("status") == "skipped":
            continue
        assert r["status"] == "ok", f"{f.name}: {r.get('error')}"
        assert r["n_chips"] == 256
        if "roofline" in r:
            roof = r["roofline"]
            assert roof["bottleneck"] in ("compute", "memory", "collective")
            assert 0 <= roof["roofline_fraction"] <= 1.0 + 1e-9

"""Hypothesis property tests on system invariants."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from tests._hyp import given, settings, st

from repro.core.collectives import _quantize_int8
from repro.kernels import ref
from repro.kernels.ops import _pad_to
from repro.models.layers import softcap


# --------------------------------------------------------------------------
# linear recurrence algebra (the SSM/RG-LRU foundation)
# --------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4), st.integers(1, 40), st.integers(1, 8),
       st.integers(0, 1000))
def test_lru_scan_composition(b, l, d, seed):
    """h(a⊕b streams) == run a then continue with b: the recurrence is a
    monoid action, which is what makes chunked kernels valid."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.uniform(k1, (b, 2 * l, d), minval=0.2, maxval=0.99)
    x = jax.random.normal(k2, (b, 2 * l, d))
    full = ref.lru_scan_ref(a, x)
    h_mid = full[:, l - 1 + l * 0, :]  # state after first half... compute:
    first = ref.lru_scan_ref(a[:, :l], x[:, :l])
    second = ref.lru_scan_ref(a[:, l:], x[:, l:], h0=first[:, -1])
    np.testing.assert_allclose(np.asarray(jnp.concatenate([first, second], 1)),
                               np.asarray(full), rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_lru_linearity(seed):
    """The recurrence is linear in the inputs b."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = jax.random.uniform(k1, (1, 10, 4), minval=0.1, maxval=0.9)
    x = jax.random.normal(k2, (1, 10, 4))
    y = jax.random.normal(k3, (1, 10, 4))
    hx = ref.lru_scan_ref(a, x)
    hy = ref.lru_scan_ref(a, y)
    hxy = ref.lru_scan_ref(a, 2.0 * x - 3.0 * y)
    np.testing.assert_allclose(np.asarray(hxy), 2 * np.asarray(hx)
                               - 3 * np.asarray(hy), rtol=1e-3, atol=1e-4)


# --------------------------------------------------------------------------
# quantization (gradient compression wire format)
# --------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1,
                max_size=64))
def test_int8_quantization_error_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, scale = _quantize_int8(x)
    deq = q.astype(jnp.float32) * scale
    amax = float(jnp.abs(x).max())
    assert float(jnp.abs(x - deq).max()) <= amax / 127.0 + 1e-6
    assert int(jnp.abs(q).max()) <= 127


# --------------------------------------------------------------------------
# numerics helpers
# --------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.integers(1, 200), st.integers(1, 64))
def test_pad_to_shape_contract(n, m):
    x = jnp.zeros((n, 7))
    padded, did = _pad_to(x, (m,), (0,))
    assert padded.shape[0] % m == 0
    assert padded.shape[0] - n < m
    assert did == (n % m != 0)


@settings(max_examples=30, deadline=None)
@given(st.floats(-30, 30), st.floats(0.5, 100))
def test_softcap_is_contraction(v, cap):
    """|softcap(x)| <= min(|x|, cap) and sign-preserving."""
    x = jnp.asarray(v, jnp.float32)
    y = float(softcap(x, float(cap)))
    assert abs(y) <= min(abs(v), cap) + 1e-5
    assert y * v >= -1e-9


# --------------------------------------------------------------------------
# cross-entropy invariants
# --------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.integers(2, 50), st.integers(0, 100))
def test_xent_uniform_is_log_v(v, seed):
    from repro.models.transformer import _xent
    logits = jnp.zeros((2, 3, v))
    targets = jax.random.randint(jax.random.PRNGKey(seed), (2, 3), 0, v)
    np.testing.assert_allclose(float(_xent(logits, targets)), np.log(v),
                               rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 100))
def test_xent_shift_invariant(seed):
    from repro.models.transformer import _xent
    k = jax.random.PRNGKey(seed)
    logits = jax.random.normal(k, (2, 4, 16))
    targets = jax.random.randint(k, (2, 4), 0, 16)
    a = float(_xent(logits, targets))
    b = float(_xent(logits + 7.5, targets))
    np.testing.assert_allclose(a, b, rtol=1e-4)


# --------------------------------------------------------------------------
# MoE packing roundtrip
# --------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.integers(1, 30), st.integers(1, 1000))
def test_moe_pack_combine_roundtrip(t, seed):
    """dispatch(x) then combine(identity-expert) == gate-weighted x when
    capacity is ample (no drops)."""
    from repro.models.moe import _combine_sort, _dispatch_sort
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    E, kk = 4, 2
    x = jax.random.normal(k1, (t, 8))
    idx = jax.random.randint(k2, (t, kk), 0, E)
    gate = jnp.full((t, kk), 0.5)
    C = t * kk  # ample
    xe, meta = _dispatch_sort(x, gate, idx, C, E)
    y = _combine_sort(xe, meta, gate, t)
    # identity expert => y = sum_k gate * x = x (gates sum to 1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-4,
                               atol=1e-5)

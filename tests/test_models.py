"""Per-arch smoke tests (reduced same-family configs) + decode equivalence.

Every one of the 10 assigned architectures: instantiate reduced config, run a
forward/train step on CPU, assert output shapes and no NaNs. Then the serving
contract: prefill+decode logits == full-forward logits, per family.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch, reduced, strategy
from repro.models import decode_step, forward, init, lm_loss, logits_fn
from repro.models.cache import init_cache
from repro.optim.optimizers import adamw
from repro.train.train_step import make_train_step


def _batch_for(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    batch["targets"] = batch["tokens"]
    if cfg.frontend == "audio":
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (B, cfg.encoder.n_frames, cfg.d_model)), jnp.bfloat16)
    if cfg.frontend == "vision":
        batch["extra_embeds"] = jnp.asarray(rng.standard_normal(
            (B, cfg.n_frontend_tokens, cfg.d_model)), jnp.bfloat16)
    return batch


# --------------------------------------------------------------------------
# smoke: forward + train step for every assigned arch (reduced config)
# --------------------------------------------------------------------------
def test_arch_smoke(arch_name):
    cfg = reduced(get_arch(arch_name))
    params = init(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)
    # forward: shapes + finite
    hidden, _, aux = forward(params, cfg, batch["tokens"],
                             frames=batch.get("frames"),
                             extra_embeds=batch.get("extra_embeds"))
    S_tot = batch["tokens"].shape[1] + (cfg.n_frontend_tokens or 0)
    assert hidden.shape == (2, S_tot, cfg.d_model)
    assert bool(jnp.isfinite(hidden.astype(jnp.float32)).all())
    logits = logits_fn(params, cfg, hidden[:, -1:, :])
    assert logits.shape[-1] >= cfg.vocab_size
    assert bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all())
    # one full train step: loss finite, params move
    opt = adamw(1e-3)
    step = jax.jit(make_train_step(cfg, opt, strategy("ramora")))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    state2, metrics = step(state, {k: batch[k] for k in batch})
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, state2["params"])
    assert max(jax.tree.leaves(moved)) > 0, "no parameter moved"


def test_all_archs_registered():
    assert len(ARCHS) == 10
    fams = {c.family for c in ARCHS.values()}
    assert fams == {"dense", "hybrid", "moe", "ssm", "audio", "vlm"}


@pytest.mark.parametrize("name,total_b", [
    ("gemma2-27b", 27e9), ("deepseek-7b", 7e9), ("minicpm-2b", 2.7e9),
    ("qwen3-0.6b", 0.6e9), ("falcon-mamba-7b", 7e9),
    ("deepseek-moe-16b", 16e9), ("qwen2-moe-a2.7b", 14e9),
    ("llava-next-mistral-7b", 7e9), ("recurrentgemma-2b", 2.7e9),
])
def test_param_counts_match_billing(name, total_b):
    """Analytic param counts land within 25% of the arch's nameplate size."""
    pc = get_arch(name).param_count()
    assert 0.75 * total_b < pc["total"] < 1.35 * total_b, pc["total"]


def test_param_count_matches_init():
    """Analytic count equals the actual initialized leaf-count (tiny cfg)."""
    cfg = reduced(get_arch("deepseek-7b"))
    params = init(jax.random.PRNGKey(0), cfg)
    n_real = sum(x.size for x in jax.tree.leaves(params))
    n_analytic = cfg.param_count()["total"]
    # analytic skips norms/small vectors — must agree within 2%
    assert abs(n_real - n_analytic) / n_real < 0.02


# --------------------------------------------------------------------------
# decode equivalence: prefill + decode == full forward (per family)
# --------------------------------------------------------------------------
DECODE_FAMILIES = ["qwen3-0.6b", "gemma2-27b", "recurrentgemma-2b",
                   "falcon-mamba-7b", "qwen2-moe-a2.7b", "whisper-tiny",
                   "llava-next-mistral-7b"]


@pytest.mark.parametrize("name", DECODE_FAMILIES)
def test_prefill_decode_matches_forward(name):
    """logits(prefill S tokens, then decode token S) == logits(forward S+1).

    MoE archs need ample capacity: the full-sequence oracle drops tokens at
    capacity_factor 1.25 while single-token decode is drop-free by design.
    """
    cfg = reduced(get_arch(name)).replace(dtype="float32")
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=64.0))
    params = init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    S = 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, S + 1)), jnp.int32)
    kw = {}
    if cfg.frontend == "audio":
        kw["frames"] = jnp.asarray(rng.standard_normal(
            (1, cfg.encoder.n_frames, cfg.d_model)), jnp.float32)
    if cfg.frontend == "vision":
        kw["extra_embeds"] = jnp.asarray(rng.standard_normal(
            (1, cfg.n_frontend_tokens, cfg.d_model)), jnp.float32)

    # oracle: full forward over S+1 tokens, logits at the last position
    hidden, _, _ = forward(params, cfg, toks, **kw)
    want = logits_fn(params, cfg, hidden[:, -1:, :])[..., :cfg.vocab_size]

    # prefill S tokens, then one decode step for token S
    cache_t = init_cache(cfg, 1, 64)
    _, cache, _ = forward(params, cfg, toks[:, :S], cache=cache_t, **kw)
    n_extra = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
    got, _ = decode_step(params, cfg, cache, toks[:, S:S + 1],
                         jnp.asarray(S + n_extra, jnp.int32))
    np.testing.assert_allclose(np.asarray(got[0, 0]), np.asarray(want[0, 0]),
                               rtol=2e-3, atol=2e-3)


def test_decode_vector_pos_matches_scalar():
    """Per-slot (vector) positions == scalar path when all slots align."""
    cfg = reduced(get_arch("gemma2-27b")).replace(dtype="float32")
    params = init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (3, 8)), jnp.int32)
    cache_t = init_cache(cfg, 3, 64)
    _, cache, _ = forward(params, cfg, toks, cache=cache_t)
    nxt = jnp.asarray(rng.integers(0, cfg.vocab_size, (3, 1)), jnp.int32)
    got_s, cache_s = decode_step(params, cfg, cache, nxt,
                                 jnp.asarray(8, jnp.int32))
    got_v, cache_v = decode_step(params, cfg, cache, nxt,
                                 jnp.full((3,), 8, jnp.int32))
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(got_v),
                               rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree.leaves(cache_s), jax.tree.leaves(cache_v)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5)


def test_local_ring_buffer_beyond_window():
    """Sliding-window ring cache stays exact once pos > window."""
    cfg = reduced(get_arch("gemma2-27b")).replace(dtype="float32", window=16)
    params = init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    S = 40  # > 2x window
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, S + 1)), jnp.int32)

    hidden, _, _ = forward(params, cfg, toks)
    want = logits_fn(params, cfg, hidden[:, -1:, :])[..., :cfg.vocab_size]

    cache_t = init_cache(cfg, 1, 64)
    _, cache, _ = forward(params, cfg, toks[:, :S], cache=cache_t)
    got, _ = decode_step(params, cfg, cache, toks[:, S:], jnp.asarray(S))
    np.testing.assert_allclose(np.asarray(got[0, 0]), np.asarray(want[0, 0]),
                               rtol=2e-3, atol=2e-3)


def test_decode_many_steps_matches_forward():
    """20 sequential decode steps == forward at every position (mamba)."""
    cfg = reduced(get_arch("falcon-mamba-7b")).replace(dtype="float32")
    params = init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    S = 20
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, S)), jnp.int32)
    hidden, _, _ = forward(params, cfg, toks)
    want = logits_fn(params, cfg, hidden)[..., :cfg.vocab_size]

    cache = init_cache(cfg, 1, 64)
    outs = []
    for t in range(S):
        lg, cache = decode_step(params, cfg, cache, toks[:, t:t + 1],
                                jnp.asarray(t))
        outs.append(lg[0, 0])
    got = jnp.stack(outs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want[0]),
                               rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------
# architectural features
# --------------------------------------------------------------------------
def test_gemma2_softcaps_active():
    cfg = reduced(get_arch("gemma2-27b")).replace(dtype="float32")
    assert cfg.attn_softcap == 50.0 and cfg.final_softcap == 30.0
    params = init(jax.random.PRNGKey(0), cfg)
    toks = jnp.zeros((1, 4), jnp.int32)
    hidden, _, _ = forward(params, cfg, toks)
    logits = logits_fn(params, cfg, hidden)
    assert float(jnp.abs(logits[..., :cfg.vocab_size]).max()) <= 30.0


def test_chunked_loss_equals_full():
    cfg = reduced(get_arch("deepseek-7b")).replace(dtype="float32")
    params = init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 40)), jnp.int32)
    full = lm_loss(params, cfg, toks, toks, loss_chunk=0)
    for lc in (8, 16, 33):  # 33: ragged tail path
        chunked = lm_loss(params, cfg, toks, toks, loss_chunk=lc)
        np.testing.assert_allclose(float(full), float(chunked),
                                   rtol=1e-5, atol=1e-5)


def test_scan_unroll_invariance():
    """scan_unroll changes lowering, never semantics."""
    cfg = reduced(get_arch("qwen3-0.6b")).replace(dtype="float32", n_layers=4)
    params = init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.arange(12)[None] % cfg.vocab_size, jnp.int32)
    l1 = lm_loss(params, cfg.replace(scan_unroll=1), toks, toks)
    l2 = lm_loss(params, cfg.replace(scan_unroll=2), toks, toks)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_remat_invariance():
    cfg = reduced(get_arch("qwen3-0.6b")).replace(dtype="float32")
    params = init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.arange(16)[None] % cfg.vocab_size, jnp.int32)

    def loss(c):
        return lm_loss(params, c, toks, toks)

    g1 = jax.grad(lambda p: lm_loss(p, cfg.replace(remat="none"), toks, toks))(params)
    g2 = jax.grad(lambda p: lm_loss(p, cfg.replace(remat="block"), toks, toks))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)

"""Structured-sparse GEMM: block masks, 2:4 layout, registry negotiation,
MoE expert consumption, and the density-discounted roofline/memfloor terms."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref, use_backend
from repro.kernels.dispatch import registry, resolve_backend
from repro.kernels.gemm_sparse import (apply_block_mask,
                                       block_mask_from_weight, densify_24,
                                       sparsify_24)


def _rand(shape, seed=0, scale=1.0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x * scale


# --------------------------------------------------------------------------
# layout helpers
# --------------------------------------------------------------------------
@pytest.mark.parametrize("density", [1.0, 0.5, 0.25])
def test_block_mask_density_and_magnitude_order(density):
    w = _rand((64, 64))
    mask = block_mask_from_weight(w, 16, 16, density)
    assert mask.shape == (4, 4) and mask.dtype == jnp.bool_
    kept = int(np.asarray(mask).sum())
    assert kept == max(1, round(density * 16))
    # kept blocks are the largest by L2 norm
    norms = np.asarray(w).reshape(4, 16, 4, 16)
    norms = np.sqrt((norms ** 2).sum(axis=(1, 3)))
    m = np.asarray(mask)
    assert norms[m].min() >= norms[~m].max() if kept < 16 else True
    wd = np.asarray(apply_block_mask(w, mask))
    blocks = wd.reshape(4, 16, 4, 16)
    assert all(not blocks[i, :, j, :].any()
               for i in range(4) for j in range(4) if not m[i, j])


def test_sparsify_24_keeps_top2_per_group():
    w = _rand((32, 16))
    vals, idx = sparsify_24(w)
    assert vals.shape == (16, 16) and idx.shape == (16, 16)
    assert idx.dtype == jnp.int8
    dense = np.asarray(densify_24(vals, idx))
    groups = dense.reshape(8, 4, 16)
    nnz = (groups != 0).sum(axis=1)
    assert (nnz <= 2).all()
    # the survivors are the two largest |w| in each group of 4
    worig = np.asarray(w).reshape(8, 4, 16)
    for g in range(8):
        for c in range(16):
            keep = set(np.argsort(-np.abs(worig[g, :, c]))[:2])
            got = set(np.nonzero(groups[g, :, c])[0])
            assert got <= keep, (g, c, got, keep)


# --------------------------------------------------------------------------
# kernel parity (exact: a skipped block contributes exactly +0.0)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape,bs", [((33, 64, 48), (16, 16)),
                                      ((8, 96, 64), (32, 32))])
@pytest.mark.parametrize("density", [0.5, 0.25])
def test_gemm_sparse_block_matches_masked_dense(shape, bs, density):
    M, K, N = shape
    x = _rand((M, K))
    w = _rand((K, N), seed=1)
    mask = block_mask_from_weight(w, *bs, density)
    wd = apply_block_mask(w, mask)
    oracle = np.asarray(ref.gemm_ref(x, wd))
    with use_backend("ref"):
        want = ops.gemm_sparse(x, w, mask)
    with use_backend("interpret"):
        got = ops.gemm_sparse(x, w, mask)
    np.testing.assert_array_equal(np.asarray(want), oracle)
    np.testing.assert_allclose(np.asarray(got), oracle, rtol=1e-5, atol=1e-5)


def test_gemm_sparse_epilogue_parity():
    x = _rand((20, 32))
    w = _rand((32, 32), seed=1)
    mask = block_mask_from_weight(w, 16, 16, 0.5)
    with use_backend("ref"):
        want = ops.gemm_sparse(x, w, mask, scale=0.5, act="gelu")
    with use_backend("interpret"):
        got = ops.gemm_sparse(x, w, mask, scale=0.5, act="gelu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(17, 32, 24), (8, 64, 130)])
def test_gemm_sparse_24_matches_densified(shape):
    M, K, N = shape
    x = _rand((M, K))
    vals, idx = sparsify_24(_rand((K, N), seed=1))
    oracle = np.asarray(ref.gemm_ref(x, densify_24(vals, idx)))
    with use_backend("ref"):
        want = ops.gemm_sparse_24(x, vals, idx)
    with use_backend("interpret"):
        got = ops.gemm_sparse_24(x, vals, idx)
    np.testing.assert_array_equal(np.asarray(want), oracle)
    np.testing.assert_allclose(np.asarray(got), oracle, rtol=1e-5, atol=1e-5)


def test_gemm_sparse_negotiation():
    """Shapes pick the layout: block mask -> pallas_block, (K/2, N) int8
    indices -> pallas_24, anything the kernels can't tile -> ref oracle."""
    x = _rand((8, 64))
    w = _rand((64, 32), seed=1)
    mask = block_mask_from_weight(w, 16, 16, 0.5)
    be = resolve_backend("interpret")
    req = registry.request("gemm_sparse", x, w, mask)
    assert registry.select("gemm_sparse", req, be).name == "pallas_block"
    vals, idx = sparsify_24(w)
    req = registry.request("gemm_sparse", x, vals, idx)
    assert registry.select("gemm_sparse", req, be).name == "pallas_24"
    # a mask grid that does not divide K negotiates down to the oracle
    badmask = jnp.ones((3, 2), jnp.bool_)
    req = registry.request("gemm_sparse", x, w, badmask)
    assert registry.select("gemm_sparse", req, be).name == "ref"


# --------------------------------------------------------------------------
# MoE consumption
# --------------------------------------------------------------------------
def test_sparsified_experts_kernel_matches_xla():
    """sparsify_experts hard-zeroes the slabs AND stores masks: the XLA
    einsum path and the gemm_sparse kernel path compute the same function."""
    from repro.models.moe import _expert_ffn, sparsify_experts

    E, d, f, G, C = 2, 32, 64, 1, 8
    p = {"experts": {"gate": _rand((E, d, f), seed=1),
                     "up": _rand((E, d, f), seed=2),
                     "down": _rand((E, f, d), seed=3)}}
    sp = sparsify_experts(p, 0.5, block=(16, 16))
    assert sp["experts"]["gate_mask"].shape == (E, d // 16, f // 16)
    # pruned slabs really are hard-zeroed outside kept blocks
    gm = np.asarray(sp["experts"]["gate_mask"][0])
    g0 = np.asarray(sp["experts"]["gate"][0]).reshape(
        d // 16, 16, f // 16, 16).transpose(0, 2, 1, 3)
    assert not g0[~gm].any()
    xe = _rand((G, E, C, d), seed=4, scale=0.3)
    want = _expert_ffn(sp, xe, "silu", jnp.float32)       # XLA einsum
    with use_backend("interpret"):                        # gemm_sparse path
        got = _expert_ffn(sp, xe, "silu", jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    # and pruning actually changed the function vs the dense experts
    dense = _expert_ffn(p, xe, "silu", jnp.float32)
    assert np.abs(np.asarray(dense) - np.asarray(want)).max() > 0


# --------------------------------------------------------------------------
# roofline / memfloor density terms
# --------------------------------------------------------------------------
def test_sparse_gemm_terms_scale_with_density():
    from repro.core.roofline import sparse_gemm_terms

    base = sparse_gemm_terms(64, 128, 256, density=1.0)
    half = sparse_gemm_terms(64, 128, 256, density=0.5)
    assert half["flops"] == pytest.approx(base["flops"] * 0.5)
    assert half["weight_bytes"] == pytest.approx(base["weight_bytes"] * 0.5)
    assert half["act_bytes"] == base["act_bytes"]         # activations dense
    masked = sparse_gemm_terms(64, 128, 256, density=0.5,
                               mask_block=(16, 16))
    assert masked["mask_bytes"] == (128 // 16) * (256 // 16)
    with pytest.raises(ValueError):
        sparse_gemm_terms(8, 8, 8, density=0.0)


def test_memfloor_weight_bytes_follow_density():
    from repro.configs import ShapeConfig, get_arch
    from repro.core.memfloor import MeshSizes, hbm_bytes_floor

    cfg = get_arch("qwen3-0.6b")
    shape = ShapeConfig(name="d", kind="decode", seq_len=2048, global_batch=8)
    mesh = MeshSizes(n_data=1, n_model=1)
    base = hbm_bytes_floor(cfg, shape, mesh, fsdp=False)
    half = hbm_bytes_floor(cfg.replace(weight_density=0.5), shape, mesh,
                           fsdp=False)
    assert half["weights"] == pytest.approx(base["weights"] / 2)
    assert half["cache"] == base["cache"]                 # KV is unaffected
    # int4 + half density compound: 0.25x the bf16 weight stream
    q = hbm_bytes_floor(cfg.replace(weight_dtype="int4", weight_density=0.5),
                        shape, mesh, fsdp=False)
    assert q["weights"] == pytest.approx(base["weights"] / 8)

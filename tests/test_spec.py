"""Speculative decoding + COW-forked parallel sampling (repro.spec).

Covers the three layers separately and end to end:

* the fused sampler's top-k / top-p filters (unit-level, exact sets);
* the rejection-sampling acceptance rule — exact greedy parity against an
  argmax chain, and a chi-squared check that the *marginal* distribution
  of the first committed token matches the verifier's own sampling
  distribution no matter how wrong the draft is (the Leviathan guarantee:
  speculation changes latency, never the distribution);
* the serving engine — greedy token parity with and without a draft,
  per-request RNG reproducibility independent of batch composition,
  Request(n=4) fan-out sharing, and leak-free drains for both paths.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import init as model_init
from repro.serve.engine import Request, ServeEngine
from repro.spec import filter_logits, filtered_probs, speculative_accept

# chi-squared critical values at alpha = 0.001 (no scipy on the container)
CHI2_999 = {7: 24.322, 15: 37.697, 31: 61.098}


def _cfg(**kw):
    base = dataclasses.replace(
        reduced(get_arch("qwen3-0.6b")), n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        dtype="float32", paged_kv=True, page_size=8)
    return dataclasses.replace(base, **kw)


def _draft_cfg(cfg):
    return dataclasses.replace(cfg, n_layers=1, d_model=32, n_heads=2,
                               n_kv_heads=1, d_ff=64)


# ---------------------------------------------------------------------------
# fused sampler filters
# ---------------------------------------------------------------------------
def test_top_k_filter_keeps_exactly_k():
    logits = jnp.asarray([[3.0, 1.0, 2.0, 0.0, -1.0]])
    out = np.asarray(filter_logits(logits, jnp.asarray([2]),
                                   jnp.asarray([1.0])))
    kept = np.where(out[0] > -1e29)[0]
    assert set(kept.tolist()) == {0, 2}, "top-2 must keep the two best"


def test_top_p_filter_nucleus():
    # probs = [0.5, 0.25, 0.125, 0.125] -> top_p=0.6 keeps {0, 1}: token 0
    # alone covers 0.5 < 0.6, so token 1 (prior mass 0.5 < p) joins
    logits = jnp.log(jnp.asarray([[0.5, 0.25, 0.125, 0.125]]))
    out = np.asarray(filter_logits(logits, jnp.asarray([0]),
                                   jnp.asarray([0.6])))
    kept = np.where(out[0] > -1e29)[0]
    assert set(kept.tolist()) == {0, 1}


def test_top_p_always_keeps_best():
    logits = jnp.asarray([[1.0, 0.9, 0.8]])
    out = np.asarray(filter_logits(logits, jnp.asarray([0]),
                                   jnp.asarray([1e-9])))
    kept = np.where(out[0] > -1e29)[0]
    assert kept.tolist() == [0], "a tiny top_p still keeps the argmax"


def test_filters_disabled_are_identity():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(3, 16)), jnp.float32)
    out = filter_logits(logits, jnp.zeros(3, jnp.int32), jnp.ones(3))
    np.testing.assert_allclose(np.asarray(out), np.asarray(logits))


# ---------------------------------------------------------------------------
# acceptance rule
# ---------------------------------------------------------------------------
def test_accept_greedy_parity_full_chain():
    """At temperature 0 with a draft that proposes the argmax chain, every
    token is accepted and the bonus token is the verifier's next argmax."""
    rng = np.random.default_rng(1)
    k, V = 3, 11
    logits = jnp.asarray(rng.normal(size=(1, k + 1, V)), jnp.float32)
    argmax = np.asarray(jnp.argmax(logits, -1))[0]          # (k+1,)
    draft = jnp.asarray(argmax[None, :k], jnp.int32)
    dprobs = jnp.asarray(jax.nn.one_hot(draft, V), jnp.float32)
    out, n_acc = speculative_accept(
        logits, draft, dprobs, jnp.zeros(1), jnp.zeros(1, jnp.int32),
        jnp.ones(1), jax.random.PRNGKey(0)[None])
    assert int(n_acc[0]) == k
    np.testing.assert_array_equal(np.asarray(out)[0], argmax)


def test_accept_greedy_rejects_at_first_mismatch():
    rng = np.random.default_rng(2)
    k, V = 4, 7
    logits = jnp.asarray(rng.normal(size=(1, k + 1, V)), jnp.float32)
    argmax = np.asarray(jnp.argmax(logits, -1))[0]
    draft_np = argmax[:k].copy()
    draft_np[2] = (draft_np[2] + 1) % V                     # diverge at 2
    draft = jnp.asarray(draft_np[None], jnp.int32)
    dprobs = jnp.asarray(jax.nn.one_hot(draft, V), jnp.float32)
    out, n_acc = speculative_accept(
        logits, draft, dprobs, jnp.zeros(1), jnp.zeros(1, jnp.int32),
        jnp.ones(1), jax.random.PRNGKey(3)[None])
    assert int(n_acc[0]) == 2
    # committed prefix: two accepted draft tokens + the verifier's argmax
    # at the rejection point (greedy residual = argmax of p)
    np.testing.assert_array_equal(np.asarray(out)[0, :3], argmax[:3])


@pytest.mark.parametrize("qkind", ["uniform", "skewed", "offbyone"])
def test_accept_preserves_marginal_distribution(qkind):
    """Chi-squared: over many PRNG keys, the first committed token's
    histogram must match the verifier's filtered softmax row — whatever
    the draft distribution was. This is the whole point of the rule."""
    V, k, N = 8, 2, 6000
    rng = np.random.default_rng(4)
    logits = jnp.asarray(rng.normal(size=(1, k + 1, V)), jnp.float32)
    p0 = np.asarray(filtered_probs(logits[:, 0], jnp.ones(1),
                                   jnp.zeros(1, jnp.int32), jnp.ones(1)))[0]
    if qkind == "uniform":
        q = np.full((1, k, V), 1.0 / V, np.float32)
    elif qkind == "skewed":
        raw = rng.random((1, k, V)).astype(np.float32) ** 4
        q = raw / raw.sum(-1, keepdims=True)
    else:   # deterministic draft proposing a near-argmax token
        tok = (int(np.argmax(p0)) + 1) % V
        q = np.asarray(jax.nn.one_hot(np.full((1, k), tok), V), np.float32)
    dtoks = jnp.asarray(
        rng.choice(V, size=(N, 1, k), p=q[0, 0] / q[0, 0].sum()), jnp.int32)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(N))

    def one(key, dt):
        out, _ = speculative_accept(
            logits, dt, jnp.asarray(q), jnp.ones(1),
            jnp.zeros(1, jnp.int32), jnp.ones(1), key[None])
        return out[0, 0]
    first = np.asarray(jax.jit(jax.vmap(one))(keys, dtoks))
    obs = np.bincount(first, minlength=V).astype(np.float64)
    exp = p0.astype(np.float64) * N
    keep = exp > 5            # standard chi-squared validity threshold
    chi2 = float(((obs[keep] - exp[keep]) ** 2 / exp[keep]).sum())
    df = int(keep.sum()) - 1
    crit = CHI2_999.get(df, CHI2_999[7] * (df + 1) / 8)
    assert chi2 < crit, (chi2, crit, obs, exp)


# ---------------------------------------------------------------------------
# serving engine: speculative decoding
# ---------------------------------------------------------------------------
def _run_engine(cfg, params, prompts, *, draft=None, dparams=None, spec_k=4,
                max_new=10, **req_kw):
    eng = ServeEngine(cfg, params, max_slots=4, max_len=96, paged=True,
                      draft_model=draft, draft_params=dparams, spec_k=spec_k)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new, **req_kw)
            for i, p in enumerate(prompts)]
    res = eng.run(reqs)
    return res, eng


def test_engine_spec_greedy_parity_and_leakfree():
    cfg = _cfg()
    dcfg = _draft_cfg(cfg)
    params = model_init(jax.random.PRNGKey(0), cfg)
    dparams = model_init(jax.random.PRNGKey(1), dcfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 255, size=n).astype(np.int32)
               for n in (12, 7, 20)]
    base, _ = _run_engine(cfg, params, prompts)
    spec, eng = _run_engine(cfg, params, prompts, draft=dcfg,
                            dparams=dparams)
    assert [r.tokens for r in base] == [r.tokens for r in spec]
    assert eng.stats["spec_turns"] > 0
    # leak-free drain: every speculative page rolled back
    assert (eng.allocator.n_free + eng.allocator.n_evictable
            == eng.allocator.capacity)


def test_engine_spec_self_draft_accepts_everything():
    """Draft == verifier: every proposal must be accepted (the acceptance
    ratio p/q is identically 1), so decode takes ~1/(k+1) the turns."""
    cfg = _cfg()
    params = model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, 255, size=10).astype(np.int32)]
    res, eng = _run_engine(cfg, params, prompts, draft=cfg, dparams=params,
                           max_new=12)
    assert res[0].finish_reason == "length"
    assert eng.stats["spec_accepted"] == eng.stats["spec_proposed"]


def test_engine_spec_requires_paged_all_full():
    cfg = _cfg(paged_kv=False)
    params = model_init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="speculative"):
        ServeEngine(cfg, params, paged=False, draft_model=_draft_cfg(cfg),
                    spec_k=2)


# ---------------------------------------------------------------------------
# serving engine: per-request RNG + filtered sampling
# ---------------------------------------------------------------------------
def test_request_seed_independent_of_batch():
    """The same (prompt, seed) request must sample the same tokens whether
    it runs alone or next to other traffic — the engine-global key
    order-dependence this subsystem removed."""
    cfg = _cfg()
    params = model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    probe = rng.integers(1, 255, size=9).astype(np.int32)
    other = [rng.integers(1, 255, size=n).astype(np.int32)
             for n in (14, 6, 11)]
    [alone], _ = _run_engine(cfg, params, [probe], temperature=1.0, seed=123)
    crowd, _ = _run_engine(cfg, params, [probe] + other, temperature=1.0,
                           seed=123)
    assert alone.tokens == crowd[0].tokens


def test_top_k_sampling_stays_in_top_k():
    cfg = _cfg()
    params = model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, 255, size=8).astype(np.int32)
    # top_k=1 at any temperature is greedy: compare to the greedy stream
    [greedy], _ = _run_engine(cfg, params, [prompt])
    [k1], _ = _run_engine(cfg, params, [prompt], temperature=1.0, seed=9,
                          top_k=1)
    assert k1.tokens == greedy.tokens


# ---------------------------------------------------------------------------
# serving engine: COW-forked parallel sampling
# ---------------------------------------------------------------------------
def test_fork_n4_distinct_streams_and_shared_pages():
    cfg = _cfg()
    params = model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    prompt = rng.integers(1, 255, size=48).astype(np.int32)
    eng = ServeEngine(cfg, params, max_slots=6, max_len=128, paged=True)
    [res] = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=8,
                             temperature=1.0, seed=7, n=4)])
    assert res.finish_reason == "length" and len(res.children) == 3
    assert all(c.finish_reason == "length" and len(c.tokens) == 8
               for c in res.children)
    seqs = {tuple(res.tokens)} | {tuple(c.tokens) for c in res.children}
    assert len(seqs) == 4, "children must diverge from the parent"
    assert eng.stats["forks"] == 3 and eng.stats["fork_shared_blocks"] > 0
    # leak-free drain: shared refcounts fully unwound
    assert (eng.allocator.n_free + eng.allocator.n_evictable
            == eng.allocator.capacity)
    # fan-out fresh KV < 2x a single request's (shared pages ride free)
    single = ServeEngine(cfg, params, max_slots=6, max_len=128, paged=True)
    single.run([Request(uid=0, prompt=prompt, max_new_tokens=8,
                        temperature=1.0, seed=7)])
    assert (eng.stats["kv_bytes_alloc"]
            < 2 * single.stats["kv_bytes_alloc"])


def test_fork_greedy_children_match_parent():
    """At temperature 0 divergence is impossible: every forked child must
    reproduce the parent's greedy stream exactly (shared pages + the
    re-decoded boundary row carry identical state)."""
    cfg = _cfg()
    params = model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    prompt = rng.integers(1, 255, size=21).astype(np.int32)
    eng = ServeEngine(cfg, params, max_slots=6, max_len=128, paged=True)
    [res] = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=6, n=3)])
    plain = ServeEngine(cfg, params, max_slots=6, max_len=128, paged=True)
    [pres] = plain.run([Request(uid=0, prompt=prompt, max_new_tokens=6)])
    assert res.tokens == pres.tokens
    assert all(c.tokens == pres.tokens for c in res.children)


def test_fork_rejected_on_dense_engine():
    cfg = _cfg(paged_kv=False)
    params = model_init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_slots=4, max_len=96, paged=False)
    [res] = eng.run([Request(uid=0, prompt=np.arange(1, 9, dtype=np.int32),
                             max_new_tokens=4, n=2)])
    assert res.finish_reason == "rejected"
    assert "parallel sampling" in res.detail


def test_fork_with_spec_decoding_combined():
    """Both consumers at once: a fan-out served under a draft model still
    produces the greedy stream on every branch and drains leak-free."""
    cfg = _cfg()
    dcfg = _draft_cfg(cfg)
    params = model_init(jax.random.PRNGKey(0), cfg)
    dparams = model_init(jax.random.PRNGKey(1), dcfg)
    rng = np.random.default_rng(6)
    prompt = rng.integers(1, 255, size=17).astype(np.int32)
    eng = ServeEngine(cfg, params, max_slots=6, max_len=128, paged=True,
                      draft_model=dcfg, draft_params=dparams, spec_k=3)
    [res] = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=7, n=3)])
    plain = ServeEngine(cfg, params, max_slots=6, max_len=128, paged=True)
    [pres] = plain.run([Request(uid=0, prompt=prompt, max_new_tokens=7)])
    assert res.tokens == pres.tokens
    assert all(c.tokens == pres.tokens for c in res.children)
    assert (eng.allocator.n_free + eng.allocator.n_evictable
            == eng.allocator.capacity)

"""Property-based invariants of the refcounted COW block allocator + prefix
index — random alloc/share/adopt/release/publish/evict/lookup/preempt/
fork/rollback action sequences checked against a pure-Python oracle after
every step.

Refcounted allocators are exactly the kind of code unit tests under-cover:
the bugs live in *interleavings* (release-then-evict, adopt-then-rollback),
not in single calls. The invariants:

* **refcount conservation** — ``allocator.ref[b]`` equals the number of
  outstanding references the driver holds on ``b``;
* **partition** — free list, live blocks (ref > 0), and cached blocks
  (indexed, ref 0) are pairwise disjoint and together cover the capacity;
* **block 0 never allocated** — the null block stays out of every state;
* **LRU never evicts a live block** — eviction only returns ref-0 blocks;
* **no double free** — over-release raises instead of corrupting;
* **transactional alloc** — a failed grant (even one that partially popped
  the free list and evicted cached blocks) leaves refcounts and free-list
  membership exactly as before.

Driven twice: via hypothesis (shrinkable random programs, ``-m property``)
and via fixed numpy seeds so the suite still exercises the invariants on
containers without a hypothesis wheel.
"""
from __future__ import annotations

import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.serve import BlockAllocator, PrefixIndex

PAGE = 4
N_BLOCKS = 9          # 8 usable + null block


def _mk():
    from repro.obs import MetricsRegistry
    reg = MetricsRegistry()     # shared: allocator + index co-register
    alloc = BlockAllocator(N_BLOCKS, PAGE, metrics=reg)
    index = PrefixIndex(PAGE, metrics=reg)
    alloc.evictor = index
    return alloc, index


def _tokens(tag: int) -> np.ndarray:
    """One unique full page of tokens per tag (unique chain hash)."""
    return np.full(PAGE, tag, np.int32)


def _check_invariants(alloc: BlockAllocator, index: PrefixIndex,
                      owners: list[int]) -> None:
    free = set(alloc._free)
    live = {b for b in range(alloc.n_blocks) if alloc.ref[b] > 0}
    cached = {b for b in index.blocks if alloc.ref[b] == 0}
    # block 0 never allocated, never free-listed, never cached
    assert 0 not in free and 0 not in live and 0 not in cached
    assert alloc.ref[0] == 0
    # refcount conservation against the driver's outstanding references
    for b in range(1, alloc.n_blocks):
        assert alloc.ref[b] == owners.count(b), f"block {b}"
    assert (alloc.ref >= 0).all()
    # free / live / cached partition the capacity
    assert not (free & live), "free list intersects live blocks"
    assert not (free & cached), "free list intersects cached blocks"
    assert len(free) == alloc.n_free, "free list holds duplicates"
    assert len(free) + len(live) + len(cached) == alloc.capacity, \
        "blocks leaked or double-counted"
    # every indexed block is live or cached, never free
    assert index.blocks <= (live | cached)
    # the O(1) cached-block counter agrees with a ground-truth scan
    assert index.n_evictable(alloc) == len(cached), \
        "incremental cached-block counter drifted"
    # metrics conservation: every block the registry counts as granted and
    # not yet released is exactly one the ground-truth scan sees as live or
    # cached (adoption moves cached -> live without granting; retention
    # moves live -> cached without releasing)
    snap = alloc.metrics.snapshot()
    assert (snap.counters.get("blocks_granted", 0)
            - snap.counters.get("blocks_released", 0)
            == len(live) + len(cached)), "metrics conservation violated"
    # index-entry conservation: entries only leave the index by eviction
    assert (snap.counters.get("prefix_index_published", 0) - len(index)
            == snap.counters.get("prefix_evictions", 0)), \
        "published/evicted entry accounting drifted"


def _run_program(program: list[tuple[int, int]]) -> None:
    """Interpret (op, arg) pairs as allocator/index actions; check the
    invariants after every action. Infeasible actions (nothing live to
    share, nothing cached to evict, ...) degrade to no-ops, so any integer
    program is a valid schedule.

    References are held in *groups* — one group models one engine slot's
    block table — so the ``preempt`` action can exercise the engine's
    eviction path: drop a whole group at once through
    ``BlockAllocator.release`` (indexed blocks retained as cached, fresh
    ones freed), exactly what a victim evicted mid-chunk-prefill does
    before its pages are published. The ``fork`` action models COW-forked
    parallel sampling (one incref per shared page into a new group plus a
    fresh private tail) and ``rollback`` models speculative-decode page
    rollback (release a suffix of one group back to the pool)."""
    alloc, index = _mk()
    groups: list[list[int]] = []    # one group per slot-like reference set
    published: list[np.ndarray] = []
    tag = 0
    gt = {"hits": 0, "hit_tokens": 0, "misses": 0}   # driver's own tally
    owners = lambda: [b for g in groups for b in g]
    for op, arg in program:
        op = op % 10
        if op == 0:                                   # alloc 1..3 blocks
            n = arg % 3 + 1
            before = (list(alloc._free), alloc.ref.copy())
            if n <= alloc.n_available:
                groups.append(alloc.alloc(n))
            else:
                with pytest.raises(RuntimeError):
                    alloc.alloc(n)
                # transactional: the failed grant rolled everything back
                # (eviction may legitimately have moved cached -> free)
                assert alloc.ref.tolist() == before[1].tolist()
                assert set(alloc._free) >= set(before[0])
        elif op == 1:                                 # share a live block
            live = sorted({b for b in owners()})
            if live:
                blk = live[arg % len(live)]
                alloc.incref(blk)
                groups.append([blk])
        elif op == 2:                                 # adopt a cached block
            cached = sorted(b for b in index.blocks if alloc.ref[b] == 0)
            if cached:
                blk = cached[arg % len(cached)]
                alloc.incref(blk)
                groups.append([blk])
        elif op == 3:                                 # release one reference
            nonempty = [g for g in groups if g]
            if nonempty:
                g = nonempty[arg % len(nonempty)]
                blk = g.pop(arg % len(g))
                alloc.decref(blk, retain=index.is_cached(blk))
                groups = [g for g in groups if g]
            else:
                with pytest.raises(RuntimeError):     # double free guarded
                    alloc.decref(1)
        elif op == 4:                                 # publish a live block
            live = sorted({b for b in owners() if not index.is_cached(b)})
            if live:
                toks = _tokens(tag)
                tag += 1
                index.publish(toks, [live[arg % len(live)]])
                published.append(toks)
        elif op == 5:                                 # LRU evict one
            n_cached = index.n_evictable(alloc)
            evicted = index.evict_one(alloc)
            assert evicted == (n_cached > 0), \
                "evict_one must succeed iff a refcount-0 cached block exists"
        elif op == 6:                                 # lookup a published page
            if published:
                hits = index.lookup(published[arg % len(published)], alloc)
                if hits:
                    groups.append(hits)   # lookup hands back references
                    gt["hits"] += 1
                    gt["hit_tokens"] += len(hits) * PAGE
                else:
                    gt["misses"] += 1
        elif op == 7:                                 # preempt a whole group
            if groups:
                g = groups.pop(arg % len(groups))
                alloc.release(g)
        elif op == 8:                                 # COW-fork a group
            # engine's _fork_children: child shares a prefix of the
            # parent's pages (incref each) and gets a fresh private tail
            nonempty = [g for g in groups if g]
            if nonempty:
                g = nonempty[arg % len(nonempty)]
                w0 = arg % (len(g) + 1)
                fresh_n = arg % 2 + 1
                if fresh_n <= alloc.n_available:
                    child = list(g[:w0])
                    for blk in child:
                        alloc.incref(blk)
                    child += alloc.alloc(fresh_n)
                    groups.append(child)
        elif op == 9:                                 # speculative rollback
            # engine's _rollback_spec: hand a suffix of one group's pages
            # back through the refcounted release path
            nonempty = [g for g in groups if g]
            if nonempty:
                g = nonempty[arg % len(nonempty)]
                keep = arg % len(g)
                tail, g[keep:] = list(g[keep:]), []
                alloc.release(tail)
                groups = [gr for gr in groups if gr]
        _check_invariants(alloc, index, owners())
    # drain: releasing every outstanding reference must account for every
    # block as free or cached — nothing leaks
    for g in groups:
        alloc.release(g)
    _check_invariants(alloc, index, [])
    assert alloc.n_free + index.n_evictable(alloc) == alloc.capacity
    # the index's registry counters agree with the driver's own tally
    snap = index.metrics.snapshot()
    assert snap.counters.get("prefix_index_hits", 0) == gt["hits"]
    assert snap.counters.get("prefix_index_hit_tokens", 0) \
        == gt["hit_tokens"]
    assert snap.counters.get("prefix_index_misses", 0) == gt["misses"]


@pytest.mark.property
@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 9), st.integers(0, 63)),
                max_size=80))
def test_allocator_invariants_random_programs(program):
    _run_program(program)


@pytest.mark.property
@pytest.mark.parametrize("seed", range(12))
def test_allocator_invariants_seeded(seed):
    """Seeded fallback of the same driver: keeps the invariant suite alive
    on containers without hypothesis (where @given-tests skip)."""
    rng = np.random.default_rng(seed)
    program = [(int(a), int(b))
               for a, b in zip(rng.integers(0, 10, 120),
                               rng.integers(0, 64, 120))]
    _run_program(program)


# --------------------------------------------------------------------------
# regression: transactional alloc (the partial-failure leak)
# --------------------------------------------------------------------------
def test_alloc_partial_failure_rolls_back():
    """alloc(n) that pops part of the free list (and evicts cached blocks)
    before discovering it cannot complete must hand everything back: the
    admission path sizes grants from prompt+budget *before* cached-block
    reservations shrink the free list, so the allocator — not the caller —
    owns making that race leak-free."""
    alloc, index = _mk()
    held = alloc.alloc(5)                  # 3 left free
    toks = _tokens(0)
    index.publish(toks, [held[0]])
    blk = held[0]
    alloc.decref(blk, retain=True)         # -> cached (evictable), 3 free
    held = held[1:]
    free_before = set(alloc._free)
    ref_before = alloc.ref.copy()
    with pytest.raises(RuntimeError, match="exhausted"):
        alloc.alloc(6)                     # 3 free + 1 evictable < 6
    # the partial grant (and nothing else) was rolled back: refcounts are
    # untouched and every popped block is free again (the evicted cached
    # block legitimately moved cached -> free; eviction is not undone)
    assert alloc.ref.tolist() == ref_before.tolist()
    assert set(alloc._free) == free_before | {blk}
    assert not index.is_cached(blk)
    assert alloc.n_free + alloc.n_evictable + len(held) == alloc.capacity
    # and the allocator still serves a feasible grant afterwards
    more = alloc.alloc(4)
    assert len(set(more)) == 4 and 0 not in more


def test_eviction_prefers_chain_tails():
    """Within one prefix chain the head page is always LRU-older than its
    suffix, but evicting it first would make every surviving suffix entry
    unreachable (lookup walks from page 0). Eviction must take childless
    (radix-leaf) entries first so the remaining cache stays matchable."""
    alloc, index = _mk()
    blocks = alloc.alloc(3)
    chain = np.concatenate([_tokens(0), _tokens(1), _tokens(2)])
    index.publish(chain, blocks)
    for b in blocks:
        alloc.decref(b, retain=True)
    assert index.evict_one(alloc)
    # pages 0 and 1 must survive (still a matchable 2-page prefix)
    assert index.lookup(chain, alloc) == blocks[:2]
    for b in blocks[:2]:
        alloc.decref(b, retain=True)
    assert index.evict_one(alloc)
    assert index.lookup(chain, alloc) == blocks[:1]
    alloc.decref(blocks[0], retain=True)
    assert index.evict_one(alloc)
    assert index.lookup(chain, alloc) == []
    assert alloc.n_free == alloc.capacity


def test_double_free_raises():
    alloc, _ = _mk()
    [blk] = alloc.alloc(1)
    assert alloc.decref(blk) == 0
    with pytest.raises(RuntimeError, match="double free"):
        alloc.decref(blk)


def test_lru_eviction_order_and_liveness():
    """Eviction order is least-recently-used (lookup refreshes recency) and
    live blocks are never victims."""
    alloc, index = _mk()
    blocks = alloc.alloc(3)
    toks = [_tokens(i) for i in range(3)]
    for t, b in zip(toks, blocks):
        index.publish(t, [b])
    # blocks 0,1 go cached; block 2 stays live
    alloc.decref(blocks[0], retain=True)
    alloc.decref(blocks[1], retain=True)
    index.lookup(toks[0], alloc)           # refresh 0 -> MRU, and re-adopt
    alloc.decref(blocks[0], retain=True)   # hand the reference back
    assert index.evict_one(alloc)
    assert not index.is_cached(blocks[1]), "LRU victim should be block 1"
    assert index.is_cached(blocks[0]) and index.is_cached(blocks[2])
    assert index.evict_one(alloc)
    assert not index.is_cached(blocks[0])
    # only the live block remains indexed: nothing left to evict
    assert not index.evict_one(alloc)
    assert index.is_cached(blocks[2])

"""MoE: packed-sort dispatch vs dense oracle, capacity, shared experts,
expert-parallel shard_map path (paper C5c analogue)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig
from repro.models.moe import (_route, capacity, moe_forward, moe_init)
from tests._subproc import run_with_devices


def _cfg(**moe_kw):
    kw = dict(n_experts=8, top_k=2, d_expert=32, capacity_factor=8.0)
    kw.update(moe_kw)
    return ModelConfig(name="m", d_model=64, moe=MoEConfig(**kw),
                       dtype="float32", param_dtype="float32")


def _x(shape=(2, 16, 64), seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def test_sort_matches_dense_dispatch():
    """With ample capacity, packed-sort dispatch == one-hot dense oracle."""
    cfg = _cfg()
    p = moe_init(jax.random.PRNGKey(1), cfg, jnp.float32)
    x = _x()
    y_sort, aux_s = moe_forward(p, cfg, x, compute_dtype=jnp.float32,
                                dispatch="sort")
    y_dense, aux_d = moe_forward(p, cfg, x, compute_dtype=jnp.float32,
                                 dispatch="dense")
    np.testing.assert_allclose(np.asarray(y_sort), np.asarray(y_dense),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_s), float(aux_d), rtol=1e-6)


def test_capacity_drops_tokens():
    """With capacity 1 token/expert, outputs differ from ample capacity
    (tokens were dropped) but remain finite."""
    cfg_low = _cfg(capacity_factor=0.1)
    cfg_high = _cfg(capacity_factor=8.0)
    p = moe_init(jax.random.PRNGKey(1), cfg_low, jnp.float32)
    x = _x()
    y_low, _ = moe_forward(p, cfg_low, x, compute_dtype=jnp.float32)
    y_high, _ = moe_forward(p, cfg_high, x, compute_dtype=jnp.float32)
    assert bool(jnp.isfinite(y_low).all())
    assert float(jnp.abs(y_low - y_high).max()) > 1e-4


def test_capacity_formula():
    m = MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25)
    assert capacity(m, 64) == int(np.ceil(64 * 2 / 8 * 1.25))
    assert capacity(m, 1) == 1  # never zero


def test_renorm_topk_flag():
    """deepseek renormalizes top-k gates; qwen2-moe does not."""
    cfg_rn = _cfg(renorm_topk=True)
    cfg_no = _cfg(renorm_topk=False)
    p = moe_init(jax.random.PRNGKey(1), cfg_rn, jnp.float32)
    x = _x((1, 8, 64))
    g_rn, _, _ = _route(p, cfg_rn.moe, x.reshape(1, 8, 64))
    g_no, _, _ = _route(p, cfg_no.moe, x.reshape(1, 8, 64))
    np.testing.assert_allclose(np.asarray(g_rn.sum(-1)), 1.0, rtol=1e-5)
    assert float(jnp.abs(g_no.sum(-1) - 1.0).max()) > 1e-3


def test_shared_experts_and_gate():
    """qwen2-moe: shared expert output added, optionally sigmoid-gated."""
    cfg_shared = _cfg(n_shared=2, shared_gate=False)
    cfg_gated = _cfg(n_shared=2, shared_gate=True)
    x = _x((1, 4, 64))
    p_g = moe_init(jax.random.PRNGKey(1), cfg_gated, jnp.float32)
    y_gated, _ = moe_forward(p_g, cfg_gated, x, compute_dtype=jnp.float32)
    p_s = {k: v for k, v in p_g.items() if k != "shared_gate"}
    y_shared, _ = moe_forward(p_s, cfg_shared, x, compute_dtype=jnp.float32)
    assert float(jnp.abs(y_gated - y_shared).max()) > 1e-5
    assert bool(jnp.isfinite(y_gated).all())


def test_aux_loss_balanced_router_is_minimal():
    """Uniform router logits -> aux loss == 1 (its minimum, E·(1/E·1/E·E))."""
    cfg = _cfg()
    p = moe_init(jax.random.PRNGKey(1), cfg, jnp.float32)
    p = jax.tree.map(lambda x: x, p)
    p["router"]["kernel"] = jnp.zeros_like(p["router"]["kernel"])
    x = _x((1, 1024, 64))
    _, _, aux = _route(p, cfg.moe, x.reshape(1, 1024, 64))
    assert 0.9 < float(aux) < 1.3


def test_assigned_moe_configs():
    q = get_arch("qwen2-moe-a2.7b")
    assert (q.moe.n_experts, q.moe.top_k, q.moe.n_shared) == (60, 4, 4)
    assert not q.moe.renorm_topk and q.moe.shared_gate
    d = get_arch("deepseek-moe-16b")
    assert (d.moe.n_experts, d.moe.top_k, d.moe.n_shared) == (64, 6, 2)
    # deepseek-moe layer 0 is dense
    assert d.prefix and d.prefix[0].mlp == "dense"


def test_expert_parallel_matches_single_device():
    """shard_map EP path (2-way model axis) == single-device sort path."""
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, MoEConfig, ShapeConfig
from repro.configs import strategy
from repro.core.sharding import Partitioner
from repro.models.moe import moe_forward, moe_init

moe = MoEConfig(n_experts=8, top_k=2, d_expert=32, capacity_factor=8.0)
cfg = ModelConfig(name="m", d_model=64, moe=moe, dtype="float32",
                  param_dtype="float32")
p = moe_init(jax.random.PRNGKey(1), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 64), jnp.float32)

y_ref, aux_ref = moe_forward(p, cfg, x, compute_dtype=jnp.float32,
                             dispatch="dense")

mesh = jax.make_mesh((4, 2), ("data", "model"))
shape = ShapeConfig("t", "train", 16, 4)
part = Partitioner(mesh, strategy("ramora"), cfg, shape)
assert part.axis_map["experts"] == ("model",)
with mesh:
    y_ep, aux_ep = jax.jit(lambda pp, xx: moe_forward(
        pp, cfg, xx, compute_dtype=jnp.float32, part=part))(p, x)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                           rtol=2e-4, atol=2e-5)
np.testing.assert_allclose(float(aux_ep), float(aux_ref), rtol=1e-4)
print("EP OK")
""")


def test_expert_parallel_2d_matches_oracle():
    """fsdp2d 2D-EP (batch over data AND model; experts over model;
    AG-tokens/RS-outputs inside the shard_map) == dense oracle."""
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, MoEConfig, ShapeConfig
from repro.configs import strategy
from repro.core.sharding import Partitioner
from repro.models.moe import moe_forward, moe_init

moe = MoEConfig(n_experts=8, top_k=2, d_expert=32, capacity_factor=8.0)
cfg = ModelConfig(name="m", d_model=64, moe=moe, dtype="float32",
                  param_dtype="float32")
p = moe_init(jax.random.PRNGKey(1), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(0), (8, 16, 64), jnp.float32)
y_ref, aux_ref = moe_forward(p, cfg, x, compute_dtype=jnp.float32,
                             dispatch="dense")
mesh = jax.make_mesh((4, 2), ("data", "model"))
part = Partitioner(mesh, strategy("fsdp2d"), cfg,
                   ShapeConfig("t", "train", 16, 8))
assert part.axis_map["batch"] == ("data", "model")
with mesh:
    y_ep, aux_ep = jax.jit(lambda pp, xx: moe_forward(
        pp, cfg, xx, compute_dtype=jnp.float32, part=part))(p, x)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                           rtol=2e-4, atol=2e-5)
np.testing.assert_allclose(float(aux_ep), float(aux_ref), rtol=1e-4)
print("2D-EP OK")
""")

"""Trainer: learning, checkpoint/restart determinism, fault tolerance,
straggler watch, preemption."""
from __future__ import annotations

import numpy as np
import pytest

from repro.configs import get_arch, reduced, strategy
from repro.configs.base import ShapeConfig
from repro.optim.optimizers import adamw
from repro.train.trainer import (FaultInjector, SimulatedDeviceFailure,
                                 StragglerWatch, Trainer, TrainerConfig)

SHAPE = ShapeConfig("t", "train", seq_len=32, global_batch=4)


def _tiny_cfg():
    return reduced(get_arch("qwen3-0.6b")).replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256)


def _trainer(tmp_path, steps=8, **kw):
    tcfg = TrainerConfig(steps=steps, ckpt_dir=str(tmp_path),
                         ckpt_every=kw.pop("ckpt_every", 4), seed=0)
    return Trainer(_tiny_cfg(), SHAPE, strategy("ramora"), adamw(1e-3), tcfg,
                   **kw)


def test_loss_decreases(tmp_path):
    out = _trainer(tmp_path, steps=30).train()
    losses = out["losses"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_restart_resumes_exactly(tmp_path):
    """Interrupted-and-resumed run == uninterrupted run (same data, steps)."""
    full = _trainer(tmp_path / "a", steps=8, ckpt_every=100).train()

    t1 = _trainer(tmp_path / "b", steps=4, ckpt_every=4)
    t1.train()
    t2 = _trainer(tmp_path / "b", steps=8, ckpt_every=4)
    resumed = t2.train()

    np.testing.assert_allclose(full["losses"][4:], resumed["losses"],
                               rtol=1e-5, atol=1e-6)


def test_fault_injection_restarts(tmp_path):
    t = _trainer(tmp_path, steps=8, ckpt_every=2,
                 fault=FaultInjector(at_step=5))
    out = t.run_with_restarts()
    assert out["restarts"] == 1
    assert out["stopped_at"] == 8


def test_fault_exhausts_restarts(tmp_path):
    t = _trainer(tmp_path, steps=8, ckpt_every=100,
                 fault=FaultInjector(prob=1.0))
    t.tcfg = TrainerConfig(steps=8, ckpt_dir=str(tmp_path), ckpt_every=100,
                           max_restarts=2, seed=0)
    with pytest.raises(SimulatedDeviceFailure):
        t.run_with_restarts()


def test_straggler_watch_unit():
    w = StragglerWatch(k=3.0, min_samples=3)
    for _ in range(5):
        assert not w.observe(1.0)
    assert w.observe(10.0)      # 10x median
    assert not w.observe(1.1)
    assert w.n_stragglers == 1


def test_straggler_hook_fires(tmp_path):
    hits = []

    class SlowDataset:
        def __init__(self, inner):
            self.inner = inner

        def batch_at(self, step):
            if step == 6:
                import time
                time.sleep(1.0)  # simulated straggling worker
            return self.inner.batch_at(step)

        def state(self, step):
            return self.inner.state(step)

    from repro.data import SyntheticLM
    ds = SlowDataset(SyntheticLM(256, 32, 4, seed=0))
    t = _trainer(tmp_path, steps=10, dataset=ds,
                 on_straggler=lambda s, dt: hits.append((s, dt)))
    t.tcfg = TrainerConfig(steps=10, ckpt_dir=str(tmp_path), ckpt_every=100,
                           straggler_k=3.0, seed=0)
    t.straggler = StragglerWatch(k=3.0, min_samples=3)
    t.train()
    assert any(s == 6 for s, _ in hits), hits


def test_preemption_checkpoints_and_exits(tmp_path):
    t = _trainer(tmp_path, steps=100, ckpt_every=1000)
    orig_build = t._build_step

    def build():
        fn = orig_build()

        def wrapped(state, batch):
            out = fn(state, batch)
            if int(np.asarray(out[0]["step"])) == 3:
                t._stop_requested = True  # SIGTERM arrives mid-run
            return out
        return wrapped

    t._build_step = build
    out = t.train()
    assert out["preempted"] and out["stopped_at"] == 3
    assert t.ckpt.latest_step() == 3

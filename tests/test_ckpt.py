"""Checkpointing: atomic roundtrip, async, GC, error surfacing, elasticity."""
from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (AsyncCheckpointer, gc_checkpoints, latest_step,
                        restore_checkpoint, save_checkpoint)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.zeros((4,))},
            "opt": {"mu": {"w": jnp.ones((8, 4)), "b": jnp.zeros((4,))}},
            "step": jnp.asarray(3, jnp.int32)}


def test_roundtrip(tmp_path):
    s = _state()
    save_checkpoint(tmp_path, 3, s)
    template = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s)
    r, meta = restore_checkpoint(tmp_path, template)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_metadata_roundtrip(tmp_path):
    save_checkpoint(tmp_path, 1, _state(), metadata={"data_step": 1, "x": "y"})
    template = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            _state())
    _, meta = restore_checkpoint(tmp_path, template)
    assert meta == {"data_step": 1, "x": "y"}


def test_latest_and_gc(tmp_path):
    for step in (1, 5, 3, 9):
        save_checkpoint(tmp_path, step, _state())
    assert latest_step(tmp_path) == 9
    gc_checkpoints(tmp_path, keep_last=2)
    remaining = sorted(p.name for p in Path(tmp_path).iterdir())
    assert remaining == ["step_00000005", "step_00000009"]


def test_atomicity_no_tmp_left(tmp_path):
    save_checkpoint(tmp_path, 2, _state())
    names = [p.name for p in Path(tmp_path).iterdir()]
    assert not any(n.startswith(".tmp") for n in names)
    manifest = json.loads((tmp_path / "step_00000002" / "manifest.json")
                          .read_text())
    assert manifest["step"] == 2 and len(manifest["leaves"]) == 5


def test_missing_leaf_fails_loudly(tmp_path):
    save_checkpoint(tmp_path, 1, {"a": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        restore_checkpoint(tmp_path, {"b": jax.ShapeDtypeStruct((2,), "float32")})


def test_shape_mismatch_fails_loudly(tmp_path):
    save_checkpoint(tmp_path, 1, {"a": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        restore_checkpoint(tmp_path, {"a": jax.ShapeDtypeStruct((3,), "float32")})


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path, keep_last=2)
    for step in (10, 20, 30):
        ck.save(step, _state(step))
    ck.wait()
    assert ck.latest_step() == 30
    remaining = sorted(p.name for p in Path(tmp_path).iterdir())
    assert len(remaining) == 2


def test_async_snapshot_isolated_from_donation(tmp_path):
    """The async save snapshots before returning: mutating (donating) the
    live buffers afterwards must not corrupt the checkpoint."""
    ck = AsyncCheckpointer(tmp_path)
    s = {"w": jnp.arange(4.0)}
    ck.save(1, s)
    s["w"] = s["w"] * 0  # simulate donation reuse
    ck.wait()
    r, _ = restore_checkpoint(tmp_path, {"w": jax.ShapeDtypeStruct((4,), "float32")})
    np.testing.assert_array_equal(np.asarray(r["w"]), np.arange(4.0))


def test_async_error_surfaces(tmp_path):
    ck = AsyncCheckpointer(tmp_path / "nope" / "\0bad")  # invalid path
    ck.save(1, {"a": jnp.zeros(())})
    with pytest.raises(BaseException):
        ck.wait()

"""Serving engine: continuous batching == sequential decode; slot lifecycle."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import decode_step, forward, init, logits_fn
from repro.models.cache import init_cache
from repro.serve import Request, ServeEngine


def _cfg():
    return reduced(get_arch("qwen3-0.6b")).replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, dtype="float32")


def _ref_greedy(cfg, params, prompt, max_new, max_len=96):
    cache_t = init_cache(cfg, 1, max_len)
    hidden, cache, _ = forward(params, cfg, jnp.asarray(prompt)[None],
                               cache=cache_t)
    lg = logits_fn(params, cfg, hidden[:, -1:, :])[..., :cfg.vocab_size]
    toks = [int(jnp.argmax(lg[0, 0]))]
    pos = len(prompt)
    for _ in range(max_new - 1):
        lg, cache = decode_step(params, cfg, cache,
                                jnp.asarray([[toks[-1]]], jnp.int32),
                                jnp.asarray(pos, jnp.int32))
        toks.append(int(jnp.argmax(lg[0, 0])))
        pos += 1
    return toks


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_continuous_batching_matches_sequential(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        rng.integers(3, 12)).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, 6)))
            for i in range(7)]
    engine = ServeEngine(cfg, params, max_slots=3, max_len=96)
    results = engine.run(reqs)
    assert all(r.finish_reason == "length" for r in results)
    for r, req in zip(results, reqs):
        ref = _ref_greedy(cfg, params, req.prompt, req.max_new_tokens)
        assert r.tokens == ref, f"uid {r.uid}"


def test_slot_reuse_exceeds_pool(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    engine = ServeEngine(cfg, params, max_slots=2, max_len=96)
    reqs = [Request(uid=i, prompt=rng.integers(0, 256, 5).astype(np.int32),
                    max_new_tokens=3) for i in range(5)]
    results = engine.run(reqs)
    assert len(results) == 5
    assert engine.stats["prefills"] == 5
    assert not engine.active.any()


def test_eos_stops_early(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, 256, 6).astype(np.int32)
    # find what greedy emits first, then declare that token the EOS
    first = _ref_greedy(cfg, params, prompt, 1)[0]
    engine = ServeEngine(cfg, params, max_slots=1, max_len=96, eos_id=first)
    [res] = engine.run([Request(uid=0, prompt=prompt, max_new_tokens=10)])
    assert res.finish_reason == "eos"
    assert len(res.tokens) == 1


def test_overflow_asserts(setup):
    cfg, params = setup
    engine = ServeEngine(cfg, params, max_slots=1, max_len=16)
    req = Request(uid=0, prompt=np.zeros(14, np.int32), max_new_tokens=8)
    with pytest.raises(AssertionError):
        engine.run([req])


def test_prefill_jit_cache_reused(setup):
    cfg, params = setup
    rng = np.random.default_rng(3)
    engine = ServeEngine(cfg, params, max_slots=2, max_len=96)
    reqs = [Request(uid=i, prompt=rng.integers(0, 256, 8).astype(np.int32),
                    max_new_tokens=2) for i in range(6)]
    engine.run(reqs)
    assert engine.stats["prefill_recompiles"] == 1  # one shared length

"""Serving engine: continuous batching == sequential decode; slot lifecycle;
paged (block-pool) vs dense cache parity; chunked prefill; block accounting;
prefix-cache sharing (refcounted COW blocks) with stateful fuzz coverage."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.configs import LayerSpec, get_arch, reduced
from repro.models import decode_step, forward, init, logits_fn
from repro.models.cache import init_cache
from repro.serve import Request, ServeEngine


def _cfg(**kw):
    return reduced(get_arch("qwen3-0.6b")).replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, dtype="float32", **kw)


def _local_cfg():
    """Sliding-window (ring-buffer) attention config."""
    return _cfg(pattern=(LayerSpec("local", "dense"),), window=8)


def _rglru_cfg():
    return reduced(get_arch("recurrentgemma-2b")).replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, dtype="float32", window=8)


def _mamba_cfg():
    return reduced(get_arch("falcon-mamba-7b")).replace(
        n_layers=2, d_model=64, vocab_size=256, dtype="float32")


def _ref_greedy(cfg, params, prompt, max_new, max_len=96):
    cache_t = init_cache(cfg, 1, max_len)
    hidden, cache, _ = forward(params, cfg, jnp.asarray(prompt)[None],
                               cache=cache_t)
    lg = logits_fn(params, cfg, hidden[:, -1:, :])[..., :cfg.vocab_size]
    toks = [int(jnp.argmax(lg[0, 0]))]
    pos = len(prompt)
    for _ in range(max_new - 1):
        lg, cache = decode_step(params, cfg, cache,
                                jnp.asarray([[toks[-1]]], jnp.int32),
                                jnp.asarray(pos, jnp.int32))
        toks.append(int(jnp.argmax(lg[0, 0])))
        pos += 1
    return toks


def _mixed_requests(cfg, n, seed, lo=3, hi=14, new_lo=2, new_hi=7):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        rng.integers(lo, hi)).astype(np.int32),
                    max_new_tokens=int(rng.integers(new_lo, new_hi)))
            for i in range(n)]


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_continuous_batching_matches_sequential(setup):
    cfg, params = setup
    reqs = _mixed_requests(cfg, 7, seed=0, lo=3, hi=12, new_lo=2, new_hi=6)
    engine = ServeEngine(cfg, params, max_slots=3, max_len=96)
    results = engine.run(reqs)
    assert all(r.finish_reason == "length" for r in results)
    for r, req in zip(results, reqs):
        ref = _ref_greedy(cfg, params, req.prompt, req.max_new_tokens)
        assert r.tokens == ref, f"uid {r.uid}"


def test_slot_reuse_exceeds_pool(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    engine = ServeEngine(cfg, params, max_slots=2, max_len=96)
    reqs = [Request(uid=i, prompt=rng.integers(0, 256, 5).astype(np.int32),
                    max_new_tokens=3) for i in range(5)]
    results = engine.run(reqs)
    assert len(results) == 5
    assert engine.stats["prefills"] == 5
    assert not engine.active.any()


def test_eos_stops_early(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, 256, 6).astype(np.int32)
    # find what greedy emits first, then declare that token the EOS
    first = _ref_greedy(cfg, params, prompt, 1)[0]
    engine = ServeEngine(cfg, params, max_slots=1, max_len=96, eos_id=first)
    [res] = engine.run([Request(uid=0, prompt=prompt, max_new_tokens=10)])
    assert res.finish_reason == "eos"
    assert len(res.tokens) == 1


def test_overflow_rejected_gracefully(setup):
    """A request that cannot fit finishes with 'rejected' instead of
    crashing the engine loop; later requests keep being served."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    engine = ServeEngine(cfg, params, max_slots=1, max_len=16)
    bad = Request(uid=0, prompt=np.zeros(14, np.int32), max_new_tokens=8)
    good = Request(uid=1, prompt=rng.integers(0, 256, 4).astype(np.int32),
                   max_new_tokens=3)
    res_bad, res_good = engine.run([bad, good])
    assert res_bad.finish_reason == "rejected"
    assert res_bad.tokens == []
    assert res_good.finish_reason == "length"
    assert len(res_good.tokens) == 3
    assert engine.stats["rejected"] == 1


def test_prefill_jit_cache_reused(setup):
    cfg, params = setup
    rng = np.random.default_rng(3)
    engine = ServeEngine(cfg, params, max_slots=2, max_len=96)
    reqs = [Request(uid=i, prompt=rng.integers(0, 256, 8).astype(np.int32),
                    max_new_tokens=2) for i in range(6)]
    engine.run(reqs)
    assert engine.stats["prefill_recompiles"] == 1


def test_chunked_prefill_one_compile_across_lengths(setup):
    """Distinct prompt lengths (shorter and longer than the chunk) all ride
    the ONE compiled extend_step shape — no per-length jit cache."""
    cfg, params = setup
    engine = ServeEngine(cfg, params, max_slots=2, max_len=96,
                         prefill_chunk=5)
    rng = np.random.default_rng(5)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, 256, length).astype(np.int32),
                    max_new_tokens=2)
            for i, length in enumerate((3, 5, 7, 11, 16, 23))]
    results = engine.run(reqs)
    assert engine.stats["prefill_recompiles"] == 1
    assert engine.stats["prefill_chunks"] == sum(
        -(-len(r.prompt) // 5) for r in reqs)
    for r, req in zip(results, reqs):
        assert r.tokens == _ref_greedy(cfg, params, req.prompt,
                                       req.max_new_tokens), f"uid {r.uid}"


# --------------------------------------------------------------------------
# paged (block-pool) cache
# --------------------------------------------------------------------------
@pytest.mark.parametrize("make_cfg", [_cfg, _local_cfg, _rglru_cfg,
                                      _mamba_cfg],
                         ids=["global", "local-window", "rglru", "mamba"])
def test_paged_matches_dense_token_for_token(make_cfg):
    """Greedy parity across interleaved admits/finishes: paged and dense
    engines emit identical tokens, which also match sequential decode —
    including eviction-sensitive caches (ring window, recurrent conv state)
    decoded past the window."""
    cfg = make_cfg()
    params = init(jax.random.PRNGKey(0), cfg)
    # prompts longer than the window (8) and generations pushing past it
    reqs = _mixed_requests(cfg, 6, seed=7, lo=4, hi=20, new_lo=3, new_hi=9)
    outs = {}
    for paged in (False, True):
        engine = ServeEngine(cfg, params, max_slots=3, max_len=64,
                             paged=paged, page_size=8, prefill_chunk=6)
        results = engine.run([Request(uid=r.uid, prompt=r.prompt,
                                      max_new_tokens=r.max_new_tokens)
                              for r in reqs])
        outs[paged] = [r.tokens for r in results]
        if paged:
            assert engine.allocator.n_free == engine.allocator.capacity, \
                "blocks leaked after all requests finished"
    assert outs[True] == outs[False]
    for toks, req in zip(outs[True], reqs):
        assert toks == _ref_greedy(cfg, params, req.prompt,
                                   req.max_new_tokens, max_len=64), \
            f"uid {req.uid}"


def test_paged_kv_memory_proportional_to_lengths(setup):
    """Paged admission charges blocks for actual prompt+budget tokens; at
    mixed lengths that is far below the dense max_len-per-slot reservation."""
    cfg, params = setup
    reqs = _mixed_requests(cfg, 6, seed=9, lo=3, hi=16, new_lo=2, new_hi=6)
    stats = {}
    for paged in (False, True):
        engine = ServeEngine(cfg, params, max_slots=3, max_len=96,
                             paged=paged, page_size=8)
        engine.run([Request(uid=r.uid, prompt=r.prompt,
                            max_new_tokens=r.max_new_tokens) for r in reqs])
        stats[paged] = engine.stats["kv_bytes_alloc"]
    assert stats[True] < stats[False] / 2


def test_block_pool_backpressure():
    """With a pool too small for all requests at once, admission waits for
    blocks to free (FCFS) and every request still completes; a request that
    can never fit the pool is rejected, not deadlocked."""
    cfg = _cfg()
    params = init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11)
    # pool of 6 usable blocks * 8 rows = 48 tokens; each request needs
    # 16 tokens -> 2 blocks; 4 slots but only 3 requests fit at once
    engine = ServeEngine(cfg, params, max_slots=4, max_len=64, paged=True,
                         page_size=8, max_blocks=7)
    reqs = [Request(uid=i, prompt=rng.integers(0, 256, 12).astype(np.int32),
                    max_new_tokens=4) for i in range(6)]
    reqs.append(Request(uid=6,                       # needs 7 > 6 blocks
                        prompt=rng.integers(0, 256, 50).astype(np.int32),
                        max_new_tokens=4))
    results = engine.run(reqs)
    assert [r.finish_reason for r in results[:6]] == ["length"] * 6
    assert results[6].finish_reason == "rejected"
    assert engine.allocator.n_free == engine.allocator.capacity


# --------------------------------------------------------------------------
# prefix caching (refcounted copy-on-write block sharing)
# --------------------------------------------------------------------------
def _shared_prefix_requests(cfg, n, seed, sys_len=16, page=8):
    """Mixed trace: most prompts extend a shared system prefix (full- or
    half-page matches), the rest are cold; lengths and budgets vary."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, cfg.vocab_size, sys_len).astype(np.int32)
    reqs = []
    for i in range(n):
        r = rng.random()
        if r < 0.5:      # shared prefix + unique tail
            tail = rng.integers(0, cfg.vocab_size, rng.integers(1, 9))
            prompt = np.concatenate([sys_prompt, tail.astype(np.int32)])
        elif r < 0.7:    # exact resubmission (page-aligned full match: COW)
            prompt = sys_prompt.copy()
        else:            # cold prompt
            prompt = rng.integers(0, cfg.vocab_size,
                                  rng.integers(3, 20)).astype(np.int32)
        reqs.append(Request(uid=i, prompt=prompt,
                            max_new_tokens=int(rng.integers(2, 7))))
    return reqs


def _run_interleaved(cfg, params, reqs, submit_at, *, prefix_cache, **kw):
    """Drive the engine with requests arriving at randomized step offsets
    (admit/decode/finish interleavings differ per schedule)."""
    engine = ServeEngine(cfg, params, paged=True, prefix_cache=prefix_cache,
                         **kw)
    order = sorted(range(len(reqs)), key=lambda i: submit_at[i])
    i, step = 0, 0
    while i < len(order) or engine.queue or engine.active.any():
        while i < len(order) and submit_at[order[i]] <= step:
            engine.submit(reqs[order[i]])
            i += 1
        engine.step()
        step += 1
        assert step < 5000, "engine failed to drain"
    return engine


def _assert_drained_leak_free(engine):
    """After drain: no live blocks, and free + cached cover the capacity."""
    alloc = engine.allocator
    assert alloc.n_live == 0
    cached = (0 if engine.prefix_index is None
              else engine.prefix_index.n_evictable(alloc))
    assert alloc.n_free + cached == alloc.capacity, \
        (alloc.n_free, cached, alloc.capacity)


def _fuzz_once(make_cfg, seed, max_blocks=None):
    cfg = make_cfg()
    params = init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    reqs = _shared_prefix_requests(cfg, 7, seed)
    submit_at = rng.integers(0, 25, len(reqs))
    outs = {}
    for pc in (False, True):
        engine = _run_interleaved(
            cfg, params,
            [Request(uid=r.uid, prompt=r.prompt,
                     max_new_tokens=r.max_new_tokens) for r in reqs],
            submit_at, prefix_cache=pc, max_slots=3, max_len=64,
            page_size=8, prefill_chunk=6, max_blocks=max_blocks)
        outs[pc] = [engine.results[r.uid].tokens for r in reqs]
        assert all(engine.results[r.uid].finish_reason == "length"
                   for r in reqs)
        _assert_drained_leak_free(engine)
    assert outs[True] == outs[False], \
        "prefix cache changed greedy outputs"


@pytest.mark.property
@pytest.mark.parametrize("make_cfg", [_cfg, _local_cfg],
                         ids=["global", "local-window"])
@pytest.mark.parametrize("seed", [0, 1])
def test_prefix_cache_fuzz_seeded(make_cfg, seed):
    """Stateful serving fuzz: randomized admit/decode/finish interleavings
    with the prefix cache on vs off emit identical greedy tokens and leak
    no blocks, on all-full and local-window paged configs (the latter is
    prefix-incapable and must degrade to cold serving, not corrupt)."""
    _fuzz_once(make_cfg, seed)


@pytest.mark.property
@settings(max_examples=5, deadline=None)
@given(st.integers(100, 10_000))
def test_prefix_cache_fuzz_hypothesis(seed):
    """Hypothesis-driven schedules over the all-full config, including a
    pool small enough (max_blocks=13) that admission backpressure and LRU
    eviction of cached blocks interleave with the hits."""
    _fuzz_once(_cfg, seed, max_blocks=13)


def test_prefix_hit_skips_prefill_and_shares_blocks(setup):
    """A warm cache turns the shared-prefix prefill into a tail-only
    extend: fewer chunks, fewer fresh KV bytes, identical greedy tokens."""
    cfg, params = setup
    rng = np.random.default_rng(21)
    sys_prompt = rng.integers(0, 256, 24).astype(np.int32)   # 3 pages
    mk = lambda: [Request(uid=i, prompt=np.concatenate(
                      [sys_prompt, rng2.integers(0, 256, 5).astype(np.int32)]),
                      max_new_tokens=4)
                  for i, rng2 in enumerate(np.random.default_rng(22).spawn(4))]
    stats = {}
    outs = {}
    for pc in (False, True):
        engine = ServeEngine(cfg, params, max_slots=2, max_len=64,
                             paged=True, page_size=8, prefill_chunk=8,
                             prefix_cache=pc)
        [w] = engine.run([Request(uid=99, prompt=sys_prompt,
                                  max_new_tokens=2)])   # warms the cache
        kv0 = engine.stats["kv_bytes_alloc"]
        res = engine.run(mk())
        outs[pc] = [r.tokens for r in res]
        stats[pc] = dict(engine.stats, kv_delta=engine.stats["kv_bytes_alloc"]
                         - kv0)
        _assert_drained_leak_free(engine)
    assert outs[True] == outs[False]
    assert stats[True]["prefix_hits"] == 4
    assert stats[True]["prefix_hit_tokens"] == 4 * 24
    # 3 of each request's 4 pages ride in shared: fewer chunks, fewer bytes
    assert stats[True]["prefill_chunks"] < stats[False]["prefill_chunks"]
    assert stats[True]["kv_delta"] < stats[False]["kv_delta"]
    # the shared pages stay resident (refcount-0 cached) after the drain
    assert stats[True]["kv_bytes_cached"] > 0


def test_prefix_full_match_triggers_cow(setup):
    """Resubmitting a page-aligned prompt matches every page; the final
    token still recomputes (its logits seed decode), so the last shared
    page is privatized copy-on-write and greedy outputs stay exact."""
    cfg, params = setup
    prompt = np.random.default_rng(23).integers(0, 256, 16).astype(np.int32)
    outs = {}
    for pc in (False, True):
        engine = ServeEngine(cfg, params, max_slots=2, max_len=64,
                             paged=True, page_size=8, prefill_chunk=8,
                             prefix_cache=pc)
        r1 = engine.run([Request(uid=0, prompt=prompt, max_new_tokens=5)])
        r2 = engine.run([Request(uid=1, prompt=prompt.copy(),
                                 max_new_tokens=5)])
        outs[pc] = [r1[0].tokens, r2[0].tokens]
        if pc:
            assert engine.stats["prefix_cow"] == 1
            assert engine.stats["prefix_hit_tokens"] == 15  # cap: last token
        _assert_drained_leak_free(engine)
    assert outs[True] == outs[False]
    assert outs[True][0] == outs[True][1]


def test_prefix_cache_eviction_under_pressure(setup):
    """A pool too small to retain every finished prompt evicts cached
    blocks LRU instead of refusing admission; every request completes and
    nothing leaks."""
    cfg, params = setup
    engine = ServeEngine(cfg, params, max_slots=2, max_len=64, paged=True,
                         page_size=8, prefill_chunk=8, prefix_cache=True,
                         max_blocks=7)                     # 6 usable blocks
    for i in range(5):
        p = np.random.default_rng(30 + i).integers(0, 256, 16)
        [r] = engine.run([Request(uid=i, prompt=p.astype(np.int32),
                                  max_new_tokens=3)])
        assert r.finish_reason == "length"
    assert engine.stats["prefix_evictions"] > 0
    _assert_drained_leak_free(engine)


def test_prefix_lru_caps_cached_blocks(setup):
    """--prefix-lru bounds the refcount-0 blocks retained after finish."""
    cfg, params = setup
    engine = ServeEngine(cfg, params, max_slots=2, max_len=64, paged=True,
                         page_size=8, prefill_chunk=8, prefix_cache=True,
                         prefix_lru=2)
    for i in range(4):
        p = np.random.default_rng(40 + i).integers(0, 256, 16)
        engine.run([Request(uid=i, prompt=p.astype(np.int32),
                            max_new_tokens=2)])
    assert engine.prefix_index.n_evictable(engine.allocator) <= 2
    _assert_drained_leak_free(engine)


def test_prefix_cache_empty_prompt_and_bad_lru(setup):
    """Regression: an empty prompt must not push the prefill offset
    negative when the prefix cache is on (first_new clamps at 0), and a
    negative prefix_lru is rejected at construction — the engine kwarg
    path must not bypass the ModelConfig validation."""
    cfg, params = setup
    engine = ServeEngine(cfg, params, max_slots=2, max_len=64, paged=True,
                         page_size=8, prefill_chunk=8, prefix_cache=True)
    [r] = engine.run([Request(uid=0, prompt=np.zeros(0, np.int32),
                              max_new_tokens=3)])
    assert r.finish_reason == "length" and len(r.tokens) == 3
    _assert_drained_leak_free(engine)
    with pytest.raises(ValueError, match="prefix_lru"):
        ServeEngine(cfg, params, max_slots=1, max_len=32, paged=True,
                    page_size=8, prefix_cache=True, prefix_lru=-1)


def test_prefix_cache_incapable_configs_serve_cold():
    """Ring-window state is per-slot dense — a prefix hit cannot restore
    it, so local-window configs silently serve cold (hits stay 0) instead
    of erroring or corrupting."""
    cfg = _local_cfg()
    params = init(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_slots=2, max_len=64, paged=True,
                         page_size=8, prefix_cache=True)
    assert not engine.prefix_cache and not engine.prefix_capable
    prompt = np.random.default_rng(50).integers(0, 256, 16).astype(np.int32)
    engine.run([Request(uid=0, prompt=prompt, max_new_tokens=2)])
    [r] = engine.run([Request(uid=1, prompt=prompt.copy(),
                              max_new_tokens=2)])
    assert r.finish_reason == "length"
    assert engine.stats["prefix_hits"] == 0
    assert engine.allocator.n_free == engine.allocator.capacity


# --------------------------------------------------------------------------
# scheduling: head-of-line fix, truncation, preemption, overlap, streaming
# --------------------------------------------------------------------------
def test_hol_small_request_overtakes_blocked_big_one(setup):
    """Regression for the head-of-line admission stall: a 1-page request
    queued behind a pool-sized one admits immediately under the priority
    policy (skip-with-aging), while fcfs keeps the legacy no-overtaking
    stall. Everything still completes either way."""
    cfg, params = setup
    rng = np.random.default_rng(60)
    mk = lambda: [
        Request(uid=0, prompt=rng0.integers(0, 256, 12).astype(np.int32),
                max_new_tokens=4)                      # 16 tok -> 2 blocks
        for rng0 in [np.random.default_rng(60)]] + [
        Request(uid=1, prompt=np.asarray(
            rng.integers(0, 256, 34), np.int32).copy(),
                max_new_tokens=6),                     # 40 tok -> 5 blocks
        Request(uid=2, prompt=np.asarray(
            rng.integers(0, 256, 4), np.int32).copy(),
                max_new_tokens=2)]                     # 6 tok -> 1 block
    admitted = {}
    for policy in ("priority", "fcfs"):
        reqs = mk()
        engine = ServeEngine(cfg, params, max_slots=2, max_len=64,
                             paged=True, page_size=8, max_blocks=7,
                             sched=policy)
        engine.submit(reqs[0])
        engine.step()                    # uid0 running: 4 of 6 blocks free
        engine.submit(reqs[1])           # needs 5 blocks -> blocked
        engine.submit(reqs[2])           # needs 1 block
        engine.step()
        # uid2 is small enough to admit AND finish within this one step
        admitted[policy] = bool(engine.results[2].tokens)
        steps = 0
        while engine._busy():
            engine.step()
            steps += 1
            assert steps < 2000
        assert all(engine.results[r.uid].finish_reason == "length"
                   for r in reqs), policy
        _assert_drained_leak_free(engine)
    assert admitted["priority"], \
        "small request must overtake the blocked pool-sized one"
    assert not admitted["fcfs"], \
        "fcfs must keep the legacy no-overtaking stall"


def test_aged_reservation_blocks_overtaking(setup):
    """Once aging promotes a blocked request to a reservation, smaller
    late arrivals stop overtaking it (starvation bound)."""
    cfg, params = setup
    rng = np.random.default_rng(61)
    engine = ServeEngine(cfg, params, max_slots=2, max_len=64, paged=True,
                         page_size=8, max_blocks=7, sched="priority",
                         sched_aging=2)
    engine.submit(Request(uid=0,
                          prompt=rng.integers(0, 256, 12).astype(np.int32),
                          max_new_tokens=20))          # 2 blocks, long-lived
    engine.step()
    big = Request(uid=1, prompt=rng.integers(0, 256, 34).astype(np.int32),
                  max_new_tokens=6)                    # 5 blocks: blocked
    engine.submit(big)
    engine.step()
    engine.step()                        # two skipped passes -> reserved
    assert engine.scheduler.stats["aged"] == 1
    engine.submit(Request(uid=2,
                          prompt=rng.integers(0, 256, 4).astype(np.int32),
                          max_new_tokens=2))           # would fit, must wait
    engine.step()
    assert 2 not in set(engine.slot_uid[engine.active].tolist()), \
        "a reserved entry must not be overtaken"
    steps = 0
    while engine._busy():
        engine.step()
        steps += 1
        assert steps < 2000
    assert all(engine.results[u].finish_reason == "length" for u in range(3))
    _assert_drained_leak_free(engine)


@pytest.mark.parametrize("overlap", [False, True], ids=["sync", "overlap"])
def test_run_max_steps_truncates_leak_free(setup, overlap):
    """Hitting max_steps finishes in-flight slots as 'truncated' (partial
    tokens kept, blocks released) and marks still-queued requests the same
    way — no half-populated Results, no leaked blocks, and the engine keeps
    serving afterwards."""
    cfg, params = setup
    reqs = _mixed_requests(cfg, 5, seed=62, lo=6, hi=14, new_lo=20,
                           new_hi=30)
    engine = ServeEngine(cfg, params, max_slots=2, max_len=64, paged=True,
                         page_size=8, prefix_cache=True, overlap=overlap)
    results = engine.run(reqs, max_steps=6)
    assert all(r.finish_reason for r in results), "half-populated Result"
    truncated = [r for r in results if r.finish_reason == "truncated"]
    assert truncated, "max_steps=6 must interrupt these budgets"
    assert any(r.tokens for r in truncated), "partial tokens must be kept"
    assert any("queued" in r.detail for r in truncated), \
        "never-admitted requests get a distinct detail"
    assert not engine.active.any() and engine._pending is None
    assert not engine._admit_hashes, "stale admission hash memo"
    _assert_drained_leak_free(engine)
    [r] = engine.run([Request(uid=99, prompt=np.arange(5, dtype=np.int32),
                              max_new_tokens=3)])
    assert r.finish_reason == "length" and len(r.tokens) == 3
    _assert_drained_leak_free(engine)


def test_preemption_decode_victim_resumes_exact(setup):
    """Under pool pressure a high-priority arrival evicts the youngest
    lower-priority decode; the victim's written pages ride the prefix index
    so resumption is a warm hit, and every request's greedy tokens match
    the unpreempted reference exactly."""
    cfg, params = setup
    rng = np.random.default_rng(63)
    reqs = [Request(uid=i, prompt=rng.integers(0, 256, 12).astype(np.int32),
                    max_new_tokens=6)                  # 3 blocks each
            for i in range(2)]
    hi = Request(uid=2, prompt=rng.integers(0, 256, 8).astype(np.int32),
                 max_new_tokens=4, priority=5)         # 2 blocks
    engine = ServeEngine(cfg, params, max_slots=2, max_len=64, paged=True,
                         page_size=8, max_blocks=7, prefix_cache=True,
                         preemption=True)
    for r in reqs:
        engine.submit(r)
    for _ in range(4):                   # both decoding, pool exhausted
        engine.step()
    assert engine.allocator.n_free == 0
    engine.submit(hi)
    engine.step()
    assert engine.stats["preemptions"] >= 1
    assert 2 in set(engine.slot_uid[engine.active].tolist()), \
        "high-priority request must admit via preemption"
    steps = 0
    while engine._busy():
        engine.step()
        steps += 1
        assert steps < 2000
    for req in reqs + [hi]:
        res = engine.results[req.uid]
        assert res.finish_reason == "length"
        assert res.tokens == _ref_greedy(cfg, params, req.prompt,
                                         req.max_new_tokens,
                                         max_len=64), f"uid {req.uid}"
    assert sum(engine.results[r.uid].preempted for r in reqs) >= 1
    assert engine.stats["prefix_hits"] >= 1, \
        "resumption should re-admit through the prefix index"
    _assert_drained_leak_free(engine)


def test_preemption_mid_prefill_rolls_back(setup):
    """A victim evicted mid-chunk-prefill (pages allocated, nothing
    published yet) rolls back through BlockAllocator.release like a failed
    admission, requeues with its original prompt, and still produces exact
    greedy tokens."""
    cfg, params = setup
    rng = np.random.default_rng(64)
    victim = Request(uid=0, prompt=rng.integers(0, 256, 16).astype(np.int32),
                     max_new_tokens=4)
    hi = Request(uid=1, prompt=rng.integers(0, 256, 8).astype(np.int32),
                 max_new_tokens=3, priority=5)
    engine = ServeEngine(cfg, params, max_slots=1, max_len=64, paged=True,
                         page_size=8, prefill_chunk=4, prefix_cache=True,
                         preemption=True)
    engine.submit(victim)
    engine.step()                        # admitted, first chunk only
    assert engine.phase[0] == 1 and 0 in engine._prefilling, \
        "victim must still be mid-chunk-prefill"
    engine.submit(hi)
    engine.step()
    assert engine.stats["preemptions"] == 1
    assert engine.results[victim.uid].preempted == 1
    steps = 0
    while engine._busy():
        engine.step()
        steps += 1
        assert steps < 2000
    for req in (victim, hi):
        res = engine.results[req.uid]
        assert res.finish_reason == "length"
        assert res.tokens == _ref_greedy(cfg, params, req.prompt,
                                         req.max_new_tokens,
                                         max_len=64), f"uid {req.uid}"
    _assert_drained_leak_free(engine)


@pytest.mark.parametrize("make_cfg", [_cfg, _local_cfg],
                         ids=["global", "local-window"])
def test_overlap_decode_token_parity(make_cfg):
    """Overlapped (double-buffered) stepping is token-identical to the
    synchronous loop on a fixed greedy trace — including a request that
    finishes via eos while step N+1 is already dispatched (its speculative
    overflow token is discarded)."""
    cfg = make_cfg()
    params = init(jax.random.PRNGKey(0), cfg)
    reqs = _mixed_requests(cfg, 6, seed=65, lo=4, hi=16, new_lo=4, new_hi=9)
    # make request 0 finish by eos mid-stream while others keep decoding:
    # its 2nd greedy token becomes the eos id
    ref0 = _ref_greedy(cfg, params, reqs[0].prompt, 3, max_len=64)
    eos = ref0[1]
    outs = {}
    for overlap in (False, True):
        engine = ServeEngine(cfg, params, max_slots=3, max_len=64,
                             paged=True, page_size=8, prefill_chunk=6,
                             eos_id=eos, overlap=overlap)
        results = engine.run([Request(uid=r.uid, prompt=r.prompt,
                                      max_new_tokens=r.max_new_tokens)
                              for r in reqs])
        outs[overlap] = [(r.tokens, r.finish_reason) for r in results]
        assert engine._pending is None
        assert engine.allocator.n_free == engine.allocator.capacity
    assert outs[True] == outs[False]
    assert any(fr == "eos" for _, fr in outs[True]), \
        "trace must include a finish while the next step is dispatched"


def test_overlap_interleaved_with_prefix_cache(setup):
    """Overlap parity holds under randomized submit offsets with prefix
    sharing and COW in play."""
    cfg, params = setup
    rng = np.random.default_rng(66)
    reqs = _shared_prefix_requests(cfg, 7, seed=66)
    submit_at = rng.integers(0, 20, len(reqs))
    outs = {}
    for overlap in (False, True):
        engine = ServeEngine(cfg, params, max_slots=3, max_len=64,
                             paged=True, page_size=8, prefill_chunk=6,
                             prefix_cache=True, overlap=overlap)
        order = sorted(range(len(reqs)), key=lambda i: submit_at[i])
        i = step = 0
        while i < len(order) or engine._busy():
            while i < len(order) and submit_at[order[i]] <= step:
                r = reqs[order[i]]
                engine.submit(Request(uid=r.uid, prompt=r.prompt,
                                      max_new_tokens=r.max_new_tokens))
                i += 1
            engine.step()
            step += 1
            assert step < 5000
        outs[overlap] = [engine.results[r.uid].tokens for r in reqs]
        _assert_drained_leak_free(engine)
    assert outs[True] == outs[False]


def test_streaming_callbacks_and_iterator(setup):
    """Tokens surface incrementally: on_token fires per token in order and
    stream() yields the same sequence the final Result holds, with one
    timestamp per token."""
    cfg, params = setup
    rng = np.random.default_rng(67)
    prompt = rng.integers(0, 256, 8).astype(np.int32)
    ref = _ref_greedy(cfg, params, prompt, 6)
    seen: list[int] = []
    engine = ServeEngine(cfg, params, max_slots=2, max_len=96)
    streamed = list(engine.stream(
        Request(uid=0, prompt=prompt, max_new_tokens=6,
                on_token=lambda t, res: seen.append(t))))
    res = engine.results[0]
    assert streamed == seen == res.tokens == ref
    assert len(res.token_ts) == len(res.tokens)
    assert res.ttft_s is not None and res.ttft_s >= 0
    assert res.token_ts == sorted(res.token_ts)


def test_token_ts_stamped_sync_visible(setup):
    """Pins the timestamp semantics ``Result.token_ts`` / ``ttft_s`` /
    ``itl_s`` are defined by: a token is stamped (and its ``on_token``
    callback fires) when the step's sampled ids become host-visible at
    sync, NOT at dispatch. Under ``overlap`` the next decode step is
    already in flight when token k surfaces, so the callback observes
    ``decode_steps == k + 1`` — except the final token, whose slot had no
    budget left to dispatch (== k). The synchronous loop observes == k."""
    cfg, params = setup
    prompt = np.arange(1, 9, dtype=np.int32)
    for overlap in (False, True):
        engine = ServeEngine(cfg, params, max_slots=1, max_len=64,
                             paged=True, page_size=8, overlap=overlap)
        seen: list[int] = []
        [res] = engine.run([Request(
            uid=0, prompt=prompt, max_new_tokens=5,
            on_token=lambda tok, r, e=engine:
                seen.append(e.stats["decode_steps"]))])
        assert res.finish_reason == "length"
        ts = res.token_ts
        assert len(ts) == 5 == len(seen)
        assert ts == sorted(ts)
        assert res.ttft_s is not None and res.ttft_s > 0
        assert res.itl_s is not None and res.itl_s > 0
        # token 0 comes from prefill (no decode dispatched yet)
        assert seen[0] == 0
        n_dec = len(seen) - 1
        if overlap:
            assert seen[1:] == [k + 1 for k in range(1, n_dec)] + [n_dec], \
                f"overlap stamps must be sync-visible: {seen}"
        else:
            assert seen[1:] == list(range(1, n_dec + 1)), seen


def test_slo_accounting(setup):
    """TTFT SLOs classify finished requests into met/missed goodput
    buckets; requests without SLOs stay unclassified."""
    cfg, params = setup
    rng = np.random.default_rng(68)
    prompt = rng.integers(0, 256, 6).astype(np.int32)
    engine = ServeEngine(cfg, params, max_slots=3, max_len=96)
    res = engine.run([
        Request(uid=0, prompt=prompt, max_new_tokens=3, slo_ttft_ms=1e9),
        Request(uid=1, prompt=prompt.copy(), max_new_tokens=3,
                slo_ttft_ms=1e-6),
        Request(uid=2, prompt=prompt.copy(), max_new_tokens=3),
    ])
    assert res[0].slo_met is True
    assert res[1].slo_met is False
    assert res[2].slo_met is None
    assert engine.stats["slo_met"] == 1 and engine.stats["slo_missed"] == 1


def test_on_device_sampling_temperature(setup):
    """temp > 0 samples on device (fused in the jitted step) and still
    respects budgets; temp == 0 rows stay greedy-deterministic."""
    cfg, params = setup
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, 256, 6).astype(np.int32)
    engine = ServeEngine(cfg, params, max_slots=2, max_len=96, seed=3)
    res = engine.run([
        Request(uid=0, prompt=prompt, max_new_tokens=5, temperature=1.0),
        Request(uid=1, prompt=prompt, max_new_tokens=5, temperature=0.0),
    ])
    assert all(len(r.tokens) == 5 for r in res)
    assert all(0 <= t < cfg.vocab_size for r in res for t in r.tokens)
    assert res[1].tokens == _ref_greedy(cfg, params, prompt, 5)

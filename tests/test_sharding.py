"""Partitioner rules: strategy policies, divisibility fallbacks, cache specs.
Uses AbstractMesh — no devices required."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch, get_shape, strategy
from repro.core.sharding import Partitioner, abstract_mesh

MESH = abstract_mesh((16, 16), ("data", "model"))
MESH3 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _part(arch="deepseek-7b", strat="ramora", shape="train_4k", mesh=MESH,
          mode="train"):
    return Partitioner(mesh, strategy(strat, multi_pod=("pod" in mesh.shape)),
                       get_arch(arch), get_shape(shape), mode=mode)


# --------------------------------------------------------------------------
# strategy policies (the paper's three generations)
# --------------------------------------------------------------------------
def test_occamy_replicates_params():
    p = _part(strat="occamy")
    spec = p._param_spec("blocks/attn/q_proj/kernel", 3, (15, 4096, 4096))
    assert spec == P(None, None, None)
    # batch over every chip (pure DP)
    assert p.axis_map["batch"] == ("data", "model")


def test_ramora_tp_fsdp():
    p = _part(strat="ramora")
    assert p._param_spec("blocks/attn/q_proj/kernel", 3,
                         (15, 4096, 4096)) == P(None, "data", "model")
    assert p._param_spec("blocks/mlp/down/kernel", 3,
                         (15, 11008, 4096)) == P(None, "model", "data")
    assert p._param_spec("embed/table", 2, (102400, 4096)) == P("model", "data")
    assert p.axis_map["batch"] == ("data",)


def test_ogopogo_pod_axis():
    p = _part(strat="ogopogo", mesh=MESH3)
    assert p.axis_map["batch"] == ("pod", "data")
    # params FSDP over data only (replicated over pod; grads all-reduce there)
    assert p._param_spec("blocks/mlp/up/kernel", 3,
                         (15, 4096, 11008)) == P(None, "data", "model")


# --------------------------------------------------------------------------
# divisibility fallbacks
# --------------------------------------------------------------------------
def test_qwen3_kv_heads_replicate():
    """qwen3: 8 kv heads on a 16-way model axis -> replicate that dim."""
    p = _part("qwen3-0.6b")
    spec = p.spec(("batch", None, "heads", None), (256, 4096, 8, 128))
    assert spec == P("data", None, None, None)
    # q heads (16) do shard
    spec_q = p.spec(("batch", None, "heads", None), (256, 4096, 16, 128))
    assert spec_q == P("data", None, "model", None)


def test_moe_expert_parallel_divisibility():
    # deepseek-moe: 64 % 16 == 0 -> experts sharded over model
    p = _part("deepseek-moe-16b")
    assert p.axis_map["experts"] == ("model",)
    spec = p._param_spec("blocks/moe/experts/up", 4, (13, 64, 2048, 1408))
    assert spec == P(None, "model", "data", None)
    # qwen2-moe: 60 % 16 != 0 -> replicate experts, TP-shard expert d_ff
    p2 = _part("qwen2-moe-a2.7b")
    assert p2.axis_map["experts"] is None
    spec2 = p2._param_spec("blocks/moe/experts/up", 4, (11, 60, 2048, 1408))
    assert spec2 == P(None, None, "data", "model")


def test_odd_vocab_replicates_embed_dim():
    """minicpm vocab 122753 is prime-ish: not divisible by 16 -> replicated."""
    p = _part("minicpm-2b")
    spec = p._param_spec("embed/table", 2, (122753, 2304))
    assert spec[0] is None


# --------------------------------------------------------------------------
# batches, caches, scalars
# --------------------------------------------------------------------------
def test_batch_sharding_leading_axis():
    p = _part()
    sh = p.batch_sharding({"tokens": jnp.zeros((256, 4096), jnp.int32)})
    assert sh["tokens"].spec == P("data", None)


def test_decode_cache_context_parallel():
    """long_500k (batch 1 < data axis): KV length sharded over 'data'."""
    p = _part("gemma2-27b", shape="long_500k", mode="decode")
    assert "data" in (p.axis_map["kv"] or ())
    # abstract shapes only — a materialized 500k-token cache is ~49 GB
    sh = p.cache_sharding({"blocks": {"self": {
        "k": jax.ShapeDtypeStruct((23, 1, 524288, 16, 128), jnp.bfloat16)}}})
    assert sh["blocks"]["self"]["k"].spec[2] == "data"


def test_decode_cache_batch_sharded():
    """decode_32k (batch 128 >= data axis): batch over 'data', length whole."""
    p = _part("gemma2-27b", shape="decode_32k", mode="decode")
    sh = p.cache_sharding({"blocks": {"self": {
        "k": jax.ShapeDtypeStruct((23, 128, 32768, 16, 128), jnp.bfloat16)}}})
    spec = sh["blocks"]["self"]["k"].spec
    assert spec[1] == "data"


def test_gather_block_drops_fsdp():
    """ZeRO-3 gather: FSDP axis dropped, TP kept, dtype cast applied."""
    p = _part()
    layer = {"attn": {"q_proj": {"kernel": jnp.zeros((4096, 4096))}}}
    # abstract mesh cannot run with_sharding_constraint eagerly -> trace it
    def f(lp):
        return p.gather_block(lp, jnp.bfloat16)
    out = jax.eval_shape(f, layer)
    k = out["attn"]["q_proj"]["kernel"]
    assert k.dtype == jnp.bfloat16


def test_scalar_sharding_replicated():
    assert _part().scalar_sharding().spec == P()

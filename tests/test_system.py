"""End-to-end behaviour tests for the paper's system (integration level)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ARCHS, SHAPES, SKIPS, all_cells, get_arch,
                           get_shape, is_skipped, reduced, strategy)


def test_assignment_coverage():
    """Exactly the assigned 10 archs x 4 shapes; skips only where the
    assignment allows (long_500k on full-attention archs)."""
    assert set(ARCHS) == {
        "gemma2-27b", "deepseek-7b", "minicpm-2b", "qwen3-0.6b",
        "recurrentgemma-2b", "whisper-tiny", "llava-next-mistral-7b",
        "qwen2-moe-a2.7b", "deepseek-moe-16b", "falcon-mamba-7b"}
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    # long_500k runs for sub-quadratic archs only
    runs_long = [a for a in ARCHS if not is_skipped(a, "long_500k")]
    assert set(runs_long) == {"gemma2-27b", "recurrentgemma-2b",
                              "falcon-mamba-7b"}
    assert all(s == "long_500k" for (_, s) in SKIPS)
    assert len(all_cells(include_skipped=True)) == 40


def test_assigned_dims_exact():
    """Spot-check the exact assigned dimensions (no drift)."""
    g = get_arch("gemma2-27b")
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff,
            g.vocab_size) == (46, 4608, 32, 16, 36864, 256000)
    f = get_arch("falcon-mamba-7b")
    assert (f.n_layers, f.d_model, f.vocab_size, f.ssm.d_state) == \
        (64, 4096, 65024, 16)
    d = get_arch("deepseek-moe-16b")
    assert (d.n_layers, d.d_model, d.moe.n_experts, d.moe.top_k) == \
        (28, 2048, 64, 6)
    q = get_arch("qwen2-moe-a2.7b")
    assert (q.n_layers, q.moe.n_experts, q.moe.top_k) == (24, 60, 4)
    w = get_arch("whisper-tiny")
    assert (w.n_layers, w.d_model, w.encoder.n_layers) == (4, 384, 4)
    r = get_arch("recurrentgemma-2b")
    assert (r.n_layers, r.d_model, r.n_kv_heads) == (26, 2560, 1)
    m = get_arch("minicpm-2b")
    assert (m.n_layers, m.d_model, m.n_heads, m.vocab_size) == \
        (40, 2304, 36, 122753)


def test_shapes_exact():
    t = get_shape("train_4k")
    assert (t.seq_len, t.global_batch, t.kind) == (4096, 256, "train")
    p = get_shape("prefill_32k")
    assert (p.seq_len, p.global_batch, p.kind) == (32768, 32, "prefill")
    d = get_shape("decode_32k")
    assert (d.seq_len, d.global_batch, d.kind) == (32768, 128, "decode")
    l = get_shape("long_500k")
    assert (l.seq_len, l.global_batch, l.kind) == (524288, 1, "decode")


def test_layer_patterns():
    """Family-defining layer layouts."""
    g = get_arch("gemma2-27b")
    assert [s.mixer for s in g.pattern] == ["local", "full"]
    r = get_arch("recurrentgemma-2b")
    assert [s.mixer for s in r.pattern] == ["rglru", "rglru", "local"]
    assert len(r.all_layers()) == 26
    f = get_arch("falcon-mamba-7b")
    assert all(s.mixer == "mamba" for s in f.all_layers())
    d = get_arch("deepseek-moe-16b")
    layers = d.all_layers()
    assert layers[0].mlp == "dense" and all(s.mlp == "moe"
                                            for s in layers[1:])


def test_e2e_training_learns_tiny():
    """A reduced model must actually learn the synthetic Markov stream."""
    from repro.configs.base import ShapeConfig
    from repro.optim.optimizers import adamw
    from repro.train.trainer import Trainer, TrainerConfig
    import tempfile

    cfg = reduced(get_arch("deepseek-7b")).replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=128)
    shape = ShapeConfig("t", "train", seq_len=64, global_batch=8)
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainerConfig(steps=25, ckpt_dir=d, ckpt_every=100, seed=0)
        out = Trainer(cfg, shape, strategy("ramora"), adamw(3e-3), tcfg).train()
    losses = out["losses"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_strategies_are_distinct():
    occ, ram, ogo = strategy("occamy"), strategy("ramora"), strategy("ogopogo")
    assert not occ.fsdp and not occ.tensor_parallel
    assert ram.fsdp and ram.tensor_parallel and not ram.hierarchical_collectives
    assert ogo.multi_pod and ogo.hierarchical_collectives and ogo.chunked_loss
    assert occ.mesh_axes == ("data", "model")
    assert ogo.mesh_axes == ("pod", "data", "model")

"""Per-kernel shape/dtype sweeps: every Pallas kernel (interpret backend on
CPU) against its pure-jnp oracle in ref.py, dispatched through the registry
(the whole module runs inside a ``use_backend("interpret")`` scope)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.dispatch import use_backend


# module-scoped: Hypothesis' function_scoped_fixture health check rejects
# function-scoped autouse fixtures around @given tests
@pytest.fixture(autouse=True, scope="module")
def _interpret_backend():
    with use_backend("interpret"):
        yield

KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype=jnp.float32, key=KEY, scale=1.0):
    x = jax.random.normal(key, shape, jnp.float32) * scale
    return x.astype(dtype)


# --------------------------------------------------------------------------
# streaming GEMM + fused in-stream epilogue (paper C1 + C5b)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (100, 96, 130), (128, 128, 128),
                                   (37, 200, 65), (256, 64, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_shapes_dtypes(m, k, n, dtype):
    x = _rand((m, k), dtype)
    w = _rand((k, n), dtype, jax.random.PRNGKey(1))
    got = ops.gemm(x, w)
    want = ref.gemm_ref(x, w)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("act", [None, "gelu", "silu"])
@pytest.mark.parametrize("scale", [1.0, 0.125])
def test_gemm_fused_epilogue(act, scale):
    x = _rand((64, 48))
    w = _rand((48, 96), key=jax.random.PRNGKey(1))
    b = _rand((96,), key=jax.random.PRNGKey(2))
    got = ops.gemm(x, w, bias=b, scale=scale, act=act)
    want = ref.gemm_ref(x, w, bias=b, scale=scale, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_gemm_block_shapes():
    x = _rand((200, 100))
    w = _rand((100, 150), key=jax.random.PRNGKey(1))
    want = ref.gemm_ref(x, w)
    for bm, bn, bk in [(64, 64, 64), (128, 256, 32), (32, 32, 128)]:
        got = ops.gemm(x, w, block_m=bm, block_n=bn,
                       block_k=bk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# flash attention (paper §II-C uses FlashAttention-2)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("sq,skv,d", [(64, 64, 16), (60, 60, 32),
                                      (128, 256, 16), (33, 95, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_shapes(sq, skv, d, causal):
    if causal and sq != skv:
        pytest.skip("causal requires aligned q/kv here")
    q = _rand((4, sq, d), scale=0.5)
    k = _rand((4, skv, d), key=jax.random.PRNGKey(1), scale=0.5)
    v = _rand((4, skv, d), key=jax.random.PRNGKey(2))
    got = ops.flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("window", [0, 16, 64])
@pytest.mark.parametrize("cap", [0.0, 20.0])
def test_flash_attention_window_softcap(window, cap):
    q = _rand((2, 96, 32), scale=0.5)
    k = _rand((2, 96, 32), key=jax.random.PRNGKey(1), scale=0.5)
    v = _rand((2, 96, 32), key=jax.random.PRNGKey(2))
    got = ops.flash_attention(q, k, v, causal=True, window=window, cap=cap,
                              block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window,
                                   cap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("g", [1, 2, 4])
def test_flash_attention_gqa(g):
    """BH = g * BK (grouped query heads share KV heads)."""
    q = _rand((2 * g, 64, 16), scale=0.5)
    k = _rand((2, 64, 16), key=jax.random.PRNGKey(1), scale=0.5)
    v = _rand((2, 64, 16), key=jax.random.PRNGKey(2))
    got = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    kr, vr = jnp.repeat(k, g, 0), jnp.repeat(v, g, 0)
    want = ref.flash_attention_ref(q, kr, vr, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_scale():
    q = _rand((1, 32, 16), scale=0.5)
    k = _rand((1, 32, 16), key=jax.random.PRNGKey(1), scale=0.5)
    v = _rand((1, 32, 16), key=jax.random.PRNGKey(2))
    got = ops.flash_attention(q, k, v, causal=True, scale=0.0833,
                              block_q=16, block_k=16)
    want = ref.flash_attention_ref(q, k, v, causal=True, scale=0.0833)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------
# LRU / SSM diagonal recurrence scan
# --------------------------------------------------------------------------
@pytest.mark.parametrize("b,l,d", [(1, 16, 8), (2, 50, 40), (3, 128, 512),
                                   (2, 100, 130)])
def test_lru_scan_shapes(b, l, d):
    a = jax.random.uniform(KEY, (b, l, d), minval=0.5, maxval=0.999)
    x = _rand((b, l, d), key=jax.random.PRNGKey(1))
    got = ops.lru_scan(a, x)
    want = ref.lru_scan_ref(a, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("chunk", [8, 32, 256])
def test_lru_scan_chunking_invariant(chunk):
    """Chunked kernel == unchunked reference for any chunk length."""
    a = jax.random.uniform(KEY, (2, 100, 64), minval=0.3, maxval=0.99)
    x = _rand((2, 100, 64), key=jax.random.PRNGKey(1))
    got = ops.lru_scan(a, x, chunk=chunk)
    want = ref.lru_scan_ref(a, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# packed irregular streams (paper C5c)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("rows,width,m", [(64, 32, 37), (4096, 64, 2048),
                                          (100, 130, 333)])
@pytest.mark.parametrize("pack", [4, 8])
def test_packed_gather(rows, width, m, pack):
    table = _rand((rows, width))
    idx = jax.random.randint(KEY, (m,), 0, rows)
    got = ops.packed_gather_rows(table, idx, pack=pack)
    want = ref.gather_rows_ref(table, idx)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_packed_gather_unsorted():
    table = _rand((128, 16))
    idx = jax.random.randint(KEY, (50,), 0, 128)
    got = ops.packed_gather_rows(table, idx, sort=False)
    want = ref.gather_rows_ref(table, idx)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 63), min_size=1, max_size=200))
def test_packed_gather_property(idx_list):
    """Property: packed+coalesced gather == table[idx] for any index stream
    (duplicates, any order, any length)."""
    table = _rand((64, 8))
    idx = jnp.asarray(idx_list, jnp.int32)
    got = ops.packed_gather_rows(table, idx)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(table)[np.asarray(idx)])


# --------------------------------------------------------------------------
# in-stream DMA ops (paper C5b)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("m,d", [(16, 8), (100, 64), (1000, 256)])
@pytest.mark.parametrize("scale,shift", [(1.0, 0.0), (2.5, -1.0)])
def test_instream_scale_reduce(m, d, scale, shift):
    x = _rand((m, d))
    got_y, got_s = ops.instream_scale_reduce(x, scale=scale, shift=shift)
    want_y, want_s = ref.instream_scale_reduce_ref(x, scale=scale, shift=shift)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(got_s), float(want_s),
                               rtol=1e-4, atol=1e-2)


@settings(max_examples=20, deadline=None)
@given(st.floats(-4, 4), st.floats(-2, 2))
def test_instream_property(scale, shift):
    x = _rand((33, 17))
    got_y, got_s = ops.instream_scale_reduce(x, scale=scale, shift=shift)
    np.testing.assert_allclose(np.asarray(got_y),
                               np.asarray(x) * scale + shift,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(got_s),
                               float((np.asarray(x) * scale + shift).sum()),
                               rtol=1e-3, atol=5e-2)

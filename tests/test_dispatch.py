"""Backend-registry dispatch: parity across every registered op and dtype,
capability negotiation (unsupported requests fall to ref, never error),
``use_backend`` scoping, block-size tuning, and the ``attention_impl``
deprecation shim."""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, StrategyConfig
from repro.kernels import ops
from repro.kernels.dispatch import (BACKENDS, blocks_from_pairs,
                                    default_backend_name, registry,
                                    requested_backend, resolve_backend,
                                    use_backend)

KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype=jnp.float32, seed=0, scale=1.0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * scale
    return x.astype(dtype)


def _op_calls(dtype):
    """One canonical invocation per registered op (thunks)."""
    from repro.quant import quantize_tensor

    x = _rand((48, 40), dtype)
    w = _rand((40, 56), dtype, seed=1)
    wq = quantize_tensor(_rand((40, 56), jnp.float32, seed=1), "int8",
                         block=20)
    q = _rand((4, 48, 16), dtype, scale=0.5)
    k = _rand((2, 48, 16), dtype, seed=1, scale=0.5)
    v = _rand((2, 48, 16), dtype, seed=2)
    a = jax.random.uniform(KEY, (2, 40, 24), minval=0.5,
                           maxval=0.99).astype(dtype)
    b = _rand((2, 40, 24), dtype, seed=3)
    table = _rand((64, 32), dtype)
    idx = jax.random.randint(KEY, (37,), 0, 64)
    qd = _rand((3, 2, 4, 16), dtype, seed=4, scale=0.5)   # (B, K, G, D)
    pool_k = _rand((9, 8, 2, 16), dtype, seed=5, scale=0.5)
    pool_v = _rand((9, 8, 2, 16), dtype, seed=6)
    tables = jax.random.randint(KEY, (3, 4), 0, 9, jnp.int32)
    lengths = jnp.asarray([5, 17, 30], jnp.int32)
    from repro.kernels.gemm_sparse import block_mask_from_weight
    mask = block_mask_from_weight(w.astype(jnp.float32), 8, 8, 0.5)
    return {
        "gemm": lambda: ops.gemm(x, w, scale=0.5, act="gelu"),
        "gemm_wq": lambda: ops.gemm_wq(x, wq.q, wq.scales, scale=0.5,
                                       act="gelu"),
        "gemm_sparse": lambda: ops.gemm_sparse(x, w, mask, scale=0.5,
                                               act="gelu"),
        "flash_attention": lambda: ops.flash_attention(q, k, v, causal=True),
        "lru_scan": lambda: ops.lru_scan(a, b),
        "gather_rows": lambda: ops.gather_rows(table, idx),
        "packed_gather_rows": lambda: ops.packed_gather_rows(table, idx),
        "instream_scale_reduce": lambda: ops.instream_scale_reduce(
            x, scale=2.0, shift=-0.5),
        "paged_attention": lambda: ops.paged_attention(
            qd, pool_k, pool_v, tables, lengths, cap=30.0),
    }


# --------------------------------------------------------------------------
# parity: pallas_interpret vs ref across every registered op and dtype
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("op", sorted(
    ["gemm", "gemm_wq", "gemm_sparse", "flash_attention", "lru_scan",
     "gather_rows", "packed_gather_rows", "instream_scale_reduce",
     "paged_attention"]))
def test_registry_parity_interpret_vs_ref(op, dtype):
    calls = _op_calls(dtype)
    with use_backend("ref"):
        want = calls[op]()
    with use_backend("interpret"):
        got = calls[op]()
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=tol, atol=tol)


def test_every_op_is_registered():
    assert registry.ops() == sorted(_op_calls(jnp.float32))
    for op in registry.ops():
        impls = registry.implementations(op)
        # each op has a kernel entry and a universal ref fallback
        assert any("ref" in e.backends for e in impls), op
        assert any(e.pass_interpret for e in impls), op


# --------------------------------------------------------------------------
# capability negotiation: unsupported requests fall to ref, never error
# --------------------------------------------------------------------------
def test_negotiates_down_tiny_head_dim():
    """D=4 is below the kernel's sublane floor -> ref oracle, same answer."""
    q = _rand((4, 32, 4), scale=0.5)
    k = _rand((2, 32, 4), seed=1, scale=0.5)
    v = _rand((2, 32, 4), seed=2)
    with use_backend("ref"):
        want = ops.flash_attention(q, k, v, causal=True)
    with use_backend("interpret"):
        got = ops.flash_attention(q, k, v, causal=True)  # must not error
    req = registry.request("flash_attention", q, k, v)
    impl = registry.select("flash_attention", req, resolve_backend("interpret"))
    assert impl.name == "ref"
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_negotiates_down_integer_dtype():
    x = jnp.arange(12, dtype=jnp.int32).reshape(3, 4)
    w = jnp.ones((4, 5), jnp.int32)
    with use_backend("interpret"):
        got = ops.gemm(x, w)  # int gemm: kernel declines, oracle serves
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(x, np.float32) @ np.ones((4, 5)),
                               rtol=1e-6, atol=1e-6)


def test_supported_request_selects_kernel():
    q = _rand((4, 32, 16))
    k = _rand((2, 32, 16), seed=1)
    req = registry.request("flash_attention", q, k, k)
    impl = registry.select("flash_attention", req, resolve_backend("interpret"))
    assert impl.name == "pallas" and impl.pass_interpret


def test_pallas_backend_off_tpu_negotiates_down():
    """Pinning 'pallas' on a platform with no compiled kernels must fall to
    the oracle, not crash inside pallas_call."""
    if jax.default_backend() == "tpu":
        pytest.skip("compiled pallas exists here")
    x = _rand((48, 40))
    w = _rand((40, 56), seed=1)
    with use_backend("ref"):
        want = ops.gemm(x, w)
    with use_backend("pallas"):
        got = ops.gemm(x, w)  # must not error
        req = registry.request("gemm", x, w)
        assert registry.select("gemm", req, resolve_backend()).name == "ref"
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_unknown_kwargs_raise():
    """Typo'd kwargs fail loudly, as the pre-registry jitted ops did."""
    x = _rand((8, 8))
    w = _rand((8, 8), seed=1)
    with pytest.raises(TypeError, match="blok_m"):
        ops.gemm(x, w, blok_m=64)
    q = _rand((2, 16, 16))
    with pytest.raises(TypeError, match="block"):
        ops.flash_attention(q, q, q, block=16)


# --------------------------------------------------------------------------
# use_backend scoping
# --------------------------------------------------------------------------
def test_use_backend_round_trips():
    assert requested_backend() is None
    with use_backend("interpret") as be:
        assert be.name == "interpret" and be.interpret
        assert requested_backend() == "interpret"
        with use_backend("ref") as inner:
            assert inner.name == "ref"
            assert requested_backend() == "ref"
        assert requested_backend() == "interpret"
    assert requested_backend() is None
    assert resolve_backend().name == default_backend_name()


def test_use_backend_restores_on_error():
    with pytest.raises(RuntimeError):
        with use_backend("interpret"):
            raise RuntimeError("boom")
    assert requested_backend() is None


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        with use_backend("cuda"):
            pass
    with pytest.raises(ValueError):
        resolve_backend("triton")
    assert set(BACKENDS) == {"ref", "interpret", "pallas"}


# --------------------------------------------------------------------------
# block-size tuning table
# --------------------------------------------------------------------------
def test_blocks_bucketed_by_shape():
    small = registry.request("gemm", _rand((48, 40)), _rand((40, 56)))
    large = registry.request("gemm", _rand((512, 256)), _rand((256, 512)))
    assert registry.blocks_for("gemm", small)["block_m"] == 32
    assert registry.blocks_for("gemm", large)["block_m"] == 128


def test_block_overrides_scope_and_nest():
    req = registry.request("gemm", _rand((48, 40)), _rand((40, 56)))
    base = registry.blocks_for("gemm", req)
    with use_backend(blocks={"gemm": {"block_m": 8}}):
        assert registry.blocks_for("gemm", req)["block_m"] == 8
        with use_backend(blocks={("gemm", "small"): {"block_m": 16}}):
            assert registry.blocks_for("gemm", req)["block_m"] == 16
    assert registry.blocks_for("gemm", req) == base


def test_block_override_changes_result_not_value():
    x = _rand((100, 96))
    w = _rand((96, 72), seed=1)
    with use_backend("interpret"):
        want = ops.gemm(x, w)
        with use_backend(blocks={"gemm": {"block_m": 64, "block_n": 8,
                                          "block_k": 16}}):
            got = ops.gemm(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_strategy_kernel_blocks_decode():
    sc = StrategyConfig(kernel_blocks=(
        ("gemm", "*", (("block_m", 64),)),
        ("flash_attention", "small", (("block_q", 16),)),
    ))
    blocks = blocks_from_pairs(sc.kernel_blocks)
    assert blocks == {"gemm": {"block_m": 64},
                      ("flash_attention", "small"): {"block_q": 16}}
    req = registry.request("gemm", _rand((48, 40)), _rand((40, 56)))
    with use_backend(blocks=blocks):
        assert registry.blocks_for("gemm", req)["block_m"] == 64


def test_caller_kwargs_beat_tuning_table():
    x = _rand((200, 100))
    w = _rand((100, 150), seed=1)
    with use_backend("interpret"):
        got = ops.gemm(x, w, block_m=64, block_n=64, block_k=64)
    with use_backend("ref"):
        want = ops.gemm(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# model-layer integration + attention_impl deprecation shim
# --------------------------------------------------------------------------
def _tiny_cfg(**kw):
    from repro.configs import get_arch, reduced
    return reduced(get_arch("gemma2-27b")).replace(dtype="float32", **kw)


def test_attention_impl_shim_warns_and_maps():
    with pytest.warns(DeprecationWarning):
        cfg = ModelConfig(attention_impl="pallas_interpret")
    assert cfg.resolved_kernel_backend == "interpret"
    with pytest.warns(DeprecationWarning):
        cfg = ModelConfig(attention_impl="pallas")
    assert cfg.resolved_kernel_backend == "pallas"
    # explicit kernel_backend wins over the deprecated field
    with pytest.warns(DeprecationWarning):
        cfg = ModelConfig(attention_impl="pallas", kernel_backend="ref")
    assert cfg.resolved_kernel_backend == "ref"
    # the shim round-trips: setting the deprecated field back to "xla"
    # restores the XLA paths
    with pytest.warns(DeprecationWarning):
        legacy = ModelConfig(attention_impl="pallas_interpret")
    assert legacy.replace(attention_impl="xla").resolved_kernel_backend == ""
    with pytest.raises(ValueError):
        ModelConfig(kernel_backend="cuda")
    with pytest.raises(ValueError):
        ModelConfig(attention_impl="flash3")


def test_attention_impl_shim_still_routes_model():
    """The deprecated switch must still drive the registry path end-to-end."""
    from repro.models import forward, init

    cfg = _tiny_cfg()
    params = init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 24)), jnp.int32)
    h_xla, _, _ = forward(params, cfg, toks)
    with pytest.warns(DeprecationWarning):
        legacy = cfg.replace(attention_impl="pallas_interpret")
    h_old, _, _ = forward(params, legacy, toks)
    h_new, _, _ = forward(params, cfg.replace(kernel_backend="interpret"),
                          toks)
    np.testing.assert_allclose(np.asarray(h_old), np.asarray(h_new),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(h_xla), np.asarray(h_new),
                               rtol=2e-3, atol=2e-3)


def test_use_backend_scope_overrides_model_config():
    """A use_backend scope around the model call wins over cfg."""
    from repro.models import forward, init

    cfg = _tiny_cfg()
    params = init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (1, 16)), jnp.int32)
    want, _, _ = forward(params, cfg.replace(kernel_backend="ref"), toks)
    with use_backend("ref"):
        got, _, _ = forward(params, cfg, toks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_cfg_backend_routes_whole_graph():
    """cfg.kernel_backend and a use_backend scope are interchangeable: both
    open a whole-graph registry scope (attention AND dense/MLP), so the
    outputs are bit-identical."""
    from repro.models import forward, init

    cfg = _tiny_cfg()
    params = init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(2).integers(
        0, cfg.vocab_size, (1, 16)), jnp.int32)
    via_cfg, _, _ = forward(params, cfg.replace(kernel_backend="interpret"),
                            toks)
    with use_backend("interpret"):
        via_scope, _, _ = forward(params, cfg, toks)
    np.testing.assert_array_equal(np.asarray(via_cfg), np.asarray(via_scope))
    # and the dense layers really did leave the plain-jnp path
    plain, _, _ = forward(params, cfg, toks)
    assert not np.array_equal(np.asarray(via_cfg), np.asarray(plain))


def test_training_immune_to_ambient_backend(monkeypatch):
    """REPRO_KERNEL_BACKEND (or TPU auto-detection) pins the *default* for
    direct op calls but must never reroute a training graph through the
    forward-only Pallas kernels: grad of an MoE model works under the CI
    env pin."""
    from repro.models import init, lm_loss

    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    assert default_backend_name() == "interpret"
    from repro.configs import get_arch, reduced
    cfg = reduced(get_arch("deepseek-moe-16b")).replace(dtype="float32")
    params = init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(3).integers(
        0, cfg.vocab_size, (2, 8)), jnp.int32)
    loss, grads = jax.value_and_grad(
        lambda p: lm_loss(p, cfg, toks, toks))(params)
    assert np.isfinite(float(loss))
    assert all(np.all(np.isfinite(np.asarray(g, np.float32)))
               for g in jax.tree_util.tree_leaves(grads))


def test_spmd_neutralizes_kernel_scope():
    """Under a partitioner, the model entry points neutralize an enclosing
    kernel scope (no pallas_call may trace inside pjit) instead of merely
    skipping it."""
    from repro.kernels.dispatch import kernel_scope_active
    from repro.models.transformer import _model_kernel_scope

    cfg = _tiny_cfg()
    with use_backend("interpret"):
        with _model_kernel_scope(cfg, part=object()):
            assert not kernel_scope_active()
            assert requested_backend() == "ref"
        with _model_kernel_scope(cfg, part=None):
            assert kernel_scope_active()
    assert requested_backend() is None


def test_mlp_dense_registry_parity():
    """apply_mlp under a kernel scope (fused-epilogue gemm) matches jnp."""
    from repro.models.layers import apply_mlp, mlp_init

    p = mlp_init(jax.random.PRNGKey(0), 32, 64, True, jnp.float32)
    x = _rand((2, 10, 32))
    want = apply_mlp(p, x, "silu", True, jnp.float32)
    with use_backend("interpret"):
        got = apply_mlp(p, x, "silu", True, jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_recurrent_diag_scan_registry_parity():
    """diag_scan under a kernel scope (carry absorbed into b_1) matches the
    chunked associative-scan path, including a nonzero initial state."""
    from repro.models.recurrent import diag_scan

    a = jax.random.uniform(KEY, (2, 50, 16), minval=0.3, maxval=0.99)
    b = _rand((2, 50, 16), seed=1)
    h0 = _rand((2, 16), seed=2)
    want_h, want_last = diag_scan(a, b, h0, 32)
    with use_backend("interpret"):
        got_h, got_last = diag_scan(a, b, h0, 32)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_last), np.asarray(want_last),
                               rtol=1e-5, atol=1e-5)


def test_serve_engine_accepts_kernel_backend():
    """Engine pins a backend for its jitted graphs; ref == default output."""
    from repro.models import init
    from repro.serve.engine import Request, ServeEngine

    cfg = _tiny_cfg()
    params = init(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(8, dtype=np.int32)

    outs = []
    for backend in (None, "ref"):
        eng = ServeEngine(cfg, params, max_slots=1, max_len=32,
                          kernel_backend=backend)
        res = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=4)])
        outs.append(res[0].tokens)
    assert outs[0] == outs[1]

"""Quantization subsystem: absmax quantizers, QuantTensor containers,
gemm_wq registry parity, quantized paged KV, engine integration, sizing,
checkpoint round-trip, and the roofline byte terms."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import quant
from repro.configs import LayerSpec, ModelConfig, get_arch, reduced
from repro.kernels import ops
from repro.kernels.dispatch import registry, resolve_backend, use_backend
from repro.models import decode_step, forward, init, logits_fn
from repro.models.cache import (init_cache, kv_block_bytes, kv_bytes,
                                n_blocks_for_bytes)
from repro.quant import QuantTensor
from repro.serve import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype=jnp.float32, seed=0, scale=1.0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * scale
    return x.astype(dtype)


def _cfg(**kw):
    base = dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                head_dim=32, d_ff=256, vocab_size=256, dtype="float32",
                param_dtype="float32")
    base.update(kw)
    return reduced(get_arch("qwen3-0.6b")).replace(**base)


# --------------------------------------------------------------------------
# quantizers
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype,tol", [("int8", 1.5 / 127), ("fp8", 0.08)])
@pytest.mark.parametrize("block", [0, 16])
def test_weight_roundtrip_error_bound(dtype, tol, block):
    w = _rand((64, 48))
    q, scales = quant.quantize_weight(w, dtype, block=block)
    back = quant.dequantize_weight(q, scales)
    # absmax quantization error is bounded by the scale step per block
    amax = np.abs(np.asarray(w)).max()
    assert np.abs(np.asarray(back) - np.asarray(w)).max() <= tol * amax
    assert scales.dtype == jnp.float16
    assert scales.shape == ((64 // block if block else 1), 48)


def test_per_block_scales_beat_per_channel_on_outliers():
    w = _rand((64, 8), scale=0.05)
    w = w.at[0, :].set(8.0)            # one outlier row blows the amax
    per_ch = quant.dequantize_weight(*quant.quantize_weight(w, "int8"))
    per_bl = quant.dequantize_weight(
        *quant.quantize_weight(w, "int8", block=8))
    # outside the outlier's scale block the per-block error collapses
    err_ch = np.abs(np.asarray(per_ch - w))[8:].max()
    err_bl = np.abs(np.asarray(per_bl - w))[8:].max()
    assert err_bl < err_ch / 4


def test_embed_axis_per_row_scales():
    t = _rand((32, 16))
    qt = quant.quantize_tensor(t, "int8", axis=-1)
    assert qt.scales.shape == (32, 1)
    err = np.abs(np.asarray(qt.dequantize()) - np.asarray(t))
    row_amax = np.abs(np.asarray(t)).max(axis=1, keepdims=True)
    assert (err <= 1.5 / 127 * row_amax + 1e-6).all()


def test_kv_row_quantize_roundtrip():
    x = _rand((5, 3, 16), scale=0.7)
    q, s = quant.quantize_kv(x, "int8")
    assert q.dtype == jnp.int8 and s.dtype == jnp.float16
    assert s.shape == (5, 3)
    back = quant.dequantize_kv(q, s)
    amax = np.abs(np.asarray(x)).max(-1, keepdims=True)
    assert (np.abs(np.asarray(back) - np.asarray(x))
            <= 1.5 / 127 * amax + 1e-6).all()


def test_quantize_int8_shared_with_collectives():
    """One absmax implementation serves the gradient channel too."""
    from repro.core import collectives

    assert collectives._quantize_int8 is quant.quantize_int8
    x = _rand((33,))
    q, scale = quant.quantize_int8(x)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(q, np.float32) * float(scale),
                               np.asarray(x), atol=float(scale) * 0.51)


def test_dtype_aliases_and_bytes():
    assert quant.canonical_dtype("fp8") == "float8_e4m3fn"
    assert quant.canonical_dtype("int4") == "int4"
    assert quant.dtype_bytes("int8") == 1
    assert quant.dtype_bytes("fp8") == 1
    assert quant.dtype_bytes("int4") == 0.5
    assert quant.dtype_bytes("bfloat16") == 2
    # int4 is weight-only: valid for weight_dtype, rejected for kv_dtype
    assert ModelConfig(weight_dtype="int4").weight_dtype == "int4"
    with pytest.raises(ValueError):
        ModelConfig(kv_dtype="int4")
    with pytest.raises(ValueError):
        ModelConfig(kv_dtype="fp16")
    with pytest.raises(ValueError):
        quant.quantize_kv(jnp.ones((2, 4)), "int4")
    with pytest.raises(ValueError):
        ModelConfig(weight_density=0.0)
    assert ModelConfig(weight_density=0.5).weight_density == 0.5


# --------------------------------------------------------------------------
# edge cases: zero rows (scale floor) and fp8 saturation
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", ["int8", "fp8", "int4"])
def test_all_zero_input_roundtrips_to_zero(dtype):
    """Regression: an all-zero block used to produce a 0.0 (or underflowed)
    scale whose reciprocal made NaN codes; the amax floor keeps the
    round-trip exactly zero and finite everywhere."""
    w = jnp.zeros((32, 16), jnp.float32)
    q, s = quant.quantize_weight(w, dtype, block=16)
    assert np.isfinite(np.asarray(s, np.float32)).all()
    assert (np.asarray(s, np.float32) > 0).all()
    back = np.asarray(quant.dequantize_weight(
        q, s, pack=2 if dtype == "int4" else 1))
    assert np.isfinite(back).all() and (back == 0).all()
    if dtype != "int4":           # KV pools are int8/fp8 only
        kv = jnp.zeros((2, 8, 16), jnp.float32)   # an all-zero KV page
        kq, ks = quant.quantize_kv(kv, dtype)
        kb = np.asarray(quant.dequantize_kv(kq, ks), np.float32)
        assert np.isfinite(kb).all() and (kb == 0).all()
    qz, sz = quant.quantize_int8(jnp.zeros((8,)))
    assert float(sz) > 0 and not np.isnan(np.asarray(qz, np.float32)).any()


def test_fp8_cast_saturates_instead_of_nan():
    """Regression: a raw ``.astype(float8_e4m3fn)`` NaNs past ~±464 on CPU;
    the quantizer clips to ±448 before casting, so outliers saturate."""
    from repro.quant.tensor import _cast_q

    x = jnp.asarray([448.0, -448.0, 464.0, 1e4, -1e38], jnp.float32)
    out = np.asarray(_cast_q(x, "float8_e4m3fn"), np.float32)
    assert np.isfinite(out).all(), out
    np.testing.assert_array_equal(out, [448.0, -448.0, 448.0, 448.0, -448.0])
    # and through the public quantizer: a wild outlier row stays finite
    w = _rand((16, 8)).at[0, 0].set(3e4)
    back = quant.dequantize_weight(*quant.quantize_weight(w, "fp8", block=8))
    assert np.isfinite(np.asarray(back)).all()


# --------------------------------------------------------------------------
# int4: nibble packing, containers, gemm_wq
# --------------------------------------------------------------------------
def test_int4_pack_unpack_roundtrip():
    from repro.quant import pack_int4, unpack_int4

    codes = jnp.asarray(np.random.default_rng(0).integers(-8, 8, (12, 6)),
                        jnp.int8)
    packed = pack_int4(codes, axis=0)
    assert packed.shape == (6, 6) and packed.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(unpack_int4(packed, axis=0)),
                                  np.asarray(codes))
    with pytest.raises(ValueError):
        pack_int4(codes[:11], axis=0)      # odd axis length


@pytest.mark.parametrize("block", [0, 16, 32])
def test_int4_weight_roundtrip_bound_and_bytes(block):
    w = _rand((64, 48))
    qt = quant.quantize_tensor(w, "int4", block=block)
    assert qt.pack == 2 and qt.q.shape == (32, 48)   # two nibbles per byte
    assert qt.shape == (64, 48)                      # logical shape
    back = np.asarray(qt.dequantize())
    amax = np.abs(np.asarray(w)).max()
    assert np.abs(back - np.asarray(w)).max() <= 1.5 / 7 * amax
    if block == 32:
        bf16_bytes = w.size * 2
        assert qt.nbytes / bf16_bytes <= 0.30, qt.nbytes / bf16_bytes


def test_int4_quant_tensor_pytree_and_legacy_aux():
    qt = quant.quantize_tensor(_rand((16, 8)), "int4", block=8)
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    rt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rt.pack == 2 and rt.axis == qt.axis
    # pre-pack checkpoints serialized a bare-int aux (axis only)
    legacy = QuantTensor.tree_unflatten(-2, (qt.q, qt.scales))
    assert legacy.pack == 1 and legacy.axis == -2


@pytest.mark.parametrize("shape,block", [((48, 40, 56), 10), ((33, 64, 17), 16),
                                         ((8, 128, 8), 32)])
def test_gemm_wq_int4_kernel_matches_ref(shape, block):
    M, K, N = shape
    x = _rand((M, K))
    qt = quant.quantize_tensor(_rand((K, N), seed=1), "int4", block=block)
    assert qt.q.shape[0] == K // 2
    exact = np.asarray(x @ qt.dequantize())
    with use_backend("ref"):
        want = ops.gemm_wq(x, qt.q, qt.scales)
    with use_backend("interpret"):
        got = ops.gemm_wq(x, qt.q, qt.scales)
    np.testing.assert_allclose(np.asarray(want), exact, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_gemm_wq_int4_negotiation():
    """Packed weights (K/2 rows) select the Pallas kernel when the scale
    blocking splits into even tiles, and fall back to the dequantize ref
    oracle otherwise — never a silent wrong-shape contraction."""
    x = _rand((8, 40))
    qt = quant.quantize_tensor(_rand((40, 16), seed=1), "int4", block=10)
    req = registry.request("gemm_wq", x, qt.q, qt.scales)
    impl = registry.select("gemm_wq", req, resolve_backend("interpret"))
    assert impl.name == "pallas"      # 40/4=10 blocks? K//nb=10 even
    # odd rows-per-scale-block (K//nb = 5) cannot tile packed bytes evenly
    qt5 = quant.quantize_tensor(_rand((40, 16), seed=1), "int4", block=5)
    req5 = registry.request("gemm_wq", x, qt5.q, qt5.scales)
    assert registry.select("gemm_wq", req5,
                           resolve_backend("interpret")).name == "ref"
    with use_backend("interpret"):     # ref still computes the right thing
        out = ops.gemm_wq(x, qt5.q, qt5.scales)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(x @ qt5.dequantize()),
                               rtol=1e-5, atol=1e-5)


def test_quantize_params_int4_bytes_and_forward():
    cfg = _cfg(weight_dtype="int4", quant_block=32)
    params = init(jax.random.PRNGKey(0), cfg)
    qp = quant.quantize_params(params, cfg)
    qt = qp["blocks"][0]["attn"]["q_proj"]["kernel"]
    assert isinstance(qt, QuantTensor) and qt.pack == 2
    assert qt.shape == params["blocks"][0]["attn"]["q_proj"]["kernel"].shape
    ratio = quant.param_bytes(qp) / quant.param_bytes(params)
    assert ratio <= 0.30 * 2, ratio    # fp32 baseline here (2x bf16 target)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 12)), jnp.int32)
    want, _, _ = forward(params, cfg, toks)
    got, _, _ = forward(qp, cfg, toks)
    w, g = np.asarray(want), np.asarray(got)
    rel = np.linalg.norm(g - w) / np.linalg.norm(w)
    # random-init hidden states at 4 bits drift hard (~0.21 per-weight step
    # compounding over layers); the trained-model accuracy gate lives in
    # benchmarks/quant_accuracy.py (teacher-forced match >= 0.95)
    assert rel < 0.6, rel
    with use_backend("interpret"):     # kernel path agrees with XLA dequant
        got_k, _, _ = forward(qp, cfg, toks)
    np.testing.assert_allclose(np.asarray(got_k), g, rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------
# property: round-trip bound across the whole ladder
# --------------------------------------------------------------------------
from tests._hyp import given, settings, st  # noqa: E402


def _roundtrip_case(dtype, nblocks, rows, n, regime, seed):
    """|dequant(quant(w)) - w| <= step * block_amax for every ladder rung,
    including all-zero rows, denormal rows, and single-element blocks."""
    rows = rows * 2 if dtype == "int4" else rows   # packing needs even K
    k = nblocks * rows
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((k, n)).astype(np.float32)
    if regime == "zero_rows":
        w[:: max(1, k // 2)] = 0.0
    elif regime == "denormal":
        w[0] = 1e-42                    # below fp32 normal range
    elif regime == "outlier":
        w[0, 0] = 3e4
    q, s = quant.quantize_weight(jnp.asarray(w), dtype, block=rows)
    sf = np.asarray(s, np.float32)
    assert np.isfinite(sf).all() and (sf > 0).all()
    back = np.asarray(quant.dequantize_weight(
        q, s, pack=2 if dtype == "int4" else 1), np.float32)
    assert np.isfinite(back).all()
    # rounding half-step + fp16 scale-storage error, per block amax
    step = {"int8": 1.5 / 127, "fp8": 0.08, "int4": 1.5 / 7}[dtype]
    amax = np.abs(w).reshape(nblocks, rows, n).max(axis=1, keepdims=True)
    bound = np.broadcast_to(step * amax + 1e-5,
                            (nblocks, rows, n)).reshape(k, n)
    # the amax floor means tiny blocks round to zero rather than scale up
    bound = np.maximum(bound, 2e-4)
    assert (np.abs(back - w) <= bound).all()


@pytest.mark.property
@settings(max_examples=40, deadline=None)
@given(st.sampled_from(["int8", "fp8", "int4"]),
       st.integers(1, 6),                    # scale blocks along K
       st.integers(1, 5),                    # rows per scale block (x2 int4)
       st.integers(1, 8),                    # N
       st.sampled_from(["normal", "zero_rows", "denormal", "outlier"]),
       st.integers(0, 2 ** 31 - 1))
def test_quantize_roundtrip_bound_property(dtype, nblocks, rows, n, regime,
                                           seed):
    _roundtrip_case(dtype, nblocks, rows, n, regime, seed)


@pytest.mark.property
@pytest.mark.parametrize("dtype", ["int8", "fp8", "int4"])
@pytest.mark.parametrize("regime", ["normal", "zero_rows", "denormal",
                                    "outlier"])
def test_quantize_roundtrip_bound_seeded(dtype, regime):
    """Seeded fallback of the same driver: keeps the round-trip bound alive
    on containers without hypothesis (where @given-tests skip)."""
    rng = np.random.default_rng(hash((dtype, regime)) % (2 ** 31))
    for _ in range(10):
        _roundtrip_case(dtype, int(rng.integers(1, 7)),
                        int(rng.integers(1, 6)), int(rng.integers(1, 9)),
                        regime, int(rng.integers(0, 2 ** 31 - 1)))


# --------------------------------------------------------------------------
# gemm_wq through the registry
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", ["int8", "fp8"])
@pytest.mark.parametrize("shape,block", [((48, 40, 56), 0), ((48, 40, 56), 10),
                                         ((33, 64, 17), 16), ((8, 128, 8), 32)])
def test_gemm_wq_kernel_matches_ref(dtype, shape, block):
    M, K, N = shape
    x = _rand((M, K))
    qt = quant.quantize_tensor(_rand((K, N), seed=1), dtype, block=block)
    exact = np.asarray(x @ qt.dequantize())
    with use_backend("ref"):
        want = ops.gemm_wq(x, qt.q, qt.scales)
    with use_backend("interpret"):
        got = ops.gemm_wq(x, qt.q, qt.scales)
    np.testing.assert_allclose(np.asarray(want), exact, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_gemm_wq_bias_act_epilogue_parity():
    x = _rand((20, 32))
    qt = quant.quantize_tensor(_rand((32, 24), seed=1), "int8", block=8)
    bias = _rand((24,), seed=2)
    with use_backend("ref"):
        want = ops.gemm_wq(x, qt.q, qt.scales, bias, scale=0.5, act="gelu")
    with use_backend("interpret"):
        got = ops.gemm_wq(x, qt.q, qt.scales, bias, scale=0.5, act="gelu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_gemm_wq_kernel_selected_and_negotiates_down():
    x = _rand((16, 32))
    qt = quant.quantize_tensor(_rand((32, 24), seed=1), "int8")
    req = registry.request("gemm_wq", x, qt.q, qt.scales)
    impl = registry.select("gemm_wq", req, resolve_backend("interpret"))
    assert impl.name == "pallas" and impl.pass_interpret
    # dense-float "weights" are not a quantized request -> oracle serves it
    wf = _rand((32, 24), seed=1)
    req = registry.request("gemm_wq", x, wf, qt.scales)
    assert registry.select("gemm_wq", req,
                           resolve_backend("interpret")).name == "ref"
    with use_backend("interpret"):
        out = ops.gemm_wq(x, wf, jnp.ones((1, 24), jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ wf),
                               rtol=1e-5, atol=1e-5)


def test_dense_layer_dispatches_quantized():
    """layers.dense with a QuantTensor routes gemm_wq on every backend."""
    from repro.models.layers import dense

    x = _rand((2, 10, 32))
    w = _rand((32, 24), seed=1)
    qt = quant.quantize_tensor(w, "int8", block=8)
    want = x @ qt.dequantize()
    got_xla = dense(x, qt)
    with use_backend("interpret"):
        got_kernel = dense(x, qt, act=None)
    np.testing.assert_allclose(np.asarray(got_xla), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_kernel), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# quantized paged attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", ["int8", "fp8"])
def test_paged_attention_quantized_parity(dtype):
    B, K, G, D, N, page, P = 3, 2, 4, 16, 9, 8, 4
    q = _rand((B, K, G, D), seed=4, scale=0.5)
    kp = _rand((N, page, K, D), seed=5, scale=0.5)
    vp = _rand((N, page, K, D), seed=6)
    tables = jax.random.randint(KEY, (B, P), 0, N, jnp.int32)
    lengths = jnp.asarray([5, 17, 30], jnp.int32)
    kq, ks = quant.quantize_kv(kp, dtype)
    vq, vs = quant.quantize_kv(vp, dtype)
    with use_backend("ref"):
        want = ops.paged_attention(q, kq, vq, tables, lengths, ks, vs)
    with use_backend("interpret"):
        got = ops.paged_attention(q, kq, vq, tables, lengths, ks, vs)
    req = registry.request("paged_attention", q, kq, vq, tables, lengths,
                           ks, vs)
    assert registry.select("paged_attention", req,
                           resolve_backend("interpret")).name == "pallas"
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-4, atol=5e-4)
    # and the quantized read stays close to the unquantized pools
    dense_out = ops.paged_attention(q, kp, vp, tables, lengths)
    tol = 0.05 if dtype == "int8" else 0.2
    np.testing.assert_allclose(np.asarray(want), np.asarray(dense_out),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", ["int8", "fp8"])
def test_paged_attention_no_float_page_bounce(dtype):
    """The quantized paged-attention kernel contracts QK^T and PV directly
    against the storage codes (native low-precision dot_general), folding
    the per-row scales into the (G, page) scores — it must never
    materialize a float page-sized (page, D) dequantized copy in-kernel."""
    B, K, G, D, N, page, P = 2, 2, 4, 32, 5, 8, 3
    q = _rand((B, K, G, D), scale=0.5)
    kq, ks = quant.quantize_kv(_rand((N, page, K, D), seed=1), dtype)
    vq, vs = quant.quantize_kv(_rand((N, page, K, D), seed=2), dtype)
    tables = jax.random.randint(KEY, (B, P), 0, N, jnp.int32)
    lengths = jnp.asarray([5, 20], jnp.int32)

    with use_backend("interpret"):
        jaxpr = jax.make_jaxpr(
            lambda *a: ops.paged_attention(*a))(q, kq, vq, tables, lengths,
                                                ks, vs)

    bad = []

    def walk(jx):
        for eqn in jx.eqns:
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is None or not hasattr(aval, "shape"):
                    continue
                if (aval.dtype in (jnp.float32, jnp.bfloat16)
                        and tuple(aval.shape[-2:]) == (page, D)):
                    bad.append((eqn.primitive.name, aval.str_short()))
            for p in eqn.params.values():
                inner = getattr(p, "jaxpr", p)
                if hasattr(inner, "eqns"):
                    walk(inner)

    walk(jaxpr.jaxpr)
    assert not bad, f"float page-sized intermediates in kernel: {bad}"


def test_quantized_pools_without_scales_error_loudly():
    """int8 pools WITHOUT scale operands must neither select the kernel nor
    silently run attention over raw codes — the public op refuses."""
    B, K, G, D, N, page, P = 2, 2, 2, 16, 5, 4, 3
    q = _rand((B, K, G, D))
    kq = jnp.zeros((N, page, K, D), jnp.int8)
    tables = jnp.zeros((B, P), jnp.int32)
    lengths = jnp.asarray([3, 4], jnp.int32)
    req = registry.request("paged_attention", q, kq, kq, tables, lengths)
    assert registry.select("paged_attention", req,
                           resolve_backend("interpret")).name == "ref"
    with pytest.raises(ValueError, match="k_scale"):
        ops.paged_attention(q, kq, kq, tables, lengths)


# --------------------------------------------------------------------------
# quantize_params + model forward
# --------------------------------------------------------------------------
def test_quantize_params_selection_and_bytes():
    cfg = _cfg(param_dtype="bfloat16", weight_dtype="int8", quant_block=32)
    params = init(jax.random.PRNGKey(0), cfg)
    qp = quant.quantize_params(params, cfg)
    assert quant.is_quantized(qp) and not quant.is_quantized(params)
    # matmul weights wrapped, embed per-row, norms untouched
    assert isinstance(qp["blocks"][0]["attn"]["q_proj"]["kernel"],
                      QuantTensor)
    assert isinstance(qp["blocks"][0]["mlp"]["up"]["kernel"], QuantTensor)
    assert isinstance(qp["embed"]["table"], QuantTensor)
    assert qp["embed"]["table"].axis == -1
    assert not isinstance(qp["final_norm"]["scale"], QuantTensor)
    ratio = quant.param_bytes(qp) / quant.param_bytes(params)
    assert ratio <= 0.55, ratio
    # idempotent
    again = quant.quantize_params(qp, cfg)
    assert quant.param_bytes(again) == quant.param_bytes(qp)


def test_quantize_params_skips_router_and_conv():
    moe_cfg = reduced(get_arch("qwen2-moe-a2.7b")).replace(
        dtype="float32", param_dtype="float32", weight_dtype="int8")
    params = init(jax.random.PRNGKey(0), moe_cfg)
    qp = quant.quantize_params(params, moe_cfg)
    block = qp["blocks"][0]
    assert not isinstance(block["moe"]["router"]["kernel"], QuantTensor)
    assert isinstance(block["moe"]["experts"]["gate"], QuantTensor)
    rec_cfg = reduced(get_arch("recurrentgemma-2b")).replace(
        dtype="float32", param_dtype="float32", weight_dtype="int8")
    rp = quant.quantize_params(init(jax.random.PRNGKey(0), rec_cfg), rec_cfg)
    leaves = jax.tree_util.tree_flatten_with_path(
        rp, is_leaf=lambda x: isinstance(x, QuantTensor))[0]
    for path, leaf in leaves:
        keys = [str(getattr(k, "key", "")) for k in path]
        if "conv" in keys:
            assert not isinstance(leaf, QuantTensor), keys


def test_quantized_forward_close_and_moe_kernel_scope():
    cfg = reduced(get_arch("qwen2-moe-a2.7b")).replace(
        dtype="float32", param_dtype="float32", weight_dtype="int8",
        quant_block=16)
    params = init(jax.random.PRNGKey(0), cfg)
    qp = quant.quantize_params(params, cfg)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 12)), jnp.int32)
    want, _, _ = forward(params, cfg, toks)
    got, _, _ = forward(qp, cfg, toks)
    # weight-only quantization: close, not equal
    w, g = np.asarray(want), np.asarray(got)
    rel = np.linalg.norm(g - w) / np.linalg.norm(w)
    assert rel < 0.05, rel
    assert np.abs(g - w).max() < 0.25 * np.abs(w).max()
    # the quantized expert FFN under a kernel scope (per-expert gemm_wq
    # grouped GEMM) matches the astype-dequant XLA path
    with use_backend("interpret"):
        got_k, _, _ = forward(qp, cfg, toks)
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(got),
                               rtol=2e-3, atol=2e-3)


def test_quant_teacher_forced_token_match():
    """Per-position greedy agreement of the int8 model vs fp32 baseline."""
    cfg = _cfg(weight_dtype="int8", quant_block=32)
    params = init(jax.random.PRNGKey(0), cfg)
    qp = quant.quantize_params(params, cfg)
    rng = np.random.default_rng(0)
    match = total = 0
    for _ in range(4):
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 32)),
                           jnp.int32)
        hb, _, _ = forward(params, cfg, toks)
        hq, _, _ = forward(qp, cfg, toks)
        gb = np.asarray(jnp.argmax(
            logits_fn(params, cfg, hb)[0, :, :cfg.vocab_size], -1))
        gq = np.asarray(jnp.argmax(
            logits_fn(qp, cfg, hq)[0, :, :cfg.vocab_size], -1))
        match += int((gb == gq).sum())
        total += len(gb)
    # random-init logits are nearly tied, so this floor is conservative;
    # benchmarks/quant_accuracy.py asserts >= 0.95 on a trained model
    assert match / total >= 0.85, (match, total)


# --------------------------------------------------------------------------
# engine integration: quantized KV + weights
# --------------------------------------------------------------------------
def _mixed_requests(cfg, n, seed, lo=4, hi=18, new_lo=3, new_hi=8):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        rng.integers(lo, hi)).astype(np.int32),
                    max_new_tokens=int(rng.integers(new_lo, new_hi)))
            for i in range(n)]


def test_engine_quantized_kv_matches_dense_greedy():
    """int8 paged KV alone (dense weights) preserves greedy decode on the
    overwhelming majority of tokens across interleaved admits/finishes."""
    cfg = _cfg()
    params = init(jax.random.PRNGKey(0), cfg)
    reqs = _mixed_requests(cfg, 6, seed=7)
    outs = {}
    for kv in ("", "int8"):
        engine = ServeEngine(cfg.replace(kv_dtype=kv), params, max_slots=3,
                             max_len=64, paged=True, page_size=8,
                             prefill_chunk=6)
        res = engine.run([Request(uid=r.uid, prompt=r.prompt,
                                  max_new_tokens=r.max_new_tokens)
                          for r in reqs])
        assert all(r.finish_reason == "length" for r in res)
        outs[kv] = [r.tokens for r in res]
    match = sum(int(x == y) for a, b in zip(outs[""], outs["int8"])
                for x, y in zip(a, b))
    total = sum(len(a) for a in outs[""])
    assert match / total >= 0.9, (match, total)


def test_engine_quantized_cache_layout_and_bytes():
    cfg = _cfg(kv_dtype="int8", weight_dtype="int8")
    params = init(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_slots=2, max_len=32, paged=True,
                         page_size=8)
    assert quant.is_quantized(engine.params)
    leaves = {"".join(str(k) for k in p): l
              for p, l in jax.tree_util.tree_flatten_with_path(
                  engine.cache)[0]}
    k_pools = [l for p, l in leaves.items()
               if p.endswith("['self']['k']")
               and engine.n_blocks in l.shape[:2]]
    scales = [l for p, l in leaves.items() if "k_scale" in p]
    assert k_pools and all(l.dtype == jnp.int8 for l in k_pools)
    assert scales and all(l.dtype == jnp.float16 for l in scales)
    # sizing reflects the narrow dtype: quantized pool < 0.55x the fp32 pool
    dense_cache = init_cache(cfg.replace(kv_dtype=""), 2, 32,
                             n_blocks=engine.n_blocks, page_size=8)
    ratio = (kv_bytes(engine.cache, pool_n_blocks=engine.n_blocks)
             / kv_bytes(dense_cache, pool_n_blocks=engine.n_blocks))
    assert ratio <= 0.55 / 2, ratio   # int8+f16 scales vs fp32 ~ 0.27


def test_kv_dtype_requires_paged_and_no_encdec():
    cfg = _cfg(kv_dtype="int8")
    params = init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, params, max_slots=1, max_len=32, paged=False)
    vlm = _cfg(kv_dtype="int8")
    engine = ServeEngine(vlm, params, max_slots=1, max_len=32, paged=True,
                         page_size=8)
    bad = Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                  max_new_tokens=2,
                  extra_embeds=np.zeros((2, vlm.d_model), np.float32))
    [res] = engine.run([bad])
    assert res.finish_reason == "rejected"
    assert "chunked-prefill" in res.detail


@pytest.mark.parametrize("kv", ["int8", "fp8"])
def test_prefix_shared_quantized_blocks_roundtrip(kv):
    """Prefix-cache block sharing over quantized pools: the per-row scale
    tensors ride along on share and copy-on-write, so a warm cache (hits +
    a full-match COW) emits exactly the tokens the cold quantized engine
    does — a dropped scale would skew every dequantized prefix row."""
    cfg = _cfg(kv_dtype=kv)
    params = init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(60)
    sys_prompt = rng.integers(0, 256, 16).astype(np.int32)   # 2 full pages
    reqs = lambda: ([Request(uid=0, prompt=sys_prompt.copy(),  # full match
                             max_new_tokens=4)]
                    + [Request(uid=i, prompt=np.concatenate(
                           [sys_prompt,
                            rng2.integers(0, 256, 5).astype(np.int32)]),
                           max_new_tokens=4)
                       for i, rng2 in
                       enumerate(np.random.default_rng(61).spawn(2), 1)])
    outs = {}
    for pc in (False, True):
        engine = ServeEngine(cfg, params, max_slots=2, max_len=64,
                             paged=True, page_size=8, prefill_chunk=8,
                             prefix_cache=pc)
        engine.run([Request(uid=99, prompt=sys_prompt, max_new_tokens=2)])
        res = engine.run(reqs())
        outs[pc] = [r.tokens for r in res]
        if pc:
            assert engine.stats["prefix_hits"] == 3
            assert engine.stats["prefix_cow"] == 1   # the full-match resubmit
            assert engine.allocator.n_live == 0
    assert outs[True] == outs[False], \
        f"{kv} scales did not survive share/COW"


def test_copy_block_carries_quant_scales():
    """cache.copy_block duplicates K/V *and* the per-row scale leaves of a
    quantized pool (and leaves non-pool state untouched)."""
    from repro.models.cache import copy_block

    cfg = _cfg(kv_dtype="int8")
    cache = init_cache(cfg, 2, 32, n_blocks=6, page_size=8)

    def fill(leaf):
        if leaf.dtype == jnp.int8:
            return jnp.arange(leaf.size, dtype=jnp.int32).reshape(
                leaf.shape).astype(jnp.int8)
        return jnp.arange(leaf.size, dtype=jnp.float32).reshape(
            leaf.shape).astype(leaf.dtype)

    cache = jax.tree.map(fill, cache)
    out = copy_block(cache, 2, 4, 6)

    def check(path, a, b):
        keys = [getattr(k, "key", None) for k in path]
        axis = 1 if "blocks" in keys else 0
        if "self" in keys and a.shape[axis] == 6:
            src = jnp.take(a, 2, axis)
            dst = jnp.take(b, 4, axis)
            np.testing.assert_array_equal(np.asarray(src), np.asarray(dst))
            # untouched blocks keep their contents
            np.testing.assert_array_equal(np.asarray(jnp.take(a, 1, axis)),
                                          np.asarray(jnp.take(b, 1, axis)))
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    jax.tree_util.tree_map_with_path(check, cache, out)
    # the quantized pool really has scale leaves, and they were copied
    leaves = jax.tree_util.tree_flatten_with_path(out)[0]
    scale_leaves = [l for p, l in leaves
                    if any(getattr(k, "key", None) == "k_scale" for k in p)]
    assert scale_leaves, "quantized pool must carry k_scale leaves"


def test_rejection_detail_reports_budget():
    cfg = _cfg()
    params = init(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_slots=1, max_len=16)
    [res] = engine.run([Request(uid=0, prompt=np.zeros(14, np.int32),
                                max_new_tokens=8)])
    assert res.finish_reason == "rejected"
    assert "22 tokens > 16" in res.detail
    paged = ServeEngine(cfg, params, max_slots=2, max_len=64, paged=True,
                        page_size=8, max_blocks=5)
    [res] = paged.run([Request(uid=1, prompt=np.zeros(40, np.int32),
                               max_new_tokens=8)])
    assert res.finish_reason == "rejected"
    assert "blocks" in res.detail and "KV bytes" in res.detail
    assert str(4 * paged._block_kv_bytes) in res.detail  # capacity budget


def test_n_blocks_for_bytes_doubles_at_int8():
    cfg = _cfg(dtype="bfloat16")
    qcfg = cfg.replace(kv_dtype="int8")
    budget = 1 << 20
    n_bf16 = n_blocks_for_bytes(cfg, budget, 8)
    n_int8 = n_blocks_for_bytes(qcfg, budget, 8)
    assert 1.8 * n_bf16 <= n_int8 <= 2.0 * n_bf16
    assert kv_block_bytes(qcfg, 8) < 0.55 * kv_block_bytes(cfg, 8)
    # the engine's budget-driven pool sizing flows through the helper
    params = init(jax.random.PRNGKey(0), cfg.replace(dtype="float32"))
    small = ServeEngine(cfg.replace(dtype="float32"), params, max_slots=2,
                        max_len=64, paged=True, page_size=8,
                        kv_budget_bytes=kv_block_bytes(
                            cfg.replace(dtype="float32"), 8) * 3)
    assert small.allocator.capacity == 3


# --------------------------------------------------------------------------
# checkpoint round-trip
# --------------------------------------------------------------------------
def test_ckpt_roundtrip_quantized_params(tmp_path):
    from repro.ckpt import restore_checkpoint, save_checkpoint

    cfg = _cfg(weight_dtype="int8", quant_block=32)
    params = quant.quantize_params(init(jax.random.PRNGKey(0), cfg), cfg)
    save_checkpoint(tmp_path, 1, params)
    template = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            params)
    restored, _ = restore_checkpoint(tmp_path, template)
    qt = restored["blocks"][0]["attn"]["q_proj"]["kernel"]
    assert isinstance(qt, QuantTensor)
    assert qt.q.dtype == jnp.int8 and qt.scales.dtype == jnp.float16
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


# --------------------------------------------------------------------------
# roofline / memfloor byte terms
# --------------------------------------------------------------------------
def test_memfloor_decode_bytes_follow_quant_dtypes():
    from repro.configs import ShapeConfig
    from repro.core.memfloor import MeshSizes, hbm_bytes_floor
    from repro.core.roofline import traffic_dtype_bytes

    assert traffic_dtype_bytes("int8") == 1
    assert traffic_dtype_bytes("fp8") == 1
    assert traffic_dtype_bytes("", 2.0) == 2.0
    cfg = get_arch("qwen3-0.6b")
    shape = ShapeConfig(name="d", kind="decode", seq_len=2048, global_batch=8)
    mesh = MeshSizes(n_data=1, n_model=1)
    base = hbm_bytes_floor(cfg, shape, mesh, fsdp=False)
    q = hbm_bytes_floor(
        cfg.replace(weight_dtype="int8", kv_dtype="int8"), shape, mesh,
        fsdp=False)
    assert q["weights"] == pytest.approx(base["weights"] / 2)
    assert q["cache"] < 0.55 * base["cache"]
    assert q["total"] < base["total"]

"""Observability subsystem unit tests: metrics registry, tracer, reports.

These are pure-Python tests (no model, no jit) — the counters-vs-engine
ground-truth checks live in tests/test_serve.py and the property oracle in
tests/test_allocator_props.py; here we pin the *contracts* of the obs
package itself: instrument semantics, Snapshot algebra and JSON round-trip,
Prometheus text shape, Chrome-trace structure and its validator's failure
modes, the StatsView dict compatibility layer, and the zero-division-safe
paths of the utilization report.
"""
from __future__ import annotations

import json

import numpy as np
import pytest

from repro.obs import (NULL_TRACER, MetricsRegistry, NullTracer, Snapshot,
                       Tracer, decode_utilization, validate_chrome_trace,
                       windows_from_trace, write_metrics_json)


# ---------------------------------------------------------------- metrics --

def test_counter_monotone_and_labeled():
    reg = MetricsRegistry()
    c = reg.counter("reqs", "requests", labels=("reason",))
    c.inc(reason="ok")
    c.inc(2, reason="ok")
    c.inc(reason="err")
    assert c.series() == {"reqs{reason=err}": 1.0, "reqs{reason=ok}": 3.0}
    with pytest.raises(ValueError):
        c.inc(-1, reason="ok")
    with pytest.raises(ValueError):
        c.inc()  # labeled counter requires its labels
    # unlabeled counter: value property + numpy-scalar coercion
    u = reg.counter("toks")
    u.inc(np.int64(5))
    assert u.value == 5.0 and type(u.value) is float


def test_gauge_set_and_inc():
    reg = MetricsRegistry()
    g = reg.gauge("live")
    g.set(4)
    g.inc(-1)
    assert g.value == 3.0
    g.set(np.float32(2.5))
    assert g.value == 2.5


def test_histogram_cumulative_buckets():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 5.0, 50.0):
        h.observe(v)
    [s] = h.series().values()
    assert s["count"] == 4 and s["sum"] == pytest.approx(55.55)
    # buckets are cumulative: le=0.1 holds 1, le=1 holds 2, le=10 holds 3
    assert s["buckets"] == {"0.1": 1, "1.0": 2, "10.0": 3}


def test_registry_get_or_create_shares_and_type_checks():
    reg = MetricsRegistry()
    a = reg.counter("prefix_evictions")
    b = reg.counter("prefix_evictions")
    assert a is b
    with pytest.raises(TypeError):
        reg.gauge("prefix_evictions")
    with pytest.raises(ValueError):
        reg.counter("prefix_evictions", labels=("who",))


def test_snapshot_delta_and_lookup():
    reg = MetricsRegistry()
    c = reg.counter("steps")
    g = reg.gauge("live")
    h = reg.histogram("win", buckets=(1.0,))
    c.inc(3)
    g.set(2)
    h.observe(0.5)
    snap0 = reg.snapshot()
    c.inc(4)
    g.set(7)
    h.observe(0.25)
    h.observe(3.0)
    d = reg.snapshot().delta(snap0)
    assert d["steps"] == 4.0          # counters subtract
    assert d["live"] == 7.0           # gauges take the later value
    assert d["win"]["count"] == 2 and d["win"]["sum"] == pytest.approx(3.25)
    assert d["win"]["buckets"]["1.0"] == 1
    assert "steps" in d and d.get("nope", "x") == "x"
    with pytest.raises(KeyError):
        d["nope"]


def test_snapshot_json_round_trip():
    reg = MetricsRegistry()
    reg.counter("a", labels=("k",)).inc(2, k="v")
    reg.gauge("b").set(1.5)
    reg.histogram("c", buckets=(0.5, 2.0)).observe(1.0)
    snap = reg.snapshot()
    back = Snapshot.from_json(snap.to_json())
    assert back == snap
    with pytest.raises(ValueError):
        Snapshot.from_json(json.dumps({"schema": "bogus"}))


def test_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter("reqs", "served requests").inc(3)
    reg.gauge("live").set(2)
    reg.histogram("lat", buckets=(0.5, 1.0)).observe(0.25)
    text = reg.to_prometheus()
    assert "# HELP repro_reqs_total served requests" in text
    assert "# TYPE repro_reqs_total counter" in text
    assert "repro_reqs_total 3" in text
    assert "repro_live 2" in text
    assert 'repro_lat_bucket{le="0.5"} 1' in text
    assert 'repro_lat_bucket{le="+Inf"} 1' in text
    assert "repro_lat_count 1" in text


def test_stats_view_dict_compat():
    reg = MetricsRegistry()
    reg.counter("prefills")
    reg.gauge("n_live")
    reg.histogram("hidden")          # histograms never appear in the view
    stats = reg.view()
    stats["prefills"] += 1
    stats["prefills"] += 1
    stats["n_live"] = 3
    stats["n_live"] -= 1
    assert stats["prefills"] == 2 and isinstance(stats["prefills"], int)
    assert dict(stats) == {"prefills": 2, "n_live": 2}
    assert "hidden" not in stats
    # counters refuse to move backwards even through the view
    with pytest.raises(ValueError):
        stats["prefills"] = 0
    with pytest.raises(TypeError):
        del stats["prefills"]


def test_stats_view_aliases():
    reg = MetricsRegistry()
    reg.counter("sched_skips")
    aliased = reg.view(aliases={"skips": "sched_skips"})
    aliased["skips"] += 5
    assert aliased["skips"] == 5
    assert dict(aliased) == {"skips": 5}
    assert reg.counter("sched_skips").value == 5.0
    with pytest.raises(KeyError):
        aliased["sched_skips"]       # closed view exposes alias keys only


# ----------------------------------------------------------------- tracer --

def test_tracer_spans_and_chrome_export():
    t = Tracer(buffer=64, clock=iter(range(100)).__next__)
    t.event("submit", uid=7)
    t.begin("request", uid=7)
    t.begin("prefill", uid=7, slot=np.int64(2), chunk=np.int64(16))
    t.end("prefill", uid=7, slot=2)
    t.end("request", uid=7)
    doc = t.to_chrome()
    summary = validate_chrome_trace(doc)
    assert summary == {"events": len(doc["traceEvents"]), "spans": 2,
                       "instants": 1, "requests": 1, "dropped": 0}
    # numpy scalars were coerced to JSON-safe types
    json.dumps(doc)
    b = next(e for e in doc["traceEvents"]
             if e["name"] == "prefill" and e["ph"] == "b")
    assert b["tid"] == 2 and b["args"] == {"chunk": 16, "uid": 7}


def test_tracer_close_open_keeps_named_spans():
    t = Tracer(buffer=64)
    t.begin("request", uid=1)
    t.begin("decode", uid=1, slot=0)
    t.close_open(1, keep=("request",), reason="preempted")
    assert t.open_spans(1) == ("request",)
    t.close_open(1)
    assert t.open_spans(1) == ()
    validate_chrome_trace(t.to_chrome())


def test_tracer_ring_buffer_drops_oldest():
    t = Tracer(buffer=4)
    for i in range(10):
        t.event("tick", uid=i)
    assert len(t) == 4 and t.dropped == 6
    assert [dict(e.args) for e in t.events()] == [{}] * 4
    assert [e.uid for e in t.events()] == [6, 7, 8, 9]
    assert t.to_chrome()["otherData"]["dropped"] == 6


def test_null_tracer_is_inert():
    assert not NULL_TRACER and not NullTracer().enabled
    NULL_TRACER.event("x", uid=1)
    NULL_TRACER.begin("request", uid=1)
    NULL_TRACER.close_open(1)
    assert len(NULL_TRACER) == 0 and NULL_TRACER.events() == []
    assert validate_chrome_trace(NULL_TRACER.to_chrome())["events"] == 0


def test_validator_rejects_broken_traces():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"nope": 1})
    # orphan end: an 'e' with no matching open 'b'
    t = Tracer(buffer=8)
    t.end("request", uid=3)
    with pytest.raises(ValueError, match="orphan end"):
        validate_chrome_trace(t.to_chrome())
    # unclosed request span
    t = Tracer(buffer=8)
    t.begin("request", uid=3)
    with pytest.raises(ValueError, match="orphan begin"):
        validate_chrome_trace(t.to_chrome())
    # lifecycle events but no request span at all
    t = Tracer(buffer=8)
    t.begin("decode", uid=3)
    t.end("decode", uid=3)
    with pytest.raises(ValueError, match="without a closed 'request'"):
        validate_chrome_trace(t.to_chrome())


# ----------------------------------------------------------------- report --

def _cfg():
    from repro.configs import get_arch, reduced
    return reduced(get_arch("qwen3-0.6b")).replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, dtype="float32")


def test_decode_utilization_zero_window_is_safe():
    row = decode_utilization(_cfg(), tokens=0, steps=0, wall_s=0.0,
                             batch_sum=0, kv_row_sum=0)
    assert row["mfu"] == 0.0 and row["hbm_util"] == 0.0
    assert row["d2d_util"] == 0.0 and row["tok_per_s"] == 0.0


def test_decode_utilization_measured_window():
    cfg = _cfg()
    # a fast window: the tiny config's MFU must survive 6-decimal rounding
    row = decode_utilization(cfg, tokens=64, steps=16, wall_s=1e-3,
                             batch_sum=64, kv_row_sum=64 * 40, kv_shard=2)
    pc = cfg.param_count()
    per_tok = 2.0 * (pc["nonembed_active"] + pc["embedding"])
    assert row["flops_per_token"] == per_tok
    assert row["tok_per_s"] == pytest.approx(64000.0)
    assert row["avg_batch"] == pytest.approx(4.0)
    assert row["avg_context"] == pytest.approx(40.0)
    assert 0 < row["mfu"] < 1 and 0 < row["hbm_util"]
    assert row["d2d_util"] > 0 and row["devices"] == 2
    # single-device run moves no D2D traffic
    solo = decode_utilization(cfg, tokens=64, steps=16, wall_s=1e-3,
                              batch_sum=64, kv_row_sum=64 * 40, kv_shard=1)
    assert solo["d2d_util"] == 0.0


def test_windows_from_trace():
    t = Tracer(buffer=256, clock=iter(np.arange(0, 10, 0.01)).__next__)
    for _ in range(8):
        t.event("dispatch", n=2, kv=24)
        t.event("sync", n=2, tokens=2)
    rows = windows_from_trace(t, _cfg(), window_steps=4)
    assert len(rows) == 2
    assert rows[0]["steps"] == 4 and rows[0]["tokens"] == 8
    assert rows[0]["avg_batch"] == pytest.approx(2.0)
    assert windows_from_trace(NULL_TRACER, _cfg()) == []


def test_write_metrics_json_schema(tmp_path):
    reg = MetricsRegistry()
    reg.counter("decode_steps").inc(4)
    path = tmp_path / "m.json"
    payload = write_metrics_json(
        str(path), suite="unit", snapshot=reg.snapshot(),
        utilization={"mfu": 0.1}, extra={"note": "x"})
    on_disk = json.loads(path.read_text())
    assert on_disk == payload
    assert on_disk["schema"] == "repro-metrics-report-v1"
    assert on_disk["suite"] == "unit" and on_disk["extra"] == {"note": "x"}
    assert Snapshot.from_json(
        json.dumps(on_disk["snapshot"])) == reg.snapshot()

"""Collective schedules (paper C5a/C5c) — multi-device subprocess tests.

Each test ships its body to a fresh interpreter with 8 fake CPU devices
(tests/_subproc.py) so the pytest process keeps its single device.
"""
from __future__ import annotations

import pytest

from tests._subproc import run_with_devices

HEADER = """
import jax, jax.numpy as jnp, numpy as np
from repro.core.collectives import (hierarchical_allreduce, flat_allreduce,
                                    multicast, barrier, compressed_psum)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
x = jax.random.normal(jax.random.PRNGKey(0), (6, 10))
"""


def test_hierarchical_equals_flat():
    run_with_devices(HEADER + """
a = hierarchical_allreduce(x, mesh, intra_axis="data", inter_axis="pod")
b = flat_allreduce(x, mesh, ("data", "pod"))
np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
# and equals an explicit *4 (axis sizes 2*2) since input is replicated
np.testing.assert_allclose(np.asarray(a), 4 * np.asarray(x), rtol=1e-6)
""")


def test_hierarchical_hlo_has_staged_collectives():
    """The inter-pod stage must move 1/|intra| of the bytes: HLO shows a
    reduce-scatter + small all-reduce + all-gather, not one big all-reduce."""
    run_with_devices(HEADER + """
f = jax.jit(lambda t: hierarchical_allreduce(t, mesh))
hlo = f.lower(x).compile().as_text()
assert "reduce-scatter" in hlo or "psum-scatter" in hlo, hlo[:2000]
assert "all-gather" in hlo
""")


def test_multicast_root():
    run_with_devices(HEADER + """
from jax.sharding import NamedSharding, PartitionSpec as P
# give each model-rank different data, multicast root 0's
xs = jax.device_put(x, NamedSharding(mesh, P()))
out = multicast(xs, mesh, "model", root=0)
np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-6)
""")


def test_barrier_counts_ranks():
    run_with_devices(HEADER + """
out = barrier(mesh, ("data", "model"))
assert int(out) == 4, out
""")


def test_compressed_psum_accuracy_and_wire_dtype():
    run_with_devices(HEADER + """
mean, err = compressed_psum(x, mesh, ("data",))
# replicated input => mean == x up to int8 quantization error
q_err = np.abs(np.asarray(mean) - np.asarray(x)).max()
amax = float(jnp.abs(x).max())
assert q_err <= amax / 127.0 + 1e-6, (q_err, amax / 127.0)
# error feedback captures exactly the quantization residual
np.testing.assert_allclose(np.asarray(err),
                           np.asarray(x) - np.asarray(mean), atol=1e-6)
# the wire carries int8: HLO all-gather operand is s8
hlo = jax.jit(lambda t: compressed_psum(t, mesh, ("data",))[0]).lower(x)\
    .compile().as_text()
assert "s8[" in hlo, "int8 tensors must cross the links"
""")


def test_compressed_psum_error_feedback_converges():
    """With EF, the *accumulated* compressed sum tracks the true sum."""
    run_with_devices(HEADER + """
true_acc = jnp.zeros_like(x)
est_acc = jnp.zeros_like(x)
err = jnp.zeros_like(x)
for step in range(30):
    g = jax.random.normal(jax.random.PRNGKey(step), x.shape) * 0.1
    mean, err = compressed_psum(g, mesh, ("data",), err=err)
    true_acc = true_acc + g          # replicated => true mean == g
    est_acc = est_acc + mean
resid = float(jnp.abs(true_acc - est_acc).max())
scale = float(jnp.abs(true_acc).max())
# EF keeps the residual bounded by one quantization step, not 30 of them
assert resid < 0.05 * scale + 0.01, (resid, scale)
""")

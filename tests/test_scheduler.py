"""Scheduler policy layer: admission ordering (priority / EDF / fair
queuing), skip-with-aging reservations, and preemption requeue identity —
pure host-side logic, no jax."""
from __future__ import annotations

import pytest

from repro.serve import SchedEntry, Scheduler
from repro.serve.scheduler import URGENT_FRAC


class _Req:
    """Duck-typed stand-in for repro.serve.engine.Request."""

    def __init__(self, uid, priority=0, user=None, slo_ttft_ms=None):
        self.uid = uid
        self.priority = priority
        self.user = user
        self.slo_ttft_ms = slo_ttft_ms


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _uids(entries):
    return [e.uid for e in entries]


def test_policy_validated():
    with pytest.raises(ValueError, match="policy"):
        Scheduler("lifo")
    with pytest.raises(ValueError, match="aging"):
        Scheduler(aging_skips=-1)


def test_fcfs_is_arrival_order():
    s = Scheduler("fcfs")
    for uid, prio in ((0, 0), (1, 9), (2, 3)):
        s.submit(_Req(uid, priority=prio))
    assert _uids(s.order()) == [0, 1, 2], "fcfs must ignore priorities"


def test_priority_policy_defaults_to_fcfs_among_equals():
    """Requests with no priorities/users/SLOs order exactly like fcfs —
    the default policy is behavior-preserving for plain traffic."""
    s = Scheduler("priority")
    for uid in range(5):
        s.submit(_Req(uid))
    assert _uids(s.order()) == list(range(5))


def test_priority_classes_dominate_arrival():
    s = Scheduler("priority")
    s.submit(_Req(0, priority=0))
    s.submit(_Req(1, priority=2))
    s.submit(_Req(2, priority=1))
    s.submit(_Req(3, priority=2))
    assert _uids(s.order()) == [1, 3, 2, 0]


def test_edf_urgency_orders_within_class():
    """A TTFT SLO only reorders once less than URGENT_FRAC of the target
    remains; urgent entries go earliest-deadline-first."""
    clk = _Clock()
    s = Scheduler("priority", now=clk)
    s.submit(_Req(0))                               # no SLO
    s.submit(_Req(1, slo_ttft_ms=1000.0))           # deadline t=1.0
    s.submit(_Req(2, slo_ttft_ms=400.0))            # deadline t=0.4
    # far from every deadline: plain arrival order
    assert _uids(s.order()) == [0, 1, 2]
    # t=0.3: uid2 has 0.1s of a 0.4s target left (< URGENT_FRAC) -> urgent
    clk.t = 0.4 - URGENT_FRAC * 0.4 + 0.1
    assert _uids(s.order())[0] == 2
    # t=0.9: both SLOs urgent, EDF puts the earlier deadline first
    clk.t = 0.9
    assert _uids(s.order()) == [2, 1, 0]


def test_fair_queuing_balances_tenants():
    """The tenant with the least admitted service goes first at equal
    priority; charging service rotates the head."""
    s = Scheduler("priority")
    bulk = [s.submit(_Req(i, user="bulk")) for i in range(3)]
    chat = s.submit(_Req(10, user="chat"))
    assert _uids(s.order()) == [0, 1, 2, 10]        # no history yet
    s.note_admitted(bulk[0], 1000)                  # bulk now owes service
    assert _uids(s.order()) == [10, 1, 2]
    s.note_admitted(chat, 2000)
    assert _uids(s.order()) == [1, 2]


def test_aging_promotes_skipped_entry_to_reservation():
    """A blocked entry overtaken aging_skips times reserves the pool: it
    sorts above everything, even higher priority classes."""
    s = Scheduler("priority", aging_skips=3)
    big = s.submit(_Req(0))
    s.submit(_Req(1, priority=5))
    assert not s.reserved(big)
    for _ in range(3):
        s.note_skip(big)
    assert s.reserved(big)
    assert _uids(s.order()) == [0, 1]
    assert s.stats["aged"] == 1 and s.stats["skips"] == 3


def test_aging_zero_never_reserves():
    s = Scheduler("priority", aging_skips=0)
    e = s.submit(_Req(0))
    for _ in range(100):
        s.note_skip(e)
    assert not s.reserved(e)


def test_requeue_keeps_place_in_line():
    """A preempted request re-enters with its original seq: it outranks
    later arrivals at equal priority."""
    s = Scheduler("priority")
    victim = s.submit(_Req(0))
    s.submit(_Req(1))
    seq, sub = victim.seq, victim.submit_s
    s.note_admitted(victim, 10)
    s.submit(_Req(2))
    s.requeue(_Req(0), seq=seq, submit_s=sub)
    assert _uids(s.order()) == [0, 1, 2]


def test_drain_empties_in_arrival_order():
    s = Scheduler("priority")
    s.submit(_Req(0))
    s.submit(_Req(1, priority=9))
    out = s.drain()
    assert _uids(out) == [0, 1] and len(s) == 0 and not s

"""Distributed integration (subprocess, 8 fake devices): sharded train step ==
single-device step; elastic checkpoint resharding; dry-run cell E2E."""
from __future__ import annotations

import pytest

from tests._subproc import run_with_devices

TINY = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch, reduced, strategy
from repro.configs.base import ShapeConfig
from repro.core.sharding import Partitioner
from repro.models import init as model_init
from repro.optim.optimizers import adamw
from repro.train.train_step import make_train_step, train_state_template

cfg = reduced(get_arch("qwen3-0.6b")).replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=256, dtype="float32")
shape = ShapeConfig("t", "train", seq_len=16, global_batch=8)
opt = adamw(1e-2)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, 256, (8, 16)), jnp.int32)}
batch["targets"] = batch["tokens"]

def state0():
    params = model_init(jax.random.PRNGKey(0), cfg)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}
"""


def test_sharded_step_equals_single_device():
    """(2 data x 2 model x 2 pod) sharded train step == unsharded step —
    the semantic core of the multi-pod dry-run."""
    run_with_devices(TINY + """
# unsharded reference on one device
step_ref = jax.jit(make_train_step(cfg, opt, strategy("ramora")))
s_ref, m_ref = step_ref(state0(), batch)

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
strat = strategy("ogopogo", multi_pod=True)
part = Partitioner(mesh, strat, cfg, shape, mode="train")
step = make_train_step(cfg, opt, strat, part)
state = state0()
st_sh = {"params": part.params_sharding(state["params"]),
         "opt": {k: part.params_sharding(v) for k, v in state["opt"].items()},
         "step": part.scalar_sharding()}
with mesh:
    state_d = jax.tree.map(jax.device_put, state, st_sh)
    batch_d = jax.tree.map(jax.device_put, batch, part.batch_sharding(batch))
    step_j = jax.jit(step, in_shardings=(st_sh, part.batch_sharding(batch)),
                     out_shardings=(st_sh, None))
    s_out, m_out = step_j(state_d, batch_d)
np.testing.assert_allclose(float(m_out["loss"]), float(m_ref["loss"]),
                           rtol=1e-5, atol=1e-6)
for a, b in zip(jax.tree.leaves(s_ref["params"]),
                jax.tree.leaves(s_out["params"])):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=5e-3, atol=5e-5)
print("sharded == unsharded OK")
""")


def test_elastic_reshard_8_to_4_to_8():
    """Checkpoints are mesh-agnostic: save on (4,2), restore on (2,2) and
    (8,1), losses identical — the elastic-resize story."""
    run_with_devices(TINY + """
import tempfile
from repro.ckpt import save_checkpoint, restore_checkpoint
from repro.train.train_step import train_state_template

def run_steps(mesh_shape, state_in=None, n=2):
    devs = np.prod(mesh_shape)
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    strat = strategy("ramora")
    part = Partitioner(mesh, strat, cfg, shape, mode="train")
    step = make_train_step(cfg, opt, strat, part)
    state = state_in if state_in is not None else state0()
    st_t = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    st_sh = {"params": part.params_sharding(st_t["params"]),
             "opt": {k: part.params_sharding(v) for k, v in st_t["opt"].items()},
             "step": part.scalar_sharding()}
    with mesh:
        state = jax.tree.map(jax.device_put, state, st_sh)
        sj = jax.jit(step, in_shardings=(st_sh, part.batch_sharding(batch)),
                     out_shardings=(st_sh, None))
        losses = []
        for _ in range(n):
            state, m = sj(state, jax.tree.map(
                jax.device_put, batch, part.batch_sharding(batch)))
            losses.append(float(m["loss"]))
    return state, losses, st_sh

# continuous 6-step run on (4,2) = truth
s_truth, l_truth = run_steps((4, 2), n=6)[:2]

# 2 steps on (4,2) -> ckpt -> 2 on (2,2) -> ckpt -> 2 on (8,1)
d = tempfile.mkdtemp()
s1, l1, _ = run_steps((4, 2), n=2)
save_checkpoint(d, 2, s1)
tmpl = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s1)
r1, _ = restore_checkpoint(d, tmpl)
s2, l2, _ = run_steps((2, 2), state_in=jax.tree.map(np.asarray, r1), n=2)
save_checkpoint(d, 4, s2)
r2, _ = restore_checkpoint(d, tmpl)
s3, l3, _ = run_steps((8, 1), state_in=jax.tree.map(np.asarray, r2), n=2)

np.testing.assert_allclose(l1 + l2 + l3, l_truth, rtol=1e-5, atol=1e-6)
print("elastic reshard OK", l_truth)
""")


@pytest.mark.slow
def test_dryrun_cell_end_to_end():
    """One full production dry-run cell (512 devices, 16x16 and 2x16x16)."""
    run_with_devices("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
r = run_cell("qwen3-0.6b", "decode_32k", multi_pod=False, analysis=False)
assert r["status"] == "ok", r
r2 = run_cell("qwen3-0.6b", "decode_32k", multi_pod=True, analysis=False)
assert r2["status"] == "ok", r2
print("dryrun cell OK")
""", n_devices=512, timeout=900)

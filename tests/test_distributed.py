"""Distributed integration (subprocess, 8 fake devices): sharded train step ==
single-device step; elastic checkpoint resharding; dry-run cell E2E."""
from __future__ import annotations

import pytest

from tests._subproc import run_with_devices

TINY = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch, reduced, strategy
from repro.configs.base import ShapeConfig
from repro.core.sharding import Partitioner
from repro.models import init as model_init
from repro.optim.optimizers import adamw
from repro.train.train_step import make_train_step, train_state_template

cfg = reduced(get_arch("qwen3-0.6b")).replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=256, dtype="float32")
shape = ShapeConfig("t", "train", seq_len=16, global_batch=8)
opt = adamw(1e-2)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, 256, (8, 16)), jnp.int32)}
batch["targets"] = batch["tokens"]

def state0():
    params = model_init(jax.random.PRNGKey(0), cfg)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}
"""


def test_sharded_step_equals_single_device():
    """(2 data x 2 model x 2 pod) sharded train step == unsharded step —
    the semantic core of the multi-pod dry-run."""
    run_with_devices(TINY + """
# unsharded reference on one device
step_ref = jax.jit(make_train_step(cfg, opt, strategy("ramora")))
s_ref, m_ref = step_ref(state0(), batch)

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
strat = strategy("ogopogo", multi_pod=True)
part = Partitioner(mesh, strat, cfg, shape, mode="train")
step = make_train_step(cfg, opt, strat, part)
state = state0()
st_sh = {"params": part.params_sharding(state["params"]),
         "opt": {k: part.params_sharding(v) for k, v in state["opt"].items()},
         "step": part.scalar_sharding()}
with mesh:
    state_d = jax.tree.map(jax.device_put, state, st_sh)
    batch_d = jax.tree.map(jax.device_put, batch, part.batch_sharding(batch))
    step_j = jax.jit(step, in_shardings=(st_sh, part.batch_sharding(batch)),
                     out_shardings=(st_sh, None))
    s_out, m_out = step_j(state_d, batch_d)
np.testing.assert_allclose(float(m_out["loss"]), float(m_ref["loss"]),
                           rtol=1e-5, atol=1e-6)
for a, b in zip(jax.tree.leaves(s_ref["params"]),
                jax.tree.leaves(s_out["params"])):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=5e-3, atol=5e-5)
print("sharded == unsharded OK")
""")


def test_elastic_reshard_8_to_4_to_8():
    """Checkpoints are mesh-agnostic: save on (4,2), restore on (2,2) and
    (8,1), losses identical — the elastic-resize story."""
    run_with_devices(TINY + """
import tempfile
from repro.ckpt import save_checkpoint, restore_checkpoint
from repro.train.train_step import train_state_template

def run_steps(mesh_shape, state_in=None, n=2):
    devs = np.prod(mesh_shape)
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    strat = strategy("ramora")
    part = Partitioner(mesh, strat, cfg, shape, mode="train")
    step = make_train_step(cfg, opt, strat, part)
    state = state_in if state_in is not None else state0()
    st_t = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    st_sh = {"params": part.params_sharding(st_t["params"]),
             "opt": {k: part.params_sharding(v) for k, v in st_t["opt"].items()},
             "step": part.scalar_sharding()}
    with mesh:
        state = jax.tree.map(jax.device_put, state, st_sh)
        sj = jax.jit(step, in_shardings=(st_sh, part.batch_sharding(batch)),
                     out_shardings=(st_sh, None))
        losses = []
        for _ in range(n):
            state, m = sj(state, jax.tree.map(
                jax.device_put, batch, part.batch_sharding(batch)))
            losses.append(float(m["loss"]))
    return state, losses, st_sh

# continuous 6-step run on (4,2) = truth
s_truth, l_truth = run_steps((4, 2), n=6)[:2]

# 2 steps on (4,2) -> ckpt -> 2 on (2,2) -> ckpt -> 2 on (8,1)
d = tempfile.mkdtemp()
s1, l1, _ = run_steps((4, 2), n=2)
save_checkpoint(d, 2, s1)
tmpl = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s1)
r1, _ = restore_checkpoint(d, tmpl)
s2, l2, _ = run_steps((2, 2), state_in=jax.tree.map(np.asarray, r1), n=2)
save_checkpoint(d, 4, s2)
r2, _ = restore_checkpoint(d, tmpl)
s3, l3, _ = run_steps((8, 1), state_in=jax.tree.map(np.asarray, r2), n=2)

np.testing.assert_allclose(l1 + l2 + l3, l_truth, rtol=1e-5, atol=1e-6)
print("elastic reshard OK", l_truth)
""")


@pytest.mark.slow
def test_dryrun_cell_end_to_end():
    """One full production dry-run cell (512 devices, 16x16 and 2x16x16)."""
    run_with_devices("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
r = run_cell("qwen3-0.6b", "decode_32k", multi_pod=False, analysis=False)
assert r["status"] == "ok", r
r2 = run_cell("qwen3-0.6b", "decode_32k", multi_pod=True, analysis=False)
assert r2["status"] == "ok", r2
print("dryrun cell OK")
""", n_devices=512, timeout=900)


# ---------------------------------------------------------------------------
# SPMD serving: KV-head-sharded paged pools + disaggregated pools
# ---------------------------------------------------------------------------
SERVE = """
import jax, numpy as np
from repro.configs import get_arch, reduced
from repro.configs.base import LayerSpec, StrategyConfig
from repro.core.sharding import Partitioner
from repro.models import init as model_init
from repro.serve import Request, ServeEngine

def full_cfg(**kw):
    return reduced(get_arch("qwen3-0.6b")).replace(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=8, head_dim=16,
        d_ff=128, vocab_size=256, dtype="float32", paged_kv=True,
        page_size=8, **kw)

def serve_part(cfg, n_model):
    from jax.sharding import Mesh
    devs = np.array(jax.devices()[:n_model]).reshape(1, n_model)
    mesh = Mesh(devs, ("data", "model"))
    return Partitioner(mesh,
                       StrategyConfig(name="ramora", tensor_parallel=True),
                       cfg, mode="serve")

def trace(cfg, n=5, seed=0, shared_prefix=0, **kw):
    rng = np.random.default_rng(seed)
    pre = rng.integers(1, cfg.vocab_size, shared_prefix).astype(np.int32)
    out = []
    for i in range(n):
        tail = rng.integers(1, cfg.vocab_size, 4 + 5 * i).astype(np.int32)
        out.append(Request(uid=i, prompt=np.concatenate([pre, tail]),
                           max_new_tokens=6, **kw))
    return out

def toks(results):
    return [(r.tokens, [c.tokens for c in r.children]) for r in results]

def drained(eng):
    assert eng.allocator is None or eng.allocator.n_live == 0, "leaked blocks"
"""


def test_sharded_paged_serving_parity():
    """1x8 KV-head-sharded paged decode == single-device greedy, token for
    token — prefix-hit stats, COW forks, and leak-free drains included."""
    run_with_devices(SERVE + """
cfg = full_cfg(prefix_cache=True)
params = model_init(jax.random.PRNGKey(0), cfg)
kw = dict(max_slots=4, max_len=96, prefix_cache=True)

ref = ServeEngine(cfg, params, **kw)
base = toks(ref.run(trace(cfg, shared_prefix=16)))
drained(ref)

part = serve_part(cfg, 8)
assert part.kv_shard == 8
eng = ServeEngine(cfg, params, part=part, **kw)
got = toks(eng.run(trace(cfg, shared_prefix=16)))
assert got == base, "sharded decode diverged from single-device greedy"
drained(eng)
for k in ("prefix_hits", "prefix_hit_tokens", "prefix_cow"):
    assert eng.stats[k] == ref.stats[k], (k, eng.stats[k], ref.stats[k])

# COW fork fan-out (n=2, seeded sampling) matches local bit for bit
def fork():
    return [Request(uid=i, prompt=np.arange(1, 14 + i, dtype=np.int32),
                    max_new_tokens=5, n=2, temperature=0.7, seed=11 + i)
            for i in range(2)]
ref2 = ServeEngine(cfg, params, **kw)
base2 = toks(ref2.run(fork()))
eng2 = ServeEngine(cfg, params, part=part, **kw)
got2 = toks(eng2.run(fork()))
assert got2 == base2, "sharded COW fork diverged"
assert eng2.stats["forks"] == 2
drained(eng2)
print("OK")
""")


def test_sharded_serving_divisibility_drop_and_local_window():
    """KV heads that do not divide the model axis fall back to replicated
    pools (recorded in Partitioner.dropped) with unchanged outputs; a
    sliding-window config keeps its dense ring buffers replicated and
    stays token-identical too."""
    run_with_devices(SERVE + """
# GQA: 2 KV heads on an 8-way axis -> divisibility drop -> replicated
cfg = full_cfg().replace(n_heads=4, n_kv_heads=2)
params = model_init(jax.random.PRNGKey(0), cfg)
ref = ServeEngine(cfg, params, max_slots=3, max_len=96)
base = toks(ref.run(trace(cfg)))
part = serve_part(cfg, 8)
assert part.kv_shard == 1
eng = ServeEngine(cfg, params, part=part, max_slots=3, max_len=96)
got = toks(eng.run(trace(cfg)))
assert got == base
assert eng._kv_shard == 1
cs = part.serve_cache_sharding(eng.cache, eng.n_blocks)
assert part.dropped and part.dropped[0]["label"] == "kv_pool", part.dropped
drained(eng)

# same GQA config on a 2-way axis DOES shard (2 % 2 == 0)
part2 = serve_part(cfg, 2)
assert part2.kv_shard == 2
eng2 = ServeEngine(cfg, params, part=part2, max_slots=3, max_len=96)
assert toks(eng2.run(trace(cfg))) == base
drained(eng2)

# local-window config: ring buffers stay dense/replicated, pools shard
lcfg = full_cfg(pattern=(LayerSpec("full", "dense"),
                         LayerSpec("local", "dense")), window=8)
lparams = model_init(jax.random.PRNGKey(1), lcfg)
lref = ServeEngine(lcfg, lparams, max_slots=3, max_len=96)
lbase = toks(lref.run(trace(lcfg, seed=2)))
leng = ServeEngine(lcfg, lparams, part=serve_part(lcfg, 8),
                   max_slots=3, max_len=96)
assert toks(leng.run(trace(lcfg, seed=2))) == lbase
drained(leng)
print("OK")
""")


def test_split_pools_parity_local_and_sharded():
    """Disaggregated prefill/decode pools: token-identical to the unified
    engine both locally and on an 8-way mesh; every chunked prefill hands
    off through the block table; drains stay leak-free."""
    run_with_devices(SERVE + """
cfg = full_cfg(prefix_cache=True)
params = model_init(jax.random.PRNGKey(0), cfg)
kw = dict(max_slots=4, max_len=96, prefix_cache=True)
ref = ServeEngine(cfg, params, **kw)
base = toks(ref.run(trace(cfg, shared_prefix=16)))

stats = {}
for part in (None, serve_part(cfg, 8)):
    eng = ServeEngine(cfg, params, part=part, split_pools=True,
                      prefill_slots=2, **kw)
    got = toks(eng.run(trace(cfg, shared_prefix=16)))
    assert got == base, f"split-pool diverged (part={part is not None})"
    assert eng.stats["handoffs"] == 5, eng.stats["handoffs"]
    stats[part is not None] = {k: eng.stats[k] for k in
                               ("prefix_hits", "prefix_hit_tokens",
                                "handoffs", "decode_steps")}
    drained(eng)
# sharding must not perturb the split engine's scheduling/prefix behavior
assert stats[True] == stats[False], stats
print("OK")
""")


def test_sharded_speculative_decode_parity():
    """Speculative decoding over a 2-way-sharded pool: greedy outputs stay
    exactly the verifier's own chain (the draft runs single-device; its
    proposals re-materialize host-side before the sharded verify)."""
    run_with_devices(SERVE + """
cfg = full_cfg()
dcfg = cfg.replace(n_layers=1, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64)
params = model_init(jax.random.PRNGKey(0), cfg)
dparams = model_init(jax.random.PRNGKey(7), dcfg)
ref = ServeEngine(cfg, params, max_slots=3, max_len=96)
base = toks(ref.run(trace(cfg)))
eng = ServeEngine(cfg, params, part=serve_part(cfg, 2), max_slots=3,
                  max_len=96, draft_model=dcfg, draft_params=dparams,
                  spec_k=3)
got = toks(eng.run(trace(cfg)))
assert got == base, "sharded speculative decode diverged from greedy"
assert eng.stats["spec_turns"] > 0
drained(eng)
print("OK")
""", timeout=900)

"""Optimizers and schedules."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.optimizers import (adafactor_lite, adamw, apply_updates,
                                    clip_by_global_norm, global_norm, sgdm)
from repro.optim.schedules import cosine, get_schedule, wsd


def test_adamw_matches_reference_math():
    """One hand-computed AdamW step on a scalar."""
    p = {"w": jnp.asarray(2.0)}
    g = {"w": jnp.asarray(0.5)}
    opt = adamw(lr=0.1, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0)
    state = opt.init(p)
    up, state = opt.update(g, state, p, jnp.asarray(0))
    # step 0: mu_hat = g, nu_hat = g^2 -> update = -lr * g/|g| = -0.1
    np.testing.assert_allclose(float(up["w"]), -0.1, rtol=1e-5)
    np.testing.assert_allclose(float(state["mu"]["w"]), 0.05, rtol=1e-6)


def test_adamw_weight_decay():
    p = {"w": jnp.asarray(2.0)}
    g = {"w": jnp.asarray(0.0)}
    opt = adamw(lr=0.1, weight_decay=0.1)
    up, _ = opt.update(g, opt.init(p), p, jnp.asarray(0))
    np.testing.assert_allclose(float(up["w"]), -0.1 * 0.1 * 2.0, atol=1e-7)


def test_optimizers_minimize_quadratic():
    for make in (lambda: adamw(0.1), lambda: sgdm(0.05),
                 lambda: adafactor_lite(0.3)):
        opt = make()
        p = {"w": jnp.asarray([3.0, -2.0])}
        state = opt.init(p)
        for step in range(150):
            g = {"w": 2 * p["w"]}
            up, state = opt.update(g, state, p, jnp.asarray(step))
            p = apply_updates(p, up)
        assert float(jnp.abs(p["w"]).max()) < 0.15, (opt.name, p)


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    # under the limit: untouched
    same, _ = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(g["a"]))


def test_wsd_schedule_phases():
    """MiniCPM's WSD: warmup ramps, plateau flat, decay drops."""
    f = wsd(1.0, warmup=10, stable=80, decay=10, min_ratio=0.01)
    assert float(f(jnp.asarray(0))) < 0.2
    np.testing.assert_allclose(float(f(jnp.asarray(10))), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(f(jnp.asarray(50))), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(f(jnp.asarray(89))), 1.0, rtol=0.2)
    np.testing.assert_allclose(float(f(jnp.asarray(100))), 0.01, rtol=0.1)


def test_cosine_schedule():
    f = cosine(1.0, warmup=10, total=110, min_ratio=0.1)
    np.testing.assert_allclose(float(f(jnp.asarray(10))), 1.0, rtol=1e-3)
    np.testing.assert_allclose(float(f(jnp.asarray(110))), 0.1, rtol=1e-3)
    mid = float(f(jnp.asarray(60)))
    assert 0.4 < mid < 0.7


def test_get_schedule_wsd_selected_for_minicpm_style():
    f = get_schedule("wsd", 2.0, 1000)
    assert float(f(jnp.asarray(500))) == pytest.approx(2.0, rel=1e-4)


def test_adafactor_state_is_factored():
    p = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    st = adafactor_lite(0.1).init(p)
    assert st["fac"]["w"]["vr"].shape == (64,)
    assert st["fac"]["w"]["vc"].shape == (32,)
    assert st["fac"]["b"]["v"].shape == (32,)

"""Attention layer: chunked flash vs naive oracle, GQA layouts, decode paths,
RoPE/qk-norm invariants."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.kernels import ref
from repro.models.attention import flash_attention_xla
from repro.models.layers import rope, softcap


def _naive(q, k, v, *, causal, window, cap, scale):
    """(B, Sq, K, G, D) vs (B, Skv, K, D) oracle via the kernel ref."""
    B, Sq, K, G, D = q.shape
    Skv = k.shape[1]
    qf = q.transpose(0, 2, 3, 1, 4).reshape(B * K * G, Sq, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3).reshape(B * K, Skv, D), G, 0)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3).reshape(B * K, Skv, D), G, 0)
    out = ref.flash_attention_ref(qf, kf, vf, causal=causal, window=window,
                                  cap=cap, scale=scale)
    return out.reshape(B, K, G, Sq, D).transpose(0, 3, 1, 2, 4)


@pytest.mark.parametrize("q_chunk", [8, 32, 1024])
@pytest.mark.parametrize("window,cap", [(0, 0.0), (16, 0.0), (0, 30.0)])
def test_flash_xla_chunks(q_chunk, window, cap):
    B, S, K, G, D = 2, 48, 2, 2, 16
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (B, S, K, G, D)) * 0.5
    k = jax.random.normal(kk, (B, S, K, D)) * 0.5
    v = jax.random.normal(kv, (B, S, K, D))
    got = flash_attention_xla(q, k, v, causal=True, window=window, cap=cap,
                              scale=0.25, q_chunk=q_chunk, kv_chunk=q_chunk)
    want = _naive(q, k, v, causal=True, window=window, cap=cap, scale=0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_xla_ragged_kv():
    """kv_lens masks trailing positions per batch row."""
    B, S, K, G, D = 2, 32, 1, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, 1, K, G, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))
    lens = jnp.asarray([10, 32])
    got = flash_attention_xla(q, k, v, causal=False, window=0, cap=0.0,
                              scale=0.35, q_chunk=1, kv_chunk=8, kv_lens=lens)
    # row 0 must equal attention over first 10 kv only
    want0 = _naive(q[:1], k[:1, :10], v[:1, :10], causal=False, window=0,
                   cap=0.0, scale=0.35)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want0[0]),
                               rtol=2e-3, atol=2e-3)


def test_flash_xla_q_offset():
    """Chunked prefill: q_offset shifts causal masking."""
    B, K, G, D = 1, 1, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    S = 24
    q = jax.random.normal(ks[0], (B, S, K, G, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))
    full = flash_attention_xla(q, k, v, causal=True, window=0, cap=0.0,
                               scale=0.35, q_chunk=8, kv_chunk=8)
    # second half queries with q_offset = 12 against the full KV
    half = flash_attention_xla(q[:, 12:], k, v, causal=True, window=0,
                               cap=0.0, scale=0.35, q_chunk=4, kv_chunk=8,
                               q_offset=12)
    np.testing.assert_allclose(np.asarray(half), np.asarray(full[:, 12:]),
                               rtol=2e-3, atol=2e-3)


def test_rope_rotation_invariant():
    """RoPE preserves norms and relative positions: <q_m, k_n> depends only
    on m - n."""
    D = 16
    k = jax.random.PRNGKey(3)
    x = jax.random.normal(k, (1, 1, 1, D))
    pos_a = jnp.asarray([[5]])
    pos_b = jnp.asarray([[9]])
    ra = rope(jnp.broadcast_to(x, (1, 1, 1, D)), pos_a, 10000.0)
    rb = rope(jnp.broadcast_to(x, (1, 1, 1, D)), pos_b, 10000.0)
    np.testing.assert_allclose(float(jnp.linalg.norm(ra)),
                               float(jnp.linalg.norm(x)), rtol=1e-5)
    y = jax.random.normal(jax.random.PRNGKey(4), (1, 1, 1, D))
    # shift both positions by +7: inner product unchanged
    q1 = rope(x, jnp.asarray([[3]]), 1e4)
    k1 = rope(y, jnp.asarray([[1]]), 1e4)
    q2 = rope(x, jnp.asarray([[10]]), 1e4)
    k2 = rope(y, jnp.asarray([[8]]), 1e4)
    np.testing.assert_allclose(float((q1 * k1).sum()), float((q2 * k2).sum()),
                               rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.floats(1.0, 100.0))
def test_softcap_bounds(cap):
    x = jnp.linspace(-1000, 1000, 101)
    y = softcap(x, cap)
    assert float(jnp.abs(y).max()) <= cap * 1.0001
    # identity for cap=0
    np.testing.assert_array_equal(np.asarray(softcap(x, 0.0)), np.asarray(x))


def test_softcap_monotone():
    x = jnp.linspace(-50, 50, 201)
    y = softcap(x, 30.0)
    assert bool((jnp.diff(y) > 0).all())


def test_pallas_attention_impl_matches_xla():
    """cfg.attention_impl='pallas_interpret' routes the model through the
    Pallas kernel (interpret mode) and must equal the XLA flash path."""
    import numpy as np
    from repro.configs import get_arch, reduced
    from repro.models import forward, init

    cfg = reduced(get_arch("gemma2-27b")).replace(dtype="float32")
    params = init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32)
    h_xla, _, _ = forward(params, cfg.replace(attention_impl="xla"), toks)
    h_pl, _, _ = forward(params, cfg.replace(attention_impl="pallas_interpret"),
                         toks)
    np.testing.assert_allclose(np.asarray(h_xla), np.asarray(h_pl),
                               rtol=2e-3, atol=2e-3)
